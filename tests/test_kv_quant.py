"""int8 KV cache (KIVI-class): quantization roundtrip + decode accuracy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMArch
from repro.models import layers as L
from repro.models import transformer as T

BASE = LMArch(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
              head_dim=8, d_ff=64, vocab=97, param_dtype="float32",
              attn_chunk=0)


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 2, 16))
    q, s = L.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (4, 7, 2, 1)
    back = L.dequantize_kv(q, s, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(np.abs(np.asarray(x)).max()) / 90)


def test_decode_with_quantized_cache_close_to_fp():
    fp = BASE
    q8 = dataclasses.replace(BASE, kv_quant=True)
    params, _ = T.init_lm(jax.random.PRNGKey(0), fp)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, fp.vocab)

    def run(arch):
        cache = T.init_cache(arch, 2, 12)
        logits = None
        for i in range(6):
            logits, cache = T.decode_step(params, cache, toks[:, i],
                                          jnp.array([i, i]), arch)
        return logits, cache

    lg_fp, _ = run(fp)
    lg_q8, cache_q8 = run(q8)
    assert cache_q8["k"].dtype == jnp.int8
    # int8 cache changes logits only slightly; top-1 prediction unchanged
    assert bool((jnp.argmax(lg_fp, -1) == jnp.argmax(lg_q8, -1)).all())
    rel = float(jnp.abs(lg_fp - lg_q8).max() / jnp.abs(lg_fp).max())
    assert rel < 0.1, rel


def test_prefill_cache_bridges_into_quantized_decode():
    """prefill emits an fp cache; prepare_cache quantizes it once so
    kv_quant decode continues seamlessly."""
    q8 = dataclasses.replace(BASE, kv_quant=True)
    params, _ = T.init_lm(jax.random.PRNGKey(0), BASE)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, BASE.vocab)
    full_logits, _ = T.forward(params, toks, BASE)
    _, cache = T.prefill(params, toks[:, :7], BASE)
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 5), (0, 0), (0, 0))), cache)
    lg, cache2 = T.decode_step(params, cache, toks[:, 7],
                               jnp.array([7, 7]), q8)
    assert cache2["k"].dtype == jnp.int8 and "k_scale" in cache2
    assert bool((jnp.argmax(lg, -1)
                 == jnp.argmax(full_logits[:, 7], -1)).all())


def test_quantized_cache_memory_halved():
    fp = T.init_cache(BASE, 2, 16)
    q8 = T.init_cache(dataclasses.replace(BASE, kv_quant=True), 2, 16)
    fp_bytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(fp))
    q8_bytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(q8))
    # f32 cache -> int8 + 1/hd f32 scales: ~3.2x smaller (2x vs bf16)
    assert q8_bytes < 0.45 * fp_bytes