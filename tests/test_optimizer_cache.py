"""Predicate-aware result cache: invalidation is driven by data versions,
never wall-clock.  Every mutation class — ingest append, delete, compaction,
codebook refresh — must flip the store's cache token (flushed or not), a
reopen of UNCHANGED state must keep it (hits survive restarts), and a crash
reopen that replays WAL records must flip it (no stale hit against rows the
replay re-added).  The cache itself is exercised with a live brute-force
query over the store so a stale hit would be OBSERVABLE, not just counted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imi
from repro.core import optimizer as O
from repro.core.index_builder import BuiltIndex, MetadataStore
from repro.store.store import VectorStore

N, D, KP = 256, 16, 4
F = N // KP


def _built(seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (N, D))
    index = imi.build_imi(jax.random.PRNGKey(seed + 1), x,
                          jnp.arange(N, dtype=jnp.int32),
                          K=4, P=4, M=8, kmeans_iters=3)
    return BuiltIndex(
        index=index,
        metadata=MetadataStore(
            video_of=(np.arange(N) // (N // 2)).astype(np.int32),
            frame_of=((np.arange(N) // KP) % (F // 2)).astype(np.int32),
            bbox_of=np.zeros((N, 4), np.float32)),
        keyframes=np.zeros((F, 8, 8, 3), np.float32),
        keyframe_video=(np.arange(F) // (F // 2)).astype(np.int32),
        keyframe_frame=(np.arange(F) % (F // 2)).astype(np.int32),
        patches_per_frame=KP)


@pytest.fixture()
def store(tmp_path):
    s = VectorStore.create(tmp_path / "s", _built())
    yield s
    s.close()


def _live_top1(store) -> int:
    """Brute-force best row id for a fixed probe over the LIVE store rows —
    recomputing this after a mutation gives a different answer, so serving
    a cached copy across a token change is an observable wrong result."""
    q = np.full((D,), 0.25, np.float32)
    pools = [(np.asarray(store.seg.base.ids),
              np.asarray(store.seg.base.vectors, np.float32))]
    pools += [(np.asarray(s.ids), np.asarray(s.vectors, np.float32))
              for s in store.seg.segments]
    best, best_s = -1, -np.inf
    dead = store.seg.tombstones
    for ids, vecs in pools:
        for i, r in enumerate(ids):
            if int(r) in dead:
                continue
            s = float(vecs[i] @ q)
            if s > best_s:
                best, best_s = int(r), s
    return best


def _cached_query(store, cache: O.ResultCache):
    key = "probe-plan"
    token = cache.token()
    hit = cache.get(key, token)
    if hit is not None:
        return hit
    res = _live_top1(store)
    cache.put(key, token, res)
    return res


def _new_rows(seed, n=8):
    r = np.random.default_rng(seed)
    # rows pointing (almost) exactly along the probe direction: after the
    # store's normalization they dominate any random base row's dot product
    x = (np.ones((n, D)) + 0.01 * r.standard_normal((n, D))).astype(
        np.float32)
    ids = np.arange(10_000 + 100 * seed, 10_000 + 100 * seed + n,
                    dtype=np.int32)
    return x, ids


def test_append_invalidates(store):
    cache = O.ResultCache(token_fn=store.cache_token)
    first = _cached_query(store, cache)
    assert _cached_query(store, cache) == first and cache.hits == 1

    x, ids = _new_rows(1)
    store.insert(x, ids)
    fresh = _cached_query(store, cache)
    assert cache.invalidations == 1
    assert fresh != first          # the new dominating rows must be seen
    assert fresh == _live_top1(store)


def test_delete_invalidates(store):
    cache = O.ResultCache(token_fn=store.cache_token)
    x, ids = _new_rows(2)
    store.insert(x, ids)
    first = _cached_query(store, cache)
    assert first in set(int(i) for i in ids)

    store.delete(np.asarray([first], np.int32))
    fresh = _cached_query(store, cache)
    assert cache.invalidations == 1
    assert fresh != first and fresh == _live_top1(store)


def test_compact_invalidates_token_even_without_result_change(store):
    """Compaction folds deltas into a new base: same logical rows, but a
    new generation + a new base segment — the token must flip (results
    were computed against arrays that no longer exist)."""
    cache = O.ResultCache(token_fn=store.cache_token)
    x, ids = _new_rows(4)
    store.insert(x, ids)
    _cached_query(store, cache)
    t0 = store.cache_token()
    store.compact()
    assert store.cache_token() != t0       # generation bump flips the token
    _cached_query(store, cache)
    assert cache.invalidations == 1


def test_refresh_codebooks_invalidates(store):
    cache = O.ResultCache(token_fn=store.cache_token)
    _cached_query(store, cache)
    t0 = store.cache_token()
    store.refresh_codebooks(seed=3, kmeans_iters=2)
    assert store.cache_token() != t0       # new codebooks name + generation
    _cached_query(store, cache)
    assert cache.invalidations == 1


def test_unchanged_reopen_keeps_token_hit(tmp_path):
    """Restart with no intervening writes: the durable part of the token is
    identical, so results cached before shutdown stay valid after."""
    VectorStore.create(tmp_path / "s", _built()).close()
    with VectorStore.open(tmp_path / "s") as s1:
        t1 = s1.cache_token()
    with VectorStore.open(tmp_path / "s") as s2:
        assert s2.cache_token() == t1


def test_mutated_reopen_never_serves_stale(tmp_path):
    cache = O.ResultCache()               # token passed explicitly per open
    with VectorStore.create(tmp_path / "s", _built()) as s1:
        first = _cached_query_open(s1, cache)
        x, ids = _new_rows(5)
        s1.insert(x, ids)
        s1.flush()
    with VectorStore.open(tmp_path / "s") as s2:
        fresh = _cached_query_open(s2, cache)
        assert cache.invalidations == 1
        assert fresh != first and fresh == _live_top1(s2)


def test_crash_reopen_replays_wal_and_invalidates(tmp_path):
    """Mutate WITHOUT flushing, drop the store (simulated crash): reopen
    replays the WAL, so the live rows differ from the pre-crash snapshot
    and the token must differ too."""
    s1 = VectorStore.create(tmp_path / "s", _built())
    cache = O.ResultCache()
    first = _cached_query_open(s1, cache)
    t0 = s1.cache_token()
    x, ids = _new_rows(6)
    s1.insert(x, ids)                     # WAL-logged, NOT flushed
    s1.close()
    with VectorStore.open(tmp_path / "s") as s2:
        assert s2.cache_token() != t0
        fresh = _cached_query_open(s2, cache)
        assert cache.invalidations == 1
        assert fresh != first and fresh == _live_top1(s2)


def _cached_query_open(store, cache):
    key = "probe-plan"
    token = store.cache_token()
    hit = cache.get(key, token)
    if hit is not None:
        return hit
    res = _live_top1(store)
    cache.put(key, token, res)
    return res


def test_lru_eviction_and_counters():
    cache = O.ResultCache(capacity=2)
    cache.put("a", None, 1)
    cache.put("b", None, 2)
    assert cache.get("a", None) == 1      # refresh a
    cache.put("c", None, 3)               # evicts b (least recent)
    assert cache.get("b", None) is None
    assert cache.get("a", None) == 1 and cache.get("c", None) == 3
    assert (cache.hits, cache.misses) == (3, 1)
    assert len(cache) == 2


# -- engine level: query_plan + enable_result_cache -------------------------
@pytest.fixture(scope="module")
def engine():
    from repro.launch.serve import build_engine
    eng, _ = build_engine(seed=0, n_videos=2, res=96)
    return eng


def test_engine_plan_cache_hit_is_identical(engine):
    engine.enable_result_cache()
    spec = ('{"and": [{"text": "a large red square"}, '
            '{"time_range": [0, 24]}]}')
    cold = engine.query_plan(spec, top_n=5)
    warm = engine.query_plan(spec, top_n=5)
    np.testing.assert_array_equal(cold.frames, warm.frames)
    np.testing.assert_array_equal(cold.scores, warm.scores)
    stats = engine.cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    # an EQUIVALENT plan (reordered And) hits via the canonical fingerprint
    engine.query_plan('{"and": [{"time_range": [0, 24]}, '
                      '{"text": "a large red square"}]}', top_n=5)
    assert engine.cache_stats()["hits"] == 2
