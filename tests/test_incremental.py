"""Incremental index maintenance (paper §IX future work, implemented)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anns, imi as imimod, pq as pqmod
from repro.core.incremental import SegmentedIndex


def _base(n=4000, d=32, seed=0):
    cents = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, d))
    a = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, 16)
    x = cents[a] + 0.4 * jax.random.normal(jax.random.PRNGKey(seed + 3),
                                           (n, d))
    idx = imimod.build_imi(jax.random.PRNGKey(seed), x, jnp.arange(n),
                           K=8, P=4, M=32, kmeans_iters=5)
    return idx, cents


CFG = anns.SearchConfig(top_a=16, max_cell_size=512, top_k=50)


def test_insert_then_find():
    idx, cents = _base()
    seg = SegmentedIndex(idx)
    new_vec = pqmod.normalize(cents[3:4] * 1.0)
    seg.insert(new_vec, np.array([999_999]))
    res = seg.search(cents[3], CFG)
    assert 999_999 in res["ids"][:5].tolist()
    assert seg.n == idx.n + 1


def test_delete_tombstone():
    idx, cents = _base()
    seg = SegmentedIndex(idx)
    res0 = seg.search(cents[2], CFG)
    victim = int(res0["ids"][0])
    seg.delete([victim])
    res1 = seg.search(cents[2], CFG)
    assert victim not in res1["ids"].tolist()


def test_compact_preserves_results():
    idx, cents = _base()
    seg = SegmentedIndex(idx, max_segments=8)
    rng = np.random.default_rng(0)
    extra = pqmod.normalize(jnp.asarray(
        np.asarray(cents)[rng.integers(0, 16, 200)]
        + 0.3 * rng.normal(0, 1, (200, 32)).astype(np.float32)))
    seg.insert(extra, np.arange(10_000, 10_200))
    seg.delete([10_005, 10_006])
    seg.compact()
    assert not seg.segments and not seg.tombstones
    after = seg.search(cents[1], CFG)
    # compacted base must drop tombstones
    assert 10_005 not in after["ids"].tolist()
    # every inserted (non-deleted) vector stays findable by self-query
    for probe_i in (0, 50, 199):
        res = seg.search(extra[probe_i], CFG)
        assert 10_000 + probe_i in res["ids"][:5].tolist(), probe_i
    # invariants of the rebuilt base
    off = np.asarray(seg.base.cell_offsets)
    assert off[-1] == seg.base.n and (np.diff(off) >= 0).all()


def test_auto_compact_on_segment_overflow():
    idx, cents = _base()
    seg = SegmentedIndex(idx, max_segments=2, segment_capacity=8)
    for i in range(5):
        v = pqmod.normalize(jax.random.normal(jax.random.PRNGKey(i), (16, 32)))
        seg.insert(v, np.arange(20_000 + 16 * i, 20_016 + 16 * i))
    assert len(seg.segments) <= 2


def test_row_mask_with_pending_deltas_refused():
    """row_mask is positional over BASE rows; silently skipping it for
    delta rows would leak filtered-out results (DESIGN.md §10.2)."""
    import pytest
    idx, cents = _base()
    seg = SegmentedIndex(idx)
    mask = np.ones(idx.n, bool)
    seg.search(cents[0], CFG, row_mask=mask)       # no deltas: fine
    seg.insert(pqmod.normalize(cents[3:4]), np.array([999_999]))
    with pytest.raises(ValueError, match="delta"):
        seg.search(cents[0], CFG, row_mask=mask)
    seg.compact()
    # folded: fine again (mask re-sized to the grown base)
    res = seg.search(cents[0], CFG, row_mask=np.ones(seg.base.n, bool))
    assert len(res["ids"]) == CFG.top_k


def test_tombstone_mask_returns_full_top_k():
    """Pushdown keeps the result at exactly top_k valid ids even when many
    of the approx top-k are tombstoned (the old post-filter shrank)."""
    idx, cents = _base()
    seg = SegmentedIndex(idx)
    res0 = seg.search(cents[2], CFG)
    victims = res0["ids"][:20].tolist()
    seg.delete(victims)
    res1 = seg.search(cents[2], CFG)
    assert len(res1["ids"]) == CFG.top_k
    assert not set(res1["ids"].tolist()) & set(victims)


def test_drift_score_flags_distribution_shift():
    idx, cents = _base()
    seg = SegmentedIndex(idx)
    # in-distribution inserts: drift ~ 1
    v = pqmod.normalize(cents[:8] + 0.4 * jax.random.normal(
        jax.random.PRNGKey(0), (8, 32)))
    seg.insert(v, np.arange(30_000, 30_008))
    in_dist = seg.drift_score()
    # shifted inserts: much worse quantization
    shifted = pqmod.normalize(10.0 + jax.random.normal(
        jax.random.PRNGKey(1), (8, 32)))
    seg2 = SegmentedIndex(idx)
    seg2.insert(shifted, np.arange(40_000, 40_008))
    assert seg2.drift_score() > in_dist
