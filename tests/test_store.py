"""repro.store: round-trip exactness, WAL crash recovery, compaction
equivalence, corruption rejection, and the serve/router wiring."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anns, imi as imimod
from repro.core.incremental import SegmentedIndex
from repro.store import VectorStore, StoreError
from repro.store import manifest as manifestmod
from repro.store import segment as segmentmod
from repro.store import wal as walmod

CFG = anns.SearchConfig(top_a=16, max_cell_size=512, top_k=50)


def _base(n=3000, d=32, seed=0):
    cents = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, d))
    a = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, 16)
    x = cents[a] + 0.4 * jax.random.normal(jax.random.PRNGKey(seed + 3),
                                           (n, d))
    idx = imimod.build_imi(jax.random.PRNGKey(seed), x, jnp.arange(n),
                           K=8, P=4, M=32, kmeans_iters=5)
    return idx, np.asarray(cents)


@pytest.fixture(scope="module")
def built():
    return _base()


def _same(r0, r1):
    return (np.array_equal(np.asarray(r0["ids"]), np.asarray(r1["ids"]))
            and np.array_equal(np.asarray(r0["scores"], np.float32),
                               np.asarray(r1["scores"], np.float32)))


def _mutate(target, cents, rng):
    x = (cents[rng.integers(0, 16, 60)]
         + 0.3 * rng.normal(0, 1, (60, 32))).astype(np.float32)
    target.insert(x, np.arange(10_000, 10_060))
    target.delete([10_005, 3])
    return x


# -- round-trip ---------------------------------------------------------------
def test_create_open_bit_exact(built, tmp_path):
    idx, cents = built
    mem = SegmentedIndex(idx)
    store = VectorStore.create(tmp_path / "s", idx)
    try:
        for qi in range(4):
            assert _same(mem.search(cents[qi], CFG),
                         store.search(cents[qi], CFG))
    finally:
        store.close()
    with VectorStore.open(tmp_path / "s") as reopened:
        for qi in range(4):
            assert _same(mem.search(cents[qi], CFG),
                         reopened.search(cents[qi], CFG))
        # ids round-trip with the canonical dtype, exactly
        assert np.asarray(reopened.seg.base.ids).dtype == imimod.ID_DTYPE
        assert np.array_equal(np.asarray(reopened.seg.base.ids),
                              np.asarray(idx.ids))


def test_wal_replay_matches_memory(built, tmp_path):
    idx, cents = built
    mem = SegmentedIndex(idx)
    rng = np.random.default_rng(0)
    store = VectorStore.create(tmp_path / "s", idx)
    _mutate(mem, cents, np.random.default_rng(0))
    _mutate(store, cents, rng)
    store.close()
    # reopen WITHOUT flush/compact: state comes purely from WAL replay
    with VectorStore.open(tmp_path / "s") as re:
        assert re.seg.segments and re.seg.tombstones
        for qi in range(4):
            assert _same(mem.search(cents[qi], CFG),
                         re.search(cents[qi], CFG))
        assert re.n == mem.n


def test_flush_then_reopen(built, tmp_path):
    idx, cents = built
    mem = SegmentedIndex(idx)
    rng = np.random.default_rng(0)
    store = VectorStore.create(tmp_path / "s", idx, flush_rows=16)
    _mutate(mem, cents, np.random.default_rng(0))
    _mutate(store, cents, rng)  # crosses flush_rows -> delta segment on disk
    m = manifestmod.read_manifest(tmp_path / "s")
    assert m["deltas"], "flush should have persisted a delta segment"
    assert m["last_seq"] >= 1
    store.close()
    with VectorStore.open(tmp_path / "s") as re:
        for qi in range(4):
            assert _same(mem.search(cents[qi], CFG),
                         re.search(cents[qi], CFG))


def test_replay_is_idempotent_after_flush_crash(built, tmp_path):
    """Crash BETWEEN manifest swap and WAL reset: records <= last_seq must
    be skipped on replay, not applied twice."""
    idx, cents = built
    store = VectorStore.create(tmp_path / "s", idx, flush_rows=10 ** 9)
    x = (cents[:8] + 0.1).astype(np.float32)
    store.insert(x, np.arange(50_000, 50_008))
    store.flush()
    # simulate the crash: un-reset the WAL by re-appending the same record
    store.wal.append_insert(1, x, np.arange(50_000, 50_008, dtype=np.int64))
    n_before = store.n
    store.close()
    with VectorStore.open(tmp_path / "s") as re:
        assert re.n == n_before  # seq 1 <= last_seq -> skipped


# -- crash recovery -----------------------------------------------------------
def test_wal_truncated_tail(built, tmp_path):
    idx, cents = built
    store = VectorStore.create(tmp_path / "s", idx, flush_rows=10 ** 9)
    a = (cents[:8] + 0.1).astype(np.float32)
    b = (cents[:8] + 0.2).astype(np.float32)
    store.insert(a, np.arange(30_000, 30_008))
    store.insert(b, np.arange(30_100, 30_108))
    store.close()
    wal_path = tmp_path / "s" / "wal.log"
    blob = wal_path.read_bytes()
    wal_path.write_bytes(blob[:-7])  # chop mid-record: torn final append
    with VectorStore.open(tmp_path / "s") as re:
        got = np.concatenate([np.asarray(s.ids) for s in re.seg.segments])
        assert set(range(30_000, 30_008)) <= set(got.tolist())
        assert not set(range(30_100, 30_108)) & set(got.tolist())
        # the damaged tail was trimmed; appends go after the good prefix
        re.insert(b, np.arange(30_200, 30_208))
    with VectorStore.open(tmp_path / "s") as re2:
        got = np.concatenate([np.asarray(s.ids) for s in re2.seg.segments])
        assert set(range(30_200, 30_208)) <= set(got.tolist())


def test_wal_scan_empty_and_garbage(tmp_path):
    assert walmod.scan(tmp_path / "missing.log").records == []
    p = tmp_path / "garbage.log"
    p.write_bytes(b"not a wal at all")
    res = walmod.scan(p)
    assert res.records == [] and res.damaged_tail


def test_wal_headerless_file_repaired(tmp_path):
    """Crash between file create and header write: appends must not land
    after a broken header (they would be unreplayable forever)."""
    for blob in (b"", b"garbage"):
        p = tmp_path / f"wal_{len(blob)}.log"
        p.write_bytes(blob)
        wal = walmod.WriteAheadLog.open(p)
        wal.append_insert(1, np.zeros((2, 4), np.float32), np.arange(2))
        wal.close()
        res = walmod.scan(p)
        assert len(res.records) == 1 and not res.damaged_tail


def test_create_recovers_from_crashed_create(built, tmp_path):
    """Leftover segment dirs without a manifest (crash mid-create) must not
    brick the directory for the next create."""
    idx, _ = built
    (tmp_path / "s" / "segments" / "seg-000001").mkdir(parents=True)
    VectorStore.create(tmp_path / "s", idx).close()
    VectorStore.open(tmp_path / "s").close()


# -- compaction ---------------------------------------------------------------
def test_compaction_equivalence(built, tmp_path):
    idx, cents = built
    mem = SegmentedIndex(idx)
    store = VectorStore.create(tmp_path / "s", idx)
    _mutate(mem, cents, np.random.default_rng(0))
    _mutate(store, cents, np.random.default_rng(0))
    mem.compact()
    store.compact()
    m = manifestmod.read_manifest(tmp_path / "s")
    assert not m["deltas"] and not m["tombstones"]
    store.close()
    with VectorStore.open(tmp_path / "s") as re:
        assert not re.seg.segments and not re.seg.tombstones
        for qi in range(4):
            assert _same(mem.search(cents[qi], CFG),
                         re.search(cents[qi], CFG))
    # compaction pruned dead segment dirs
    seg_dirs = {p.name for p in (tmp_path / "s" / "segments").iterdir()}
    assert seg_dirs == {m["base"]}


def test_replay_compaction_then_flush_keeps_new_rows(built, tmp_path):
    """Crash after WAL-append but before apply, where replaying that record
    triggers auto-compaction: the deferred base rewrite must not drop rows
    inserted (into fresh delta segments) after the reopen."""
    idx, cents = built
    store = VectorStore.create(tmp_path / "s", idx, max_segments=1,
                               segment_capacity=8, flush_rows=10 ** 9)
    a = (cents[:16] + 0.1).astype(np.float32)
    store.insert(a, np.arange(70_000, 70_016))  # one 16-row delta, no compact
    seq = store._seq
    store.close()
    # crash-after-log: the record hit the WAL but was never applied; its
    # replay appends a 2nd segment -> exceeds max_segments -> replay-compact
    wal = walmod.WriteAheadLog.open(tmp_path / "s" / "wal.log")
    wal.append_insert(seq + 1, (cents[:16] + 0.2).astype(np.float32),
                      np.arange(70_100, 70_116))
    wal.close()
    with VectorStore.open(tmp_path / "s") as re:
        assert re._needs_base_rewrite and not re.seg.segments
        re.insert((cents[:8] + 0.3).astype(np.float32),
                  np.arange(70_200, 70_208))
        n = re.n
        re.flush()  # must persist base AND the new delta, not base alone
    with VectorStore.open(tmp_path / "s") as re2:
        assert re2.n == n
        got = np.concatenate([np.asarray(s.ids) for s in re2.seg.segments]) \
            if re2.seg.segments else np.asarray([])
        assert set(range(70_200, 70_208)) <= \
            set(np.asarray(re2.seg.base.ids).tolist()) | set(got.tolist())


def test_flush_reuses_unchanged_delta_segments(built, tmp_path):
    idx, cents = built
    store = VectorStore.create(tmp_path / "s", idx, max_segments=8,
                               segment_capacity=8, flush_rows=10 ** 9)
    store.insert((cents[:8] + 0.1).astype(np.float32),
                 np.arange(80_000, 80_008))  # fills segment 0 exactly
    store.flush()
    first = manifestmod.read_manifest(tmp_path / "s")["deltas"]
    store.insert((cents[:8] + 0.2).astype(np.float32),
                 np.arange(80_100, 80_108))  # can't merge -> new segment
    store.flush()
    second = manifestmod.read_manifest(tmp_path / "s")["deltas"]
    assert second[0] == first[0], "sealed delta must keep its on-disk name"
    assert len(second) == 2
    store.close()


def test_auto_compact_persists(built, tmp_path):
    idx, cents = built
    store = VectorStore.create(tmp_path / "s", idx, max_segments=1,
                               segment_capacity=8)
    for i in range(3):  # overflows max_segments -> auto-compact inside insert
        x = (cents[:16] + 0.01 * i).astype(np.float32)
        store.insert(x, np.arange(60_000 + 16 * i, 60_016 + 16 * i))
    n = store.n
    store.close()
    with VectorStore.open(tmp_path / "s") as re:
        assert re.n == n


# -- corruption ---------------------------------------------------------------
def test_corrupted_checksum_rejected(built, tmp_path):
    idx, _ = built
    VectorStore.create(tmp_path / "s", idx).close()
    m = manifestmod.read_manifest(tmp_path / "s")
    victim = tmp_path / "s" / "segments" / m["base"] / "vectors.npy"
    blob = bytearray(victim.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    victim.write_bytes(bytes(blob))
    with pytest.raises(segmentmod.SegmentCorrupt):
        VectorStore.open(tmp_path / "s")
    # verify=False trusts the medium and opens anyway
    VectorStore.open(tmp_path / "s", verify=False).close()


def test_missing_footer_rejected(built, tmp_path):
    idx, _ = built
    VectorStore.create(tmp_path / "s", idx).close()
    m = manifestmod.read_manifest(tmp_path / "s")
    (tmp_path / "s" / "segments" / m["base"] / "footer.json").unlink()
    with pytest.raises(segmentmod.SegmentCorrupt):
        VectorStore.open(tmp_path / "s")


def test_create_refuses_existing(built, tmp_path):
    idx, _ = built
    VectorStore.create(tmp_path / "s", idx).close()
    with pytest.raises(StoreError):
        VectorStore.create(tmp_path / "s", idx)


# -- wiring -------------------------------------------------------------------
def test_router_add_replica_from_store(built, tmp_path):
    idx, cents = built
    VectorStore.create(tmp_path / "s", idx).close()
    from repro.serving.router import QueryRouter
    router = QueryRouter(hedge=False)
    store = router.add_replica_from_store("pod0", str(tmp_path / "s"),
                                          search_cfg=CFG)
    try:
        mem = SegmentedIndex(idx)
        out = router(cents[1])
        assert _same(mem.search(cents[1], CFG), out)
    finally:
        store.close()


def test_built_index_sidecar_roundtrip(tmp_path):
    from repro.core.index_builder import load_built, save_built
    from repro.launch.serve import build_engine
    engine, _ = build_engine(n_videos=2)
    save_built(tmp_path / "s", engine.built)
    re = load_built(tmp_path / "s")
    b = engine.built
    assert np.array_equal(np.asarray(re.index.ids), np.asarray(b.index.ids))
    assert np.array_equal(np.asarray(re.index.vectors, np.float32),
                          np.asarray(b.index.vectors, np.float32))
    assert np.array_equal(re.keyframes, b.keyframes)
    assert np.array_equal(re.metadata.bbox_of, b.metadata.bbox_of)
    assert re.patches_per_frame == b.patches_per_frame
    # a rebuilt engine over the reopened index answers queries
    engine2, _ = build_engine(n_videos=2, built=re)
    r = engine2.query("a large red square", top_n=2)
    assert len(r.frames) > 0


def test_store_without_sidecar_refuses_built_index(built, tmp_path):
    idx, _ = built
    store = VectorStore.create(tmp_path / "s", idx)
    with pytest.raises(StoreError):
        store.to_built_index()
    store.close()
