"""Quantization-accuracy coverage for the two-level residual PQ + OPQ +
streaming build (ISSUE 3 satellites; DESIGN.md §9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imi as imimod, pq as pqmod
from repro.core.index_builder import (StreamingBuildConfig,
                                      StreamingIndexBuilder,
                                      build_imi_streaming)


def clustered(seed, n, d, k=20, noise=0.3, shift=0.0):
    """Gaussian mixture; ``shift`` displaces every point (a 'shifted'
    distribution relative to a zero-centered prior)."""
    cents = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    a = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, k)
    x = cents[a] + noise * jax.random.normal(jax.random.PRNGKey(seed + 2),
                                             (n, d))
    return x + shift, cents + shift


def anisotropic(seed, n, d, decay=0.75):
    """Correlated data whose principal axes are misaligned with the
    contiguous subspace split — the regime OPQ's rotation exists for."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    scales = decay ** jnp.arange(d)
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                           (d, d)))
    return (z * scales) @ q.T


def recall_at(exact, approx, k_true=10, k_ret=50):
    top_true = set(np.argsort(-np.asarray(exact))[:k_true].tolist())
    top_ret = np.argsort(-np.asarray(approx))[:k_ret].tolist()
    return len(top_true & set(top_ret)) / k_true


# ---------------------------------------------------------------------------
# recall@k vs exact scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shift", [0.0, 2.0])
def test_pq_recall_clustered_and_shifted(shift):
    """LOVO retrieval protocol (ADC overfetch -> exact rescore -> top-k)
    through the expanded residual codebook preserves the exact top-k on
    clustered (and mean-shifted) data.  Clusters produce hundreds of
    near-tied scores, so raw ADC order alone cannot rank within a cluster —
    the refine stage is part of the contract being tested."""
    n, d = 8000, 32
    x, cents = clustered(11, n, d, k=12, noise=0.25, shift=shift)
    x = pqmod.normalize(x)
    pq = pqmod.train_pq(jax.random.PRNGKey(3), x, P=8, M=32, iters=8)
    codes = pqmod.pq_encode(pq, x)
    for qi in range(3):
        q = pqmod.normalize(cents[qi] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(40 + qi), (d,)))
        exact = np.asarray(x @ q)
        approx = np.asarray(pqmod.adc_scores(pqmod.similarity_lut(pq, q),
                                             codes))
        fetch = np.argsort(-approx)[:2048]          # candidate multiplier
        refined = fetch[np.argsort(-exact[fetch])]  # exact rescore
        top_true = set(np.argsort(-exact)[:10].tolist())
        rec = len(top_true & set(refined[:50].tolist())) / 10
        assert rec >= 0.9, (qi, rec)


def test_expanded_codebook_beats_flat_at_same_bits():
    """The point of the two-level codebook: at the same uint8/subspace
    storage, coarse+residual reconstruction error < the seed's flat-M
    Lloyd (G=1)."""
    x = pqmod.normalize(clustered(5, 6000, 32, k=15)[0])
    mses = []
    for cells in (1, 2):
        pq = pqmod.train_pq(jax.random.PRNGKey(0), x, P=8, M=32, iters=8,
                            coarse_cells=cells)
        rec = pqmod.pq_decode(pq, pqmod.pq_encode(pq, x))
        mses.append(float(jnp.mean(jnp.sum(jnp.square(rec - x), -1))))
    assert mses[1] < mses[0], mses


# ---------------------------------------------------------------------------
# OPQ rotation
# ---------------------------------------------------------------------------
def test_opq_reduces_reconstruction_error_vs_no_opq():
    x = anisotropic(7, 5000, 32)
    plain = pqmod.train_pq(jax.random.PRNGKey(1), x, P=8, M=16, iters=8)
    opq = pqmod.train_opq(jax.random.PRNGKey(1), x, P=8, M=16, iters=8,
                          opq_iters=3)
    def mse(pq):
        rec = pqmod.pq_decode(pq, pqmod.pq_encode(pq, x))
        return float(jnp.mean(jnp.sum(jnp.square(rec - x), -1)))
    assert mse(opq) < mse(plain), (mse(opq), mse(plain))


def test_opq_rotation_is_orthogonal_and_score_correct():
    x = anisotropic(9, 2000, 16)
    opq = pqmod.train_opq(jax.random.PRNGKey(2), x, P=4, M=16, iters=5,
                          opq_iters=2)
    r = np.asarray(opq.rotation)
    np.testing.assert_allclose(r @ r.T, np.eye(16), atol=1e-5)
    # ADC through the rotated LUT == q . decode(codes): score correctness
    # of every ADC consumer falls out of this identity
    codes = pqmod.pq_encode(opq, x)
    q = pqmod.normalize(jax.random.normal(jax.random.PRNGKey(3), (16,)))
    s1 = pqmod.adc_scores(pqmod.similarity_lut(opq, q), codes)
    s2 = pqmod.pq_decode(opq, codes) @ q
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Lloyd internals
# ---------------------------------------------------------------------------
def test_kmeans_reseeds_empty_clusters():
    """k = n on distinct points: k-means++ seeds duplicates, so empties are
    guaranteed mid-run; with farthest-point re-seeding every point ends up
    its own centroid (distortion -> 0).  The seed bug froze empties at
    stale positions, leaving distortion > 0 forever."""
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 8))
    cents, assign = pqmod.kmeans(jax.random.PRNGKey(1), x, k=48, iters=25)
    dist = float(jnp.sum(jnp.square(x - cents[assign])))
    assert dist < 1e-6, dist


def test_pairwise_sqdist_non_negative_on_near_duplicates():
    base = jax.random.normal(jax.random.PRNGKey(4), (1, 16)) * 100.0
    x = jnp.repeat(base, 64, axis=0) + 1e-6 * jax.random.normal(
        jax.random.PRNGKey(5), (64, 16))
    d2 = pqmod._pairwise_sqdist(x, x[:8])
    assert float(jnp.min(d2)) >= 0.0


def test_kmeans_assign_batched_matches_ref():
    from repro.kernels import ops, ref
    xs = jax.random.normal(jax.random.PRNGKey(6), (5, 300, 8))
    cents = jax.random.normal(jax.random.PRNGKey(7), (5, 17, 8))
    a, d = ops.kmeans_assign_batched(xs, cents)
    ar, dr = ref.kmeans_assign_batched_ref(xs, cents)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# streaming build
# ---------------------------------------------------------------------------
def test_streaming_build_bit_equals_monolithic(tmp_path):
    """Full-reservoir streaming build == build_imi, bit for bit: codes,
    ids, cells, CSR offsets, bf16 vectors."""
    n, d = 4000, 32
    x, _ = clustered(3, n, d)
    ids = jnp.arange(n, dtype=jnp.int32)
    mono = imimod.build_imi(jax.random.PRNGKey(0), x, ids,
                            K=8, P=8, M=32, kmeans_iters=5)

    xs = np.asarray(x, np.float32)
    def chunks(sz=1000):
        def it():
            for lo in range(0, n, sz):
                yield (xs[lo: lo + sz],
                       np.arange(lo, min(lo + sz, n), dtype=np.int32))
        return it
    cfg = StreamingBuildConfig(K=8, P=8, M=32, kmeans_iters=5,
                               sample_size=n, chunk_rows=1000)
    stream = build_imi_streaming(jax.random.PRNGKey(0), chunks(), cfg,
                                 spill_dir=tmp_path / "spill")
    np.testing.assert_array_equal(np.asarray(mono.codes),
                                  np.asarray(stream.codes))
    np.testing.assert_array_equal(np.asarray(mono.ids),
                                  np.asarray(stream.ids))
    np.testing.assert_array_equal(np.asarray(mono.cell_of),
                                  np.asarray(stream.cell_of))
    np.testing.assert_array_equal(np.asarray(mono.cell_offsets),
                                  np.asarray(stream.cell_offsets))
    np.testing.assert_array_equal(
        np.asarray(mono.vectors).view(np.uint16),
        np.asarray(stream.vectors).view(np.uint16))
    assert not (tmp_path / "spill").exists()  # spill cleaned up


def test_streaming_reservoir_subsample_still_searches(tmp_path):
    """Sub-corpus reservoir (the actual streaming regime): codebooks from a
    sample, whole corpus encoded; self-retrieval via the standard search
    path still works."""
    from repro.core import anns
    n, d = 6000, 32
    x, _ = clustered(8, n, d, k=10)
    xs = np.asarray(x, np.float32)
    def it():
        for lo in range(0, n, 1500):
            yield (xs[lo: lo + 1500],
                   np.arange(lo, min(lo + 1500, n), dtype=np.int32))
    cfg = StreamingBuildConfig(K=8, P=8, M=32, kmeans_iters=5,
                               sample_size=2048, chunk_rows=1500)
    index = build_imi_streaming(jax.random.PRNGKey(1), lambda: it(), cfg,
                                spill_dir=tmp_path / "spill")
    assert index.n == n
    hits = 0
    for qi in range(20):
        # clusters put ~600 rows within the ADC noise floor of each other:
        # the overfetch must span the tie set for exact rerank to resolve it
        res = anns.search(index, x[qi], anns.SearchConfig(
            top_a=16, max_cell_size=1024, top_k=10, rerank_overfetch=64))
        hits += int(qi in set(np.asarray(res["ids"]).tolist()))
    assert hits >= 18, hits


def test_streaming_builder_phase_order_enforced():
    cfg = StreamingBuildConfig(K=4, P=4, M=8)
    b = StreamingIndexBuilder(jax.random.PRNGKey(0), cfg)
    with pytest.raises(RuntimeError):
        b.train()
    with pytest.raises(RuntimeError):
        b.add(np.zeros((4, 16), np.float32), np.arange(4, dtype=np.int32))


def test_streaming_builder_enforces_chunk_rows():
    """chunk_rows is a hard working-set bound, not caller discipline: one
    oversized add() is resliced and produces the same index as pre-sliced
    feeding."""
    n, d = 2000, 32
    x = np.asarray(clustered(2, n, d)[0], np.float32)
    ids = np.arange(n, dtype=np.int32)

    def build(feed_whole):
        cfg = StreamingBuildConfig(K=4, P=8, M=16, kmeans_iters=3,
                                   sample_size=n, chunk_rows=512)
        b = StreamingIndexBuilder(jax.random.PRNGKey(0), cfg)
        if feed_whole:
            b.observe(x)            # 2000 rows > chunk_rows=512
        else:
            for lo in range(0, n, 512):
                b.observe(x[lo: lo + 512])
        b.train()
        if feed_whole:
            b.add(x, ids)
        else:
            for lo in range(0, n, 512):
                b.add(x[lo: lo + 512], ids[lo: lo + 512])
        return b.finish()

    a, bb = build(True), build(False)
    np.testing.assert_array_equal(np.asarray(a.codes), np.asarray(bb.codes))
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(bb.ids))


def test_streaming_builder_spill_cleanup_is_scoped(tmp_path):
    """finish() removes only its own chunk segments — a caller-provided
    spill_dir with unrelated contents survives."""
    spill = tmp_path / "scratch"
    spill.mkdir()
    keep = spill / "unrelated.txt"
    keep.write_text("precious")
    n, d = 600, 16
    x = np.asarray(clustered(4, n, d, k=4)[0], np.float32)
    cfg = StreamingBuildConfig(K=4, P=4, M=8, kmeans_iters=3,
                               sample_size=n, chunk_rows=256)
    b = StreamingIndexBuilder(jax.random.PRNGKey(0), cfg, spill_dir=spill)
    b.observe(x)
    b.train()
    b.add(x, np.arange(n, dtype=np.int32))
    b.finish()
    assert keep.read_text() == "precious"
    assert not list(spill.glob("chunk-*"))
