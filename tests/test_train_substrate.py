"""Substrate tests: optimizer state dtypes, checkpoint/restart, fault
tolerance with injected failures, grad compression, pipeline determinism."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer, restore, save
from repro.data.pipeline import DeterministicSource, Prefetcher, lm_batch_fn
from repro.launch.fault_tolerance import (RunnerConfig, StepFailure,
                                          TrainRunner, TrainState)
from repro.train.grad_compression import (compress_grads, compressed_psum,
                                          decompress_grads, ef_init)
from repro.train.optimizer import AdamConfig, adam_init, adam_update


def quad_problem(dtype: str):
    """Minimize ||Wx - y||^2; returns (params, step_fn)."""
    W = jnp.zeros((8, 8))
    target = jax.random.normal(jax.random.PRNGKey(0), (8, 8))

    def loss(p):
        return jnp.sum((p["W"] - target) ** 2)

    cfg = AdamConfig(lr=5e-2, state_dtype=dtype, schedule="constant",
                     warmup_steps=1)
    params = {"W": W}
    opt = adam_init(params, cfg)

    def step(params, opt):
        g = jax.grad(loss)(params)
        return adam_update(params, g, opt, cfg)

    return params, opt, jax.jit(step), loss


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adam_state_dtypes_converge(dtype):
    params, opt, step, loss = quad_problem(dtype)
    l0 = float(loss(params))
    for _ in range(150):
        params, opt, _ = step(params, opt)
    assert float(loss(params)) < l0 * 0.05, (dtype, float(loss(params)))


def test_adam_int8_states_are_int8():
    params, opt, step, _ = quad_problem("int8")
    params, opt, _ = step(params, opt)
    q, scale = opt["m"]["W"]
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)},
            "s": jnp.asarray(7, jnp.int32)}
    save(tmp_path / "ck", tree, step=42)
    got, step = restore(tmp_path / "ck", tree)
    assert step == 42
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x, np.float32), np.asarray(y, np.float32)), tree, got)


def test_checkpointer_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (10, 20, 30):
        ck.save_async({"w": jnp.full((4,), float(s))}, s)
    ck.wait()
    assert ck.steps() == [20, 30]
    got, step = ck.restore_latest(tree)
    assert step == 30 and float(got["w"][0]) == 30.0


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir never shadows the good checkpoint."""
    ck = Checkpointer(tmp_path, keep=3)
    ck.save({"w": jnp.ones((2,))}, 5)
    bad = tmp_path / "step_00000009.tmp"
    bad.mkdir()
    assert ck.latest_step() == 5


def test_restore_with_resharding(tmp_path):
    """Checkpoint saved unsharded restores under explicit shardings
    (elastic re-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    save(tmp_path / "ck", tree, 1)
    sh = {"w": NamedSharding(mesh, P("data"))}
    got, _ = restore(tmp_path / "ck", tree, sh)
    assert got["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# Fault-tolerant runner
# ---------------------------------------------------------------------------
def _runner_fixture(tmp_path, fail_at=()):
    cfg = AdamConfig(lr=1e-2, schedule="constant", warmup_steps=1)
    target = jnp.full((4,), 3.0)

    def step(params, opt, batch):
        g = jax.tree.map(lambda p: 2 * (p - target) + 0 * batch["x"].sum(),
                         params)
        p2, o2, m = adam_update(params, g, opt, cfg)
        m["loss"] = jnp.sum((params["w"] - target) ** 2)
        return p2, o2, m

    fails = set(fail_at)
    calls = {"n": 0}

    def hook(s):
        calls["n"] += 1
        if s in fails:
            fails.discard(s)
            raise StepFailure(f"injected at {s}")

    params = {"w": jnp.zeros((4,))}
    opt = adam_init(params, cfg)
    ck = Checkpointer(tmp_path / "ck")
    runner = TrainRunner(step, ck, RunnerConfig(total_steps=20,
                                                checkpoint_every=5),
                         failure_hook=hook)
    state = TrainState(params=params, opt_state=opt, step=0,
                       rng=jax.random.PRNGKey(0), data_cursor=0)
    batches = iter(DeterministicSource(
        lambda seed, i: {"x": np.zeros((1,), np.float32)}, 0).iterate())
    return runner, state, batches


def test_runner_retries_injected_failures(tmp_path):
    runner, state, batches = _runner_fixture(tmp_path, fail_at=(3, 7, 11))
    out = runner.run(state, batches)
    assert out.step == 20
    assert runner.metrics_log[-1]["loss"] < runner.metrics_log[0]["loss"]


def test_runner_restart_resumes_from_checkpoint(tmp_path):
    runner, state, batches = _runner_fixture(tmp_path)
    out = runner.run(state, batches)
    assert out.step == 20
    # simulate process death + restart: fresh runner restores step 20
    runner2, state2, batches2 = _runner_fixture(tmp_path)
    restored = runner2.restore_or_init(state2)
    assert restored.step == 20
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(out.params["w"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
def test_error_feedback_bounds_bias():
    """Property: with EF, the CUMULATIVE compressed sum tracks the true
    cumulative gradient (residual stays bounded)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
    ef = ef_init(g)
    total_true = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(i), (64,))}
        q, s, ef = compress_grads(gi, ef)
        sent = decompress_grads(q, s)
        total_true += gi["w"]
        total_sent += sent["w"]
    resid = np.abs(np.asarray(total_true - total_sent)).max()
    # residual equals |ef| <= one quantization bin, NOT O(steps)
    assert resid <= float(np.abs(np.asarray(ef["w"])).max()) + 1e-5
    assert resid < 0.2


def test_compressed_psum_shard_map():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8, dtype=jnp.float32)
    ef = jnp.zeros((8,))
    from repro.core.distributed import shard_map_compat
    f = shard_map_compat(
        lambda x, e: compressed_psum(x, "data", e), mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        out_specs=(jax.sharding.PartitionSpec(),
                   jax.sharding.PartitionSpec()), check=True)
    mean, resid = f(x, ef)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x), atol=0.05)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    fn = lm_batch_fn(vocab=101, accum=1, micro=2, seq=8)
    src = DeterministicSource(fn, seed=7)
    a = [src(i)["tokens"] for i in range(5)]
    b = [src(i)["tokens"] for i in range(5)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # resume from cursor 3 == original stream at 3
    it = src.iterate(start_cursor=3)
    np.testing.assert_array_equal(next(it)["tokens"], a[3])


def test_pipeline_host_sharding_disjoint():
    fn = lm_batch_fn(vocab=101, accum=1, micro=2, seq=8)
    h0 = DeterministicSource(fn, seed=7, host_id=0, num_hosts=2)
    h1 = DeterministicSource(fn, seed=7, host_id=1, num_hosts=2)
    assert not np.array_equal(h0(0)["tokens"], h1(0)["tokens"])
    # host 0 cursor 1 == global index 2; host 1 cursor 0 == global index 1
    full = DeterministicSource(fn, seed=7)
    np.testing.assert_array_equal(h0(1)["tokens"], full(2)["tokens"])


def test_prefetcher_preserves_order_and_errors():
    pf = Prefetcher(iter(range(10)), depth=3)
    assert list(pf) == list(range(10))

    def boom():
        yield 1
        raise ValueError("boom")
    pf2 = Prefetcher(boom(), depth=2)
    assert next(pf2) == 1
    with pytest.raises(ValueError):
        next(pf2)
