"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("Q,P,M,N,block", [
    (1, 4, 16, 100, 64),
    (4, 8, 64, 1000, 256),
    (8, 16, 256, 2048, 512),
    (2, 64, 256, 777, 128),   # LOVO production P/M, ragged N
])
def test_pq_scan_sweep(Q, P, M, N, block):
    k1, k2 = jax.random.split(jax.random.PRNGKey(P * M + N))
    luts = jax.random.normal(k1, (Q, P, M), jnp.float32)
    codes = jax.random.randint(k2, (N, P), 0, M)
    out = ops.pq_scan_batched(luts, codes, block_n=block)
    want = ref.pq_scan_ref(luts, codes)
    # bf16 one-hot matmul path: tolerance scales with sum-of-P bf16 products
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-2, atol=3e-2 * np.sqrt(P))


@pytest.mark.parametrize("codes_dtype", [jnp.uint8, jnp.int32])
def test_pq_scan_dtypes(codes_dtype):
    luts = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
    codes = jax.random.randint(jax.random.PRNGKey(1), (500, 8), 0, 64
                               ).astype(codes_dtype)
    out = ops.pq_scan_batched(luts, codes)
    want = ref.pq_scan_ref(luts, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-2, atol=1e-1)


def test_pq_scan_single_query_wrapper():
    lut = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    codes = jax.random.randint(jax.random.PRNGKey(1), (300, 8), 0, 64)
    out = ops.pq_scan(lut, codes)
    want = ref.pq_scan_ref(lut[None], codes)[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-2, atol=1e-1)


@pytest.mark.parametrize("N,M,m,block", [
    (100, 8, 4, 64), (1000, 64, 16, 256), (513, 256, 8, 128),
])
def test_kmeans_assign_sweep(N, M, m, block):
    x = jax.random.normal(jax.random.PRNGKey(N), (N, m))
    cents = jax.random.normal(jax.random.PRNGKey(M), (M, m))
    a, d = ops.kmeans_assign(x, cents)
    ar, dr = ref.kmeans_assign_ref(x, cents)
    assert bool((a == ar).all())
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,H,S,T,d", [
    (1, 2, 64, 64, 16), (2, 4, 130, 257, 32), (1, 1, 576, 64, 64),
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_sweep(B, H, S, T, d, causal):
    if causal and S != T:
        pytest.skip("causal requires square")
    ks = jax.random.split(jax.random.PRNGKey(S + T), 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, T, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, T, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap_and_gqa():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (2, 8, 96, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 96, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 96, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, softcap=30.0)
    want = ref.flash_attention_ref(q, jnp.repeat(k, 4, 1),
                                   jnp.repeat(v, 4, 1),
                                   causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.bfloat16)
    out = ops.flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
