"""Seeded fault-injection helpers for router/shard tests.

``FaultyReplica`` wraps a working replica fn and fails DETERMINISTICALLY:
a seeded schedule decides which calls raise, so tests of the demotion /
re-route / refuse-to-merge paths are reproducible.  Import from tests as
``from _faulty import FaultyReplica`` (conftest puts tests/ on the path).
"""
from __future__ import annotations

import random
from typing import Any, Callable, Optional


class ShardFault(RuntimeError):
    """The injected failure — distinct type so tests can assert provenance."""


class FaultyReplica:
    """A replica callable that fails on a seeded schedule.

    ``fail_rate``: probability (seeded ``random.Random(seed)``) that any
    given call raises.  ``fail_calls``: explicit 0-based call indices that
    raise (takes precedence; e.g. ``{0}`` = fail only the first call —
    exactly one mid-stream fault).  ``fail_after``: every call from that
    index on raises (a replica that dies and stays dead).  Counts calls
    across both the scalar and batch entry points; ``batch_fn`` is exposed
    so the router's batched path exercises the same schedule.
    """

    def __init__(self, fn: Callable[[Any], Any], *, seed: int = 0,
                 fail_rate: float = 0.0,
                 fail_calls: Optional[set] = None,
                 fail_after: Optional[int] = None,
                 flap_period: Optional[int] = None):
        self._fn = fn
        self._rng = random.Random(seed)
        self._fail_rate = fail_rate
        self._fail_calls = fail_calls
        self._fail_after = fail_after
        self._flap_period = flap_period
        self.calls = 0
        self.faults = 0

    def _should_fail(self, idx: int) -> bool:
        if self._fail_calls is not None:
            return idx in self._fail_calls
        if self._fail_after is not None and idx >= self._fail_after:
            return True
        if self._flap_period is not None:
            # flapping replica: alternates P bad calls, P good calls, ...
            # (starts BAD, so breakers trip, half-open probes catch the
            # good window, and the cycle repeats deterministically)
            return (idx // self._flap_period) % 2 == 0
        return self._rng.random() < self._fail_rate

    def __call__(self, payload: Any) -> Any:
        idx = self.calls
        self.calls += 1
        if self._should_fail(idx):
            self.faults += 1
            raise ShardFault(f"injected fault on call {idx}")
        return self._fn(payload)

    def batch_fn(self, payloads: list) -> list:
        return [self(p) for p in payloads]
