"""Torn-tail / corruption fuzz for store reopen (DESIGN.md §16.5).

Two families of damage, exhaustively applied:

  * WAL truncation at EVERY byte offset — the tail record is torn at
    every possible instant; ``wal.scan`` must return exactly the intact
    record prefix (bit-identical payloads), flag the damaged tail, and
    never raise or fabricate rows.  Representative offsets then go
    through a full ``VectorStore.open`` to prove the recovered live-id
    set equals the intact-prefix expectation.
  * Segment corruption — a single bit flipped in any base array, a
    truncated array file, a deleted footer: ``open(verify=True)`` must
    refuse loudly (``SegmentCorrupt``), never serve wrong rows.
"""
import json
import pathlib
import shutil

import numpy as np
import pytest

from repro.store import VectorStore
from repro.store import manifest as manifestmod
from repro.store import segment as segmentmod
from repro.store import wal as walmod

D = 8


def _records():
    rng = np.random.default_rng(0)
    return [
        ("insert", 1, rng.normal(0, 1, (4, D)).astype(np.float32),
         np.arange(100, 104)),
        ("delete", 2, None, np.array([101])),
        ("insert", 3, rng.normal(0, 1, (3, D)).astype(np.float32),
         np.arange(104, 107)),
    ]


def _write_wal(path: pathlib.Path) -> list[int]:
    """Write the fixture records; return the byte offset after each
    record (frame boundaries, starting with the header end)."""
    wal = walmod.WriteAheadLog.open(path)
    bounds = [path.stat().st_size]
    for kind, seq, vecs, ids in _records():
        if kind == "insert":
            wal.append_insert(seq, vecs, ids)
        else:
            wal.append_delete(seq, ids)
        bounds.append(path.stat().st_size)
    wal.close()
    return bounds


def _same_record(a: walmod.WalRecord, b: walmod.WalRecord) -> bool:
    if (a.seq, a.kind) != (b.seq, b.kind):
        return False
    if not np.array_equal(a.ids, b.ids):
        return False
    if (a.vectors is None) != (b.vectors is None):
        return False
    return a.vectors is None or np.array_equal(a.vectors, b.vectors)


def test_wal_scan_survives_truncation_at_every_byte(tmp_path):
    path = tmp_path / "wal.log"
    bounds = _write_wal(path)
    data = path.read_bytes()
    full = walmod.scan(path)
    assert len(full.records) == 3 and not full.damaged_tail
    assert full.good_end == bounds[-1] == len(data)

    cut = tmp_path / "cut.log"
    for off in range(len(data) + 1):
        cut.write_bytes(data[:off])
        res = walmod.scan(cut)           # must never raise
        # exactly the records whose frames fit under the cut, no more
        n_expect = sum(1 for b in bounds[1:] if b <= off)
        assert len(res.records) == n_expect, f"offset {off}"
        for got, want in zip(res.records, full.records):
            assert _same_record(got, want), f"offset {off}: payload drift"
        assert res.good_end == (bounds[n_expect] if off >= bounds[0] else 0)
        # damaged iff the cut landed inside a frame (or a non-empty
        # partial header; a zero-byte file is absent, not damaged)
        expect_damaged = off > 0 if off < bounds[0] \
            else off not in bounds
        assert res.damaged_tail == expect_damaged, f"offset {off}"


def test_wal_bitflip_in_any_record_drops_only_the_tail(tmp_path):
    """A flipped bit inside record k kills k and everything after (scan
    cannot trust framing past a bad CRC) but records < k replay intact."""
    path = tmp_path / "wal.log"
    bounds = _write_wal(path)
    data = bytearray(path.read_bytes())
    full = walmod.scan(path).records
    flip = tmp_path / "flip.log"
    for k in range(3):                    # corrupt one byte inside record k
        mid = (bounds[k] + bounds[k + 1]) // 2
        mutated = bytearray(data)
        mutated[mid] ^= 0x40
        flip.write_bytes(bytes(mutated))
        res = walmod.scan(flip)
        assert res.damaged_tail and len(res.records) == k
        for got, want in zip(res.records, full[:k]):
            assert _same_record(got, want)


def _mini_store(tmp_path, *, n=200):
    import jax
    import jax.numpy as jnp
    from repro.core import imi as imimod

    x = np.random.default_rng(3).normal(0, 1, (n, D)).astype(np.float32)
    idx = imimod.build_imi(jax.random.PRNGKey(3), jnp.asarray(x),
                           jnp.arange(n), K=4, P=2, M=8, kmeans_iters=2)
    store = VectorStore.create(tmp_path / "s", idx, flush_rows=10 ** 9)
    rng = np.random.default_rng(4)
    for lo in (1000, 1010, 1020):
        store.insert(rng.normal(0, 1, (10, D)).astype(np.float32),
                     np.arange(lo, lo + 10))
    store.delete([1003, 7])
    store.close()
    return tmp_path / "s", set(range(n))


def _live_ids(store) -> set:
    ids = [int(v) for v in np.asarray(store.seg.base.ids) if int(v) >= 0]
    for s in store.seg.segments:
        ids.extend(int(v) for v in np.asarray(s.ids))
    tomb = {int(t) for t in store.seg.tombstones}
    return {v for v in ids if v not in tomb}


def test_store_reopen_after_wal_truncation_representative_offsets(tmp_path):
    """Full-open spot checks over the same offset space: the recovered
    id set must equal applying exactly the surviving record prefix."""
    root, base = _mini_store(tmp_path)
    wal_path = root / "wal.log"
    data = wal_path.read_bytes()
    res = walmod.scan(wal_path)
    assert len(res.records) == 4          # 3 inserts + 1 delete

    def apply(records):
        live = set(base)
        for r in records:
            if r.kind == walmod.KIND_INSERT:
                live |= {int(i) for i in r.ids}
            else:
                live -= {int(i) for i in r.ids}
        return live

    # representative cuts: header-only, mid-record-1, exactly after
    # record 2, mid-last-record, one byte short of intact
    head = len(walmod.MAGIC) + 4
    frame_ends = [head]
    off = head
    for r in walmod.scan(wal_path).records:
        body = (walmod._encode_insert(r.seq, r.vectors, r.ids)
                if r.kind == walmod.KIND_INSERT
                else walmod._encode_delete(r.seq, r.ids))
        off += walmod._HDR.size + len(body)
        frame_ends.append(off)
    assert frame_ends[-1] == len(data)
    cuts = [head, (frame_ends[0] + frame_ends[1]) // 2, frame_ends[2],
            (frame_ends[3] + frame_ends[4]) // 2, len(data) - 1]
    for off in cuts:
        with open(wal_path, "wb") as f:
            f.write(data[:off])
        surviving = walmod.scan(wal_path).records
        with VectorStore.open(root, verify=True) as store:
            assert _live_ids(store) == apply(surviving), f"offset {off}"
        # reopen trimmed/repaired the tail: put the full WAL back for
        # the next cut (open may rewrite the file)
        with open(wal_path, "wb") as f:
            f.write(data)


def test_segment_bitflip_refuses_loudly(tmp_path):
    root, _ = _mini_store(tmp_path)
    m = manifestmod.read_manifest(root)
    seg_dir = root / "segments" / m["base"]
    npys = sorted(seg_dir.glob("*.npy"))
    assert npys, "base segment should contain array files"
    for npy in npys:
        orig = npy.read_bytes()
        mutated = bytearray(orig)
        mutated[len(mutated) // 2] ^= 0x01          # single bit
        npy.write_bytes(bytes(mutated))
        with pytest.raises(segmentmod.SegmentCorrupt):
            VectorStore.open(root, verify=True)
        npy.write_bytes(orig)                       # restore
    with VectorStore.open(root, verify=True) as store:
        assert store.n > 0                          # clean again


def test_segment_truncation_and_missing_footer_refuse(tmp_path):
    root, _ = _mini_store(tmp_path)
    m = manifestmod.read_manifest(root)
    seg_dir = root / "segments" / m["base"]
    npy = sorted(seg_dir.glob("*.npy"))[0]
    orig = npy.read_bytes()
    npy.write_bytes(orig[: len(orig) // 2])
    with pytest.raises(segmentmod.SegmentCorrupt):
        VectorStore.open(root, verify=True)
    npy.write_bytes(orig)
    footer = seg_dir / segmentmod.FOOTER
    saved = footer.read_text()
    footer.unlink()
    with pytest.raises(segmentmod.SegmentCorrupt):
        VectorStore.open(root, verify=True)
    footer.write_text(saved)
    # corrupt CRC in the footer itself: the array is fine but the
    # contract (footer describes the bytes) is broken -> refuse
    doc = json.loads(saved)
    name = next(iter(doc["arrays"]))
    doc["arrays"][name]["crc32"] = (doc["arrays"][name]["crc32"] + 1) \
        % (2 ** 32)
    footer.write_text(json.dumps(doc))
    with pytest.raises(segmentmod.SegmentCorrupt):
        VectorStore.open(root, verify=True)
    footer.write_text(saved)
    with VectorStore.open(root, verify=True) as store:
        assert store.n > 0


def test_manifest_never_names_missing_segment(tmp_path):
    root, _ = _mini_store(tmp_path)
    m = manifestmod.read_manifest(root)
    seg_dir = root / "segments" / m["base"]
    moved = seg_dir.with_suffix(".gone")
    shutil.move(seg_dir, moved)
    with pytest.raises(Exception):
        VectorStore.open(root, verify=True)
    shutil.move(moved, seg_dir)
    with VectorStore.open(root, verify=True) as store:
        assert store.n > 0
