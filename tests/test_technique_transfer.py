"""DESIGN.md §5: LOVO's PQ-ADC scoring applied to recsys retrieval
(retrieval_cand = the paper's fast-search regime on item embeddings)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import pq as pqmod
from repro.models import recsys as R


def test_pq_retrieval_matches_exact_ordering():
    """PQ-coded candidate scoring preserves the exact top-k ordering well
    enough for retrieval (recall@50 of exact top-10 >= 0.9)."""
    d, C = 64, 20_000
    cand = pqmod.normalize(
        jax.random.normal(jax.random.PRNGKey(0), (C, d)))
    user = pqmod.normalize(
        jax.random.normal(jax.random.PRNGKey(1), (4, d)))  # 4 interests

    exact = R.retrieval_scores(user, cand)
    pq = pqmod.train_pq(jax.random.PRNGKey(2), cand, P=16, M=64, iters=8)
    codes = pqmod.pq_encode(pq, cand)
    approx = R.retrieval_scores_pq(user, pq.centroids, codes)

    top_exact = set(np.argsort(-np.asarray(exact))[:10].tolist())
    top_pq = np.argsort(-np.asarray(approx))[:50].tolist()
    recall = len(top_exact & set(top_pq)) / 10
    assert recall >= 0.9, recall


def test_pq_retrieval_compresses_candidates():
    """The point of the transfer: PQ codes are 16x smaller than f32
    embeddings at these settings (dim 64 f32 = 256 B -> 16 B codes)."""
    d = 64
    P = 16
    assert P * 1 < d * 4 / 4  # 16 uint8 codes vs 256 bytes
    arch = get_arch("mind")
    assert arch.embed_dim == d


def test_mind_interests_shapes_and_norms():
    arch = dataclasses.replace(get_arch("mind"), vocab_sizes=(101,))
    params, _ = R.init_mind(jax.random.PRNGKey(0), arch)
    hist = jax.random.randint(jax.random.PRNGKey(1), (3, arch.seq_len), 0, 101)
    mask = jnp.ones((3, arch.seq_len))
    caps = R.mind_interests(params, arch, history=hist, hist_mask=mask)
    assert caps.shape == (3, arch.n_interests, arch.embed_dim)
    assert bool(jnp.isfinite(caps).all())
