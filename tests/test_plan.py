"""Complex-query planner: masked-scan parity vs numpy oracles, boolean
algebra on posting lists, grouped top-k stability across shard counts, and
the engine-level compound-query path (DESIGN.md §10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anns, imi, pq as pqmod
from repro.core import plan as P


# ---------------------------------------------------------------------------
# masked PQ scan: kernel parity vs the numpy/jnp oracle
# ---------------------------------------------------------------------------
def test_masked_scan_matches_oracle_incl_all_filtered_rows():
    from repro.kernels import ops, ref
    luts = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))
    codes = jax.random.randint(jax.random.PRNGKey(1), (700, 8),
                               0, 32).astype(jnp.uint8)
    mask = jax.random.bernoulli(jax.random.PRNGKey(2), 0.3, (3, 700))
    mask = mask.at[1].set(False)          # one query filters EVERY row
    got = ops.pq_scan_batched_masked(luts, codes, mask, block_n=256)
    want = ref.pq_scan_masked_ref(luts, codes, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isneginf(np.asarray(got)[1]).all()     # sentinel, never NaN

    codes_p = jax.random.randint(jax.random.PRNGKey(3), (3, 700, 8),
                                 0, 32).astype(jnp.uint8)
    got_p = ops.pq_scan_paired_masked(luts, codes_p, mask, block_n=256)
    want_p = jnp.where(mask != 0,
                       jax.vmap(pqmod.adc_scores)(luts, codes_p), -jnp.inf)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# masked Algorithm 1: filtered search vs brute-force-over-valid-rows oracle
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def index():
    x = jax.random.normal(jax.random.PRNGKey(0), (3000, 64))
    ids = jnp.arange(3000, dtype=jnp.int32)
    return imi.build_imi(jax.random.PRNGKey(1), x, ids,
                         K=8, P=8, M=32, kmeans_iters=5)


QS = jax.random.normal(jax.random.PRNGKey(7), (4, 64))
# full coverage + covering overfetch: the masked pipeline must equal exact
# brute force over the valid rows at EVERY selectivity
FULL_CFG = anns.SearchConfig(top_a=64, max_cell_size=1024, top_k=32,
                             rerank_overfetch=16)


def _oracle_ids(index, valid_rows, k):
    qn = np.asarray(pqmod.normalize(QS.astype(jnp.float32)))
    vecs = np.asarray(index.vectors, np.float32)
    out = []
    for i in range(qn.shape[0]):
        s = vecs @ qn[i]
        s[~valid_rows] = -np.inf
        out.append(np.asarray(index.ids)[np.argsort(-s)[:k]])
    return np.stack(out)


@pytest.mark.parametrize("selectivity", [0.01, 0.1, 0.5])
@pytest.mark.parametrize("use_kernel", ["jnp", "pallas"])
def test_masked_search_matches_numpy_oracle(index, selectivity, use_kernel):
    valid = np.asarray(index.ids) < int(3000 * selectivity)
    k = min(32, valid.sum())
    cfg = anns.SearchConfig(top_a=64, max_cell_size=1024, top_k=32,
                            rerank_overfetch=16, use_kernel=use_kernel)
    res = anns.search_batch(index, QS, cfg, jnp.asarray(valid))
    got = np.asarray(res["ids"])
    want = _oracle_ids(index, valid, k)
    np.testing.assert_array_equal(got[:, :k], want)
    # beyond the valid population: exactly-k padding, not garbage ids
    assert (got[:, k:] == -1).all()
    assert np.isneginf(np.asarray(res["scores"])[:, k:]).all()


def test_all_rows_filtered_returns_exactly_k_padding(index):
    res = anns.search_batch(index, QS, FULL_CFG,
                            jnp.zeros((index.n,), jnp.uint8))
    assert res["ids"].shape == (4, 32)
    assert (np.asarray(res["ids"]) == -1).all()
    assert (np.asarray(res["rows"]) == -1).all()
    assert np.isneginf(np.asarray(res["scores"])).all()


def test_windowed_path_mask_parity_single_vs_batch(index):
    cfg = anns.SearchConfig(top_a=4, max_cell_size=128, top_k=32)
    mask = jnp.asarray(np.asarray(index.ids) % 3 == 0)
    b = anns.search_batch(index, QS, cfg, mask)
    for i in range(QS.shape[0]):
        s = anns.search(index, QS[i], cfg, mask)
        np.testing.assert_array_equal(np.asarray(s["ids"]),
                                      np.asarray(b["ids"][i]))
    got = np.asarray(b["ids"])
    assert ((got % 3 == 0) | (got == -1)).all()


# ---------------------------------------------------------------------------
# plan algebra on synthetic posting lists (no encoders needed)
# ---------------------------------------------------------------------------
F, KP = 30, 4   # 3 videos x 10 key frames, 4 patches per frame


@pytest.fixture()
def meta():
    return P.PlanMeta(
        row_video=np.repeat(np.arange(3), 10 * KP).astype(np.int32),
        row_time=np.tile(np.repeat(np.arange(10), KP), 3).astype(np.int32),
        frame_video=np.repeat(np.arange(3), 10).astype(np.int32),
        frame_time=np.tile(np.arange(10), 3).astype(np.int32),
        patches_per_frame=KP)


def fake_search(texts, masks, k=20):
    """Deterministic per-text posting lists over patch ids 0..F*KP-1; row i
    of the index is patch id i, so masks apply directly."""
    ids = np.zeros((len(texts), k), np.int32)
    scores = np.zeros((len(texts), k), np.float32)
    for i, t in enumerate(texts):
        r = np.random.default_rng(sum(t.encode()) % 2**32)
        pid = r.choice(F * KP, size=k, replace=False).astype(np.int32)
        sc = (1.0 + r.random(k)).astype(np.float32)
        if masks is not None:
            ok = masks[i][pid]
            pid = np.where(ok, pid, -1)
            sc = np.where(ok, sc, -np.inf)
        o = np.argsort(-sc)
        ids[i], scores[i] = pid[o], sc[o]
    return ids, scores


def test_de_morgan_on_ids(meta):
    a, b = P.Text("red truck"), P.Text("pedestrian")
    lhs = P.execute(P.Not(P.Or(a, b)), meta, fake_search)
    rhs = P.execute(P.And(P.Not(a), P.Not(b)), meta, fake_search)
    np.testing.assert_array_equal(np.sort(lhs.frames), np.sort(rhs.frames))
    # and the second law
    lhs2 = P.execute(P.Not(P.And(a, b)), meta, fake_search)
    rhs2 = P.execute(P.Or(P.Not(a), P.Not(b)), meta, fake_search)
    np.testing.assert_array_equal(np.sort(lhs2.frames),
                                  np.sort(rhs2.frames))


def test_and_or_fusion_semantics(meta):
    a, b = P.Text("red truck"), P.Text("pedestrian")
    ra = P.execute(a, meta, fake_search)
    rb = P.execute(b, meta, fake_search)
    rand = P.execute(P.And(a, b), meta, fake_search)
    ror = P.execute(P.Or(a, b), meta, fake_search)
    np.testing.assert_array_equal(np.sort(rand.frames),
                                  np.intersect1d(ra.frames, rb.frames))
    np.testing.assert_array_equal(np.sort(ror.frames),
                                  np.union1d(ra.frames, rb.frames))
    sa = dict(zip(ra.frames.tolist(), ra.scores.tolist()))
    sb = dict(zip(rb.frames.tolist(), rb.scores.tolist()))
    for f, s in zip(rand.frames, rand.scores):   # And = min (weakest link)
        assert s == pytest.approx(min(sa[f], sb[f]))
    for f, s in zip(ror.frames, ror.scores):     # Or = max
        assert s == pytest.approx(max(sa.get(f, -np.inf),
                                      sb.get(f, -np.inf)))


def test_predicates_restrict_and_push_masks(meta):
    a = P.Text("red truck")
    res = P.execute(P.And(a, P.TimeRange(3, 7), P.VideoIn([0, 2])),
                    meta, fake_search)
    assert ((res.times >= 3) & (res.times < 7)).all()
    assert np.isin(res.videos, [0, 2]).all()
    # the compiled masks really are the conjunction of both predicates
    leaves = P.collect_leaves(P.And(a, P.TimeRange(3, 7), P.VideoIn([0, 2])))
    masks = P.compile_masks(leaves, meta)
    want = ((meta.row_time >= 3) & (meta.row_time < 7)
            & np.isin(meta.row_video, [0, 2]))
    np.testing.assert_array_equal(masks[0], want)


def test_empty_video_set_yields_empty_not_garbage(meta):
    res = P.execute(P.And(P.Text("red truck"), P.VideoIn([])),
                    meta, fake_search)
    assert len(res.frames) == 0


def test_group_topk_and_moments(meta):
    q = P.Or(P.Text("red truck"), P.Text("pedestrian"))
    g = P.execute(P.GroupTopK(q, per="video", k=2), meta, fake_search)
    for v in np.unique(g.videos):
        assert (g.videos == v).sum() <= 2
    m = P.execute(P.GroupTopK(q, per="video", mode="moment"),
                  meta, fake_search)
    mm = m.moments
    assert mm is not None and len(mm["video"]) == len(np.unique(mm["video"]))
    assert (mm["end"] >= mm["start"]).all()
    assert (mm["n_frames"] >= 1).all()
    # a moment's score is the summed frame scores of a contiguous run, so
    # it is >= the best single frame of its video in the child set
    child = P.execute(q, meta, fake_search)
    for i, v in enumerate(mm["video"]):
        best = child.scores[child.videos == v].max()
        assert mm["score"][i] >= best - 1e-6


def test_json_round_trip():
    node = P.GroupTopK(
        P.And(P.Text("x", weight=2.0), P.TimeRange(0, 5, video=1),
              P.Not(P.Or(P.Text("y"), P.VideoIn([1, 2])))),
        per="video", k=3, mode="moment", max_gap=2)
    assert P.from_json(P.to_json(node)) == node
    assert P.from_json('{"text": "a red square"}') == P.Text("a red square")


# ---------------------------------------------------------------------------
# grouped top-k stability across shard counts
# ---------------------------------------------------------------------------
def _frame_aligned_bounds(n_shards: int) -> np.ndarray:
    """Shard boundaries on whole-frame multiples: the decomposition
    contract (DESIGN.md §10.3) — every patch of a frame on ONE shard."""
    bounds = np.linspace(0, F, n_shards + 1).astype(int) * KP
    assert (bounds % KP == 0).all()
    return bounds


@pytest.mark.parametrize("n_shards", [1, 2, 3, 5])
@pytest.mark.parametrize("mode", ["frames", "moment"])
@pytest.mark.parametrize("root", ["or", "and"])
def test_grouped_results_stable_across_shard_counts(meta, n_shards, mode,
                                                    root):
    child = P.Or(P.Text("red truck"), P.Text("pedestrian")) \
        if root == "or" else \
        P.And(P.Text("red truck"), P.Text("pedestrian"))
    node = P.GroupTopK(child, per="video", k=2, mode=mode)
    full = P.execute(node, meta, fake_search)
    bounds = _frame_aligned_bounds(n_shards)
    shard_results = []
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]

        def shard_search(texts, masks, lo=lo, hi=hi):
            ids, sc = fake_search(texts, masks)
            ok = (ids >= lo) & (ids < hi)
            return np.where(ok, ids, -1), np.where(ok, sc, -np.inf)

        shard_results.append(P.execute(P.shard_plan(node), meta,
                                       shard_search))
    merged = P.merge_grouped(shard_results, node, meta)
    np.testing.assert_array_equal(merged.frames, full.frames)
    np.testing.assert_allclose(merged.scores, full.scores)
    if mode == "moment":
        for key in ("video", "start", "end", "n_frames"):
            np.testing.assert_array_equal(merged.moments[key],
                                          full.moments[key])
        np.testing.assert_allclose(merged.moments["score"],
                                   full.moments["score"], rtol=1e-6)


def test_shard_plan_refuses_not():
    """Per-shard complement is against the GLOBAL universe — Not-bearing
    plans must run unsharded (DESIGN.md §10.3)."""
    with pytest.raises(ValueError, match="unsharded"):
        P.shard_plan(P.GroupTopK(P.And(P.Text("a"), P.Not(P.Text("b"))),
                                 per="video"))


def test_call_sharded_raises_on_demoted_shard(meta):
    from repro.serving.router import QueryRouter, ReplicaUnavailable
    router = QueryRouter()
    router.add_replica("s0", lambda p: P.execute(p, meta, fake_search))
    router.add_replica("s1", lambda p: (_ for _ in ()).throw(
        RuntimeError("shard down")))
    node = P.Or(P.Text("red truck"), P.Text("pedestrian"))
    # the mid-call fault is re-raised, never merged around
    with pytest.raises(RuntimeError, match="shard down"):
        router.call_sharded(node, lambda outs: outs)
    for _ in range(3):   # demote s1 fully
        try:
            router.call_sharded(node, lambda outs: outs)
        except RuntimeError:
            pass
    # an already-demoted shard refuses the broadcast up front
    with pytest.raises(ReplicaUnavailable, match="s1"):
        router.call_sharded(node, lambda outs: outs)
    router.close()


def test_router_call_sharded_merges_plan_results(meta):
    from repro.serving.router import QueryRouter
    node = P.GroupTopK(P.Or(P.Text("red truck"), P.Text("pedestrian")),
                       per="video", k=2)
    full = P.execute(node, meta, fake_search)
    bounds = np.linspace(0, F * KP, 3).astype(int)
    router = QueryRouter()
    for s in range(2):
        lo, hi = bounds[s], bounds[s + 1]

        def shard_fn(payload, lo=lo, hi=hi):
            def shard_search(texts, masks):
                ids, sc = fake_search(texts, masks)
                ok = (ids >= lo) & (ids < hi)
                return np.where(ok, ids, -1), np.where(ok, sc, -np.inf)
            return P.execute(payload, meta, shard_search)

        router.add_replica(f"shard-{s}", shard_fn)
    merged = router.call_sharded(
        P.shard_plan(node), lambda outs: P.merge_grouped(outs, node, meta))
    np.testing.assert_array_equal(merged.frames, full.frames)
    router.close()


# ---------------------------------------------------------------------------
# engine integration: compound query end to end (index-only)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    from repro.launch.serve import build_engine
    eng, _ = build_engine(seed=0, n_videos=2, res=96)
    return eng


def test_engine_query_plan_end_to_end(engine):
    res = engine.query_plan(
        P.And(P.Text("a large red square"), P.TimeRange(0, 16)), top_n=5)
    assert len(res.frames) <= 5
    assert (res.times < 16).all()
    # JSON syntax answers identically
    res_j = engine.query_plan(
        '{"and": [{"text": "a large red square"}, '
        '{"time_range": [0, 16]}]}', top_n=5)
    np.testing.assert_array_equal(res.frames, res_j.frames)
    np.testing.assert_allclose(res.scores, res_j.scores)


def test_engine_plan_filter_beats_posthoc_on_recall(engine):
    """The over-fetch bug class the pushdown exists for: restrict to one
    video; the masked search must still fill its quota from that video,
    while post-hoc filtering of the unmasked top-k may keep fewer."""
    text = "a small blue circle"
    masked = engine.query_plan(P.And(P.Text(text), P.VideoIn([1])))
    ids, _, _ = engine.fast_search(text)
    kp = engine.built.patches_per_frame
    posthoc = np.unique(ids[ids >= 0] // kp)
    posthoc = posthoc[engine.built.keyframe_video[posthoc] == 1]
    assert (masked.videos == 1).all()
    assert len(masked.frames) >= len(posthoc)


def test_engine_moment_query(engine):
    res = engine.query_plan(P.GroupTopK(
        P.Or(P.Text("a large red square"), P.Text("a small blue circle")),
        per="video", mode="moment"))
    assert res.moments is not None
    assert (res.moments["end"] >= res.moments["start"]).all()


def test_plan_metadata_survives_store_round_trip(engine, tmp_path):
    """Filters must work on REOPENED indexes: the sidecar carries the
    video/frame metadata the planner compiles masks from."""
    from repro.core.index_builder import load_built, save_built
    save_built(tmp_path / "store", engine.built)
    reopened = load_built(tmp_path / "store")
    m0 = P.plan_meta_from_built(engine.built)
    m1 = P.plan_meta_from_built(reopened)
    np.testing.assert_array_equal(m0.row_video, m1.row_video)
    np.testing.assert_array_equal(m0.row_time, m1.row_time)
    np.testing.assert_array_equal(m0.frame_video, m1.frame_video)
    np.testing.assert_array_equal(m0.frame_time, m1.frame_time)
    assert m0.patches_per_frame == m1.patches_per_frame
    # and a predicate mask compiled on the reopened view is identical
    leaves = [(P.Text("x"), (P.TimeRange(0, 16), P.VideoIn([0])))]
    np.testing.assert_array_equal(P.compile_masks(leaves, m0),
                                  P.compile_masks(leaves, m1))
