"""PQ / IMI / ANNS correctness + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import anns, imi as imimod, pq as pqmod


def clustered(seed, n, d, k=20, noise=0.3):
    cents = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    a = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, k)
    x = cents[a] + noise * jax.random.normal(jax.random.PRNGKey(seed + 2),
                                             (n, d))
    return x, cents


def test_kmeans_reduces_distortion():
    x, _ = clustered(0, 2000, 16)
    c1, a1 = pqmod.kmeans(jax.random.PRNGKey(0), x, 16, iters=1)
    c2, a2 = pqmod.kmeans(jax.random.PRNGKey(0), x, 16, iters=15)
    d1 = float(jnp.sum((x - c1[a1]) ** 2))
    d2 = float(jnp.sum((x - c2[a2]) ** 2))
    assert d2 <= d1 * 1.0001


def test_pq_roundtrip_error_shrinks_with_M():
    x, _ = clustered(1, 3000, 32)
    x = pqmod.normalize(x)
    errs = []
    for M in (8, 64):
        pq = pqmod.train_pq(jax.random.PRNGKey(0), x, P=8, M=M, iters=10)
        codes = pqmod.pq_encode(pq, x)
        rec = pqmod.pq_decode(pq, codes)
        errs.append(float(jnp.mean(jnp.sum((x - rec) ** 2, -1))))
    assert errs[1] < errs[0]


def test_adc_equals_decode_dot():
    """ADC(lut, codes) == q . decode(codes) exactly (same centroids)."""
    x, cents = clustered(2, 500, 16)
    pq = pqmod.train_pq(jax.random.PRNGKey(0), x, P=4, M=16, iters=5)
    codes = pqmod.pq_encode(pq, x)
    q = pqmod.normalize(jax.random.normal(jax.random.PRNGKey(9), (16,)))
    lut = pqmod.similarity_lut(pq, q)
    s1 = pqmod.adc_scores(lut, codes)
    s2 = pqmod.pq_decode(pq, codes) @ q
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(1, 30), st.data())
def test_multi_sequence_top_a_exact(K, a, data):
    """Property: the frontier traversal == brute-force top-A of the outer
    sum, for any scores (modulo tie ordering)."""
    a = min(a, K * K)
    s1 = np.asarray(data.draw(st.lists(
        st.floats(-10, 10, allow_nan=False), min_size=K, max_size=K)),
        np.float32)
    s2 = np.asarray(data.draw(st.lists(
        st.floats(-10, 10, allow_nan=False), min_size=K, max_size=K)),
        np.float32)
    got = np.asarray(imimod.multi_sequence_top_a(
        jnp.asarray(s1), jnp.asarray(s2), a))
    outer = (s1[:, None] + s2[None, :]).reshape(-1)
    got_scores = np.sort(outer[got])[::-1]
    want_scores = np.sort(outer)[::-1][:a]
    np.testing.assert_allclose(got_scores, want_scores, rtol=1e-5, atol=1e-5)


def test_imi_build_invariants():
    x, _ = clustered(3, 4000, 32)
    index = imimod.build_imi(jax.random.PRNGKey(0), x, jnp.arange(4000),
                             K=8, P=8, M=32, kmeans_iters=5)
    off = np.asarray(index.cell_offsets)
    assert off[0] == 0 and off[-1] == 4000
    assert (np.diff(off) >= 0).all()
    cell = np.asarray(index.cell_of)
    assert (np.diff(cell) >= 0).all()  # cell-sorted
    # every row's cell matches its CSR bucket
    for c in np.unique(cell)[:10]:
        lo, hi = off[c], off[c + 1]
        assert (cell[lo:hi] == c).all()
    # stored vectors are unit-norm
    norms = np.linalg.norm(np.asarray(index.vectors, np.float32), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=2e-2)


def test_anns_recall_with_candidate_multiplier():
    """Fast search with 10x candidate multiplier + exact rerank reaches
    high recall vs brute force (the paper's retrieval protocol)."""
    x, cents = clustered(4, 20000, 64, k=50)
    index = imimod.build_imi(jax.random.PRNGKey(0), x, jnp.arange(20000),
                             K=16, P=8, M=64, kmeans_iters=8)
    hits, total = 0, 0
    for qi in range(5):
        q = cents[qi] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(100 + qi), (64,))
        bf = anns.brute_force(index, q, k=20)
        cfg = anns.SearchConfig(top_a=64, max_cell_size=2048, top_k=400)
        res = anns.search(index, q, cfg)
        got = set(np.asarray(res["ids"])[:400].tolist())
        want = np.asarray(bf["ids"]).tolist()
        hits += sum(1 for w in want if w in got)
        total += len(want)
    # clustered data has near-tied scores (ADC error ~ score gaps); the
    # paper's protocol retrieves a 10-20x candidate multiplier before rerank
    assert hits / total >= 0.85, hits / total


def test_exhaustive_adc_superset_of_cell_probe():
    """w/o-ANNS ablation scans everything: recall(exhaustive) >=
    recall(cell-probe) vs brute force on average."""
    x, cents = clustered(5, 8000, 32)
    index = imimod.build_imi(jax.random.PRNGKey(0), x, jnp.arange(8000),
                             K=8, P=8, M=32, kmeans_iters=5)
    q = cents[0]
    bf = set(np.asarray(anns.brute_force(index, q, k=50)["ids"]).tolist())
    ex = set(np.asarray(anns.exhaustive_adc(index, q, k=200)["ids"]).tolist())
    cp = set(np.asarray(anns.search(index, q, anns.SearchConfig(
        top_a=4, max_cell_size=256, top_k=200))["ids"]).tolist())
    assert len(ex & bf) >= len(cp & bf)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 7), st.integers(1, 6), st.data())
def test_patch_vote_majority(rows, P, data):
    ids = np.asarray(data.draw(st.lists(
        st.lists(st.integers(0, 5), min_size=P, max_size=P),
        min_size=rows, max_size=rows)), np.int32)
    got = np.asarray(anns.patch_vote(jnp.asarray(ids)))
    for r in range(rows):
        vals, counts = np.unique(ids[r], return_counts=True)
        assert counts[vals == got[r]][0] == counts.max()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(2, 8))
def test_normalize_unit_norm(n, d):
    x = jax.random.normal(jax.random.PRNGKey(n * d), (n, d)) * 10
    nx = pqmod.normalize(x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(nx), axis=-1),
                               1.0, atol=1e-5)
