import os
import pathlib

# smoke tests and benches must see the REAL device count (1 CPU device);
# only launch/dryrun.py forces 512 host devices.  Guard against leakage.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "dryrun XLA_FLAGS leaked into the test environment"

# pyproject's pythonpath=["src"] only patches sys.path of THIS process;
# subprocess-based tests (test_distributed) need the env var too so plain
# `pytest` works without an explicit PYTHONPATH=src.
_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in os.environ.get("PYTHONPATH", "").split(os.pathsep):
    os.environ["PYTHONPATH"] = _SRC + (
        os.pathsep + os.environ["PYTHONPATH"]
        if os.environ.get("PYTHONPATH") else "")

import jax

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# hypothesis fallback: the property tests only use a tiny slice of the API
# (given / settings / st.integers / st.floats / st.lists / st.data).  When
# the real package is unavailable (hermetic container), install a minimal
# deterministic stand-in so the property tests still run instead of erroring
# at collection.  With hypothesis installed this block is a no-op.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi, **kw):
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _lists(elem, *, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _sampled_from(xs):
        xs = list(xs)
        return _Strategy(lambda rng: xs[rng.randrange(len(xs))])

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy._draw(self._rng)

    _DATA = _Strategy(None)  # sentinel; materialized per-example in given()

    def _data():
        return _DATA

    def _given(*strategies):
        def deco(fn):
            def run():
                examples = getattr(run, "_max_examples", 10)
                for ex in range(examples):
                    rng = random.Random(0xC0FFEE + 7919 * ex)
                    drawn = [(_Data(rng) if s is _DATA else s._draw(rng))
                             for s in strategies]
                    fn(*drawn)
            # do NOT functools.wraps: pytest would introspect the wrapped
            # signature and demand fixtures for the property arguments
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco

    def _settings(max_examples=10, **kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.lists = _lists
    st_mod.sampled_from = _sampled_from
    st_mod.booleans = _booleans
    st_mod.data = _data
    stub.strategies = st_mod
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = st_mod
