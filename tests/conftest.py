import os

# smoke tests and benches must see the REAL device count (1 CPU device);
# only launch/dryrun.py forces 512 host devices.  Guard against leakage.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "dryrun XLA_FLAGS leaked into the test environment"

import jax

jax.config.update("jax_enable_x64", False)
