"""Static invariant checker (repro.analysis, DESIGN.md §14).

Every implemented rule is demonstrated against a seeded violation — a
fixture snippet (AST rules) or a deliberately-broken traced function
(jaxpr rules) — plus the matching negative: the correct idiom, or the
current tree, stays silent.  The last test runs the real CI gate
(``python -m tools.lint --strict``) on the working tree as a subprocess.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.analysis import (ast_checks, baseline as basemod, chaos_checks,
                            jaxpr_checks)
from repro.analysis.findings import (
    Finding,
    RULE_SUPPRESSION,
    apply_suppressions,
    scan_suppressions,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# KN1xx kernel purity
# ---------------------------------------------------------------------------
BAD_KERNEL = textwrap.dedent("""
    import numpy as np
    import jax.numpy as jnp

    def _bad_kernel(lut_ref, codes_ref, out_ref):
        if codes_ref[0, 0] > 0:                 # KN101: branch on a ref
            out_ref[...] = lut_ref[...]
        for c in codes_ref:                     # KN101: iterate a ref
            out_ref[0] += c
        x = np.take(lut_ref[...], 0)            # KN102: numpy in kernel
        y = out_ref[0].item()                   # KN103: host escape
        out_ref[...] = lut_ref[...].astype(jnp.float64)   # KN104
""")


def test_kernel_rules_fire_on_seeded_violations():
    got = rules_of(ast_checks.check_kernel_source(BAD_KERNEL, "fix.py"))
    assert got.count("KN101") == 2
    assert "KN102" in got and "KN103" in got and "KN104" in got


def test_static_python_branch_in_kernel_is_allowed():
    # `if has_bias:` on a static (non-ref) value is the repo's standard
    # kernel-specialization idiom and must not be flagged
    src = textwrap.dedent("""
        def _kernel(lut_ref, out_ref, *, has_bias):
            if has_bias:
                out_ref[...] = lut_ref[...] + 1.0
            else:
                out_ref[...] = lut_ref[...]
    """)
    assert ast_checks.check_kernel_source(src, "k.py") == []


def test_kernel_discovery_unwraps_partial_and_aliases():
    src = textwrap.dedent("""
        import functools
        import numpy as np

        def body(a_tile, o_tile):              # no *_ref naming on purpose
            o_tile[...] = np.abs(a_tile[...])  # KN102 once discovered

        def launch(x):
            kern = functools.partial(body, 3)
            return pl.pallas_call(kern, out_shape=x)(x)
    """)
    import ast as astmod
    assert "body" in ast_checks.kernel_body_names(astmod.parse(src))
    assert rules_of(ast_checks.check_kernel_source(src, "k.py")) == ["KN102"]


def test_current_kernel_tree_is_clean():
    for rel in sorted((REPO / "src/repro/kernels").glob("*.py")):
        src = rel.read_text(encoding="utf-8")
        assert ast_checks.check_kernel_source(src, rel.name) == [], rel


# ---------------------------------------------------------------------------
# RG301 registry cross-check
# ---------------------------------------------------------------------------
REF_SRC = "def pq_scan_topk_ref(l, c, k):\n    return l\n"


def test_registry_flags_unregistered_kernel():
    src = "def pq_scan_topk_foo(luts, codes, k):\n    return luts\n"
    got = ast_checks.check_registry(src, REF_SRC)
    assert rules_of(got) == ["RG301"]
    assert "no KERNEL_ORACLES entry" in got[0].message


def test_registry_flags_dangling_oracle_and_fallback():
    src = ("def pq_scan_topk_batched(luts, codes, k):\n    return luts\n")
    reg = {"pq_scan_topk_batched": ("missing_ref", "missing_jnp")}
    got = ast_checks.check_registry(src, REF_SRC, registry=reg)
    assert rules_of(got) == ["RG301", "RG301"]


def test_registry_passes_on_current_tree():
    pq = (REPO / "src/repro/kernels/pq_scan.py").read_text(encoding="utf-8")
    ref = (REPO / "src/repro/kernels/ref.py").read_text(encoding="utf-8")
    fb = {"repro.core.pq":
          (REPO / "src/repro/core/pq.py").read_text(encoding="utf-8")}
    assert ast_checks.check_registry(pq, ref, fallback_srcs=fb) == []


# ---------------------------------------------------------------------------
# DS2xx durability ordering
# ---------------------------------------------------------------------------
def test_unfsyncd_replace_fires_ds201_and_ds204():
    src = textwrap.dedent("""
        import json, os

        def save_state(tmp, path, state):
            with open(tmp, "w") as f:
                json.dump(state, f)
            os.replace(tmp, path)              # no flush/fsync, no dir sync
    """)
    got = rules_of(ast_checks.check_durability_source(src, "s.py",
                                                      ingest=False))
    assert got == ["DS201", "DS204"]


def test_correct_replace_chain_is_clean():
    src = textwrap.dedent("""
        import json, os

        def save_state(tmp, path, state):
            with open(tmp, "w") as f:
                json.dump(state, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            _fsync_dir(path.parent)
    """)
    assert ast_checks.check_durability_source(src, "s.py", ingest=False) == []


def test_unfsyncd_savez_fires_ds202():
    src = textwrap.dedent("""
        import numpy as np

        def write_codebooks(path, arrays):
            np.savez(path, **arrays)           # bytes may never hit disk
    """)
    got = rules_of(ast_checks.check_durability_source(src, "s.py",
                                                      ingest=False))
    assert got == ["DS202"]


def test_meta_log_after_wal_fires_ds203():
    src = textwrap.dedent("""
        class Ingest:
            def bad_chunk(self, chunk, rec):
                self.store.insert(chunk)       # WAL append first: wrong
                self._append_meta(rec)

            def good_chunk(self, chunk, rec):
                self._append_meta(rec)         # meta-log-then-WAL: right
                self.store.insert(chunk)
    """)
    got = ast_checks.check_durability_source(src, "p.py", ingest=True)
    assert rules_of(got) == ["DS203"]
    assert "bad_chunk" in got[0].message
    # the same source is NOT an ingest concern in store/ modules
    assert ast_checks.check_durability_source(src, "p.py",
                                              ingest=False) == []


def test_current_durability_tree_is_clean():
    findings, _ = ast_checks.run_ast_checks(REPO)
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# CH4xx failpoint / kill-harness cross-checks
# ---------------------------------------------------------------------------
CHAOS_REGISTRY_FIXTURE = textwrap.dedent("""
    SITES = (
        Site("store.thing.write", "durability", "repro.store.thing",
             ("raise", "crash"), "doc"),
        Site("rpc.thing.call", "rpc", "repro.thing", ("raise",), "doc"),
    )
""")


def test_ch401_flags_non_literal_and_unregistered_names():
    sites = chaos_checks.registry_sites(CHAOS_REGISTRY_FIXTURE)
    src = textwrap.dedent("""
        from repro import chaos

        def f(name):
            chaos.failpoint(name)               # computed: not checkable
            chaos.failpoint("no.such.site")     # unregistered
            chaos.failpoint("store.thing.write")
    """)
    got, called = chaos_checks.check_failpoint_source(src, "m.py", sites)
    assert rules_of(got) == ["CH401", "CH401"]
    assert "string literal" in got[0].message
    assert "no.such.site" in got[1].message
    assert called == {"store.thing.write"}


def test_ch402_flags_unexercised_site_stale_entry_and_wrong_kind():
    harness = 'EXERCISED_SITES = ["rpc.thing.call", "gone.site"]\n'
    got = chaos_checks.check_kill_coverage(CHAOS_REGISTRY_FIXTURE, harness)
    assert rules_of(got) == ["CH402", "CH402", "CH402"]
    assert "store.thing.write" in got[0].message      # durability, missing
    assert "not 'durability'" in got[1].message        # rpc in kill list
    assert "not a registered" in got[2].message        # gone.site, stale


def _chaos_mini_tree(tmp_path, *, call_rpc):
    (tmp_path / "src/repro/chaos").mkdir(parents=True, exist_ok=True)
    (tmp_path / "src/repro/chaos/registry.py").write_text(
        CHAOS_REGISTRY_FIXTURE)
    (tmp_path / "src/repro/chaos/harness.py").write_text(
        'EXERCISED_SITES = ["store.thing.write"]\n')
    body = ('from repro import chaos\n\n'
            'def f():\n    chaos.failpoint("store.thing.write")\n')
    if call_rpc:
        body += '    chaos.failpoint("rpc.thing.call")\n'
    (tmp_path / "src/repro/mod.py").write_text(body)


def test_ch401_flags_dead_registry_entry(tmp_path):
    # a site nobody calls is dead configuration; adding the call site
    # makes the mini tree fully clean
    _chaos_mini_tree(tmp_path, call_rpc=False)
    findings, _ = chaos_checks.run_chaos_checks(tmp_path)
    assert rules_of(findings) == ["CH401"]
    assert "rpc.thing.call" in findings[0].message
    _chaos_mini_tree(tmp_path, call_rpc=True)
    findings, _ = chaos_checks.run_chaos_checks(tmp_path)
    assert findings == [], [f.format() for f in findings]


def test_ch4_parsed_registry_matches_imported_catalog():
    from repro.chaos import registry as live
    parsed = chaos_checks.registry_sites(
        (REPO / chaos_checks.REGISTRY_REL).read_text(encoding="utf-8"))
    assert set(parsed) == set(live.site_names())
    assert {n for n, (_, k) in parsed.items() if k == "durability"} \
        == set(live.durability_sites())


def test_ch4_current_tree_is_clean():
    findings, _ = chaos_checks.run_chaos_checks(REPO)
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# JX00x jaxpr contract audits
# ---------------------------------------------------------------------------
G = dict(jaxpr_checks.CANON)


def test_jx001_fires_on_legacy_path_and_not_on_fused():
    # THE acceptance-criterion pair: SearchConfig.fused_topk=False's
    # scan-then-select materializes the (Q, N) score matrix and must be
    # flagged; the default fused path must trace clean.
    legacy = jaxpr_checks._entry_search_batch(False, True, False, "jnp")
    fn, args = legacy(G)
    j = jaxpr_checks.trace_jaxpr(fn, args)
    got = jaxpr_checks.check_qn_materialization(j, G["Q"], G["N"],
                                                "legacy", "anns.py")
    assert rules_of(got) == ["JX001"]
    assert "score matrix" in got[0].message

    fused = jaxpr_checks._entry_search_batch(True, True, False, "jnp")
    fn, args = fused(G)
    j = jaxpr_checks.trace_jaxpr(fn, args)
    assert jaxpr_checks.check_qn_materialization(
        j, G["Q"], G["N"], "fused", "anns.py") == []


def test_jx002_fires_on_f64_trace():
    import jax
    import jax.numpy as jnp

    def promote(x):
        return x.astype(jnp.float64) * 2.0

    with jax.experimental.enable_x64():
        j = jaxpr_checks.trace_jaxpr(
            promote, [jax.ShapeDtypeStruct((4,), np.float32)])
        got = jaxpr_checks.check_no_f64(j, "promote", "x.py")
    assert rules_of(got) == ["JX002"]


def test_jx003_fires_on_wrong_id_dtype():
    import jax.numpy as jnp

    def search_like(q):
        return {"ids": q.astype(jnp.float32), "scores": q}

    got = jaxpr_checks.check_id_dtype(
        search_like, [jaxpr_checks._sds((8,), np.float32)], ("ids",),
        "fake", "x.py")
    assert rules_of(got) == ["JX003"]
    # int32 ids pass
    ok = lambda q: {"ids": q.astype(jnp.int32)}
    assert jaxpr_checks.check_id_dtype(
        ok, [jaxpr_checks._sds((8,), np.float32)], ("ids",),
        "fake", "x.py") == []


def test_jx004_fires_on_debug_print():
    import jax

    def noisy(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2

    j = jaxpr_checks.trace_jaxpr(
        noisy, [jax.ShapeDtypeStruct((4,), np.float32)])
    got = jaxpr_checks.check_no_callbacks(j, "noisy", "x.py")
    assert rules_of(got) == ["JX004"]


def test_jx005_fires_on_shape_dependent_branch():
    import jax

    def leaky(x):            # Python branch on a trace-time shape value
        if x.shape[0] > 5:
            return x * 2.0
        return x + 1.0

    a = [jax.ShapeDtypeStruct((7,), np.float32)]
    b = [jax.ShapeDtypeStruct((5,), np.float32)]
    got = jaxpr_checks.check_retrace_stable(leaky, a, leaky, b,
                                            "leaky", "x.py")
    assert rules_of(got) == ["JX005"]
    stable = lambda x: x * 2.0
    assert jaxpr_checks.check_retrace_stable(stable, a, stable, b,
                                             "stable", "x.py") == []


def test_jaxpr_battery_clean_on_current_tree():
    findings = jaxpr_checks.run_jaxpr_checks()
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# suppressions + baseline
# ---------------------------------------------------------------------------
def test_suppression_drops_finding_but_bare_suppression_is_finding():
    src = ("import os\n"
           "os.replace('a', 'b')  # repro-lint: allow[DS201] test fixture\n"
           "os.rename('c', 'd')  # repro-lint: allow[DS204]\n")
    f1 = Finding("DS201", "f.py", 2, "error", "msg", snippet="x")
    kept, suppressed = apply_suppressions([f1], {"f.py": src})
    assert [f.rule for f in suppressed] == ["DS201"]
    assert [f.rule for f in kept] == [RULE_SUPPRESSION]   # line 3 is bare
    assert kept[0].line == 3


def test_suppression_scan_parses_rules_and_justification():
    sups = scan_suppressions(
        "x = 1  # repro-lint: allow[KN101, KN102] trace-time constant\n")
    assert sups[0].rules == ("KN101", "KN102")
    assert sups[0].justification == "trace-time constant"


def test_baseline_fingerprint_survives_line_shift(tmp_path):
    f_old = Finding("DS201", "s.py", 10, "error", "m",
                    snippet="os.replace(tmp, path)")
    path = tmp_path / "base.json"
    entries = basemod.save(path, [f_old])
    # same flagged line, different location/message formatting
    f_new = Finding("DS201", "s.py", 42, "error", "m2",
                    snippet="  os.replace(tmp,  path)")
    m = basemod.match([f_new], basemod.load(path))
    assert m.new == [] and m.accepted == [f_new]
    # entries carry the placeholder until a human justifies them
    assert entries[0].justification == basemod.PLACEHOLDER
    assert m.unjustified


def test_baseline_save_preserves_justifications_and_flags_stale(tmp_path):
    path = tmp_path / "base.json"
    f1 = Finding("KN102", "k.py", 3, "error", "m", snippet="np.take(x, 0)")
    basemod.save(path, [f1])
    entries = basemod.load(path)
    entries[0].justification = "trace-time constant fold, reviewed"
    basemod.save(path, [f1], previous=entries)
    kept = basemod.load(path)
    assert kept[0].justification == "trace-time constant fold, reviewed"
    m = basemod.match([], kept)          # finding fixed -> entry stale
    assert [e.fingerprint for e in m.stale] == [kept[0].fingerprint]
    assert not m.unjustified


# ---------------------------------------------------------------------------
# the real CI gate on the working tree
# ---------------------------------------------------------------------------
def test_tools_lint_strict_passes_on_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--strict"], cwd=REPO,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 new" in proc.stdout


def test_committed_baseline_is_current_version():
    data = json.loads((REPO / "tools/lint_baseline.json").read_text())
    assert data["version"] == basemod.VERSION
