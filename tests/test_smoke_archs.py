"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step on CPU; output shapes + finiteness asserted.
(The FULL configs are exercised via the dry-run only.)"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMArch, MoESpec, get_arch
from repro.train.optimizer import AdamConfig, adam_init
from repro.train.train_loop import make_train_step

LM_ARCHS = ["gemma2-9b", "llama3-405b", "qwen2-0.5b",
            "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b"]


def reduced_lm(name: str) -> LMArch:
    arch = get_arch(name)
    moe = arch.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=4, top_k=min(moe.top_k, 2),
                                  expert_ff=32,
                                  n_shared_experts=min(moe.n_shared_experts, 1))
    return dataclasses.replace(
        arch, n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=211, moe=moe,
        sliding_window=min(arch.sliding_window, 8) or 0,
        param_dtype="float32", attn_chunk=0)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_forward_and_train(name):
    from repro.models import transformer as T
    arch = reduced_lm(name)
    params, specs = T.init_lm(jax.random.PRNGKey(0), arch)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple) and
        all(e is None or isinstance(e, str) for e in x))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, arch.vocab)
    logits, aux = T.forward(params, toks, arch)
    assert logits.shape == (2, 12, arch.vocab)
    assert bool(jnp.isfinite(logits).all())

    step = make_train_step(
        lambda p, tokens, labels: T.lm_loss(p, tokens, labels, arch),
        AdamConfig(lr=1e-3))
    opt = adam_init(params, AdamConfig())
    batch = {"tokens": toks[None], "labels": jnp.roll(toks, -1, 1)[None]}
    p2, o2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) > 1.0  # random init ~ ln(211) + margin


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_prefill_decode(name):
    from repro.models import transformer as T
    arch = reduced_lm(name)
    params, _ = T.init_lm(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, arch.vocab)
    logits, cache = T.prefill(params, toks, arch)
    assert logits.shape == (2, arch.vocab)
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0))), cache)
    lg2, cache = T.decode_step(params, cache, toks[:, 0],
                               jnp.array([8, 8]), arch)
    assert lg2.shape == (2, arch.vocab)
    assert bool(jnp.isfinite(lg2).all())


def test_egnn_smoke():
    from repro.models import egnn as E
    cfg = E.EGNNConfig(n_layers=2, d_hidden=16, d_feat=8, n_classes=3)
    params, _ = E.init_egnn(jax.random.PRNGKey(0), cfg)
    n, e = 20, 40
    rng = np.random.default_rng(0)
    batch = {
        "node_feats": jnp.asarray(rng.normal(0, 1, (n, 8)), jnp.float32),
        "coords": jnp.asarray(rng.normal(0, 1, (n, 3)), jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32),
        "edge_mask": jnp.ones((e,), jnp.float32),
        "node_mask": jnp.ones((n,), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 3, (n,)), jnp.int32),
    }
    loss, aux = E.egnn_node_loss(params, cfg, batch)
    assert np.isfinite(float(loss)) and 0.0 <= float(aux["acc"]) <= 1.0

    step = make_train_step(lambda p, **b: E.egnn_node_loss(p, cfg, b),
                           AdamConfig(lr=1e-3))
    opt = adam_init(params, AdamConfig())
    b1 = jax.tree.map(lambda x: x[None], batch)
    p2, _, m = jax.jit(step)(params, opt, b1)
    assert np.isfinite(float(m["loss"]))


def test_egnn_equivariance():
    """E(n) property: rotation+translation of coords leaves logits invariant
    and transforms coordinates covariantly."""
    from repro.models import egnn as E
    cfg = E.EGNNConfig(n_layers=2, d_hidden=16, d_feat=8, n_classes=3)
    params, _ = E.init_egnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    n, e = 12, 30
    feats = jnp.asarray(rng.normal(0, 1, (n, 8)), jnp.float32)
    coords = jnp.asarray(rng.normal(0, 1, (n, 3)), jnp.float32)
    ei = jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32)
    em = jnp.ones((e,), jnp.float32)
    nm = jnp.ones((n,), jnp.float32)
    # random rotation (QR) + translation
    q, _ = np.linalg.qr(rng.normal(0, 1, (3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    t = rng.normal(0, 2, (3,))
    lo = E.egnn_forward(params, cfg, node_feats=feats, coords=coords,
                        edge_index=ei, edge_mask=em, node_mask=nm)
    lr = E.egnn_forward(params, cfg,
                        node_feats=feats,
                        coords=coords @ jnp.asarray(q, jnp.float32)
                        + jnp.asarray(t, jnp.float32),
                        edge_index=ei, edge_mask=em, node_mask=nm)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lr),
                               rtol=2e-4, atol=2e-4)


REC_ARCHS = ["dlrm-rm2", "xdeepfm", "mind", "bert4rec"]


def reduced_rec(name: str):
    arch = get_arch(name)
    return dataclasses.replace(
        arch, vocab_sizes=tuple(min(v, 97) for v in arch.vocab_sizes),
        seq_len=min(arch.seq_len, 16) or 0)


@pytest.mark.parametrize("name", REC_ARCHS)
def test_rec_train_step(name):
    from repro.data.pipeline import rec_batch_fn
    from repro.launch.steps import _rec_init, _rec_loss
    arch = reduced_rec(name)
    params, _ = _rec_init(arch)(jax.random.PRNGKey(0), arch)
    batch = rec_batch_fn(arch, batch=8, accum=1)(0, 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss_fn = _rec_loss(arch)
    loss, aux = loss_fn(params, **batch)
    assert np.isfinite(float(loss))

    step = make_train_step(loss_fn, AdamConfig(lr=1e-3))
    opt = adam_init(params, AdamConfig())
    b1 = jax.tree.map(lambda x: x[None], batch)
    p2, _, m = jax.jit(step)(params, opt, b1)
    assert np.isfinite(float(m["loss"]))


def test_rec_losses_fall():
    """The planted CTR rule is learnable: 30 steps cut the dlrm loss."""
    from repro.data.pipeline import DeterministicSource, rec_batch_fn
    from repro.launch.steps import _rec_init, _rec_loss
    arch = reduced_rec("dlrm-rm2")
    params, _ = _rec_init(arch)(jax.random.PRNGKey(0), arch)
    step = jax.jit(make_train_step(_rec_loss(arch), AdamConfig(lr=5e-3)))
    opt = adam_init(params, AdamConfig())
    src = DeterministicSource(rec_batch_fn(arch, batch=64, accum=1), seed=3)
    losses = []
    for i in range(30):
        batch = jax.tree.map(lambda x: jnp.asarray(x)[None], src(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_lovo_arch_registered():
    arch = get_arch("lovo")
    assert arch.pq_subspaces * (arch.embed_dim // arch.pq_subspaces) \
        == arch.embed_dim
    assert len(arch.shapes) == 4


def test_all_archs_listed():
    names = ["gemma2-9b", "llama3-405b", "qwen2-0.5b",
             "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b", "egnn",
             "xdeepfm", "mind", "dlrm-rm2", "bert4rec", "lovo"]
    for n in names:
        arch = get_arch(n)
        assert arch.shapes, n
