"""Elastic query router: load balancing, failure demotion, recovery,
scale-out."""
import threading

import pytest

from repro.serving.router import QueryRouter, ReplicaUnavailable


def test_routes_and_balances():
    counts = {"a": 0, "b": 0}
    r = QueryRouter()
    r.add_replica("a", lambda x: counts.__setitem__("a", counts["a"] + 1) or x)
    r.add_replica("b", lambda x: counts.__setitem__("b", counts["b"] + 1) or x)
    for i in range(200):
        assert r(i) == i
    assert counts["a"] > 40 and counts["b"] > 40  # both used


def test_failure_demotes_and_survives():
    r = QueryRouter(unhealthy_after=2)
    calls = {"bad": 0}

    def bad(x):
        calls["bad"] += 1
        raise RuntimeError("replica crash")

    r.add_replica("bad", bad)
    r.add_replica("good", lambda x: ("ok", x))
    outs = [r(i) for i in range(50)]
    assert all(o[0] == "ok" for o in outs)
    assert not r.stats()["bad"]["healthy"]
    assert calls["bad"] <= 3  # demoted after threshold, not hammered


def test_all_down_then_recovery():
    r = QueryRouter(unhealthy_after=1, recovery_probe_s=0.0)
    state = {"up": False}

    def flaky(x):
        if not state["up"]:
            raise RuntimeError("down")
        return x * 2

    r.add_replica("only", flaky)
    with pytest.raises(ReplicaUnavailable):
        r(1)
    # recovery: probe path retries the unhealthy replica once it's back
    state["up"] = True
    assert r(3) == 6
    assert r.stats()["only"]["healthy"]


def test_elastic_scale_out():
    r = QueryRouter()
    r.add_replica("r0", lambda x: "r0")
    assert r(0) == "r0"
    r.add_replica("r1", lambda x: "r1")
    seen = {r(i) for i in range(50)}
    assert seen == {"r0", "r1"}
    r.remove_replica("r0")
    assert all(r(i) == "r1" for i in range(5))


def test_concurrent_routing_consistent():
    r = QueryRouter()
    r.add_replica("a", lambda x: x + 1)
    r.add_replica("b", lambda x: x + 1)
    results = []

    def worker(base):
        for i in range(50):
            results.append(r(base + i) == base + i + 1)

    ts = [threading.Thread(target=worker, args=(k * 100,)) for k in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert all(results) and len(results) == 200
