"""Elastic query router: load balancing, failure demotion, recovery,
scale-out, circuit-breaker lifecycle (DESIGN.md §16.3)."""
import threading

import pytest

from _faulty import FaultyReplica
from repro.core.resilience import CircuitBreaker
from repro.serving.router import QueryRouter, ReplicaUnavailable


def test_routes_and_balances():
    counts = {"a": 0, "b": 0}
    r = QueryRouter()
    r.add_replica("a", lambda x: counts.__setitem__("a", counts["a"] + 1) or x)
    r.add_replica("b", lambda x: counts.__setitem__("b", counts["b"] + 1) or x)
    for i in range(200):
        assert r(i) == i
    assert counts["a"] > 40 and counts["b"] > 40  # both used


def test_failure_demotes_and_survives():
    r = QueryRouter(unhealthy_after=2)
    calls = {"bad": 0}

    def bad(x):
        calls["bad"] += 1
        raise RuntimeError("replica crash")

    r.add_replica("bad", bad)
    r.add_replica("good", lambda x: ("ok", x))
    outs = [r(i) for i in range(50)]
    assert all(o[0] == "ok" for o in outs)
    assert not r.stats()["bad"]["healthy"]
    assert calls["bad"] <= 3  # demoted after threshold, not hammered


def test_all_down_then_recovery():
    r = QueryRouter(unhealthy_after=1, recovery_probe_s=0.0)
    state = {"up": False}

    def flaky(x):
        if not state["up"]:
            raise RuntimeError("down")
        return x * 2

    r.add_replica("only", flaky)
    with pytest.raises(ReplicaUnavailable):
        r(1)
    # recovery: probe path retries the unhealthy replica once it's back
    state["up"] = True
    assert r(3) == 6
    assert r.stats()["only"]["healthy"]


def test_elastic_scale_out():
    r = QueryRouter()
    r.add_replica("r0", lambda x: "r0")
    assert r(0) == "r0"
    r.add_replica("r1", lambda x: "r1")
    seen = {r(i) for i in range(50)}
    assert seen == {"r0", "r1"}
    r.remove_replica("r0")
    assert all(r(i) == "r1" for i in range(5))


def test_breaker_lifecycle_open_halfopen_close():
    """Full breaker walk through the router, on a controllable clock:
    closed -> open (consecutive failures) -> refused inside the recovery
    window -> half-open probe fails -> re-trip -> probe succeeds ->
    closed.  States observed via ``stats()``."""
    r = QueryRouter(unhealthy_after=2, recovery_probe_s=30.0)
    state = {"up": False}
    calls = {"n": 0}

    def backend(x):
        calls["n"] += 1
        if not state["up"]:
            raise RuntimeError("down")
        return x + 1

    r.add_replica("solo", backend)
    t = {"now": 0.0}
    br = CircuitBreaker(failure_threshold=2, recovery_s=30.0,
                        clock=lambda: t["now"])
    r._replicas["solo"].breaker = br

    with pytest.raises(ReplicaUnavailable):
        r(0)                       # 2 attempts, 2 failures -> trips
    assert r.stats()["solo"]["state"] == "open"
    assert r.stats()["solo"]["opens"] == 1 and calls["n"] == 2

    # inside the recovery window: refused WITHOUT touching the backend
    t["now"] = 10.0
    with pytest.raises(ReplicaUnavailable):
        r(0)
    assert calls["n"] == 2

    # window elapsed, backend still down: one half-open probe, re-trip
    t["now"] = 31.0
    with pytest.raises(ReplicaUnavailable):
        r(0)
    assert calls["n"] == 3          # exactly one probe admitted
    assert r.stats()["solo"]["state"] == "open"
    assert r.stats()["solo"]["opens"] == 2

    # next window, backend recovered: probe succeeds, breaker closes
    t["now"] = 62.0
    state["up"] = True
    assert r(5) == 6
    assert r.stats()["solo"]["state"] == "closed"
    assert r.stats()["solo"]["failures"] == 0


def test_single_flapping_replica_recovers_via_probes():
    """A deterministically flapping backend (2 bad calls, 2 good calls,
    repeating): with an immediate recovery window the router's probe
    path re-admits it every good phase — service degrades in the bad
    windows and self-heals, with no operator intervention."""
    r = QueryRouter(unhealthy_after=1, recovery_probe_s=0.0)
    flapper = FaultyReplica(lambda x: x + 1, flap_period=2)
    r.add_replica("flap", flapper)
    got = []
    for i in range(9):
        try:
            got.append(r(i))
        except ReplicaUnavailable:
            got.append("down")
    # bad window -> down (after bounded attempts); good window -> served
    assert got == ["down", 2, 3, "down", 5, 6, "down", 8, 9]
    st = r.stats()["flap"]
    assert st["state"] == "closed"          # ends mid good-phase
    assert st["opens"] >= 3                 # tripped on every bad phase


def test_call_batch_reroutes_around_flapping_replica():
    """Batched scatter/gather with one flapping shard holder: the failed
    shard's items are re-routed per item to the good replica, the batch
    completes correctly, and the flapper is left demoted (open breaker),
    not hammered."""
    r = QueryRouter(unhealthy_after=1, recovery_probe_s=60.0)
    flapper = FaultyReplica(lambda x: x * 10, flap_period=2)
    r.add_replica("flap", flapper, batch_fn=flapper.batch_fn)
    r.add_replica("good", lambda x: x * 10,
                  batch_fn=lambda ps: [p * 10 for p in ps])
    out = r.call_batch(list(range(8)))
    assert out == [x * 10 for x in range(8)]
    assert r.stats()["flap"]["state"] == "open"
    assert flapper.calls == 1               # demoted on first fault

    # while open, batches flow through the remaining healthy replica
    out = r.call_batch(list(range(8, 12)))
    assert out == [x * 10 for x in range(8, 12)]
    assert flapper.calls == 1               # open breaker: never probed

    # operator heals it during a good phase: serves batches again
    r.mark_recovered("flap")
    assert r.stats()["flap"]["state"] == "closed"
    # flapper idx 1 is still in the bad phase; drain it via direct calls
    # until the good window, then both replicas share the load again
    out = r.call_batch(list(range(12, 20)))
    assert out == [x * 10 for x in range(12, 20)]


def test_concurrent_routing_consistent():
    r = QueryRouter()
    r.add_replica("a", lambda x: x + 1)
    r.add_replica("b", lambda x: x + 1)
    results = []

    def worker(base):
        for i in range(50):
            results.append(r(base + i) == base + i + 1)

    ts = [threading.Thread(target=worker, args=(k * 100,)) for k in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert all(results) and len(results) == 200
