"""Cost model calibration + binder + canonicalization unit tests.

Regression anchors the ISSUE pins: selectivity estimates within bounded
error of exact counts, bitmap pushdown always chosen below ~5% selectivity,
post-hoc filtering above ~50% (inside the exactness envelope), probe
tightening provably inert on results, and the legacy stage-2 rerank pool
default (``top_n * 4`` floored at ``rerank_batch``) now routed through
``SearchConfig.candidate_overfetch`` instead of a hardcoded constant.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import anns
from repro.core import optimizer as O
from repro.core import plan as P

V, FR, KP = 4, 32, 4
F, N = V * FR, V * FR * KP


def _meta():
    return P.PlanMeta(
        row_video=np.repeat(np.arange(V), FR * KP).astype(np.int32),
        row_time=np.tile(np.repeat(np.arange(FR), KP), V).astype(np.int32),
        frame_video=np.repeat(np.arange(V), FR).astype(np.int32),
        frame_time=np.tile(np.arange(FR), V).astype(np.int32),
        patches_per_frame=KP)


def _stats(meta=None):
    return O.PlanStats.from_meta(meta or _meta())


# -- selectivity calibration ------------------------------------------------
@pytest.mark.parametrize("pred", [
    P.TimeRange(0, 8),                 # 25% of every video
    P.TimeRange(0, 8, video=1),        # 25% of one video = 1/16 overall
    P.TimeRange(0, 0),                 # empty
    P.TimeRange(0, 10_000),            # all rows
    P.VideoIn([0, 2]),                 # half the videos
    P.VideoIn([]),                     # nothing
])
def test_selectivity_within_bounded_error(pred):
    meta, stats = _meta(), _stats()
    exact = P.predicate_row_mask(pred, meta).mean()
    got = stats.estimate_selectivity([pred])
    # one histogram bin of slack on each boundary (uniform data: exact)
    bin_frac = 1.0 / stats.time_counts.shape[1]
    assert abs(got - exact) <= 2 * bin_frac + 1e-9


def test_selectivity_conjunction_independence():
    meta, stats = _meta(), _stats()
    preds = [P.TimeRange(0, 16), P.VideoIn([0, 1])]
    exact = (P.predicate_row_mask(preds[0], meta)
             & P.predicate_row_mask(preds[1], meta)).mean()
    got = stats.estimate_selectivity(preds)
    assert got == pytest.approx(exact, abs=0.05)


def test_stats_npz_round_trip(tmp_path):
    stats = _stats()
    path = tmp_path / "stats.npz"
    np.savez(path, **stats.to_arrays())
    with np.load(path) as z:
        back = O.PlanStats.from_arrays(dict(z))
    assert back.n_rows == stats.n_rows
    np.testing.assert_array_equal(back.video_rows, stats.video_rows)
    np.testing.assert_array_equal(back.time_counts, stats.time_counts)
    p = [P.TimeRange(3, 19, video=2)]
    assert back.estimate_rows(p) == stats.estimate_rows(p)


# -- pushdown / post-filter crossover (regression anchors) ------------------
def test_pushdown_below_5pct_postfilter_above_50pct():
    cost = O.CostModel()
    for sel in (0.0, 0.01, 0.049):
        assert cost.choose_pushdown(sel, exact_envelope=True)
    for sel in (0.50, 0.7, 1.0):
        assert not cost.choose_pushdown(sel, exact_envelope=True)


def test_postfilter_never_chosen_outside_envelope():
    cost = O.CostModel()
    for sel in (0.0, 0.5, 1.0):
        assert cost.choose_pushdown(sel, exact_envelope=False)


def test_envelope_requires_full_coverage():
    stats = _stats()
    stats.n_cells, stats.max_cell_rows = 16, 40
    good = anns.SearchConfig(top_a=16, max_cell_size=64, top_k=64,
                             rerank_overfetch=N // 64 + 1)
    assert O.exact_envelope(good, stats)
    assert not O.exact_envelope(
        dataclasses.replace(good, top_a=8), stats)            # cells missed
    assert not O.exact_envelope(
        dataclasses.replace(good, max_cell_size=32), stats)   # window short
    assert not O.exact_envelope(
        dataclasses.replace(good, rerank_overfetch=1), stats)  # fetch short
    assert not O.exact_envelope(
        dataclasses.replace(good, exact_rerank=False), stats)
    assert not O.exact_envelope(good, None)


def test_optimize_leaf_choices_follow_selectivity():
    meta, stats = _meta(), _stats()
    stats.n_cells, stats.max_cell_rows = 16, 40
    cfg = anns.SearchConfig(top_a=16, max_cell_size=64, top_k=64,
                            rerank_overfetch=N // 64 + 1)
    node = P.Or(
        P.And(P.Text("rare"), P.TimeRange(0, 1, video=0)),     # ~0.1% sel
        P.And(P.Text("common"), P.TimeRange(0, 31)))           # ~97% sel
    phys = O.optimize(node, meta, stats, cfg=cfg)
    by_text = {leaf.query: phys.post_filter[i]
               for i, (leaf, _) in enumerate(phys.leaves)}
    assert by_text["rare"] is False            # pushdown
    assert by_text["common"] is True           # post-filter
    # and the guaranteed overfetch covers top_k + every invalid row
    i = next(i for i, (l, _) in enumerate(phys.leaves)
             if l.query == "common")
    invalid = N - (P.predicate_row_mask(P.TimeRange(0, 31), meta)).sum()
    assert phys.post_k[i] >= cfg.top_k + invalid


# -- probe tightening -------------------------------------------------------
def test_tighten_probe_clamps_only_when_inert():
    cfg = anns.SearchConfig(top_a=64, max_cell_size=1024, top_k=32,
                            rerank_overfetch=16)
    t = anns.tighten_probe(cfg, n=480, n_cells=16, max_cell_rows=40)
    assert (t.top_a, t.max_cell_size) == (16, 40)
    # fetch_k unchanged: still covers min(top_k * overfetch, pool)
    assert min(t.top_k * t.rerank_overfetch, t.top_a * t.max_cell_size) \
        == min(cfg.top_k * cfg.rerank_overfetch, 512)
    # refuses a clamp that would flip the shared->paired kernel branch
    same = anns.tighten_probe(cfg, n=630, n_cells=16, max_cell_rows=39)
    assert same == cfg
    # refuses a clamp that would shrink the refine pool below fetch_k
    same2 = anns.tighten_probe(
        dataclasses.replace(cfg, rerank_overfetch=1024),
        n=480, n_cells=16, max_cell_rows=20)
    assert same2 == cfg or same2.top_a * same2.max_cell_size >= 480


def test_tighten_probe_identical_results_on_real_index():
    import jax
    import jax.numpy as jnp
    from repro.core import imi

    x = jax.random.normal(jax.random.PRNGKey(0), (480, 32))
    index = imi.build_imi(jax.random.PRNGKey(1), x,
                          jnp.arange(480, dtype=jnp.int32),
                          K=4, P=4, M=16, kmeans_iters=3)
    counts = np.diff(np.asarray(index.cell_offsets))
    cfg = anns.SearchConfig(top_a=16, max_cell_size=512, top_k=24,
                            rerank_overfetch=20)
    tight = anns.tighten_probe(cfg, n=480, n_cells=len(counts),
                               max_cell_rows=int(counts.max()))
    assert tight != cfg
    qs = jax.random.normal(jax.random.PRNGKey(2), (3, 32))
    a = anns.search_batch(index, qs, cfg)
    b = anns.search_batch(index, qs, tight)
    np.testing.assert_array_equal(np.asarray(a["ids"]),
                                  np.asarray(b["ids"]))


# -- adaptive rerank depth --------------------------------------------------
def test_rerank_depth_margin_behavior():
    cost = O.CostModel()
    scores = np.r_[np.linspace(1.0, 0.9, 5), np.linspace(0.3, 0.2, 20)]
    # wide boundary gap: everything below top_n is outside the margin
    assert cost.rerank_depth(scores, 5, full_depth=25, margin=0.05) == 5
    # margin wide enough to reach into the tail keeps part of it
    d = cost.rerank_depth(scores, 5, full_depth=25, margin=0.65)
    assert 5 < d <= 25
    # no measured margin -> no early exit
    assert cost.rerank_depth(scores, 5, full_depth=25, margin=0.0) == 25
    # fewer scores than top_n -> full depth (nothing to separate)
    assert cost.rerank_depth(scores[:3], 5, full_depth=25, margin=0.1) == 25


def test_measured_margin_is_positive_on_real_index():
    import jax
    import jax.numpy as jnp
    from repro.core import imi

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    index = imi.build_imi(jax.random.PRNGKey(1), x,
                          jnp.arange(256, dtype=jnp.int32),
                          K=4, P=4, M=8, kmeans_iters=3)
    m = O.measure_score_margin(index)
    assert m > 0.0
    assert m == O.measure_score_margin(index)      # deterministic


def test_choose_fanout_small_index_stays_single_replica():
    cost = O.CostModel()
    assert cost.choose_fanout(10_000, 4) == 1      # merge overhead dominates
    assert cost.choose_fanout(10_000_000, 4) == 4
    assert cost.choose_fanout(10_000_000, 1) == 1


# -- binder / catalog -------------------------------------------------------
def _catalog():
    return O.Catalog.from_meta(
        _meta(), video_names={"lobby": 0, "garage": 1},
        labels={"truck": "a red truck"})


def test_bind_resolves_names_and_labels():
    node = O.bind({"and": [{"label": "truck"},
                           {"videos": ["lobby", "garage"]},
                           {"time_range": {"lo": 0, "hi": 8,
                                           "video": "garage"}}]},
                  _catalog())
    leaves = P.collect_leaves(node)
    assert leaves[0][0].query == "a red truck"
    kinds = {type(p) for p in leaves[0][1]}
    assert kinds == {P.VideoIn, P.TimeRange}
    vi = next(p for p in leaves[0][1] if isinstance(p, P.VideoIn))
    assert tuple(vi.videos) == (0, 1)


@pytest.mark.parametrize("bad", [
    {"videos": ["rooftop"]},                       # unknown camera name
    {"videos": [99]},                              # id out of range
    {"label": "llama"},                            # unknown class label
    {"time_range": {"lo": 0, "hi": 8, "video": "rooftop"}},
    {"frobnicate": 1},                             # unknown node kind
    {"time_range": {"lo": "a"}},                   # malformed payload
    "not json {",                                  # unparseable string
])
def test_bind_errors_fail_at_bind_time(bad):
    with pytest.raises(O.BindError):
        O.bind(bad, _catalog())


def test_bind_validates_parsed_trees_too():
    with pytest.raises(O.BindError):
        O.bind(P.And(P.Text("x"), P.VideoIn([99])), _catalog())


# -- canonicalization + fingerprints ----------------------------------------
def test_fingerprint_invariant_to_child_order_and_duplicates():
    a, b = P.Text("red truck"), P.Text("pedestrian")
    f1 = P.plan_fingerprint(P.And(a, b))
    assert f1 == P.plan_fingerprint(P.And(b, a))
    assert f1 == P.plan_fingerprint(P.And(a, b, a))
    assert f1 != P.plan_fingerprint(P.Or(a, b))
    assert f1 != P.plan_fingerprint(P.And(a, P.Text("blue car")))


def test_canonicalize_merges_and_predicates():
    node = P.And(P.Text("x"), P.TimeRange(2, 20), P.TimeRange(5, 30),
                 P.VideoIn([0, 1, 2]), P.VideoIn([1, 2, 3]))
    c = P.canonicalize(node)
    preds = [n for n in c.children if not isinstance(n, P.Text)]
    assert {type(p) for p in preds} == {P.TimeRange, P.VideoIn}
    tr = next(p for p in preds if isinstance(p, P.TimeRange))
    vi = next(p for p in preds if isinstance(p, P.VideoIn))
    assert (tr.lo, tr.hi) == (5, 20)
    assert tuple(vi.videos) == (1, 2)
    # distinct pinned videos can never both hold -> empty range
    c2 = P.canonicalize(P.And(P.Text("x"), P.TimeRange(0, 9, video=0),
                              P.TimeRange(0, 9, video=1)))
    tr2 = next(p for p in c2.children if isinstance(p, P.TimeRange))
    assert tr2.lo >= tr2.hi


def test_canonicalize_flatten_respects_pushdown_scoping():
    """An inner And that carries its own predicates must NOT be flattened:
    collect_leaves scopes direct-child predicates to the leaves under that
    And, and hoisting them would widen the masked sets."""
    inner = P.And(P.Text("a"), P.TimeRange(0, 4))
    outer = P.canonicalize(P.And(inner, P.Text("b")))
    assert any(isinstance(ch, P.And) for ch in outer.children)
    # predicate-free inner Ands DO flatten
    flat = P.canonicalize(P.And(P.And(P.Text("a"), P.Text("b")),
                                P.Text("c")))
    assert not any(isinstance(ch, P.And) for ch in flat.children)
    assert len(flat.children) == 3


def test_canonicalize_double_not_only_for_score_free():
    scored = P.Not(P.Not(P.Text("a")))
    assert isinstance(P.canonicalize(scored), P.Not)    # scores differ
    free = P.Not(P.Not(P.VideoIn([1, 0])))
    assert isinstance(P.canonicalize(free), P.VideoIn)  # sets identical


def test_canonicalize_singleton_unwrap_guards_moments():
    g = P.GroupTopK(P.Text("a"), per="video", mode="moment")
    assert isinstance(P.canonicalize(P.And(g)), P.And)  # moments stay inner
    assert isinstance(P.canonicalize(P.And(P.Text("a"))), P.Text)


# -- legacy rerank pool default now routed through SearchConfig -------------
def test_candidate_overfetch_default_pins_legacy_behavior():
    assert anns.SearchConfig().candidate_overfetch == 4


def test_engine_candidate_pool_uses_config(monkeypatch):
    """QueryEngine._candidate_frames must derive its pool from
    ``search_cfg.candidate_overfetch`` (was: hardcoded ``top_n * 4``)."""
    from repro.core.query import QueryEngine

    eng = QueryEngine.__new__(QueryEngine)      # no heavy init needed
    eng.search_cfg = anns.SearchConfig(candidate_overfetch=4)
    eng.rerank_batch = 8

    class _B:                                   # minimal built stand-in
        patches_per_frame = 1
    eng.built = _B()

    ids = np.arange(64, dtype=np.int64)
    scores = np.linspace(1.0, 0.0, 64, dtype=np.float32)
    cand, _ = eng._candidate_frames(ids, scores, top_n=5)
    assert len(cand) == 20                      # top_n * candidate_overfetch
    eng.search_cfg = anns.SearchConfig(candidate_overfetch=8)
    cand, _ = eng._candidate_frames(ids, scores, top_n=5)
    assert len(cand) == 40
    # explicit depth (the adaptive-rerank path) overrides the config pool
    cand, _ = eng._candidate_frames(ids, scores, top_n=5, depth=11)
    assert len(cand) == 11
