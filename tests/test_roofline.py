"""Roofline machinery: HLO collective parser, term math, probe extrapolation."""
import numpy as np
import pytest

from repro.launch import roofline as RL

HLO = """
ENTRY %main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ag = bf16[256,1024]{1,0} all-gather(%p0), replica_groups=[16,16], dimensions={0}
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,512]{1,0} reduce-scatter(%y), replica_groups=[4,4], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = f32[32,64]{1,0} all-to-all(%w), replica_groups={{0,1,2,3,4,5,6,7}}
  %ags = bf16[512,4]{1,0} all-gather-start(%q), replica_groups=[8,8]
  %agd = bf16[512,4]{1,0} all-gather-done(%ags)
}
"""


def test_collective_parser_counts_and_bytes():
    st = RL.collective_bytes(HLO, world=16)
    # 6 collectives (done-op not double counted)
    assert st.count == 6
    assert set(st.by_op) == {"all-gather", "all-reduce", "reduce-scatter",
                             "collective-permute", "all-to-all"}
    # all-reduce: 1024*512*4 bytes, g=4 -> wire 2*b*(3/4)
    ar_bytes = 1024 * 512 * 4
    assert abs(st.by_op["all-reduce"][1] - 2 * ar_bytes * 3 / 4) < 1
    # permute: exactly payload
    assert st.by_op["collective-permute"][1] == 8 * 128 * 2


def test_group_size_formats():
    assert RL._group_size("replica_groups={{0,1,2}}", 99) == 3
    assert RL._group_size("replica_groups=[8,64]", 99) == 64
    assert RL._group_size("no groups here", 7) == 7


def test_shape_bytes_dtypes():
    assert RL._shape_bytes("bf16[2,3]") == 12
    assert RL._shape_bytes("f32[10]") == 40
    assert RL._shape_bytes("(f32[2], bf16[4])") == 16
    assert RL._shape_bytes("s8[5,5]") == 25
    assert RL._shape_bytes("tuple()") == 0


def test_roofline_terms_and_bottleneck():
    r = RL.Roofline(arch="a", shape="s", mesh="16x16", chips=256,
                    hlo_flops=197e12, hlo_bytes=819e9 * 2,
                    coll_wire_bytes=50e9 * 0.5, coll_operand_bytes=0,
                    model_flops=197e12 * 256 * 0.5,
                    per_device_peak_bytes=10 ** 9)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.25) < 1e-9


def test_fmt_seconds():
    assert RL.fmt_seconds(0) == "0"
    assert RL.fmt_seconds(5e-7) == "0.5us"
    assert RL.fmt_seconds(2e-3) == "2.00ms"
    assert RL.fmt_seconds(3.5) == "3.500s"


def test_probe_extrapolation_math(monkeypatch):
    """C(L, A) reconstruction from 4 probes: linear ground truth recovers
    exactly; clamping activates on decreasing series."""
    from repro.launch import probes as P

    # ground truth: per-layer a=10, per-accum base b=5, accum-layer slope 2
    def fake_measure(arch, spec, mesh):
        L = arch.n_layers
        A = getattr(spec, "grad_accum", 1)
        val = A * (10.0 * L + 5.0) + 3.0
        return {m: val for m in P.METRICS}

    class FakeArch:
        n_layers = 24
        local_global_pattern = False

        def __init__(self, L=None):
            if L:
                self.n_layers = L

    import dataclasses as dc
    from repro.configs.base import get_arch, merged_rules
    arch = get_arch("qwen2-0.5b")
    spec = next(s for s in arch.shapes if s.name == "train_4k")
    monkeypatch.setattr(P, "_measure", fake_measure)

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    out = P.probe_corrected_costs(arch, spec, FakeMesh(), verbose=False)
    A = spec.grad_accum  # 4 (divisible: 256/4 % 16 == 0)
    want = A * (10.0 * arch.n_layers + 5.0) + 3.0
    assert abs(out["flops"] - want) < 1e-6, (out["flops"], want)
