"""Batched query pipeline: search_batch/query_batch parity with the
per-query path, static-shape tail padding, and embedding-cache semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anns, imi
from repro.core.query import EmbedCache


@pytest.fixture(scope="module")
def index():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (3000, 64))
    ids = jnp.arange(3000, dtype=jnp.int32)
    return imi.build_imi(jax.random.PRNGKey(1), x, ids,
                         K=8, P=8, M=32, kmeans_iters=5)


@pytest.fixture(scope="module")
def engine():
    from repro.launch.serve import build_engine
    eng, _ = build_engine(seed=0, n_videos=2, res=96)
    return eng


QS = jax.random.normal(jax.random.PRNGKey(7), (5, 64))


@pytest.mark.parametrize("cfg", [
    # windows cover the index -> shared scan-all-rows ADC path
    anns.SearchConfig(top_a=8, max_cell_size=1024, top_k=32),
    # windows smaller than the index -> per-query windowed gather path
    anns.SearchConfig(top_a=4, max_cell_size=128, top_k=32),
    # no exact refine: approx scores returned directly
    anns.SearchConfig(top_a=8, max_cell_size=512, top_k=32,
                      exact_rerank=False),
], ids=["scan_all", "windowed", "no_refine"])
def test_search_batch_matches_sequential(index, cfg):
    batched = anns.search_batch(index, QS, cfg)
    for i in range(QS.shape[0]):
        single = anns.search(index, QS[i], cfg)
        np.testing.assert_array_equal(np.asarray(single["ids"]),
                                      np.asarray(batched["ids"][i]))
        np.testing.assert_array_equal(np.asarray(single["rows"]),
                                      np.asarray(batched["rows"][i]))
        np.testing.assert_allclose(np.asarray(single["scores"]),
                                   np.asarray(batched["scores"][i]),
                                   rtol=1e-4, atol=1e-4)


def test_search_batch_pallas_kernel_matches_jnp(index):
    cfg_j = anns.SearchConfig(top_a=8, max_cell_size=512, top_k=32)
    cfg_p = anns.SearchConfig(top_a=8, max_cell_size=512, top_k=32,
                              use_kernel="pallas")
    rj = anns.search_batch(index, QS, cfg_j)
    rp = anns.search_batch(index, QS, cfg_p)
    # exact refine re-scores against stored vectors, so ids survive the
    # kernel's bf16 LUT quantization
    np.testing.assert_array_equal(np.asarray(rj["ids"]),
                                  np.asarray(rp["ids"]))
    np.testing.assert_allclose(np.asarray(rj["scores"]),
                               np.asarray(rp["scores"]), rtol=1e-3, atol=1e-3)


def test_pq_scan_paired_matches_oracle():
    from repro.core import pq as pqmod
    from repro.kernels import ops
    luts = jax.random.normal(jax.random.PRNGKey(3), (3, 8, 32))
    codes = jax.random.randint(jax.random.PRNGKey(4), (3, 700, 8),
                               0, 32).astype(jnp.uint8)
    want = jax.vmap(pqmod.adc_scores)(luts, codes)
    got = ops.pq_scan_paired(luts, codes, block_n=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2 * np.sqrt(8))


# -- engine level -------------------------------------------------------------
def test_fast_search_batch_matches_single_incl_padded_tail(engine):
    texts = [f"a large red square number {i}" for i in range(5)]
    engine.query_batch_size = 4          # Q=5 -> one full chunk + padded tail
    ids_b, scores_b, _ = engine.fast_search_batch(texts)
    assert ids_b.shape[0] == 5
    for i, t in enumerate(texts):
        ids_s, scores_s, _ = engine.fast_search(t)
        np.testing.assert_array_equal(ids_s, ids_b[i])
        np.testing.assert_allclose(scores_s, scores_b[i],
                                   rtol=1e-4, atol=1e-4)


def test_query_batch_matches_single_query(engine):
    texts = ["a large red square", "a small blue circle"]
    batched = engine.query_batch(texts, top_n=3)
    for t, rb in zip(texts, batched):
        rs = engine.query(t, top_n=3)
        np.testing.assert_array_equal(rs.frames, rb.frames)
        np.testing.assert_allclose(rs.scores, rb.scores,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(rs.boxes, rb.boxes, rtol=1e-4, atol=1e-4)


def test_query_batch_no_rerank(engine):
    rs = engine.query_batch(["a green triangle", "a black bar"],
                            top_n=2, use_rerank=False)
    assert len(rs) == 2
    for r in rs:
        assert "rerank" not in r.timings
        assert len(r.frames) <= 2


def test_embed_cache_hit_semantics(engine):
    text = "a purple triangle cache probe"    # unique to this test
    m0 = engine.embed_cache.misses
    r1 = engine.query(text, top_n=2, use_rerank=False)
    assert engine.embed_cache.misses > m0
    h1 = engine.embed_cache.hits
    r2 = engine.query(text, top_n=2, use_rerank=False)
    assert engine.embed_cache.hits > h1          # second call hits
    np.testing.assert_array_equal(r1.frames, r2.frames)
    np.testing.assert_allclose(r1.scores, r2.scores)


def test_embed_cache_lru_eviction():
    c = EmbedCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1                        # refresh 'a'
    c.put("c", 3)                                 # evicts 'b' (LRU)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2
