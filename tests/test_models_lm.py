"""LM model invariants: decode==forward consistency, chunked==full
attention, MoE dispatch properties, window schedule."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import LMArch, MoESpec
from repro.models import layers as L
from repro.models import moe as M
from repro.models import transformer as T

TINY = LMArch(name="tiny", n_layers=3, d_model=48, n_heads=4, n_kv_heads=2,
              head_dim=12, d_ff=96, vocab=97, param_dtype="float32",
              attn_chunk=0)


def test_decode_matches_forward():
    """prefill + decode_step must reproduce full-forward logits exactly
    (the KV-cache path is equivalent to recomputation)."""
    arch = TINY
    params, _ = T.init_lm(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, arch.vocab)
    # full forward logits at the last position of toks[:, :8] given 9 tokens
    full_logits, _ = T.forward(params, toks, arch)
    _, cache = T.prefill(params, toks[:, :8], arch)
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))), cache)
    dec_logits, _ = T.decode_step(params, cache, toks[:, 8],
                                  jnp.array([8, 8]), arch)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, 8]),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_windowed_softcap():
    arch = dataclasses.replace(TINY, sliding_window=4,
                               local_global_pattern=True,
                               attn_softcap=20.0, final_softcap=10.0,
                               post_norms=True)
    params, _ = T.init_lm(jax.random.PRNGKey(2), arch)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 9), 0, arch.vocab)
    full_logits, _ = T.forward(params, toks, arch)
    _, cache = T.prefill(params, toks[:, :8], arch)
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))), cache)
    dec_logits, _ = T.decode_step(params, cache, toks[:, 8],
                                  jnp.array([8, 8]), arch)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, 8]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [None, 5])
def test_chunked_attention_equals_full(window):
    cfg = L.AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16)
    b = L.ParamBuilder(jax.random.PRNGKey(0), "float32")
    L.init_attention(b, "a", 32, cfg)
    p = b.build()[0]["a"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 32))
    pos = jnp.broadcast_to(jnp.arange(37)[None], (2, 37))
    full, _ = L.attention(p, x, cfg, positions=pos, window=window)
    for unroll in (False, True):
        chunked, _ = L.attention_chunked(p, x, cfg, positions=pos,
                                         window=window, chunk=8,
                                         remat_chunk=True, unroll=unroll)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)


def test_chunked_attention_grads_match():
    cfg = L.AttnConfig(n_heads=2, n_kv_heads=2, head_dim=8)
    b = L.ParamBuilder(jax.random.PRNGKey(0), "float32")
    L.init_attention(b, "a", 16, cfg)
    p = b.build()[0]["a"]
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))

    def loss_full(p):
        return jnp.sum(L.attention(p, x, cfg, positions=pos)[0] ** 2)

    def loss_chunk(p):
        return jnp.sum(L.attention_chunked(p, x, cfg, positions=pos,
                                           chunk=4, remat_chunk=True)[0] ** 2)

    g1 = jax.grad(loss_full)(p)
    g2 = jax.grad(loss_chunk)(p)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4), g1, g2)


def test_window_schedule_patterns():
    g = dataclasses.replace(TINY, n_layers=6, sliding_window=4,
                            local_global_pattern=True)
    ws = T.window_schedule(g)
    assert ws.tolist() == [4, 0, 4, 0, 4, 0]
    u = dataclasses.replace(TINY, n_layers=3, sliding_window=7)
    assert T.window_schedule(u).tolist() == [7, 7, 7]
    f = dataclasses.replace(TINY, n_layers=2)
    assert T.window_schedule(f).tolist() == [0, 0]


def test_scan_vs_unrolled_layers_identical():
    arch = TINY
    params, _ = T.init_lm(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, arch.vocab)
    l1, _ = T.forward(params, toks, arch)
    l2, _ = T.forward(params, toks,
                      dataclasses.replace(arch, scan_layers=False))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch properties
# ---------------------------------------------------------------------------
def _moe(E=8, k=2, ff=32, shared=0, cf=8.0):
    return MoESpec(n_experts=E, top_k=k, expert_ff=ff,
                   n_shared_experts=shared, capacity_factor=cf)


def test_moe_matches_dense_reference():
    """With huge capacity (no drops), sort-based dispatch must equal the
    dense per-token expert mixture."""
    spec = _moe(E=4, k=2, cf=16.0)
    b = L.ParamBuilder(jax.random.PRNGKey(0), "float32")
    M.init_moe(b, "moe", 16, spec)
    p = b.build()[0]["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    out, aux = M.moe_apply(p, x, spec)

    # dense reference: every token through every expert, weighted
    xf = x.reshape(-1, 16)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, e = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(2):
            ee = int(e[t, j])
            h = jax.nn.silu(xf[t] @ p["w_gate"][ee]) * (xf[t] @ p["w_in"][ee])
            ref = ref.at[t].add(w[t, j] * (h @ p["w_out"][ee]))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 16)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens_not_crashes():
    spec = _moe(E=4, k=2, cf=0.25)  # tiny capacity -> heavy drops
    b = L.ParamBuilder(jax.random.PRNGKey(0), "float32")
    M.init_moe(b, "moe", 16, spec)
    p = b.build()[0]["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    out, aux = M.moe_apply(p, x, spec)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_moe_shared_expert_always_applies():
    spec = _moe(E=4, k=1, shared=1, cf=0.01)  # capacity ~0: routed all drop
    b = L.ParamBuilder(jax.random.PRNGKey(0), "float32")
    M.init_moe(b, "moe", 16, spec)
    p = b.build()[0]["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    out, _ = M.moe_apply(p, x, spec)
    want = L.gated_mlp(p["shared"], x.reshape(-1, 16), "silu")
    # capacity 8 (min) may still route a few tokens; check shared-only lower
    # bound: outputs correlate strongly with the shared path
    corr = np.corrcoef(np.asarray(out).ravel(), np.asarray(want).ravel())[0, 1]
    assert corr > 0.5


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3))
def test_moe_capacity_bound_property(E, k):
    k = min(k, E)
    spec = _moe(E=E, k=k, cf=1.0)
    T_ = 32
    cap = M.capacity(T_, spec)
    assert cap >= T_ * k / E
    assert cap % 8 == 0
