"""End-to-end trainer: full substrate stack (config -> model -> step ->
pipeline -> fault-tolerant runner -> checkpoints) converges on CPU."""
import sys

import jax
import numpy as np
import pytest


@pytest.mark.parametrize("arch_name", ["qwen2-0.5b", "phi3.5-moe-42b-a6.6b"])
def test_train_driver_loss_falls(tmp_path, arch_name):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import get_arch
    from repro.data.pipeline import DeterministicSource, lm_batch_fn
    from repro.launch.fault_tolerance import (RunnerConfig, TrainRunner,
                                              TrainState)
    from repro.launch.train import scaled_lm_arch
    from repro.models import transformer as T
    from repro.train.optimizer import AdamConfig, adam_init
    from repro.train.train_loop import make_train_step

    moe = arch_name != "qwen2-0.5b"
    steps = 45 if moe else 25       # MoE routing warms slower
    lr = 1e-2 if moe else 3e-3
    arch = scaled_lm_arch(get_arch(arch_name), 0.04)
    rng = jax.random.PRNGKey(0)
    params, _ = T.init_lm(rng, arch)
    adam = AdamConfig(lr=lr, total_steps=steps, warmup_steps=3)
    step = jax.jit(make_train_step(
        lambda p, tokens, labels: T.lm_loss(p, tokens, labels, arch), adam),
        donate_argnums=(0, 1))
    src = DeterministicSource(lm_batch_fn(arch.vocab, 1, 8, 64), 0)
    runner = TrainRunner(step, Checkpointer(tmp_path),
                         RunnerConfig(total_steps=steps, checkpoint_every=10))
    state = TrainState(params=params, opt_state=adam_init(params, adam),
                       step=0, rng=rng, data_cursor=0)
    out = runner.run(state, iter(src.iterate()))
    losses = [m["loss"] for m in runner.metrics_log]
    assert out.step == steps
    assert losses[-1] < losses[0] * 0.95, losses[::8]
    # checkpoint directory holds the final state
    assert Checkpointer(tmp_path).latest_step() == steps
