"""Sharding-rules engine: divisibility fallbacks, pod-axis absorption,
axis-reuse guards."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import effective_rules, spec_for

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


class FakeMesh:
    """Duck-typed mesh: axis_names + devices.shape are all spec_for uses."""

    class _Dev:
        def __init__(self, shape):
            self.shape = shape

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = self._Dev(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))

RULES = {"batch": ("data",), "heads": ("model",), "ff": ("model",),
         "rows": ("data", "model"), "none": None}


def test_basic_mapping():
    eff = effective_rules(RULES, MESH)
    assert spec_for(("batch", "heads"), eff, MESH) == P("data", "model")


def test_divisibility_fallback():
    eff = effective_rules(RULES, MESH)
    # 8 heads cannot split 16 ways -> replicated
    assert spec_for(("batch", "heads"), eff, MESH, (32, 8)) == P("data")
    # batch 1 cannot shard
    assert spec_for(("batch",), eff, MESH, (1,)) == P()


def test_multi_axis_partial_divisibility():
    eff = effective_rules(RULES, MESH)
    # rows=('data','model') needs /256; 64 rows only fits 'data' (16)
    assert spec_for(("rows",), eff, MESH, (64,)) == P("data")
    assert spec_for(("rows",), eff, MESH, (512,)) == P(("data", "model"))


def test_axis_never_reused():
    eff = effective_rules({"a": ("model",), "b": ("model",)}, MESH)
    assert spec_for(("a", "b"), eff, MESH) == P("model")  # b dropped


def test_pod_absorption():
    eff = effective_rules(RULES, POD)
    assert eff["batch"] == ("pod", "data")
    assert eff["heads"] == ("model",)  # non-absorber untouched


def test_pod_axis_dropped_on_single_pod():
    rules = {"batch": ("pod", "data")}
    eff = effective_rules(rules, MESH)
    assert eff["batch"] == ("data",)


def test_trailing_none_trimmed():
    eff = effective_rules(RULES, MESH)
    s = spec_for(("batch", None, None), eff, MESH)
    assert s == P("data")


def test_merged_rules_override_order():
    from repro.configs.base import DEFAULT_RULES, get_arch, merged_rules
    arch = get_arch("llama3-405b")
    spec = next(s for s in arch.shapes if s.name == "train_4k")
    rules = merged_rules(arch, spec)
    assert rules["embed"] == ("data",)        # arch override
    assert rules["seq_act"] == ("model",)     # shape override
    assert rules["batch"] == DEFAULT_RULES["batch"]
