"""Plan-equivalence harness: the cost-based optimizer NEVER changes results.

Hundreds of seeded random plan trees — every node type, nesting 1–4 deep,
adversarial selectivities (empty predicates, all-rows predicates, pinned-
video time ranges, duplicate subtrees) — are executed both ways against the
REAL masked search pipeline (``anns.search_batch`` over a built IMI index,
no fakes) and must return bit-identical frame ids, bit-identical scores,
and tie-stable ordering:

    optimized  = optimizer.optimize(...) + execute_physical(...)
    reference  = plan.execute(...)            (the unoptimized path)

across four environments:

    fresh       a freshly built index
    reopened    the same index persisted through VectorStore and reopened
    tombstoned  rows deleted (an alive-mask riding every search, both sides)
    sharded     1/2/4 frame-aligned shards, per-shard optimized execution
                merged by ``plan.merge_grouped`` vs the UNSHARDED reference

There is no per-plan special-casing anywhere: one generator, one assertion.
``PLANNER_EQUIV_EXAMPLES`` scales the sweep (default 80 -> 200 plans
total; the ``planner-equivalence`` CI job raises it).  The hypothesis-wired
property test at the bottom runs under the conftest shim locally and under
real Hypothesis (with shrinking) in CI.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import anns, imi
from repro.core import optimizer as O
from repro.core import plan as P

N_EXAMPLES = int(os.environ.get("PLANNER_EQUIV_EXAMPLES", "80"))

# -- a small but real world: V videos x FR key frames x KP patches ----------
V, FR, KP, D = 4, 30, 4, 32
F = V * FR                    # 120 key frames
N = F * KP                    # 480 index rows
TMAX = FR                     # per-video source-frame indexes 0..FR-1
TEXTS = ["red truck", "pedestrian", "blue car", "a dog",
         "traffic light", "white van"]

# covering config: every cell probed, windows cover the largest cell, fetch
# covers all rows -> both physical alternatives are exact (the envelope the
# optimizer's post-filter substitution is gated on)
CFG = anns.SearchConfig(top_a=16, max_cell_size=512, top_k=24,
                        rerank_overfetch=20)


def _encode(texts):
    """Deterministic text -> unit embedding (stable across processes)."""
    out = np.zeros((len(texts), D), np.float32)
    for i, t in enumerate(texts):
        r = np.random.default_rng(sum(t.encode()) % 2**32)
        v = r.standard_normal(D).astype(np.float32)
        out[i] = v / np.linalg.norm(v)
    return jnp.asarray(out)


def _make_meta(index):
    ids = np.asarray(index.ids)
    frame = ids // KP
    return P.PlanMeta(
        row_video=(frame // FR).astype(np.int32),
        row_time=(frame % FR).astype(np.int32),
        frame_video=np.repeat(np.arange(V), FR).astype(np.int32),
        frame_time=np.tile(np.arange(FR), V).astype(np.int32),
        patches_per_frame=KP)


_WORLD: list = []   # lazy singleton: shared by fixtures AND the property
                    # test (the hypothesis shim cannot inject fixtures)


def _get_world():
    if not _WORLD:
        x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
        index = imi.build_imi(jax.random.PRNGKey(1), x,
                              jnp.arange(N, dtype=jnp.int32),
                              K=4, P=4, M=16, kmeans_iters=4)
        meta = _make_meta(index)
        stats = O.PlanStats.from_meta(
            meta, cell_offsets=np.asarray(index.cell_offsets))
        assert O.exact_envelope(CFG, stats), "harness config must be covering"
        _WORLD.append((index, meta, stats))
    return _WORLD[0]


@pytest.fixture(scope="module")
def world():
    return _get_world()


def _binding(index, base_mask=None):
    """The engine's search_texts contract over a real index, memoized.

    ``base_mask`` (N,) rides every call — tombstone alive-masks and shard
    row-ranges enter here, on BOTH the optimized and reference paths."""
    cache = {}

    def search_texts(texts, masks, top_k=None):
        key = (tuple(texts),
               None if masks is None else np.asarray(masks).tobytes(),
               top_k)
        if key in cache:
            return cache[key]
        eff = None if masks is None else np.asarray(masks, bool)
        if base_mask is not None:
            bm = np.broadcast_to(base_mask, (len(texts), N))
            eff = bm.copy() if eff is None else (eff & bm)
        cfg = CFG if top_k is None else \
            dataclasses.replace(CFG, top_k=int(top_k))
        res = anns.search_batch(
            index, _encode(texts), cfg,
            None if eff is None else jnp.asarray(eff.astype(np.uint8)))
        out = (np.asarray(res["ids"]), np.asarray(res["scores"]))
        cache[key] = out
        return out

    return search_texts


# -- seeded random plan trees (no per-plan special-casing) ------------------
def _rand_pred(r):
    if r.random() < 0.5:
        lo = int(r.integers(0, TMAX + 1))
        hi = int(r.integers(0, TMAX + 1))
        if r.random() < 0.8:
            lo, hi = min(lo, hi), max(lo, hi)   # else possibly empty/reversed
        video = int(r.integers(0, V)) if r.random() < 0.3 else None
        return P.TimeRange(lo, hi, video)
    k = int(r.integers(0, V + 1))               # includes empty + all videos
    return P.VideoIn(sorted(r.choice(V, size=k, replace=False).tolist()))


def _rand_tree(r, depth, allow_not):
    if depth <= 0 or r.random() < 0.25:
        return P.Text(TEXTS[int(r.integers(len(TEXTS)))])
    roll = r.random()
    if roll < 0.15 and allow_not:
        return P.Not(_rand_tree(r, depth - 1, allow_not))
    kids = [_rand_tree(r, depth - 1, allow_not)
            for _ in range(int(r.integers(2, 4)))]
    if roll < 0.6:
        if r.random() < 0.7:                    # And carries predicates
            kids += [_rand_pred(r) for _ in range(int(r.integers(1, 3)))]
        return P.And(*kids)
    return P.Or(*kids)


def _rand_plan(seed, *, allow_not=True):
    r = np.random.default_rng(seed)
    root = _rand_tree(r, int(r.integers(1, 5)), allow_not)
    if not P.collect_leaves(root):              # ensure a scored leaf exists
        root = P.And(root, P.Text(TEXTS[seed % len(TEXTS)]))
    if r.random() < 0.3:
        root = P.GroupTopK(root, per="video", k=int(r.integers(1, 4)),
                           mode=("moment" if r.random() < 0.4 else "frames"),
                           max_gap=int(r.integers(1, 3)))
    return root


def _assert_bit_identical(got, want, ctx):
    """Bit-identical ids and tie-stable ordering; scores ulp-tight.

    Frame ids, videos, times, and their ORDER must match exactly — exact
    score ties included (both paths end in the same stable argsort over
    candidates in the same deterministic order).  Scores themselves are
    compared at float32-ulp tolerance: XLA tiles the exact-rescore matmul
    differently for different batch shapes (canonicalization dedups leaf
    texts, changing Q), which legitimately perturbs the last mantissa bit
    of identical row dot products."""
    __tracebackhide__ = True
    np.testing.assert_array_equal(got.frames, want.frames, err_msg=ctx)
    np.testing.assert_array_equal(got.videos, want.videos, err_msg=ctx)
    np.testing.assert_array_equal(got.times, want.times, err_msg=ctx)
    np.testing.assert_allclose(got.scores, want.scores,
                               rtol=2e-6, atol=2e-7, err_msg=ctx)
    assert (got.moments is None) == (want.moments is None), ctx
    if got.moments is not None:
        for key in ("video", "start", "end", "n_frames"):
            np.testing.assert_array_equal(got.moments[key],
                                          want.moments[key], err_msg=ctx)
        np.testing.assert_allclose(got.moments["score"],
                                   want.moments["score"],
                                   rtol=2e-6, atol=2e-7, err_msg=ctx)


def _check_seed(seed, index, meta, stats, base_mask=None, env="fresh"):
    node = _rand_plan(seed)
    search_texts = _binding(index, base_mask)
    want = P.execute(node, meta, search_texts)
    got = O.execute_optimized(node, meta, search_texts, cfg=CFG, stats=stats)
    _assert_bit_identical(got, want, f"env={env} seed={seed} plan={node!r}")


# -- environment 1: fresh index ---------------------------------------------
def test_equivalence_fresh(world):
    index, meta, stats = world
    for seed in range(N_EXAMPLES):
        _check_seed(seed, index, meta, stats, env="fresh")


# -- environment 2: store round trip ----------------------------------------
@pytest.fixture(scope="module")
def reopened(world, tmp_path_factory):
    from repro.core.index_builder import BuiltIndex, MetadataStore
    from repro.store.store import VectorStore

    index, meta, _ = world
    built = BuiltIndex(
        index=index,
        metadata=MetadataStore(
            video_of=(np.arange(N) // KP // FR).astype(np.int32),
            frame_of=((np.arange(N) // KP) % FR).astype(np.int32),
            bbox_of=np.zeros((N, 4), np.float32)),
        keyframes=np.zeros((F, 8, 8, 3), np.float32),
        keyframe_video=np.asarray(meta.frame_video),
        keyframe_frame=np.asarray(meta.frame_time),
        patches_per_frame=KP)
    root = tmp_path_factory.mktemp("optstore")
    VectorStore.create(root, built).close()
    with VectorStore.open(root) as store:
        built2 = store.to_built_index()
        stats2 = store.plan_stats()
    index2 = built2.index
    meta2 = _make_meta(index2)
    return index2, meta2, stats2


def test_equivalence_reopened_store(world, reopened):
    index2, meta2, stats2 = reopened
    assert stats2 is not None          # persisted sidecar came back
    assert O.exact_envelope(CFG, stats2)
    for seed in range(1000, 1000 + N_EXAMPLES // 2):
        _check_seed(seed, index2, meta2, stats2, env="reopened")


def test_reopened_rows_bit_equal(world, reopened):
    """The store round trip itself must be lossless, or 'equivalence on the
    reopened index' would be vacuous."""
    index, _, _ = world
    index2, _, _ = reopened
    np.testing.assert_array_equal(np.asarray(index.ids),
                                  np.asarray(index2.ids))
    np.testing.assert_array_equal(np.asarray(index.codes),
                                  np.asarray(index2.codes))


# -- environment 3: tombstones ----------------------------------------------
def test_equivalence_with_tombstones(world):
    index, meta, stats = world
    r = np.random.default_rng(99)
    dead_frames = r.choice(F, size=F // 5, replace=False)
    alive = ~np.isin(np.asarray(index.ids) // KP, dead_frames)
    for seed in range(2000, 2000 + N_EXAMPLES // 2):
        _check_seed(seed, index, meta, stats, base_mask=alive,
                    env="tombstoned")


# -- environment 4: sharded 1/2/4 -------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_equivalence_sharded(world, n_shards):
    """Per-shard optimized execution + cross-shard merge must equal the
    per-shard UNOPTIMIZED execution + the same merge.  (Shard count itself
    changes answers whenever a leaf's top_k doesn't cover all its matching
    rows — per-shard quotas refill — so the equivalence claim is within the
    sharded environment, matching ``plan.execute_sharded`` semantics.)"""
    index, meta, stats = world
    frame_of_row = np.asarray(index.ids) // KP
    bounds = np.linspace(0, F, n_shards + 1).astype(np.int64)
    shard_bindings = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        shard_mask = (frame_of_row >= lo) & (frame_of_row < hi)
        shard_bindings.append(_binding(index, shard_mask))
    for seed in range(3000 + 100 * n_shards,
                      3000 + 100 * n_shards + N_EXAMPLES // 4):
        node = _rand_plan(seed, allow_not=False)   # shard_plan refuses Not
        sp = P.shard_plan(node)
        want = P.merge_grouped(
            [P.execute(sp, meta, b) for b in shard_bindings], node, meta)
        got = P.merge_grouped(
            [O.execute_optimized(sp, meta, b, cfg=CFG, stats=stats)
             for b in shard_bindings], node, meta)
        _assert_bit_identical(got, want,
                              f"env=sharded{n_shards} seed={seed} "
                              f"plan={node!r}")


# -- hypothesis property (shim locally, real Hypothesis + shrinking in CI) --
@settings(max_examples=max(10, N_EXAMPLES // 4), deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_equivalence_property(seed):
    index, meta, stats = _get_world()
    _check_seed(seed, index, meta, stats, env="property")
