"""repro.chaos + repro.core.resilience: deterministic failpoints, the
unified retry/deadline/breaker layer, and the graceful-degradation
contract (DESIGN.md §16)."""
import json

import pytest

from repro import chaos
from repro.chaos import registry as chaos_registry
from repro.chaos.failpoints import ChaosSchedule, FailpointError
from repro.core.resilience import (CircuitBreaker, Completeness, Deadline,
                                   DeadlineExceeded, DegradedResult,
                                   RetryPolicy, completeness_from_routing)


# ---------------------------------------------------------------------------
# Registry + failpoint engine
# ---------------------------------------------------------------------------
def test_registry_catalog_well_formed():
    names = chaos_registry.site_names()
    assert len(names) == len(chaos_registry.SITES)
    for s in chaos_registry.SITES:
        assert s.kind in ("durability", "rpc")
        assert s.supports and set(s.supports) <= set(chaos_registry.ACTIONS)
        # torn requires call-site cooperation; crash is universal
        assert "crash" in s.supports
    assert set(chaos_registry.durability_sites()) \
        | set(chaos_registry.rpc_sites()) == names
    with pytest.raises(KeyError):
        chaos_registry.site("no.such.site")


def test_failpoint_inactive_is_noop_and_uncounted():
    assert not chaos.is_active()
    assert chaos.failpoint("store.wal.append.pre_fsync") is None
    assert chaos.hits() == {} and chaos.fired() == []


def test_schedule_validates_at_build_time():
    with pytest.raises(KeyError):
        ChaosSchedule().on("no.such.site", "raise")
    with pytest.raises(ValueError):
        # manifest replace must not offer torn (that would inject a bug,
        # not simulate a crash — registry docstring)
        ChaosSchedule().on("store.manifest.replace", "torn")
    with pytest.raises(ValueError):
        ChaosSchedule().on("router.replica.call", "explode")
    with pytest.raises(ValueError):
        ChaosSchedule().on("router.replica.call", "raise", hit=0)


def test_failpoint_nth_hit_raise_and_counters():
    sched = ChaosSchedule(seed=3).on("router.replica.call", "raise", hit=3)
    with chaos.active(sched):
        assert chaos.failpoint("router.replica.call") is None
        assert chaos.failpoint("router.replica.call") is None
        with pytest.raises(FailpointError) as ei:
            chaos.failpoint("router.replica.call")
        assert ei.value.site == "router.replica.call" and ei.value.hit == 3
        assert chaos.failpoint("router.replica.call") is None  # only hit 3
        assert chaos.hits() == {"router.replica.call": 4}
        assert chaos.fired() == [("router.replica.call", "raise", 3)]
    assert not chaos.is_active() and chaos.hits() == {}


def test_failpoint_every_and_torn_return():
    sched = (ChaosSchedule()
             .on("router.replica.call", "raise", hit=2, every=True)
             .on("store.wal.append.pre_fsync", "torn", hit=1))
    with chaos.active(sched):
        assert chaos.failpoint("router.replica.call") is None
        for _ in range(3):                       # fires on 2, 3, 4, ...
            with pytest.raises(FailpointError):
                chaos.failpoint("router.replica.call")
        # torn is returned to the call site, not acted on here
        assert chaos.failpoint("store.wal.append.pre_fsync") == "torn"


def test_failpoint_active_rejects_unregistered_name():
    with chaos.active(ChaosSchedule()):
        with pytest.raises(KeyError):
            chaos.failpoint("not.a.site")


def test_schedule_spec_roundtrip_and_env_install():
    sched = (ChaosSchedule(seed=11)
             .on("router.replica.call", "raise", hit=2)
             .on("serving.batcher.dispatch", "delay", delay_s=0.5))
    spec = json.loads(json.dumps(sched.to_spec()))   # through real JSON
    back = ChaosSchedule.from_spec(spec)
    assert back.seed == 11 and back.rules == sched.rules
    assert not chaos.install_from_env(environ={})
    assert chaos.install_from_env(
        environ={chaos.failpoints.ENV_SPEC: json.dumps(spec)})
    try:
        assert chaos.is_active()
        with pytest.raises(FailpointError):
            chaos.failpoint("router.replica.call")
            chaos.failpoint("router.replica.call")
    finally:
        chaos.uninstall()


# ---------------------------------------------------------------------------
# RetryPolicy + Deadline
# ---------------------------------------------------------------------------
def test_retry_policy_backoff_deterministic_exponential_capped():
    p = RetryPolicy(base_backoff_s=0.1, max_backoff_s=1.0, jitter=0.5,
                    seed=4)
    seq = [p.backoff_s(a) for a in range(1, 8)]
    assert seq == [p.backoff_s(a) for a in range(1, 8)]  # deterministic
    for a, b in enumerate(seq, start=1):
        assert 0.05 * 2 ** (a - 1) * 0.999 <= b or b <= 1.0
        assert b <= 1.0 + 1e-9                            # hard cap
    assert RetryPolicy(seed=1).backoff_s(1) != \
        RetryPolicy(seed=2).backoff_s(1)                  # decorrelated
    no_jitter = RetryPolicy(base_backoff_s=0.1, max_backoff_s=10.0,
                            jitter=0.0)
    assert [no_jitter.backoff_s(a) for a in (1, 2, 3)] == [0.1, 0.2, 0.4]


def test_retry_policy_call_retries_then_succeeds():
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    p = RetryPolicy(max_attempts=4, base_backoff_s=0.01)
    assert p.call(flaky, sleep=slept.append) == "ok"
    assert calls["n"] == 3 and len(slept) == 2
    assert slept == [p.backoff_s(1), p.backoff_s(2)]


def test_retry_policy_exhaustion_reraises():
    p = RetryPolicy(max_attempts=3, base_backoff_s=0.0, jitter=0.0)
    calls = {"n": 0}

    def always():
        calls["n"] += 1
        raise ValueError("nope")

    with pytest.raises(ValueError):
        p.call(always, sleep=lambda s: None)
    assert calls["n"] == 3


def test_deadline_budget_caps_retry_loop():
    clock = {"t": 0.0}
    dl = Deadline.after(1.0, clock=lambda: clock["t"])
    assert not dl.expired() and dl.remaining() == 1.0
    p = RetryPolicy(max_attempts=100, base_backoff_s=0.4, jitter=0.0)
    attempts = {"n": 0}

    def failing():
        attempts["n"] += 1
        clock["t"] += 0.3
        raise RuntimeError("down")

    def sleep(s):
        clock["t"] += s

    with pytest.raises(DeadlineExceeded):
        p.call(failing, deadline=dl, sleep=sleep)
    assert attempts["n"] < 100       # the budget, not max_attempts, ended it
    clock["t"] = 2.0
    with pytest.raises(DeadlineExceeded):
        dl.check("late work")


# ---------------------------------------------------------------------------
# CircuitBreaker lifecycle
# ---------------------------------------------------------------------------
def test_breaker_trips_after_threshold_and_half_open_recovers():
    clock = {"t": 0.0}
    b = CircuitBreaker(failure_threshold=3, recovery_s=5.0,
                       clock=lambda: clock["t"])
    assert b.closed and b.can_attempt()
    b.record_failure()
    b.record_failure()
    assert b.closed and b.failures == 2
    b.record_failure()
    assert b.state == "open" and not b.can_attempt() and b.opens == 1
    assert not b.try_acquire()                     # still inside recovery_s
    clock["t"] = 5.0
    assert b.can_attempt()
    assert b.try_acquire()                         # -> half-open, probe slot
    assert b.state == "half-open"
    assert not b.try_acquire()                     # probe budget exhausted
    b.record_success()
    assert b.closed and b.failures == 0


def test_breaker_half_open_probe_failure_retrips():
    clock = {"t": 0.0}
    b = CircuitBreaker(failure_threshold=1, recovery_s=2.0,
                       clock=lambda: clock["t"])
    b.record_failure()
    clock["t"] = 2.0
    assert b.try_acquire()
    b.record_failure()                             # probe failed
    assert b.state == "open" and b.opens == 2
    assert not b.try_acquire()                     # window restarted
    clock["t"] = 4.0
    assert b.try_acquire()


def test_breaker_zero_recovery_probes_immediately_and_force_close():
    b = CircuitBreaker(failure_threshold=1, recovery_s=0.0)
    b.record_failure()
    assert b.try_acquire()           # legacy recovery_probe_s=0.0 semantics
    b.record_failure()
    assert b.try_acquire()
    b.force_close()
    assert b.closed and b.failures == 0
    b.force_open()
    assert b.state == "open"


# ---------------------------------------------------------------------------
# Completeness / DegradedResult / cache exclusion
# ---------------------------------------------------------------------------
def test_completeness_coverage_and_complete():
    full = Completeness(shards_total=4, shards_answered=4)
    assert full.complete and full.coverage == 1.0
    part = Completeness(shards_total=4, shards_answered=3,
                        missing=("shard-2",), rows_total=1000,
                        rows_covered=700, generation=7)
    assert not part.complete and part.coverage == 0.7


def test_completeness_from_routing_rows():
    import dataclasses as dc

    @dc.dataclass
    class A:
        shard_id: int
        row_range: tuple
        replica: str

    class RT:
        generation = 7
        assignments = (A(0, (0, 600), "r0"), A(1, (600, 1000), "r1"))

    comp = completeness_from_routing(["r0"], ["r1"], routing=RT())
    assert comp.shards_total == 2 and comp.shards_answered == 1
    assert comp.rows_total == 1000 and comp.rows_covered == 600
    assert comp.generation == 7 and not comp.complete
    bare = completeness_from_routing(["a", "b"], [])
    assert bare.complete and bare.rows_total is None


def test_result_cache_refuses_degraded_results():
    from repro.core.optimizer import ResultCache

    cache = ResultCache(capacity=8)
    degraded = DegradedResult(
        value={"ids": [1, 2]},
        completeness=Completeness(shards_total=2, shards_answered=1,
                                  missing=("s1",)))
    cache.put("k", None, degraded)
    assert cache.get("k", None) is None
    assert len(cache) == 0 and cache.rejected_degraded == 1
    # a COMPLETE degraded-path result is admissible
    ok = DegradedResult(
        value={"ids": [1, 2]},
        completeness=Completeness(shards_total=2, shards_answered=2))
    cache.put("k", None, ok)
    assert cache.get("k", None) == ok and cache.rejected_degraded == 1
    # plain results unaffected
    cache.put("p", None, {"ids": [3]})
    assert cache.get("p", None) == {"ids": [3]}


# ---------------------------------------------------------------------------
# MicroBatcher deadlines + dispatch failpoint
# ---------------------------------------------------------------------------
def test_batcher_sheds_expired_requests_and_propagates_deadline():
    from repro.serving.batcher import MicroBatcher

    seen = {"deadline": "unset"}

    def backend(payloads, deadline=None):
        seen["deadline"] = deadline
        return [p * 2 for p in payloads]

    mb = MicroBatcher(backend, batch_size=4, max_wait_ms=5.0)
    try:
        dl = Deadline.after(30.0)
        assert mb.submit(3, deadline=dl).result(timeout=5) == 6
        assert seen["deadline"] is dl            # tightest budget forwarded
        # an already-expired request never reaches the backend
        dead = Deadline.after(-1.0)
        with pytest.raises(DeadlineExceeded):
            mb.submit(4, deadline=dead).result(timeout=5)
        assert mb.expired >= 1
    finally:
        mb.close()


def test_batcher_default_deadline_and_backend_without_kwarg():
    from repro.serving.batcher import MicroBatcher

    mb = MicroBatcher(lambda ps: [p + 1 for p in ps], batch_size=2,
                      default_deadline_ms=30_000.0)
    try:
        assert mb.submit(1).result(timeout=5) == 2   # no kwarg passed
    finally:
        mb.close()


def test_batcher_dispatch_failpoint_fails_the_batch():
    from repro.serving.batcher import MicroBatcher

    mb = MicroBatcher(lambda ps: ps, batch_size=1, max_wait_ms=1.0)
    try:
        with chaos.active(ChaosSchedule().on("serving.batcher.dispatch",
                                             "raise", hit=1)):
            with pytest.raises(FailpointError):
                mb.submit("x").result(timeout=5)
        assert mb.submit("y").result(timeout=5) == "y"   # off again
    finally:
        mb.close()


# ---------------------------------------------------------------------------
# Router deadlines + degraded reads
# ---------------------------------------------------------------------------
def test_router_deadline_refuses_expired_call():
    from repro.serving.router import QueryRouter

    r = QueryRouter(hedge=False)
    r.add_replica("a", lambda p: p)
    assert r(1, deadline=Deadline.after(30.0)) == 1
    with pytest.raises(DeadlineExceeded):
        r(1, deadline=Deadline.after(-1.0))
    with pytest.raises(DeadlineExceeded):
        r.call_batch([1, 2], deadline=Deadline.after(-1.0))
    with pytest.raises(DeadlineExceeded):
        r.call_sharded(1, sum, deadline=Deadline.after(-1.0))
    r.close()


def test_router_degraded_read_skips_dead_shard_and_labels_result():
    from repro.serving.router import QueryRouter, ReplicaUnavailable

    r = QueryRouter(hedge=False, unhealthy_after=1)
    r.add_replica("s0", lambda p: [p])
    r.add_replica("s1", lambda p: [p * 10])
    # strict + healthy: plain merged value (not wrapped)
    assert r.call_sharded(2, lambda outs: sorted(
        v for o in outs for v in o)) == [2, 20]
    # demote s1
    r._replicas["s1"].breaker.force_open()
    with pytest.raises(ReplicaUnavailable):
        r.call_sharded(2, lambda outs: outs)          # strict refuses
    res = r.call_sharded(2, lambda outs: sorted(
        v for o in outs for v in o), degraded_ok=True)
    assert isinstance(res, DegradedResult)
    assert res.value == [2]
    assert not res.completeness.complete
    assert res.completeness.missing == ("s1",)
    assert res.completeness.shards_answered == 1
    # degraded with every shard up: complete, still labeled
    r.mark_recovered("s1")
    res2 = r.call_sharded(2, lambda outs: sorted(
        v for o in outs for v in o), degraded_ok=True)
    assert isinstance(res2, DegradedResult) and res2.completeness.complete
    assert res2.value == [2, 20]
    r.close()


def test_router_degraded_read_with_all_shards_dead_raises():
    from repro.serving.router import QueryRouter, ReplicaUnavailable

    r = QueryRouter(hedge=False)
    r.add_replica("s0", lambda p: [p])
    r._replicas["s0"].breaker.force_open()
    with pytest.raises(ReplicaUnavailable):
        r.call_sharded(1, lambda o: o, degraded_ok=True)
    r.close()


def test_router_degraded_result_never_enters_cache():
    from repro.core.optimizer import ResultCache
    from repro.serving.router import QueryRouter

    r = QueryRouter(hedge=False, unhealthy_after=1)
    r.add_replica("s0", lambda p: [p])
    r.add_replica("s1", lambda p: [p])
    r._replicas["s1"].breaker.force_open()
    res = r.call_sharded(5, lambda outs: outs, degraded_ok=True)
    cache = ResultCache()
    cache.put("plan-key", None, res)
    assert len(cache) == 0 and cache.rejected_degraded == 1
    r.close()


def test_router_replica_call_failpoint_drives_breaker():
    from repro.serving.router import QueryRouter

    r = QueryRouter(hedge=False, unhealthy_after=2)
    r.add_replica("only", lambda p: p)
    sched = ChaosSchedule().on("router.replica.call", "raise", hit=1,
                               every=True)
    with chaos.active(sched):
        with pytest.raises(Exception):
            for _ in range(4):
                r(1)
    assert not r.stats()["only"]["healthy"]
    assert r.stats()["only"]["state"] == "open"
    r.mark_recovered("only")
    assert r(1) == 1 and r.stats()["only"]["healthy"]
    r.close()
