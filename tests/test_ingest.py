"""Live ingest: sampling bandit, standing queries, alerts, crash
consistency, compaction scheduling (DESIGN.md §12)."""
import json
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anns, imi as imimod, pq as pqmod
from repro.core.incremental import SegmentedIndex
from repro.data import video as videomod
from repro.ingest import (Alert, CameraBandit, CompactionPolicy,
                          CompactionScheduler, IngestService, JsonlSink,
                          MemorySink, ReplayCamera, RetryingSink,
                          StandingQueryRegistry, dedup_by_key,
                          plan_fingerprint)
from repro.store import VectorStore

# ---------------------------------------------------------------------------
# A deterministic miniature world: frames carry a label index in their
# pixels; fake encoders map labels and captions to shared fixed
# directions, so "this caption matches that frame" is exact by
# construction and every alert expectation is computable.
# ---------------------------------------------------------------------------
D = 32
KP = 4  # patches per frame
LABELS = ["red square", "blue circle", "green triangle", "nothing"]
_BASIS = np.random.default_rng(7).normal(0, 1, (16, D)).astype(np.float32)


def _dir(text: str) -> np.ndarray:
    return _BASIS[zlib.crc32(text.encode()) % 16]


def encode_texts(texts):
    return np.stack([_dir(t) for t in texts])


def label_frames(labels, res=8):
    out = np.zeros((len(labels), res, res, 3), np.float32)
    for i, lab in enumerate(labels):
        out[i, :, :, 0] = LABELS.index(lab) / 10.0
    return out


def encode_frames(frames):
    f = frames.shape[0]
    out = np.zeros((f, KP, D), np.float32)
    for i in range(f):
        lab = LABELS[int(round(float(frames[i, 0, 0, 0]) * 10))]
        d = _dir(lab)
        for p in range(KP):
            out[i, p] = d + 0.01 * _BASIS[(p + 7) % 16]
    return out


def _base_index(n=2000, seed=0):
    x = np.random.default_rng(seed).normal(0, 1, (n, D)).astype(np.float32)
    return imimod.build_imi(jax.random.PRNGKey(seed), jnp.asarray(x),
                            jnp.arange(n), K=4, P=4, M=16, kmeans_iters=3)


def _service(store, cameras, registry, **kw):
    """All frames become key frames: stride 1, per-camera floor covering
    the whole step — alert expectations stay exact."""
    fps = kw.pop("frames_per_step", 8)
    bandit = CameraBandit(len(cameras), min_per_camera=fps)
    kw.setdefault("sink", MemorySink())
    return IngestService(store, cameras, encode_frames, registry,
                         bandit=bandit, frames_per_step=fps,
                         keyframe_stride=1,
                         keyframe_budget=fps * len(cameras), **kw)


# ---------------------------------------------------------------------------
# Sampler
# ---------------------------------------------------------------------------
def test_bandit_budget_split_and_adaptation():
    b = CameraBandit(3, min_per_camera=1, seed=0)
    alloc = b.allocate(12)
    assert alloc.sum() == 12 and (alloc >= 1).all()
    # camera 1 keeps matching, others never do
    for _ in range(50):
        b.update(0, samples=4, matches=0)
        b.update(1, samples=4, matches=3)
        b.update(2, samples=4, matches=0)
    rates = b.match_rate()
    assert rates[1] > rates[0] and rates[1] > rates[2]
    # over many draws the matching camera wins most of the budget
    total = np.zeros(3)
    for _ in range(50):
        total += b.allocate(12)
    assert total[1] > total[0] and total[1] > total[2]
    # state round-trip
    b2 = CameraBandit(3)
    b2.load_state_dict(json.loads(json.dumps(b.state_dict())))
    np.testing.assert_allclose(b2.match_rate(), rates)


# ---------------------------------------------------------------------------
# Alert sinks
# ---------------------------------------------------------------------------
class FlakySink:
    def __init__(self, fail_times):
        self.fail_times = fail_times
        self.calls = 0
        self.alerts = []

    def emit(self, alerts):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise RuntimeError("transient")
        self.alerts.extend(alerts)


def _alert(i, sub="s"):
    return Alert(subscription=sub, fingerprint="f", camera=0, frame=i,
                 score=1.0)


def test_retrying_sink_backoff_then_delivery():
    clock = {"t": 0.0}
    flaky = FlakySink(fail_times=2)
    sink = RetryingSink(flaky, base_backoff_s=1.0, max_backoff_s=8.0,
                        clock=lambda: clock["t"], sleep=lambda s: None)
    # the RetryPolicy owns the (jittered, exponential, capped) schedule;
    # pin the windows it actually produces rather than bare doubling
    b1, b2 = sink.policy.backoff_s(1), sink.policy.backoff_s(2)
    assert 0.5 <= b1 <= 1.5 and b2 > b1 and b2 <= 8.0
    assert b1 == sink.policy.backoff_s(1)   # deterministic per attempt
    sink.enqueue([_alert(1), _alert(2)])
    assert not sink.try_deliver() and sink.pending == 2
    # backoff window: an immediate retry is a no-op (no sink call)
    assert not sink.try_deliver() and flaky.calls == 1
    clock["t"] = b1 / 2
    assert not sink.try_deliver() and flaky.calls == 1   # still inside
    clock["t"] = b1 + 1e-6
    assert not sink.try_deliver() and flaky.calls == 2   # fails again
    clock["t"] = b1 + 1e-6 + b2 + 1e-6                    # wider 2nd window
    assert sink.try_deliver() and sink.pending == 0
    assert [a.frame for a in flaky.alerts] == [1, 2]
    assert sink.delivered == 2


def test_retrying_sink_gives_up_after_total_deadline():
    clock = {"t": 0.0}
    flaky = FlakySink(fail_times=10**9)     # never recovers
    sink = RetryingSink(flaky, base_backoff_s=0.1, max_backoff_s=0.5,
                        give_up_after_s=2.0,
                        clock=lambda: clock["t"], sleep=lambda s: None)
    sink.enqueue([_alert(1), _alert(2)])
    while clock["t"] < 2.0:
        sink.try_deliver()
        clock["t"] += 0.25
    sink.try_deliver()
    # the batch held the queue head for > 2s of failures -> dropped loudly
    assert sink.pending == 0 and sink.expired == 2 and sink.delivered == 0
    # and the failure state reset: a fresh batch starts a fresh budget
    flaky.fail_times = flaky.calls          # sink recovers now
    sink.enqueue([_alert(3)])
    assert sink.try_deliver() and sink.delivered == 1 and sink.expired == 2


def test_retrying_sink_bounded_queue_drops_oldest():
    sink = RetryingSink(FlakySink(fail_times=10**9), max_queue=3,
                        clock=lambda: 0.0, sleep=lambda s: None)
    sink.enqueue([_alert(i) for i in range(5)])
    assert sink.pending == 3 and sink.dropped == 2
    assert [a.frame for a in sink.pending_alerts] == [2, 3, 4]


def test_alert_json_roundtrip_and_fingerprint():
    a = _alert(3)
    assert Alert.from_json(json.loads(json.dumps(a.to_json()))) == a
    from repro.core import plan as planmod
    p1 = planmod.from_json({"and": [{"text": "x"}, {"videos": [1]}]})
    p2 = planmod.from_json({"and": [{"text": "x"}, {"videos": [1]}]})
    assert plan_fingerprint(p1) == plan_fingerprint(p2)
    assert plan_fingerprint(p1) != plan_fingerprint(
        planmod.from_json({"text": "x"}))


# ---------------------------------------------------------------------------
# SegmentedIndex ingest seams
# ---------------------------------------------------------------------------
def test_row_mask_over_base_plus_delta_rows():
    """The PR 4 refusal is lifted: a mask covering base+delta rows
    filters pending delta segments instead of raising."""
    idx = _base_index()
    seg = SegmentedIndex(idx)
    cfg = anns.SearchConfig(top_a=16, max_cell_size=512, top_k=20)
    v0 = np.random.default_rng(1).normal(0, 1, D).astype(np.float32)
    v = np.asarray(pqmod.normalize(jnp.asarray(
        np.stack([v0, v0 + 0.01]))))  # near-twins: both rank for q
    seg.insert(v, np.array([50_000, 50_001]))
    q = v[0]
    full = np.ones(idx.n + 2, bool)
    res = seg.search(q, cfg, row_mask=full)
    assert 50_000 in res["ids"].tolist()
    # mask out exactly that delta row: it must vanish, its twin stays
    full[idx.n] = False
    res = seg.search(q, cfg, row_mask=full)
    assert 50_000 not in res["ids"].tolist()
    assert 50_001 in res["ids"].tolist()
    # base-only mask still refused while deltas pend; wrong length named
    with pytest.raises(ValueError, match="delta"):
        seg.search(q, cfg, row_mask=np.ones(idx.n, bool))
    with pytest.raises(ValueError, match="neither"):
        seg.search(q, cfg, row_mask=np.ones(idx.n + 5, bool))


def test_rows_since_watermark():
    idx = _base_index()
    seg = SegmentedIndex(idx)
    v = np.asarray(pqmod.normalize(jnp.asarray(
        np.random.default_rng(2).normal(0, 1, (6, D)).astype(np.float32))))
    seg.insert(v[:3], np.array([8_000, 8_001, 8_002]))
    seg.insert(v[3:], np.array([8_003, 8_004, 8_005]))
    rows = seg.rows_since(8_001)
    assert rows["ids"].tolist() == [8_002, 8_003, 8_004, 8_005]
    assert rows["codes"].shape == (4, 4) and rows["vectors"].shape == (4, D)
    seg.delete([8_004])
    assert seg.rows_since(8_001)["ids"].tolist() == [8_002, 8_003, 8_005]
    # after compaction the gather falls back to the base id scan
    seg.compact()
    assert seg.rows_since(8_001)["ids"].tolist() == [8_002, 8_003, 8_005]
    assert seg.rows_since(10_000)["ids"].size == 0


# ---------------------------------------------------------------------------
# End-to-end standing queries
# ---------------------------------------------------------------------------
def _two_camera_world():
    cam0 = ReplayCamera(label_frames(
        ["nothing"] * 10 + ["red square"] * 4 + ["nothing"] * 10))
    cam1 = ReplayCamera(label_frames(
        ["blue circle"] * 3 + ["nothing"] * 18 + ["green triangle"] * 3))
    return cam0, cam1


def _registry(**kw):
    reg = StandingQueryRegistry(encode_texts, patches_per_frame=KP,
                                pad_rows=64, **kw)
    # compound plan: caption AND camera scope (VideoIn doubles as the
    # camera-id predicate in ingest coordinates)
    reg.register("red@0", {"and": [{"text": "red square"},
                                   {"videos": [0]}]},
                 threshold=0.5, top_k=32)
    reg.register("moving@1", {"or": [{"text": "blue circle"},
                                     {"text": "green triangle"}]},
                 threshold=0.5, top_k=32)
    return reg


EXPECTED_RED = {(0, t) for t in range(10, 14)}
EXPECTED_MOVING = {(1, t) for t in range(0, 3)} | {(1, t)
                                                   for t in range(21, 24)}


def test_ingest_e2e_exactly_once_and_delta_only(tmp_path):
    store = VectorStore.create(tmp_path / "s", _base_index(),
                               flush_rows=10**9)
    reg = _registry()
    svc = _service(store, list(_two_camera_world()), reg)
    svc.run()
    alerts = svc.sink.sink.alerts
    # every ground-truth (camera, frame) fired, exactly once, no extras
    assert {(a.camera, a.frame) for a in alerts
            if a.subscription == "red@0"} == EXPECTED_RED
    assert {(a.camera, a.frame) for a in alerts
            if a.subscription == "moving@1"} == EXPECTED_MOVING
    assert len(alerts) == len(dedup_by_key(alerts))
    # delta-only evaluation: scanned rows ~ ingested rows, far below
    # what per-evaluation full rescans of the index would cost
    assert reg.total_rows_scanned <= svc.stats.rows
    assert reg.total_rows_scanned < store.n * reg.evaluations / 10
    assert svc.latencies and max(svc.latencies) < 60.0
    svc.close()

    # reopen: seen-set + watermark round-trip -> nothing re-fires
    store2 = VectorStore.open(tmp_path / "s")
    reg2 = StandingQueryRegistry(encode_texts, patches_per_frame=KP,
                                 pad_rows=64)
    svc2 = _service(store2, list(_two_camera_world()), reg2)
    assert set(reg2.subs) == {"red@0", "moving@1"}
    assert svc2.run(max_steps=5) == []
    assert svc2.sink.sink.alerts == []
    svc2.close()


def test_crash_mid_chunk_no_lost_no_duplicate_alerts(tmp_path):
    """Kill after the WAL append but before the manifest swap / state
    save: reopen must fire the crashed chunk's alerts exactly once
    (idempotent replay + seen-set round-trip)."""
    store = VectorStore.create(tmp_path / "s", _base_index(),
                               flush_rows=10**9)
    reg = _registry()
    svc = _service(store, list(_two_camera_world()), reg,
                   checkpoint_every_steps=0)
    first = svc.step()          # frames 0..7: blue-circle alerts fire

    class Crash(Exception):
        pass

    def boom(*a, **kw):
        raise Crash

    reg.evaluate = boom
    with pytest.raises(Crash):
        svc.step()              # frames 8..15 hit the WAL, then we die
    # no close(), no flush: the manifest still points at the pre-crash
    # state; only the fsync'd WAL + frame-meta log survive

    store2 = VectorStore.open(tmp_path / "s")
    reg2 = StandingQueryRegistry(encode_texts, patches_per_frame=KP,
                                 pad_rows=64)
    svc2 = _service(store2, list(_two_camera_world()), reg2)
    # recovery evaluated the replayed rows; resume the stream to the end
    svc2.run()
    svc2.close()

    combined = first + svc2.sink.sink.alerts
    assert {(a.camera, a.frame) for a in combined
            if a.subscription == "red@0"} == EXPECTED_RED
    assert {(a.camera, a.frame) for a in combined
            if a.subscription == "moving@1"} == EXPECTED_MOVING
    assert len(combined) == len(dedup_by_key(combined))


def test_crash_before_wal_append_rewinds_camera(tmp_path):
    """The other half of the window: the frame-meta record is durable but
    the rows never reached the WAL — reopen trims the dangling tail and
    rewinds the camera so the frames are re-consumed, not lost."""
    store = VectorStore.create(tmp_path / "s", _base_index(),
                               flush_rows=10**9)
    reg = _registry()
    svc = _service(store, list(_two_camera_world()), reg,
                   checkpoint_every_steps=0)
    first = svc.step()

    class Crash(Exception):
        pass

    orig = store.insert
    calls = {"n": 0}

    def insert_then_die(x, ids):
        raise Crash  # meta log written, WAL append never happens

    store.insert = insert_then_die
    with pytest.raises(Crash):
        svc.step()

    store2 = VectorStore.open(tmp_path / "s")
    reg2 = StandingQueryRegistry(encode_texts, patches_per_frame=KP,
                                 pad_rows=64)
    cam0, cam1 = _two_camera_world()
    svc2 = _service(store2, [cam0, cam1], reg2)
    assert cam0.pos == 8        # rewound to the last durable position
    svc2.run()
    svc2.close()
    combined = first + svc2.sink.sink.alerts
    assert {(a.camera, a.frame) for a in combined
            if a.subscription == "red@0"} == EXPECTED_RED
    assert len(combined) == len(dedup_by_key(combined))


def test_registry_threshold_and_unregister():
    reg = StandingQueryRegistry(encode_texts, patches_per_frame=KP,
                                pad_rows=64)
    reg.register("hi", {"text": "red square"}, threshold=10.0)  # unmeetable
    base = _base_index()
    seg = SegmentedIndex(base)
    rows = encode_frames(label_frames(["red square"] * 2)).reshape(-1, D)
    seg.insert(rows, np.arange(90_000, 90_000 + len(rows)))
    got = seg.rows_since(-1)
    sel = got["ids"] >= 90_000
    from repro.ingest.registry import DeltaChunk
    chunk = DeltaChunk(
        codes=got["codes"][sel], vectors=got["vectors"][sel],
        cells=got["cells"][sel], ids=got["ids"][sel],
        row_camera=np.zeros(sel.sum(), np.int32),
        row_time=np.repeat([0, 1], KP).astype(np.int32),
        frame_seq=np.asarray([22_500, 22_501]),
        frame_camera=np.zeros(2, np.int32),
        frame_time=np.asarray([0, 1], np.int32))
    alerts, st = reg.evaluate(seg.base, chunk)
    assert alerts == [] and st.rows_scanned == 2 * KP
    reg.unregister("hi")
    assert reg.min_watermark() is None


# ---------------------------------------------------------------------------
# Compaction scheduling
# ---------------------------------------------------------------------------
def test_compaction_scheduler_triggers_and_bounded_pause(tmp_path):
    store = VectorStore.create(tmp_path / "s", _base_index(),
                               flush_rows=10**9)
    seg = store.to_segmented_index()
    seg.max_segments = 100      # let pressure build; the POLICY decides
    seg.segment_capacity = 8
    sched = CompactionScheduler(store, CompactionPolicy(
        max_segments=2, max_drift=float("inf")))
    assert sched.maybe_run() is None
    rng = np.random.default_rng(3)
    for i in range(4):
        v = pqmod.normalize(jnp.asarray(
            rng.normal(0, 1, (8, D)).astype(np.float32)))
        store.insert(np.asarray(v), np.arange(70_000 + 8 * i,
                                              70_008 + 8 * i))
    assert len(seg.segments) > 2
    gen0 = seg.generation
    assert sched.maybe_run() == "compact"
    assert seg.generation == gen0 + 1 and not seg.segments
    assert sched.compactions == 1
    # the reader-visible pause is the pointer swap, not the merge
    assert sched.pauses and sched.pauses[-1] < 0.1
    # background thread mode: starts, acts, stops cleanly
    store.insert(np.asarray(v), np.arange(71_000, 71_008))
    sched.policy.max_segments = 0
    sched.start()
    import time
    deadline = time.monotonic() + 5.0
    while seg.segments and time.monotonic() < deadline:
        time.sleep(0.01)
    sched.stop()
    assert sched.last_error is None
    assert not seg.segments


def test_codebook_refresh_swaps_base_and_codebooks(tmp_path):
    store = VectorStore.create(tmp_path / "s", _base_index(n=800),
                               flush_rows=10**9)
    seg = store.to_segmented_index()
    # out-of-distribution inserts: the frozen codebooks quantize poorly
    shifted = np.asarray(pqmod.normalize(jnp.asarray(
        5.0 + np.random.default_rng(4).normal(
            0, 1, (32, D)).astype(np.float32))))
    store.insert(shifted, np.arange(60_000, 60_032))
    assert seg.drift_score() > 1.0
    old_cb = store.manifest["codebooks"]
    gen0 = seg.generation
    store.refresh_codebooks(kmeans_iters=3)
    assert seg.generation > gen0 and not seg.segments
    assert store.manifest["codebooks"] != old_cb
    assert not (store.root / old_cb).exists()
    store.close()
    # reopen with the refreshed codebooks; inserted rows stay findable
    store2 = VectorStore.open(tmp_path / "s")
    cfg = anns.SearchConfig(top_a=16, max_cell_size=512, top_k=10)
    res = store2.search(jnp.asarray(shifted[3]), cfg)
    assert 60_003 in np.asarray(res["ids"]).tolist()
    store2.close()


# ---------------------------------------------------------------------------
# Chunked key-frame extraction parity (data/video.py streaming knobs)
# ---------------------------------------------------------------------------
def test_chunked_keyframe_extraction_matches_batch():
    # Noise-free, one flash per 8-frame chunk: the chunk-local peak
    # threshold (mean + sigma of the chunk's own energies) then lands
    # below the flash energy exactly as the batch threshold does.  The
    # flash at 24 sits ON a chunk boundary — only the prev_frame knob
    # gives e[24] its true cross-boundary motion energy.
    frames = np.full((32, 8, 8, 3), 0.4, np.float32)
    for t in (5, 13, 24):
        frames[t] += 0.5
    batch = videomod.extract_keyframes(frames, stride=8, peak_sigma=1.0)
    chunked = []
    for lo in range(0, 32, 8):
        chunk = frames[lo: lo + 8]
        idx = videomod.extract_keyframes(
            chunk, stride=8, peak_sigma=1.0,
            prev_frame=frames[lo - 1] if lo else None,
            offset=lo, always_first=(lo == 0))
        chunked.extend((lo + idx).tolist())
    assert sorted(set(chunked)) == sorted(batch.tolist())


def test_keyframe_budget_keeps_highest_energy():
    frames = np.zeros((16, 8, 8, 3), np.float32)
    frames[10] += 0.9           # the single dominant motion event
    idx = videomod.extract_keyframes(frames, stride=4, max_keyframes=2)
    assert 0 in idx and 10 in idx and len(idx) == 2
