"""Serving substrate: micro-batching and hedged (straggler) execution."""
import threading
import time

import numpy as np
import pytest

from repro.serving.batcher import HedgedExecutor, LatencyTracker, MicroBatcher


def test_microbatcher_batches_and_orders():
    seen_batches = []

    def run(batch):
        seen_batches.append(len(batch))
        return [x * 2 for x in batch]

    mb = MicroBatcher(run, batch_size=4, max_wait_ms=30)
    futs = [mb.submit(i) for i in range(10)]
    assert [f.result(timeout=5) for f in futs] == [2 * i for i in range(10)]
    mb.close()
    assert sum(seen_batches) == 10
    assert max(seen_batches) <= 4


def test_microbatcher_propagates_errors():
    def run(batch):
        raise RuntimeError("backend down")
    mb = MicroBatcher(run, batch_size=2, max_wait_ms=5)
    f = mb.submit(1)
    with pytest.raises(RuntimeError):
        f.result(timeout=5)
    mb.close()


def test_hedged_executor_beats_straggler():
    calls = {"a": 0, "b": 0}

    def slow(x):
        calls["a"] += 1
        time.sleep(0.5)
        return ("slow", x)

    def fast(x):
        calls["b"] += 1
        return ("fast", x)

    hx = HedgedExecutor([slow, fast], max_hedges=1)
    # warm the tracker with fast latencies so hedge delay is small
    for _ in range(10):
        hx.latency.record(0.01)
    out = hx(42)
    assert out == ("fast", 42)
    assert hx.hedges_issued >= 1 and hx.hedges_won >= 1


def test_hedged_executor_no_hedge_when_fast():
    def fast(x):
        return x + 1
    hx = HedgedExecutor([fast, fast], max_hedges=1)
    for _ in range(10):
        hx.latency.record(0.05)
    assert hx(1) == 2
    assert hx.hedges_won == 0


def test_latency_tracker_quantiles():
    t = LatencyTracker()
    for v in np.linspace(0.01, 0.1, 100):
        t.record(float(v))
    assert 0.04 < t.quantile(0.5) < 0.07
    assert t.quantile(0.95) > t.quantile(0.5)
