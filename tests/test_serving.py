"""Serving substrate: micro-batching and hedged (straggler) execution."""
import threading
import time

import numpy as np
import pytest

from repro.serving.batcher import HedgedExecutor, LatencyTracker, MicroBatcher


def test_microbatcher_batches_and_orders():
    seen_batches = []

    def run(batch):
        seen_batches.append(len(batch))
        return [x * 2 for x in batch]

    mb = MicroBatcher(run, batch_size=4, max_wait_ms=30)
    futs = [mb.submit(i) for i in range(10)]
    assert [f.result(timeout=5) for f in futs] == [2 * i for i in range(10)]
    mb.close()
    assert sum(seen_batches) == 10
    assert max(seen_batches) <= 4


def test_microbatcher_propagates_errors():
    def run(batch):
        raise RuntimeError("backend down")
    mb = MicroBatcher(run, batch_size=2, max_wait_ms=5)
    f = mb.submit(1)
    with pytest.raises(RuntimeError):
        f.result(timeout=5)
    mb.close()


def test_hedged_executor_beats_straggler():
    calls = {"a": 0, "b": 0}

    def slow(x):
        calls["a"] += 1
        time.sleep(0.5)
        return ("slow", x)

    def fast(x):
        calls["b"] += 1
        return ("fast", x)

    hx = HedgedExecutor([slow, fast], max_hedges=1)
    # warm the tracker with fast latencies so hedge delay is small
    for _ in range(10):
        hx.latency.record(0.01)
    out = hx(42)
    assert out == ("fast", 42)
    assert hx.hedges_issued >= 1 and hx.hedges_won >= 1


def test_hedged_executor_no_hedge_when_fast():
    def fast(x):
        return x + 1
    hx = HedgedExecutor([fast, fast], max_hedges=1)
    for _ in range(10):
        hx.latency.record(0.05)
    assert hx(1) == 2
    assert hx.hedges_won == 0


def test_microbatcher_concurrent_submit_ordering():
    """Results must map back to their own payloads regardless of how
    concurrent submitters interleave and how batches are cut (tail
    batches included)."""
    def run(batch):
        return [x * 10 + 1 for x in batch]

    mb = MicroBatcher(run, batch_size=8, max_wait_ms=5)
    results = {}
    lock = threading.Lock()

    def client(lo, hi):
        futs = [(i, mb.submit(i)) for i in range(lo, hi)]
        for i, f in futs:
            r = f.result(timeout=10)
            with lock:
                results[i] = r

    threads = [threading.Thread(target=client, args=(k * 25, (k + 1) * 25))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mb.close()
    assert results == {i: i * 10 + 1 for i in range(100)}


def test_hedged_executor_all_fail_raises_real_exception():
    """All-replicas-fail must surface the first real exception — not
    TypeError from raising None, and without blocking on pending futures."""
    class ReplicaDown(RuntimeError):
        pass

    def fail_fast(x):
        raise ReplicaDown("replica 0 down")

    def fail_slow(x):
        time.sleep(0.2)
        raise ReplicaDown("replica 1 down")

    hx = HedgedExecutor([fail_fast, fail_slow], max_hedges=1)
    for _ in range(10):
        hx.latency.record(0.01)
    t0 = time.perf_counter()
    with pytest.raises(ReplicaDown, match="replica 0 down"):
        hx(42)
    assert time.perf_counter() - t0 < 5


def test_hedged_executor_primary_fails_hedge_wins():
    def fail(x):
        raise RuntimeError("down")

    def ok(x):
        return ("ok", x)

    hx = HedgedExecutor([fail, ok], max_hedges=1)
    for _ in range(10):
        hx.latency.record(0.01)
    assert hx(7) == ("ok", 7)


def test_router_call_batch_scatter_gather_order():
    from repro.serving.router import QueryRouter
    seen = {"a": [], "b": []}

    def mk(name):
        def batch_fn(items):
            seen[name].append(list(items))
            return [(name, x) for x in items]
        return batch_fn

    router = QueryRouter(hedge=False)
    router.add_replica("a", lambda x: ("a", x), batch_fn=mk("a"))
    router.add_replica("b", lambda x: ("b", x), batch_fn=mk("b"))
    out = router.call_batch(list(range(10)))
    assert [x for _, x in out] == list(range(10))   # gather preserves order
    served = [x for batches in seen.values() for b in batches for x in b]
    assert sorted(served) == list(range(10))
    assert all(len(b) > 0 for bs in seen.values() for b in bs)


def test_router_call_batch_survives_bad_replica():
    from repro.serving.router import QueryRouter

    def bad_batch(items):
        raise RuntimeError("pod lost")

    router = QueryRouter(hedge=False, unhealthy_after=1)
    router.add_replica("bad", lambda x: (_ for _ in ()).throw(
        RuntimeError("pod lost")), batch_fn=bad_batch)
    router.add_replica("good", lambda x: x + 1,
                       batch_fn=lambda items: [x + 1 for x in items])
    out = router.call_batch(list(range(8)))
    assert out == [x + 1 for x in range(8)]
    # the faulting shard must have demoted its replica (unhealthy_after=1)
    assert not router.stats()["bad"]["healthy"]


def test_latency_tracker_quantiles():
    t = LatencyTracker()
    for v in np.linspace(0.01, 0.1, 100):
        t.record(float(v))
    assert 0.04 < t.quantile(0.5) < 0.07
    assert t.quantile(0.95) > t.quantile(0.5)
