"""Distributed-index tests.  Multi-device cases run in a subprocess so the
XLA host-device-count flag never leaks into this process."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anns, distributed as dist, imi as imimod, pq as pqmod


def _mk_index(n=4096, d=32, seed=0):
    cents = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, d))
    a = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, 16)
    x = cents[a] + 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 3),
                                           (n, d))
    return imimod.build_imi(jax.random.PRNGKey(seed), x, jnp.arange(n),
                            K=8, P=4, M=32, kmeans_iters=5), cents


def test_shard_index_partitions_all_rows():
    index, _ = _mk_index()
    s = dist.shard_index(index, 4)
    assert s.codes.shape[0] == 4
    got = np.sort(np.asarray(s.ids).ravel())
    got = got[got >= 0]
    np.testing.assert_array_equal(got, np.arange(index.n))
    # per-shard CSR offsets well-formed
    off = np.asarray(s.cell_offsets)
    assert (np.diff(off, axis=1) >= 0).all()
    # shards are CONTIGUOUS global row ranges: local row i of shard s is
    # global row row_start[s] + i (the distributed-parity precondition)
    starts = np.asarray(s.row_start)[:, 0]
    valid = np.asarray(s.row_valid).astype(bool)
    gids = np.asarray(index.ids)
    for sh in range(4):
        nl = valid[sh].sum()
        np.testing.assert_array_equal(
            np.asarray(s.ids)[sh][: nl], gids[starts[sh]: starts[sh] + nl])


def test_single_device_sharded_search_equals_exhaustive_adc():
    """Implementation equivalence: 1-shard distributed exhaustive search ==
    single-process exhaustive ADC (same candidates, same exact rerank).
    ANN *quality* vs BF is covered in test_pq_imi (it is data-conditioned)."""
    index, cents = _mk_index()
    s = dist.shard_index(index, 1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    search = dist.make_sharded_search(mesh, top_k=128, mode="exhaustive")
    qs = pqmod.normalize(cents[2:4])
    res = jax.jit(search)(s, qs)
    for qi in range(2):
        ex = anns.exhaustive_adc(index, qs[qi], k=128)
        got = set(np.asarray(res["ids"])[qi].tolist())
        want = set(np.asarray(ex["ids"]).tolist())
        # identical up to ADC-score ties at the k-boundary
        assert len(got & want) >= 120, len(got & want)


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import anns, distributed as dist, imi as imimod, pq as pqmod

    n, d = 4096, 32
    cents = jax.random.normal(jax.random.PRNGKey(1), (16, d))
    a = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, 16)
    x = cents[a] + 0.3 * jax.random.normal(jax.random.PRNGKey(3), (n, d))
    index = imimod.build_imi(jax.random.PRNGKey(0), x, jnp.arange(n),
                             K=8, P=4, M=32, kmeans_iters=5)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sidx = dist.shard_put(dist.shard_index(index, 8), mesh)
    qs = pqmod.normalize(cents[2:6])
    out = {}
    # cell_probe: BIT-IDENTICAL to the single-host fused scan (the shared
    # branch holds: top_a * max_cell_size >= n)
    cfg = anns.SearchConfig(top_a=16, max_cell_size=256, top_k=32)
    search = dist.make_sharded_search(mesh, cfg=cfg, mode="cell_probe")
    res = jax.jit(search)(sidx, qs)
    ref = jax.jit(lambda q: anns.search_batch(index, q, cfg))(qs)
    out["cell_probe_parity"] = bool(all(
        np.array_equal(np.asarray(ref[k]), np.asarray(res[k]))
        for k in ("ids", "rows", "scores", "approx_scores")))
    # exhaustive: same candidate semantics as single-host exhaustive_adc
    # (overlap up to ADC ties at the k boundary; quality vs brute force is
    # data-conditioned and covered in test_pq_imi)
    search = dist.make_sharded_search(mesh, top_k=32, mode="exhaustive")
    res = jax.jit(search)(sidx, qs)
    ov = []
    for qi in range(4):
        ex = anns.exhaustive_adc(index, qs[qi], k=32)
        got = set(np.asarray(res["ids"])[qi].tolist())
        ov.append(len(got & set(np.asarray(ex["ids"]).tolist())) / 32)
    out["exhaustive_overlap"] = ov
    scores = np.asarray(res["scores"])
    assert (np.diff(scores, axis=1) <= 1e-5).all(), "scores sorted"
    print("RESULT " + json.dumps(out))
""")


def test_multidevice_sharded_search_matches_single_host():
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = next(l for l in r.stdout.splitlines() if l.startswith("RESULT "))
    out = json.loads(line[len("RESULT "):])
    assert out["cell_probe_parity"]
    assert np.mean(out["exhaustive_overlap"]) >= 0.95, out
