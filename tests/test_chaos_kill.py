"""Kill-at-every-failpoint crash-consistency harness (DESIGN.md §16.5).

Each test arms a subprocess to hard-crash (``os._exit``) at one
registered durability failpoint, then reopens the survivors and asserts
the invariant catalog (no acked row lost, idempotent replay,
exactly-once-effect alerts, cache-token flip, manifest integrity).  The
subprocess MUST die with ``CRASH_EXIT`` — a clean exit means the
failpoint never fired and the test would be vacuous.
"""
import pytest

from repro.chaos import harness
from repro.chaos import registry as chaos_registry

STORE_SITES = [s for s in harness.EXERCISED_SITES
               if harness.SITE_PLANS[s].workload == "store"]
INGEST_SITES = [s for s in harness.EXERCISED_SITES
                if harness.SITE_PLANS[s].workload == "ingest"]


def test_every_durability_site_has_a_kill_plan():
    harness.check_coverage()
    assert set(harness.EXERCISED_SITES) \
        == set(chaos_registry.durability_sites())


@pytest.mark.parametrize("site", STORE_SITES)
def test_kill_store_site(site, tmp_path):
    rep = harness.kill_at_site(site, tmp_path)
    assert rep["ok"] and rep["site"] == site


@pytest.mark.parametrize("site", INGEST_SITES)
def test_kill_ingest_site(site, tmp_path):
    rep = harness.kill_at_site(site, tmp_path)
    assert rep["ok"] and rep["site"] == site
    assert rep["alerts"] == len(harness.EXPECTED_KEYS)


def test_clean_run_exits_zero_and_verifies(tmp_path):
    """Without a chaos spec the same workloads complete and verify —
    the harness's invariants hold on the happy path too."""
    harness.run_store_workload(tmp_path / "store_flavor")
    rep = harness.verify_store(tmp_path / "store_flavor")
    assert rep["ok"] and rep["inflight"] is None
    harness.run_ingest_workload(tmp_path / "ingest_flavor")
    rep = harness.verify_ingest(tmp_path / "ingest_flavor")
    assert rep["ok"] and rep["alerts"] == len(harness.EXPECTED_KEYS)
