"""Fused scan->select kernels vs the materialize-then-top_k oracle, and
search_batch end-to-end id-equality with the fusion on vs off.

Parity tests use integer-valued f32 LUTs: every ADC sum is then exact in
f32 regardless of reduction order, so id equality is bit-for-bit across
the one-hot-matmul (Pallas), gather-sum (jnp), and oracle formulations —
and exact score ties are abundant, exercising the lower-index-first tie
rule at the L boundary instead of dodging it.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anns, imi
from repro.kernels import ops, ref
from repro.kernels import pq_scan as pqs


def _int_luts(key, Q, P, M):
    return jax.random.randint(key, (Q, P, M), -32, 32).astype(jnp.float32)


def _check(got, want):
    gs, gi = map(np.asarray, got)
    ws, wi = map(np.asarray, want)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_array_equal(
        np.nan_to_num(gs, neginf=-1e30), np.nan_to_num(ws, neginf=-1e30))


@pytest.mark.parametrize("Q,P,M,N,k,block", [
    (1, 4, 16, 100, 10, 64),
    (4, 8, 32, 1000, 37, 256),     # k unaligned, N % block != 0
    (2, 4, 16, 130, 200, 128),     # k > N: dead slots
    (3, 8, 32, 2048, 100, 512),
])
def test_topk_batched_oracle_parity(Q, P, M, N, k, block):
    k1, k2 = jax.random.split(jax.random.PRNGKey(P * M + N))
    luts = _int_luts(k1, Q, P, M)
    codes = jax.random.randint(k2, (N, P), 0, M)
    # duplicated rows across block boundaries: exact ties at the L boundary
    codes = codes.at[N // 2:N // 2 + 5].set(codes[:5])
    _check(ops.pq_scan_topk_batched(luts, codes, k, block_n=block),
           ref.pq_scan_topk_ref(luts, codes, k))


def test_topk_batched_bias_and_mask():
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    luts = _int_luts(keys[0], 3, 8, 32)
    codes = jax.random.randint(keys[1], (777, 8), 0, 32)
    bias = jax.random.randint(keys[2], (777,), -16, 16).astype(jnp.float32)
    mask = (jax.random.uniform(keys[3], (3, 777)) < 0.5).astype(jnp.uint8)
    _check(ops.pq_scan_topk_batched(luts, codes, 50, bias=bias, block_n=256),
           ref.pq_scan_topk_ref(luts, codes, 50, bias=bias))
    _check(ops.pq_scan_topk_batched_masked(luts, codes, mask, 50, bias=bias,
                                           block_n=256),
           ref.pq_scan_topk_ref(luts, codes, 50, bias=bias, mask=mask))


@pytest.mark.parametrize("masked", [False, True])
def test_topk_windowed_oracle_parity(masked):
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    Q, P, M, N, A, k = 3, 8, 32, 911, 5, 50
    luts = _int_luts(keys[0], Q, P, M)
    codes = jax.random.randint(keys[1], (N, P), 0, M)
    starts = jax.random.randint(keys[2], (Q, A), 0, N)
    counts = jnp.minimum(jax.random.randint(keys[3], (Q, A), 0, 200),
                         N - starts)
    bases = jax.random.randint(keys[4], (Q, A), -16, 16).astype(jnp.float32)
    mask = (jax.random.uniform(keys[5], (Q, N)) < 0.7).astype(jnp.uint8)
    if masked:
        got = ops.pq_scan_topk_windowed_masked(luts, codes, starts, counts,
                                               bases, mask, k, block_n=256)
        want = ref.pq_scan_topk_windowed_ref(luts, codes, starts, counts,
                                             bases, k, mask=mask)
    else:
        got = ops.pq_scan_topk_windowed(luts, codes, starts, counts,
                                        bases, k, block_n=256)
        want = ref.pq_scan_topk_windowed_ref(luts, codes, starts, counts,
                                             bases, k)
    _check(got, want)


@pytest.mark.parametrize("masked", [False, True])
def test_topk_paired_oracle_parity(masked):
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    Q, P, M, Nc, k = 3, 8, 32, 700, 64
    luts = _int_luts(keys[0], Q, P, M)
    codes = jax.random.randint(keys[1], (Q, Nc, P), 0, M)
    bias = jax.random.randint(keys[2], (Q, Nc), -16, 16).astype(jnp.float32)
    mask = (jax.random.uniform(keys[3], (Q, Nc)) < 0.6).astype(jnp.uint8)
    if masked:
        got = ops.pq_scan_topk_paired_masked(luts, codes, mask, k,
                                             bias=bias, block_n=256)
        want = ref.pq_scan_topk_ref(luts, codes, k, bias=bias, mask=mask)
    else:
        got = ops.pq_scan_topk_paired(luts, codes, k, bias=bias, block_n=256)
        want = ref.pq_scan_topk_ref(luts, codes, k, bias=bias)
    _check(got, want)


def test_topk_jnp_blocked_parity():
    """The blocked-jnp fused formulations (the 'auto' path off-TPU) honor
    the exact same contract as the Pallas kernels."""
    keys = jax.random.split(jax.random.PRNGKey(11), 6)
    luts = _int_luts(keys[0], 3, 8, 32)
    codes = jax.random.randint(keys[1], (901, 8), 0, 32)
    bias = jax.random.randint(keys[2], (901,), -16, 16).astype(jnp.float32)
    mask = (jax.random.uniform(keys[3], (3, 901)) < 0.5).astype(jnp.uint8)
    _check(pqs.pq_scan_topk_jnp(luts, codes, 40, bias, mask, block_n=256),
           ref.pq_scan_topk_ref(luts, codes, 40, bias=bias, mask=mask))
    starts = jax.random.randint(keys[4], (3, 4), 0, 901)
    counts = jnp.minimum(
        jax.random.randint(keys[5], (3, 4), 0, 300), 901 - starts)
    bases = bias[:12].reshape(3, 4)
    _check(pqs.pq_scan_topk_windowed_jnp(luts, codes, starts, counts,
                                         bases, 40, mask, block_n=256),
           ref.pq_scan_topk_windowed_ref(luts, codes, starts, counts,
                                         bases, 40, mask=mask))
    pcodes = jax.random.randint(keys[1], (3, 500, 8), 0, 32)
    pbias = jax.random.randint(keys[2], (3, 500), -16, 16) \
        .astype(jnp.float32)
    pmask = (jax.random.uniform(keys[3], (3, 500)) < 0.6).astype(jnp.uint8)
    _check(pqs.pq_scan_topk_paired_jnp(luts, pcodes, 64, pbias, pmask,
                                       block_n=128),
           ref.pq_scan_topk_ref(luts, pcodes, 64, bias=pbias, mask=pmask))


def test_topk_massive_ties_lower_index_first():
    """A constant LUT makes every row score identically: the top-k must be
    rows 0..k-1 in order, across block boundaries."""
    luts = jnp.ones((2, 4, 16), jnp.float32)
    codes = jax.random.randint(jax.random.PRNGKey(0), (500, 4), 0, 16)
    for got in (ops.pq_scan_topk_batched(luts, codes, 20, block_n=128),
                pqs.pq_scan_topk_jnp(luts, codes, 20, block_n=128)):
        s, i = map(np.asarray, got)
        np.testing.assert_array_equal(
            i, np.broadcast_to(np.arange(20), (2, 20)))
        np.testing.assert_array_equal(s, np.full((2, 20), 4.0))


def test_topk_all_rows_masked_dead_slots():
    """All-False mask: exactly k (-inf, -1) slots — never a garbage index."""
    luts = _int_luts(jax.random.PRNGKey(1), 2, 4, 16)
    codes = jax.random.randint(jax.random.PRNGKey(2), (300, 4), 0, 16)
    zmask = jnp.zeros((2, 300), jnp.uint8)
    for got in (
            ops.pq_scan_topk_batched_masked(luts, codes, zmask, 10,
                                            block_n=128),
            pqs.pq_scan_topk_jnp(luts, codes, 10, None, zmask, block_n=128)):
        s, i = map(np.asarray, got)
        assert (i == -1).all() and np.isneginf(s).all()


def test_topk_k_exceeds_live_rows():
    """Mask leaves fewer than k selectable rows: the tail is dead slots."""
    luts = _int_luts(jax.random.PRNGKey(4), 2, 4, 16)
    codes = jax.random.randint(jax.random.PRNGKey(5), (400, 4), 0, 16)
    mask = jnp.zeros((2, 400), jnp.uint8).at[:, :7].set(1)
    for got in (
            ops.pq_scan_topk_batched_masked(luts, codes, mask, 25,
                                            block_n=128),
            pqs.pq_scan_topk_jnp(luts, codes, 25, None, mask, block_n=128)):
        s, i = map(np.asarray, got)
        assert np.isfinite(s[:, :7]).all() and (i[:, :7] >= 0).all()
        assert (i[:, 7:] == -1).all() and np.isneginf(s[:, 7:]).all()
    _check(ops.pq_scan_topk_batched_masked(luts, codes, mask, 25,
                                           block_n=128),
           ref.pq_scan_topk_ref(luts, codes, 25, mask=mask))


# -- search_batch end to end --------------------------------------------------

@pytest.fixture(scope="module")
def index():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (3000, 64))
    ids = jnp.arange(3000, dtype=jnp.int32)
    return imi.build_imi(jax.random.PRNGKey(1), x, ids,
                         K=8, P=8, M=32, kmeans_iters=5)


QS = jax.random.normal(jax.random.PRNGKey(7), (5, 64))


@pytest.mark.parametrize("use_kernel", ["jnp", "pallas"])
@pytest.mark.parametrize("branch,masked", [
    ("shared", False), ("shared", True),
    ("paired", False), ("paired", True),
])
def test_search_batch_fused_matches_legacy(index, branch, masked,
                                           use_kernel):
    """The fused path must return identical ids (scores to f32 noise) to
    the legacy materialize-then-top_k path, on both scan branches, with
    and without the planner's row mask.

    Exact equality relies on the fetch_k-boundary approx scores being
    distinct (generic for real-valued embeddings): on an exact cross-
    window score tie the shared-branch fused path breaks by global row id
    (the oracle's rule) while legacy breaks by probe-window position —
    see the note in ``search_batch``."""
    if branch == "shared":
        kw = dict(top_a=8, max_cell_size=1024)      # covers the index
    else:
        kw = dict(top_a=4, max_cell_size=128)
    cfg_fused = anns.SearchConfig(top_k=32, use_kernel=use_kernel, **kw)
    cfg_legacy = anns.SearchConfig(top_k=32, use_kernel=use_kernel,
                                   fused_topk=False, **kw)
    mask = None
    if masked:
        mask = (np.arange(index.n) % 3 != 0)
        mask = jnp.asarray(mask)
    rf = anns.search_batch(index, QS, cfg_fused, mask)
    rl = anns.search_batch(index, QS, cfg_legacy, mask)
    np.testing.assert_array_equal(np.asarray(rf["ids"]),
                                  np.asarray(rl["ids"]))
    np.testing.assert_array_equal(np.asarray(rf["rows"]),
                                  np.asarray(rl["rows"]))
    np.testing.assert_allclose(
        np.nan_to_num(np.asarray(rf["scores"]), neginf=-1e30),
        np.nan_to_num(np.asarray(rl["scores"]), neginf=-1e30),
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.nan_to_num(np.asarray(rf["approx_scores"]), neginf=-1e30),
        np.nan_to_num(np.asarray(rl["approx_scores"]), neginf=-1e30),
        rtol=1e-3, atol=1e-3)


def test_search_single_query_fused_matches_legacy(index):
    cfg_f = anns.SearchConfig(top_a=4, max_cell_size=128, top_k=16)
    cfg_l = anns.SearchConfig(top_a=4, max_cell_size=128, top_k=16,
                              fused_topk=False)
    rf = anns.search(index, QS[0], cfg_f)
    rl = anns.search(index, QS[0], cfg_l)
    np.testing.assert_array_equal(np.asarray(rf["ids"]),
                                  np.asarray(rl["ids"]))


def test_search_batch_all_masked_returns_dead_slots(index):
    cfg = anns.SearchConfig(top_a=8, max_cell_size=1024, top_k=16)
    res = anns.search_batch(index, QS, cfg,
                            jnp.zeros((index.n,), jnp.uint8))
    assert (np.asarray(res["ids"]) == -1).all()
    assert (np.asarray(res["rows"]) == -1).all()
    assert np.isneginf(np.asarray(res["scores"])).all()


def test_exhaustive_adc_fused_matches_legacy(index):
    rf = anns.exhaustive_adc(index, QS[0], k=20)
    rl = anns.exhaustive_adc(index, QS[0], k=20, fused_topk=False)
    np.testing.assert_array_equal(np.asarray(rf["ids"]),
                                  np.asarray(rl["ids"]))
    np.testing.assert_allclose(np.asarray(rf["scores"]),
                               np.asarray(rl["scores"]),
                               rtol=1e-5, atol=1e-5)


def test_use_kernel_auto_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert ops.resolve_use_kernel("auto") == expect
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert ops.resolve_use_kernel("auto") == "pallas"
    assert ops.resolve_use_kernel("jnp") == "jnp"
    assert ops.resolve_use_kernel("pallas") == "pallas"
    with pytest.raises(ValueError):
        ops.resolve_use_kernel("cuda")
