"""End-to-end behaviour tests for the LOVO system (Algorithm 2 pipeline).

Builds a small-but-real index over synthetic videos and checks the paper's
qualitative claims hold in-system: two-stage query runs, ablations change
behavior in the predicted direction, keyframing reduces index size.
"""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="module")
def engine():
    from repro.launch.serve import build_engine
    eng, videos = build_engine(seed=0, n_videos=4, res=96)
    return eng, videos


def test_index_built(engine):
    eng, videos = engine
    total_frames = sum(v.frames.shape[0] for v in videos)
    assert eng.built.index.n == len(eng.built.keyframes) \
        * eng.built.patches_per_frame
    # keyframing reduced the frame count (Table IV 'w/o Key frame')
    assert len(eng.built.keyframes) < total_frames


def test_two_stage_query_runs(engine):
    eng, _ = engine
    r = eng.query("a large red square", top_n=3)
    assert len(r.frames) <= 3 and len(r.frames) > 0
    assert r.boxes.shape[-1] == 4
    assert np.isfinite(r.scores).all()
    assert (r.boxes >= 0).all() and (r.boxes <= 1).all()
    assert set(r.timings) >= {"encode", "fast_search", "rerank"}


def test_fast_search_only_is_faster(engine):
    eng, _ = engine
    r_fast = eng.query("a small blue circle", top_n=3, use_rerank=False)
    r_full = eng.query("a small blue circle", top_n=3, use_rerank=True)
    assert "rerank" not in r_fast.timings
    assert r_full.timings["rerank"] > 0


def test_metadata_store_linkage(engine):
    eng, videos = engine
    ids, scores, _ = eng.fast_search("a green triangle")
    meta = eng.built.metadata.lookup(ids)
    assert (meta["video"] >= 0).all()
    assert (meta["video"] < len(videos)).all()
    assert meta["bbox"].shape == (len(ids), 4)
    # patch id -> keyframe row consistency
    rows = ids // eng.built.patches_per_frame
    assert (rows < len(eng.built.keyframes)).all()


def test_keyframe_ablation_grows_index():
    """'w/o Key frame' indexes every frame: larger index (paper: 7976MB vs
    2453MB memory), same pipeline."""
    from repro.core.index_builder import build_from_videos
    from repro.data.synthetic import make_dataset
    from repro.models import vit as V
    vcfg = V.ViTConfig(n_layers=1, d_model=32, n_heads=2, d_ff=64,
                       patch=16, img_res=96, embed_dim=32)
    vp = V.init_vit(jax.random.PRNGKey(0), vcfg)[0]
    videos = make_dataset(1, n_videos=2, res=96)
    with_kf = build_from_videos(jax.random.PRNGKey(1), videos, vp, vcfg,
                                K=4, P=4, M=16, use_keyframes=True)
    without = build_from_videos(jax.random.PRNGKey(1), videos, vp, vcfg,
                                K=4, P=4, M=16, use_keyframes=False)
    assert without.index.n > with_kf.index.n


def test_motion_keyframes_catch_scene_change():
    from repro.data.synthetic import make_video
    from repro.data.video import extract_keyframes, motion_energy
    rng = np.random.default_rng(5)
    v = make_video(rng, n_frames=32, res=64)
    idx = extract_keyframes(v.frames, stride=16)
    assert 0 in idx.tolist()
    assert len(idx) >= 2
    e = motion_energy(v.frames)
    assert e.shape == (32,) and e[0] == 0.0
