"""Multi-host sharded fused scan: shard-parity + fault-injection layer.

The headline contract (DESIGN.md §13): the ``shard_map`` top-k farm in
``core.distributed`` is BIT-IDENTICAL to the single-host fused scan
(``anns.search_batch(fused_topk=True)``) for every shard count — same ids,
same scores, same dead-slot ``(-inf, -1)`` padding — under row masks,
tombstone bitmaps, exact ADC ties at the fetch boundary, ragged last
shards, and after shard-boundary migration.  Multi-device cases run in one
cached subprocess over 8 simulated host devices (conftest forbids
``xla_force_host_platform_device_count`` in the pytest process itself);
property tests, the merge primitive, ``shard_map_compat`` spellings, the
generation-stamped routing protocol, and the router fault-injection layer
run in-process.
"""
import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from _faulty import FaultyReplica, ShardFault
from repro.core import anns, distributed as dist, imi as imimod
from repro.core import plan as P, pq as pqmod
from repro.kernels import ops as kops, pq_scan as _pq, ref as kref

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# headline: bit-parity across shard counts on 8 simulated devices
# ---------------------------------------------------------------------------
_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import anns, distributed as dist, imi as imimod

    out = {"devices": len(jax.devices())}
    n, d = 4096, 32
    cents = jax.random.normal(jax.random.PRNGKey(1), (16, d))
    a = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, 16)
    x = np.array(cents[a] + 0.3 * jax.random.normal(
        jax.random.PRNGKey(3), (n, d)))
    # duplicated rows encode to identical PQ codes -> exact ADC score ties,
    # including at the fetch-k boundary; parity must hold through them
    x[1024:1056] = x[0:32]
    x[3000:3008] = x[2000:2008]
    index = imimod.build_imi(jax.random.PRNGKey(0), jnp.asarray(x),
                             jnp.arange(n), K=8, P=4, M=32, kmeans_iters=5)
    cfg = anns.SearchConfig(top_a=16, max_cell_size=256, top_k=32,
                            rerank_overfetch=4)
    assert cfg.top_a * cfg.max_cell_size >= n   # shared/windowed branch
    qs = jax.random.normal(jax.random.PRNGKey(9), (5, d))
    ref = jax.jit(lambda q: anns.search_batch(index, q, cfg))(qs)
    # evidence the tie scenario is real: duplicate approx scores survive
    # into the returned window
    ap = np.asarray(ref["approx_scores"])
    out["ties_present"] = bool(any(
        len(np.unique(r[np.isfinite(r)])) < np.isfinite(r).sum()
        for r in ap))

    KEYS = ("ids", "rows", "scores", "approx_scores")
    def parity(got, want, keys=KEYS):
        return bool(all(np.array_equal(np.asarray(want[k]),
                                       np.asarray(got[k])) for k in keys))

    mask1 = jnp.asarray((np.arange(n) % 3 != 0).astype(np.uint8))
    maskq = jnp.asarray((np.random.default_rng(4).random((5, n)) < 0.7)
                        .astype(np.uint8))
    refm1 = jax.jit(lambda q, m: anns.search_batch(index, q, cfg,
                                                   row_mask=m))(qs, mask1)
    refmq = jax.jit(lambda q, m: anns.search_batch(index, q, cfg,
                                                   row_mask=m))(qs, maskq)

    for S in (1, 2, 4, 8):
        mesh = Mesh(np.array(jax.devices()[:S]), ("shards",))
        sidx = dist.shard_put(dist.shard_index(index, S), mesh)
        search = jax.jit(dist.make_sharded_search(mesh, cfg=cfg))
        out[f"parity_s{S}"] = parity(search(sidx, qs), ref)
        out[f"masked_s{S}"] = parity(search(sidx, qs, mask1), refm1,
                                     ("ids", "rows", "scores"))
    mesh4 = Mesh(np.array(jax.devices()[:4]), ("shards",))
    search4 = jax.jit(dist.make_sharded_search(mesh4, cfg=cfg))
    sidx4 = dist.shard_put(dist.shard_index(index, 4), mesh4)
    out["per_query_mask"] = parity(search4(sidx4, qs, maskq), refmq,
                                   ("ids", "rows", "scores"))
    # tombstone bitmap folded into row_valid == single-host row_mask
    tomb = dist.shard_put(
        dist.shard_index(index, 4, alive=np.asarray(mask1, bool)), mesh4)
    out["tombstones"] = parity(search4(tomb, qs), refm1,
                               ("ids", "rows", "scores"))
    # ragged/uneven shard boundaries (tiny + huge + empty-ish shards)
    rag = dist.shard_put(dist.shard_index(
        index, 4, boundaries=[0, 64, 64, 3777, n]), mesh4)
    out["ragged"] = parity(search4(rag, qs), ref)
    # shard-boundary migration: a segment moves from shard 0 to shard 1
    # (the routing-table generation bump rides the host tier; the farm
    # itself must give identical answers for BOTH layouts)
    pre = dist.shard_put(dist.shard_index(
        index, 4, boundaries=[0, 2048, 2560, 3072, n]), mesh4)
    post = dist.shard_put(dist.shard_index(
        index, 4, boundaries=[0, 1024, 2560, 3072, n]), mesh4)
    out["migration"] = (parity(search4(pre, qs), ref)
                        and parity(search4(post, qs), ref))
    # multi-axis mesh -> all_gather merge branch (butterfly needs a flat
    # power-of-two axis)
    mesh42 = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    s8 = dist.shard_put(dist.shard_index(index, 8, cell_aligned=True),
                        mesh42)
    out["all_gather_mesh"] = parity(
        jax.jit(dist.make_sharded_search(mesh42, cfg=cfg))(s8, qs), ref)

    # elastic: shards built straight from a VectorStore (segment fold +
    # tombstone bitmap), parity vs single-host over the same store state
    from repro.store import VectorStore
    root = tempfile.mkdtemp()
    store = VectorStore.create(root, index)
    extra = jax.random.normal(jax.random.PRNGKey(11), (64, d))
    store.insert(np.asarray(extra), np.arange(n, n + 64))
    sidx_st = dist.shard_index_from_store(store, 4)   # folds the delta
    base2 = store.seg.base
    cfg2 = anns.SearchConfig(top_a=16, max_cell_size=-(-base2.n // 16),
                             top_k=32, rerank_overfetch=4)
    ref2 = jax.jit(lambda q: anns.search_batch(base2, q, cfg2))(qs)
    search_st = jax.jit(dist.make_sharded_search(mesh4, cfg=cfg2))
    out["from_store"] = parity(search_st(
        dist.shard_put(sidx_st, mesh4), qs), ref2)
    # now tombstones only (no pending segments -> no compact, bitmap path)
    dead_ids = np.arange(0, base2.n, 5)
    store.delete(dead_ids)
    sidx_tomb = dist.shard_index_from_store(store, 4)
    alive2 = ~np.isin(np.asarray(base2.ids), dead_ids)
    ref3 = jax.jit(lambda q, m: anns.search_batch(
        base2, q, cfg2, row_mask=m))(qs, jnp.asarray(
            alive2.astype(np.uint8)))
    out["from_store_tombstones"] = parity(
        search_st(dist.shard_put(sidx_tomb, mesh4), qs), ref3,
        ("ids", "rows", "scores"))
    print("RESULT " + json.dumps(out))
""")


@functools.lru_cache(maxsize=1)
def _subprocess_results() -> dict:
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:] + proc.stderr[-2000:]
    return json.loads(line[-1][len("RESULT "):])


def test_sharded_scan_bit_parity_across_shard_counts():
    r = _subprocess_results()
    assert r["devices"] == 8
    for S in (1, 2, 4, 8):
        assert r[f"parity_s{S}"], f"S={S} diverged from single-host scan"


def test_sharded_scan_parity_under_masks_and_tombstones():
    r = _subprocess_results()
    for S in (1, 2, 4, 8):
        assert r[f"masked_s{S}"]
    assert r["per_query_mask"]
    assert r["tombstones"]


def test_sharded_scan_parity_ties_ragged_migration():
    r = _subprocess_results()
    assert r["ties_present"], "tie scenario was not actually exercised"
    assert r["ragged"]
    assert r["migration"]
    assert r["all_gather_mesh"]


def test_sharded_scan_from_store_segment_aligned():
    r = _subprocess_results()
    assert r["from_store"]
    assert r["from_store_tombstones"]


# ---------------------------------------------------------------------------
# property-based shard parity: sharded merge == materialize-then-top_k oracle
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_sharded_merge_matches_oracle(data):
    """Random (N, Q, k, shard count, mask) draws: contiguous per-shard
    fused scans + ``tree_merge_topk`` must equal ``ref.pq_scan_topk_ref``
    over the union — including k > live rows, fully-masked shards, empty
    shards, and the exact ``(-inf, -1)`` dead-slot contract."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    N = data.draw(st.integers(1, 300))
    Q = data.draw(st.integers(1, 4))
    k = data.draw(st.integers(1, 2 * N))        # may exceed live rows
    S = data.draw(st.integers(1, 6))
    P_, M = 4, 16
    luts = jnp.asarray(rng.normal(size=(Q, P_, M)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, M, size=(N, P_)).astype(np.uint8))
    mask_kind = data.draw(st.sampled_from(
        ["none", "random", "dead_shard", "all_dead"]))
    cuts = sorted(rng.integers(0, N + 1, size=S - 1).tolist())
    bounds = [0] + cuts + [N]
    mask = None
    if mask_kind == "random":
        mask = (rng.random((Q, N)) < 0.6).astype(np.uint8)
    elif mask_kind == "dead_shard":                 # one whole shard masked
        mask = np.ones((Q, N), np.uint8)
        s = int(rng.integers(0, S))
        mask[:, bounds[s]: bounds[s + 1]] = 0
    elif mask_kind == "all_dead":
        mask = np.zeros((Q, N), np.uint8)

    parts = []
    for s in range(S):
        lo, hi = bounds[s], bounds[s + 1]
        if hi == lo:                                 # empty shard
            parts.append((jnp.full((Q, k), -jnp.inf),
                          jnp.full((Q, k), -1, jnp.int32)))
            continue
        m = jnp.asarray(mask[:, lo:hi]) if mask is not None else None
        sc, rows = _pq.pq_scan_topk_jnp(luts, codes[lo:hi], k, None, m)
        parts.append((sc, jnp.where(rows >= 0, rows + lo, -1)))
    got_s, got_i = dist.tree_merge_topk(parts, k)
    want_s, want_i = kref.pq_scan_topk_ref(
        luts, codes, k, mask=jnp.asarray(mask) if mask is not None else None)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    dead = ~np.isfinite(np.asarray(got_s))
    assert (np.asarray(got_i)[dead] == -1).all()


def test_topk_merge_ties_payload_and_dead_slots():
    """Unit contract of the merge primitive: (score desc, id asc) keying,
    payload permutation, dead slots last with ``(-inf, -1)``."""
    s_a = jnp.asarray([[3.0, 1.0, -jnp.inf]])
    i_a = jnp.asarray([[7, 2, -1]], dtype=jnp.int32)
    s_b = jnp.asarray([[3.0, 2.0, -jnp.inf]])
    i_b = jnp.asarray([[4, 9, -1]], dtype=jnp.int32)
    pay_a = (jnp.asarray([[70.0, 20.0, 0.0]]),)
    pay_b = (jnp.asarray([[40.0, 90.0, 0.0]]),)
    s, i, p = kops.topk_merge(s_a, i_a, s_b, i_b, 6, pay_a, pay_b)
    # tie at 3.0 -> lower id (4) first; dead slots trail as (-inf, -1)
    np.testing.assert_array_equal(np.asarray(i), [[4, 7, 9, 2, -1, -1]])
    np.testing.assert_array_equal(np.asarray(s)[0, :4], [3.0, 3.0, 2.0, 1.0])
    np.testing.assert_array_equal(np.asarray(p)[0, :4],
                                  [40.0, 70.0, 90.0, 20.0])
    assert np.isneginf(np.asarray(s)[0, 4:]).all()
    # k smaller than either input cuts after the global sort
    s2, i2 = kops.topk_merge(s_a, i_a, s_b, i_b, 2)
    np.testing.assert_array_equal(np.asarray(i2), [[4, 7]])


# ---------------------------------------------------------------------------
# shard_map_compat: both jax spellings
# ---------------------------------------------------------------------------
def test_shard_map_compat_stable_spelling(monkeypatch):
    """When ``jax.shard_map`` exists (newer jax), compat must route there
    with ``check_vma`` (not the legacy ``check_rep``)."""
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma):
        seen.update(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_vma)
        return lambda *a: "stable"

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    mesh = jax.make_mesh((1,), ("shards",))
    wrapped = dist.shard_map_compat(lambda x: x, mesh=mesh,
                                    in_specs=(None,), out_specs=None)
    assert wrapped() == "stable"
    assert seen["check_vma"] is False and seen["mesh"] is mesh


def test_shard_map_compat_experimental_spelling(monkeypatch):
    """Without ``jax.shard_map`` (this container's jax), compat must fall
    back to ``jax.experimental.shard_map`` — and actually execute."""
    monkeypatch.delattr(jax, "shard_map", raising=False)
    assert not hasattr(jax, "shard_map")
    from jax.sharding import PartitionSpec as PS
    mesh = jax.make_mesh((1,), ("shards",))
    f = dist.shard_map_compat(lambda x: x * 2, mesh=mesh,
                              in_specs=(PS("shards"),),
                              out_specs=PS("shards"))
    np.testing.assert_array_equal(
        np.asarray(f(jnp.arange(4.0))), [0.0, 2.0, 4.0, 6.0])


# ---------------------------------------------------------------------------
# satellite fix: defaults + optional-rotation handling
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_index():
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 32))
    return imimod.build_imi(jax.random.PRNGKey(1), x, jnp.arange(512),
                            K=4, P=4, M=16, kmeans_iters=4)


def test_make_sharded_search_default_kernel_matches_auto(small_index):
    """The default config must flow through ``resolve_use_kernel('auto')``
    like the single-host PR-5 path (stale pre-fusion defaults are gone):
    off-TPU the auto route IS the jnp route, bit for bit."""
    assert anns.SearchConfig().use_kernel == "auto"
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("shards",))
    sidx = dist.shard_put(dist.shard_index(small_index, 1), mesh)
    qs = jax.random.normal(jax.random.PRNGKey(2), (3, 32))
    cfg = anns.SearchConfig(top_a=8, max_cell_size=64, top_k=16)
    auto = dist.make_sharded_search(mesh, cfg=cfg)(sidx, qs)
    forced = dist.make_sharded_search(
        mesh, cfg=cfg, use_kernel="jnp")(sidx, qs)
    for k in ("ids", "scores", "rows"):
        np.testing.assert_array_equal(np.asarray(auto[k]),
                                      np.asarray(forced[k]))
    # parity against the single-host auto path too
    ref = anns.search_batch(small_index, qs, cfg)
    for k in ("ids", "scores", "rows"):
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(auto[k]))


def test_sharded_index_rotation_is_structurally_optional(small_index):
    """No OPQ -> ``pq_rotation`` is None (an absent pytree child), not a
    dense identity matmul smuggled into every LUT build; with OPQ the
    rotation rides along and parity still holds."""
    s = dist.shard_index(small_index, 2)
    assert small_index.pq.rotation is None
    assert s.pq_rotation is None
    leaves = jax.tree_util.tree_leaves(s)
    assert not any(l.ndim == 2 and l.shape[0] == l.shape[1]
                   and np.array_equal(np.asarray(l), np.eye(l.shape[0]))
                   for l in leaves if hasattr(l, "ndim"))

    x = jax.random.normal(jax.random.PRNGKey(5), (512, 32))
    opq = imimod.build_imi(jax.random.PRNGKey(6), x, jnp.arange(512),
                           K=4, P=4, M=16, kmeans_iters=4, opq_iters=2)
    assert opq.pq.rotation is not None
    so = dist.shard_index(opq, 2)
    assert so.pq_rotation is not None
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("shards",))
    cfg = anns.SearchConfig(top_a=8, max_cell_size=64, top_k=16)
    qs = jax.random.normal(jax.random.PRNGKey(7), (2, 32))
    got = dist.make_sharded_search(mesh, cfg=cfg)(
        dist.shard_put(dist.shard_index(opq, 1), mesh), qs)
    ref = anns.search_batch(opq, qs, cfg)
    for k in ("ids", "scores"):
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(got[k]))


# ---------------------------------------------------------------------------
# fault injection: the router around the shard farm
# ---------------------------------------------------------------------------
F, KP = 30, 4


def _plan_meta():
    return P.PlanMeta(
        row_video=np.repeat(np.arange(3), 10 * KP).astype(np.int32),
        row_time=np.tile(np.repeat(np.arange(10), KP), 3).astype(np.int32),
        frame_video=np.repeat(np.arange(3), 10).astype(np.int32),
        frame_time=np.tile(np.arange(10), 3).astype(np.int32),
        patches_per_frame=KP)


def _shard_search(lo, hi):
    def search(texts, masks, k=20):
        ids = np.zeros((len(texts), k), np.int32)
        scores = np.full((len(texts), k), -np.inf, np.float32)
        for i, t in enumerate(texts):
            r = np.random.default_rng(sum(t.encode()) % 2**32)
            pid = r.choice(F * KP, size=k, replace=False).astype(np.int32)
            sc = (1.0 + r.random(k)).astype(np.float32)
            ok = (pid >= lo) & (pid < hi)
            if masks is not None:
                ok &= masks[i][pid]
            pid, sc = np.where(ok, pid, -1), np.where(ok, sc, -np.inf)
            o = np.argsort(-sc)
            ids[i], scores[i] = pid[o], sc[o]
        return ids, scores
    return search


def test_execute_sharded_raises_on_midstream_fault_never_merges():
    """A shard fault mid-``call_sharded`` via ``plan.execute_sharded``
    must RAISE (missing shard == incomplete merge), while ``call_batch``
    over the same router re-routes around the demoted replica."""
    from repro.serving.router import QueryRouter, ReplicaUnavailable
    meta = _plan_meta()
    node = P.GroupTopK(P.Or(P.Text("red truck"), P.Text("pedestrian")),
                       per="video", k=2)
    router = QueryRouter(unhealthy_after=1)
    bounds = [0, F * KP // 2, F * KP]
    faulty = None
    for s in range(2):
        fn = (lambda payload, s=s: P.execute(
            payload, meta, _shard_search(bounds[s], bounds[s + 1])))
        if s == 1:
            fn = faulty = FaultyReplica(fn, fail_calls={0})  # first call dies
        router.add_replica(f"shard-{s}", fn)
    with pytest.raises(ShardFault):
        P.execute_sharded(node, meta, router)
    assert faulty.faults == 1
    # the fault demoted shard-1 -> the broadcast now refuses up front
    with pytest.raises(ReplicaUnavailable, match="shard-1"):
        P.execute_sharded(node, meta, router)
    # call_batch by contrast degrades: items re-route to shard-0
    router.mark_recovered("shard-1")
    got = router.call_batch([P.Text("red truck")] * 3)
    assert len(got) == 3 and all(g is not None for g in got)
    router.close()

    # healthy run for reference: merged == single-index execution
    router2 = QueryRouter()
    for s in range(2):
        router2.add_replica(f"shard-{s}", lambda payload, s=s: P.execute(
            payload, meta, _shard_search(bounds[s], bounds[s + 1])))
    merged = P.execute_sharded(node, meta, router2)
    full = P.execute(node, meta, _shard_search(0, F * KP))
    np.testing.assert_array_equal(merged.frames, full.frames)
    router2.close()


def test_seeded_faulty_replica_rates_are_deterministic():
    f1 = FaultyReplica(lambda p: p, seed=7, fail_rate=0.5)
    f2 = FaultyReplica(lambda p: p, seed=7, fail_rate=0.5)
    pat1, pat2 = [], []
    for f, pat in ((f1, pat1), (f2, pat2)):
        for i in range(20):
            try:
                f(i)
                pat.append(True)
            except ShardFault:
                pat.append(False)
    assert pat1 == pat2 and not all(pat1) and any(pat1)


# ---------------------------------------------------------------------------
# generation-stamped routing: migration/split protocol
# ---------------------------------------------------------------------------
def test_routing_table_generation_protocol():
    t0 = dist.RoutingTable.initial(["a", "b"], boundaries=[0, 100, 200])
    assert t0.generation == 0 and t0.replicas() == ("a", "b")
    t1 = t0.migrate(1, "c")
    assert t1.generation == 1 and t1.replicas() == ("a", "c")
    assert t0.replicas() == ("a", "b")          # immutable
    t2 = t1.split(0, 50, "d")
    assert t2.generation == 2
    ranges = {a.shard_id: a.row_range for a in t2.assignments}
    assert ranges[0] == (0, 50) and (50, 100) in ranges.values()
    with pytest.raises(ValueError):
        t0.migrate(9, "x")
    with pytest.raises(ValueError):
        t0.split(0, 999, "x")


def test_router_refuses_stale_generation_broadcast():
    """A replica that re-registers (pod restart) after a routing install
    has not acked the shard layout — ``call_sharded`` must refuse it
    exactly like a demoted shard, never merge around it."""
    from repro.serving.router import QueryRouter, ReplicaUnavailable
    router = QueryRouter()
    router.add_replica("a", lambda p: [("a", p)])
    router.add_replica("b", lambda p: [("b", p)])
    table = dist.RoutingTable.initial(["a", "b"])
    router.install_routing(table)
    assert router.call_sharded("q", lambda outs: len(outs)) == 2
    router.add_replica("b", lambda p: [("b2", p)])   # restart: stamp lost
    with pytest.raises(ReplicaUnavailable, match="stale"):
        router.call_sharded("q", lambda outs: outs)
    router.install_routing(table)                    # re-ack -> serves again
    assert router.call_sharded("q", lambda outs: len(outs)) == 2
    # a migration bumps the generation; an un-acked table refuses too
    router.install_routing(table.migrate(0, "b"))
    assert router.call_sharded("q", lambda outs: len(outs)) == 1
    with pytest.raises(ReplicaUnavailable):
        router.install_routing(dist.RoutingTable.initial(["a", "ghost"]))
    router.close()


def test_pick_placement_prefers_least_loaded():
    from repro.serving.router import QueryRouter, ReplicaUnavailable
    router = QueryRouter()
    router.add_replica("busy", lambda p: p)
    router.add_replica("idle", lambda p: p)
    with router._lock:
        router._replicas["busy"].outstanding = 5
    assert router.pick_placement() == "idle"
    assert router.pick_placement(exclude=("idle",)) == "busy"
    with pytest.raises(ReplicaUnavailable):
        router.pick_placement(exclude=("idle", "busy"))
    router.close()


# ---------------------------------------------------------------------------
# data plane: WAL-logged segment migration between shard stores
# ---------------------------------------------------------------------------
def test_migrate_rows_between_stores_survives_reopen(tmp_path):
    from repro.store import VectorStore, migrate_rows

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 32))
    idx_a = imimod.build_imi(jax.random.PRNGKey(1), x[:192],
                             jnp.arange(192), K=4, P=4, M=16,
                             kmeans_iters=4)
    idx_b = imimod.build_imi(jax.random.PRNGKey(2), x[192:],
                             jnp.arange(192, 256), K=4, P=4, M=16,
                             kmeans_iters=4)
    src = VectorStore.create(tmp_path / "src", idx_a)
    dst = VectorStore.create(tmp_path / "dst", idx_b)

    moved = migrate_rows(src, dst, np.arange(100, 140))
    assert moved == 40
    assert migrate_rows(src, dst, np.arange(5000, 5010)) == 0  # unknown ids
    # already-moved rows are tombstoned at the source -> idempotent
    assert migrate_rows(src, dst, np.arange(100, 140)) == 0

    def live_ids(store):
        ids = set(np.asarray(store.seg.base.ids).tolist())
        for s in store.seg.segments:
            ids |= set(np.asarray(s.ids).tolist())
        return ids - {int(t) for t in store.seg.tombstones}

    assert live_ids(src) == set(range(100)) | set(range(140, 192))
    assert live_ids(dst) == set(range(100, 140)) | set(range(192, 256))

    # both halves are WAL-logged: a reopen (replay) loses nothing
    src.close(), dst.close()
    src2 = VectorStore.open(tmp_path / "src")
    dst2 = VectorStore.open(tmp_path / "dst")
    assert live_ids(src2) == set(range(100)) | set(range(140, 192))
    assert live_ids(dst2) == set(range(100, 140)) | set(range(192, 256))
    src2.close(), dst2.close()
