"""Elastic query router: replica groups over index shards, failure handling.

The pod-level picture for a 1000+-node LOVO deployment: the index is split
into S logical shards; each REPLICA GROUP (a pod or sub-mesh) holds every
shard once and can answer any query; the router

  * load-balances queries across healthy replica groups (power-of-two
    choices on outstanding load),
  * scatter/gathers BATCHES across replicas (`call_batch`): a query batch
    is split into contiguous shards, each shard goes to a least-loaded
    replica's batch-native fn concurrently, and results are gathered back
    in submit order (failed shards fall back to per-item routing),
  * broadcasts one payload to EVERY replica and merges (`call_sharded`):
    the partitioned-index path, where each replica owns a row shard and a
    complex-query plan must be answered by all of them, with the grouped
    results merged once (`repro.core.plan.merge_grouped`),
  * retires replicas on failure and restores them on recovery (health
    callbacks), rejecting only when NO replica is healthy,
  * hedges stragglers through serving.batcher.HedgedExecutor,
  * supports elastic scale-out: `add_replica()` at runtime, and
    `add_replica_from_store()` — a new pod joins by reopening a persisted
    `repro.store.VectorStore` (mmap segments + WAL replay, no rebuild).

Replicas are callables (in production: per-pod jitted search fns behind an
RPC stub; in tests: functions).  Pure host-side logic — the module imports
no jax; `add_replica_from_store` pulls the store in lazily so the router
can still front any backend.

Failure handling is delegated to one `core.resilience.CircuitBreaker` per
replica (closed / open / half-open with timed recovery probes); the old
`unhealthy_after` / `recovery_probe_s` constructor knobs map onto the
breaker's `failure_threshold` / `recovery_s` and keep their meaning.
Callers may pass a `core.resilience.Deadline` down `__call__` /
`call_batch` / `call_sharded`; the router refuses to start (or keep
retrying) work past the deadline.  `call_sharded(..., degraded_ok=True)`
opts into partial merges: missing shards are skipped and the merged value
comes back wrapped in a `DegradedResult` carrying a `Completeness` record
instead of raising — the strict default still refuses silent partials.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Optional, Sequence

from repro import chaos
from repro.core.resilience import (CircuitBreaker, Deadline, DegradedResult,
                                   RetryPolicy, completeness_from_routing)
from repro.serving.batcher import HedgedExecutor, LatencyTracker


@dataclasses.dataclass
class Replica:
    name: str
    fn: Callable[[Any], Any]
    batch_fn: Optional[Callable[[list], list]] = None
    breaker: CircuitBreaker = dataclasses.field(
        default_factory=CircuitBreaker)
    outstanding: int = 0
    last_error: Optional[str] = None
    # routing-table generation this replica last acknowledged
    # (core.distributed.RoutingTable protocol); -1 = never installed
    generation: int = -1

    @property
    def healthy(self) -> bool:
        return self.breaker.closed

    @property
    def failures(self) -> int:
        return self.breaker.failures


class ReplicaUnavailable(RuntimeError):
    pass


class QueryRouter:
    def __init__(self, *, unhealthy_after: int = 3,
                 recovery_probe_s: float = 5.0, hedge: bool = True,
                 retry: Optional[RetryPolicy] = None):
        self._replicas: dict[str, Replica] = {}
        self._lock = threading.Lock()
        self.unhealthy_after = unhealthy_after
        self.recovery_probe_s = recovery_probe_s
        self.hedge = hedge
        # Optional backoff between failover attempts in __call__; None
        # keeps the historical retry-immediately behavior.
        self.retry = retry
        self.latency = LatencyTracker()
        self._rng = random.Random(0)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._routing: Optional[Any] = None   # distributed.RoutingTable

    def _new_breaker(self) -> CircuitBreaker:
        return CircuitBreaker(failure_threshold=self.unhealthy_after,
                              recovery_s=self.recovery_probe_s)

    # -- membership -----------------------------------------------------------
    def add_replica(self, name: str, fn: Callable[[Any], Any], *,
                    batch_fn: Optional[Callable[[list], list]] = None
                    ) -> None:
        """``fn`` answers one payload; optional ``batch_fn`` answers a LIST
        of payloads in order (e.g. ``engine.query_batch``) and is what
        ``call_batch`` scatters shards to.  Without it, a shard is served
        by mapping ``fn`` inside the shard's worker thread."""
        with self._lock:
            self._replicas[name] = Replica(name=name, fn=fn,
                                           batch_fn=batch_fn,
                                           breaker=self._new_breaker())

    def add_replica_from_store(self, name: str, store_dir: str, *,
                               search_cfg: Any = None,
                               verify: bool = False) -> Any:
        """Elastic join: restore a replica's search fn from a persisted
        ``VectorStore`` (open = mmap + WAL replay; no encode, no k-means).

        Returns the opened store so the caller can keep feeding it inserts.
        ``verify=False`` by default — joining pods favor open latency and
        trust the medium; pass True to checksum every segment first.
        """
        from repro.core import anns
        from repro.store import VectorStore

        store = VectorStore.open(store_dir, verify=verify)
        cfg = search_cfg or anns.SearchConfig()
        self.add_replica(name, lambda q: store.search(q, cfg))
        return store

    def install_routing(self, table: Any) -> None:
        """Install a ``core.distributed.RoutingTable``: every replica the
        table names gets stamped with its generation (acknowledging the
        shard layout).  A later ``call_sharded`` broadcast refuses any
        target still stamped with an OLDER generation — after a migration
        or split, a straggler replica serving the pre-move layout would
        double-count or drop the moved rows, so staleness is a hard error,
        exactly like a demoted shard."""
        with self._lock:
            missing = [n for n in table.replicas() if n not in self._replicas]
            if missing:
                raise ReplicaUnavailable(
                    f"routing table names unregistered replicas: {missing}")
            self._routing = table
            for n in table.replicas():
                self._replicas[n].generation = table.generation

    def pick_placement(self, exclude: Sequence[str] = ()) -> str:
        """Load-aware placement for a NEW or migrating shard: the healthy
        replica with the fewest outstanding requests (ties -> fewest
        recent failures, then name for determinism).  ``exclude`` skips
        the shard's current holder."""
        with self._lock:
            cands = [r for r in self._replicas.values()
                     if r.healthy and r.name not in exclude]
        if not cands:
            raise ReplicaUnavailable("no healthy replica for placement")
        return min(cands, key=lambda r: (r.outstanding, r.failures,
                                         r.name)).name

    def remove_replica(self, name: str) -> None:
        with self._lock:
            self._replicas.pop(name, None)

    def mark_recovered(self, name: str) -> None:
        """Administrative override: force the replica's breaker closed."""
        with self._lock:
            r = self._replicas.get(name)
            if r:
                r.breaker.force_close()

    def healthy_replicas(self) -> list[Replica]:
        with self._lock:
            return [r for r in self._replicas.values() if r.healthy]

    # -- routing ----------------------------------------------------------------
    def _pick(self) -> Replica:
        healthy = self.healthy_replicas()
        if not healthy:
            # No closed breaker: ask each open/half-open breaker for a
            # recovery-probe slot (self-healing; rate-limited by the
            # breaker's recovery window + half-open probe budget).
            with self._lock:
                for r in self._replicas.values():
                    if r.breaker.try_acquire():
                        return r
            raise ReplicaUnavailable("no healthy replicas")
        if len(healthy) == 1:
            return healthy[0]
        a, b = self._rng.sample(healthy, 2)  # power of two choices
        return a if a.outstanding <= b.outstanding else b

    def __call__(self, payload: Any, *,
                 deadline: Optional[Deadline] = None) -> Any:
        last_exc: Optional[BaseException] = None
        for attempt in range(1, max(2, len(self._replicas)) + 1):
            if deadline is not None:
                deadline.check("router call")
            r = self._pick()
            t0 = time.perf_counter()
            with self._lock:
                r.outstanding += 1
            try:
                chaos.failpoint("router.replica.call")
                out = r.fn(payload)
                self.latency.record(time.perf_counter() - t0)
                with self._lock:
                    r.breaker.record_success()
                return out
            except ReplicaUnavailable:
                raise
            except BaseException as e:  # replica fault -> demote, retry next
                last_exc = e
                with self._lock:
                    r.breaker.record_failure()
                    r.last_error = repr(e)
            finally:
                with self._lock:
                    r.outstanding -= 1
            if self.retry is not None:
                backoff = self.retry.backoff_s(attempt)
                if deadline is not None:
                    backoff = min(backoff, max(deadline.remaining(), 0.0))
                if backoff > 0.0:
                    time.sleep(backoff)
        raise ReplicaUnavailable(f"all replicas failing; last: {last_exc!r}")

    # -- batched scatter/gather -------------------------------------------------
    def call_batch(self, payloads: Sequence[Any], *,
                   deadline: Optional[Deadline] = None) -> list:
        """Scatter a batch across healthy replicas, gather in submit order.

        The batch is split into up to ``len(healthy)`` contiguous shards
        assigned least-loaded-first; shards run concurrently.  A shard whose
        replica faults is demoted exactly like ``__call__`` and its items
        are re-routed individually (so one bad pod degrades, not fails, the
        batch).  Raises ``ReplicaUnavailable`` only when no replica works.
        """
        payloads = list(payloads)
        if not payloads:
            return []
        if deadline is not None:
            deadline.check("router call_batch")
        healthy = self.healthy_replicas()
        if len(healthy) <= 1:
            # single (or no) healthy replica: per-item path handles
            # probing/recovery; batch_fn still amortizes if present
            r = healthy[0] if healthy else None
            if r is not None and r.batch_fn is not None:
                try:
                    return self._run_shard(r, payloads)
                except Exception:
                    pass                      # demoted; re-route per item
            return [self(p, deadline=deadline) for p in payloads]

        n_shards = min(len(healthy), len(payloads))
        base, rem = divmod(len(payloads), n_shards)
        shards: list[tuple[int, list]] = []
        lo = 0
        for i in range(n_shards):
            size = base + (1 if i < rem else 0)
            shards.append((lo, payloads[lo: lo + size]))
            lo += size
        targets = sorted(healthy, key=lambda r: r.outstanding)

        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(max_workers=32)
        results: list[Any] = [None] * len(payloads)
        futs = [self._pool.submit(self._run_shard, targets[i], items)
                for i, (_, items) in enumerate(shards)]
        for (off, items), f in zip(shards, futs):
            try:
                out = f.result(timeout=(None if deadline is None
                                        else max(deadline.remaining(), 0.0)))
            except ReplicaUnavailable:
                raise
            except Exception:
                if deadline is not None:
                    deadline.check("router call_batch re-route")
                out = [self(p, deadline=deadline) for p in items]
            results[off: off + len(items)] = out
        return results

    def call_sharded(self, payload: Any, merge: Callable[[list], Any],
                     *, replicas: Optional[Sequence[str]] = None,
                     deadline: Optional[Deadline] = None,
                     degraded_ok: bool = False) -> Any:
        """Broadcast ONE payload to every healthy replica and merge.

        The partitioned-index path: when each replica holds a SHARD of the
        index (rows partitioned, e.g. one ``add_replica_from_store`` per
        shard store), a query — in particular a complex-query plan — must
        run on every shard and the per-shard results must be combined
        (``plan.merge_grouped`` for grouped plan results: send
        ``plan.shard_plan(p)`` as the payload so grouped reductions run
        once, over the merged set).  ``replicas`` restricts the broadcast
        to a named subset (one replica per shard when extra pure replicas
        are registered).

        Unlike ``call_batch``, a faulting OR already-demoted replica here
        means a MISSING SHARD — the merged answer would be silently
        incomplete — so by default the broadcast refuses to run without
        every shard and a mid-call fault is demoted and re-raised, never
        degraded.  With a ``RoutingTable`` installed (``install_routing``),
        the default targets come from the table (one per shard) and any
        target stamped with an older generation is refused the same way —
        a straggler from before a migration/split must not be merged.

        ``degraded_ok=True`` is the EXPLICIT opt-in to partial answers:
        unhealthy, stale, and mid-call-faulting shards are skipped instead
        of refused, and the return value is always a
        :class:`~repro.core.resilience.DegradedResult` whose
        ``completeness`` records exactly which shards (and, with a routing
        table, which row spans) the merge covers — there is no silent
        partial merge, only a labeled one.  A degraded result must never
        be inserted into the plan-level ``ResultCache`` (the cache refuses
        it; DESIGN.md §16).  Raises only when NO shard can answer.
        """
        if deadline is not None:
            deadline.check("router call_sharded")
        with self._lock:
            routing = self._routing
            if replicas is None and routing is not None:
                replicas = routing.replicas()
            targets = [r for r in self._replicas.values()
                       if replicas is None or r.name in replicas]
            if not targets:
                raise ReplicaUnavailable("no shard replicas registered")
            dead = [r.name for r in targets if not r.healthy]
            stale = []
            if routing is not None:
                stale = [r.name for r in targets
                         if r.generation != routing.generation
                         and r.name not in dead]
            if not degraded_ok:
                if dead:
                    raise ReplicaUnavailable(
                        f"shard replicas unhealthy (merge would be "
                        f"incomplete): {dead}")
                if stale:
                    raise ReplicaUnavailable(
                        f"shard replicas stale (routing generation "
                        f"{routing.generation}, merge would be "
                        f"incomplete): {stale}")
            skipped = list(dead) + list(stale)
            live = [r for r in targets if r.name not in skipped]
            if not live:
                raise ReplicaUnavailable(
                    f"no shard replica can answer (unhealthy: {dead}, "
                    f"stale: {stale})")
            if self._pool is None:
                self._pool = ThreadPoolExecutor(max_workers=32)
        futs = [self._pool.submit(self._run_shard, r, [payload])
                for r in live]
        if not degraded_ok:
            outs = [f.result()[0] for f in futs]  # _run_shard demotes on fault
            return merge(outs)
        outs, answered, failed = [], [], []
        for r, f in zip(live, futs):
            try:
                out = f.result(timeout=(None if deadline is None
                                        else max(deadline.remaining(), 0.0)))
                outs.append(out[0])
                answered.append(r.name)
            except Exception:               # demoted by _run_shard; skip
                failed.append(r.name)
        if not answered:
            raise ReplicaUnavailable(
                f"no shard replica answered (failed: {failed})")
        comp = completeness_from_routing(answered, skipped + failed,
                                         routing=routing)
        return DegradedResult(value=merge(outs), completeness=comp)

    def _run_shard(self, r: Replica, items: list) -> list:
        t0 = time.perf_counter()
        with self._lock:
            r.outstanding += len(items)
        try:
            chaos.failpoint("router.replica.call")
            if r.batch_fn is not None:
                out = list(r.batch_fn(items))
            else:
                out = [r.fn(p) for p in items]
            if len(out) != len(items):
                raise RuntimeError(
                    f"replica {r.name!r} batch_fn returned {len(out)} "
                    f"results for {len(items)} payloads")
            self.latency.record(time.perf_counter() - t0)
            with self._lock:
                r.breaker.record_success()
            return out
        except Exception as e:
            with self._lock:
                r.breaker.record_failure()
                r.last_error = repr(e)
            raise
        finally:
            with self._lock:
                r.outstanding -= len(items)

    def close(self) -> None:
        """Release the scatter/gather worker pool (idempotent)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=False)

    def stats(self) -> dict:
        with self._lock:
            return {name: {"healthy": r.healthy, "failures": r.failures,
                           "outstanding": r.outstanding,
                           "state": r.breaker.state,
                           "opens": r.breaker.opens}
                    for name, r in self._replicas.items()}
