"""Elastic query router: replica groups over index shards, failure handling.

The pod-level picture for a 1000+-node LOVO deployment: the index is split
into S logical shards; each REPLICA GROUP (a pod or sub-mesh) holds every
shard once and can answer any query; the router

  * load-balances queries across healthy replica groups (power-of-two
    choices on outstanding load),
  * scatter/gathers BATCHES across replicas (`call_batch`): a query batch
    is split into contiguous shards, each shard goes to a least-loaded
    replica's batch-native fn concurrently, and results are gathered back
    in submit order (failed shards fall back to per-item routing),
  * broadcasts one payload to EVERY replica and merges (`call_sharded`):
    the partitioned-index path, where each replica owns a row shard and a
    complex-query plan must be answered by all of them, with the grouped
    results merged once (`repro.core.plan.merge_grouped`),
  * retires replicas on failure and restores them on recovery (health
    callbacks), rejecting only when NO replica is healthy,
  * hedges stragglers through serving.batcher.HedgedExecutor,
  * supports elastic scale-out: `add_replica()` at runtime, and
    `add_replica_from_store()` — a new pod joins by reopening a persisted
    `repro.store.VectorStore` (mmap segments + WAL replay, no rebuild).

Replicas are callables (in production: per-pod jitted search fns behind an
RPC stub; in tests: functions).  Pure host-side logic — the module imports
no jax; `add_replica_from_store` pulls the store in lazily so the router
can still front any backend.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Optional, Sequence

from repro.serving.batcher import HedgedExecutor, LatencyTracker


@dataclasses.dataclass
class Replica:
    name: str
    fn: Callable[[Any], Any]
    batch_fn: Optional[Callable[[list], list]] = None
    healthy: bool = True
    outstanding: int = 0
    failures: int = 0
    last_error: Optional[str] = None
    # routing-table generation this replica last acknowledged
    # (core.distributed.RoutingTable protocol); -1 = never installed
    generation: int = -1


class ReplicaUnavailable(RuntimeError):
    pass


class QueryRouter:
    def __init__(self, *, unhealthy_after: int = 3,
                 recovery_probe_s: float = 5.0, hedge: bool = True):
        self._replicas: dict[str, Replica] = {}
        self._lock = threading.Lock()
        self.unhealthy_after = unhealthy_after
        self.recovery_probe_s = recovery_probe_s
        self.hedge = hedge
        self.latency = LatencyTracker()
        self._rng = random.Random(0)
        self._last_probe: dict[str, float] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self._routing: Optional[Any] = None   # distributed.RoutingTable

    # -- membership -----------------------------------------------------------
    def add_replica(self, name: str, fn: Callable[[Any], Any], *,
                    batch_fn: Optional[Callable[[list], list]] = None
                    ) -> None:
        """``fn`` answers one payload; optional ``batch_fn`` answers a LIST
        of payloads in order (e.g. ``engine.query_batch``) and is what
        ``call_batch`` scatters shards to.  Without it, a shard is served
        by mapping ``fn`` inside the shard's worker thread."""
        with self._lock:
            self._replicas[name] = Replica(name=name, fn=fn,
                                           batch_fn=batch_fn)

    def add_replica_from_store(self, name: str, store_dir: str, *,
                               search_cfg: Any = None,
                               verify: bool = False) -> Any:
        """Elastic join: restore a replica's search fn from a persisted
        ``VectorStore`` (open = mmap + WAL replay; no encode, no k-means).

        Returns the opened store so the caller can keep feeding it inserts.
        ``verify=False`` by default — joining pods favor open latency and
        trust the medium; pass True to checksum every segment first.
        """
        from repro.core import anns
        from repro.store import VectorStore

        store = VectorStore.open(store_dir, verify=verify)
        cfg = search_cfg or anns.SearchConfig()
        self.add_replica(name, lambda q: store.search(q, cfg))
        return store

    def install_routing(self, table: Any) -> None:
        """Install a ``core.distributed.RoutingTable``: every replica the
        table names gets stamped with its generation (acknowledging the
        shard layout).  A later ``call_sharded`` broadcast refuses any
        target still stamped with an OLDER generation — after a migration
        or split, a straggler replica serving the pre-move layout would
        double-count or drop the moved rows, so staleness is a hard error,
        exactly like a demoted shard."""
        with self._lock:
            missing = [n for n in table.replicas() if n not in self._replicas]
            if missing:
                raise ReplicaUnavailable(
                    f"routing table names unregistered replicas: {missing}")
            self._routing = table
            for n in table.replicas():
                self._replicas[n].generation = table.generation

    def pick_placement(self, exclude: Sequence[str] = ()) -> str:
        """Load-aware placement for a NEW or migrating shard: the healthy
        replica with the fewest outstanding requests (ties -> fewest
        recent failures, then name for determinism).  ``exclude`` skips
        the shard's current holder."""
        with self._lock:
            cands = [r for r in self._replicas.values()
                     if r.healthy and r.name not in exclude]
        if not cands:
            raise ReplicaUnavailable("no healthy replica for placement")
        return min(cands, key=lambda r: (r.outstanding, r.failures,
                                         r.name)).name

    def remove_replica(self, name: str) -> None:
        with self._lock:
            self._replicas.pop(name, None)

    def mark_recovered(self, name: str) -> None:
        with self._lock:
            r = self._replicas.get(name)
            if r:
                r.healthy, r.failures = True, 0

    def healthy_replicas(self) -> list[Replica]:
        with self._lock:
            return [r for r in self._replicas.values() if r.healthy]

    # -- routing ----------------------------------------------------------------
    def _pick(self) -> Replica:
        healthy = self.healthy_replicas()
        if not healthy:
            # probe one unhealthy replica occasionally (self-healing)
            with self._lock:
                for r in self._replicas.values():
                    last = self._last_probe.get(r.name, 0.0)
                    if time.monotonic() - last > self.recovery_probe_s:
                        self._last_probe[r.name] = time.monotonic()
                        return r
            raise ReplicaUnavailable("no healthy replicas")
        if len(healthy) == 1:
            return healthy[0]
        a, b = self._rng.sample(healthy, 2)  # power of two choices
        return a if a.outstanding <= b.outstanding else b

    def __call__(self, payload: Any) -> Any:
        last_exc: Optional[BaseException] = None
        for _ in range(max(2, len(self._replicas))):
            r = self._pick()
            t0 = time.perf_counter()
            with self._lock:
                r.outstanding += 1
            try:
                out = r.fn(payload)
                self.latency.record(time.perf_counter() - t0)
                with self._lock:
                    r.failures = 0
                    r.healthy = True
                return out
            except ReplicaUnavailable:
                raise
            except BaseException as e:  # replica fault -> demote, retry next
                last_exc = e
                with self._lock:
                    r.failures += 1
                    r.last_error = repr(e)
                    if r.failures >= self.unhealthy_after:
                        r.healthy = False
            finally:
                with self._lock:
                    r.outstanding -= 1
        raise ReplicaUnavailable(f"all replicas failing; last: {last_exc!r}")

    # -- batched scatter/gather -------------------------------------------------
    def call_batch(self, payloads: Sequence[Any]) -> list:
        """Scatter a batch across healthy replicas, gather in submit order.

        The batch is split into up to ``len(healthy)`` contiguous shards
        assigned least-loaded-first; shards run concurrently.  A shard whose
        replica faults is demoted exactly like ``__call__`` and its items
        are re-routed individually (so one bad pod degrades, not fails, the
        batch).  Raises ``ReplicaUnavailable`` only when no replica works.
        """
        payloads = list(payloads)
        if not payloads:
            return []
        healthy = self.healthy_replicas()
        if len(healthy) <= 1:
            # single (or no) healthy replica: per-item path handles
            # probing/recovery; batch_fn still amortizes if present
            r = healthy[0] if healthy else None
            if r is not None and r.batch_fn is not None:
                try:
                    return self._run_shard(r, payloads)
                except Exception:
                    pass                      # demoted; re-route per item
            return [self(p) for p in payloads]

        n_shards = min(len(healthy), len(payloads))
        base, rem = divmod(len(payloads), n_shards)
        shards: list[tuple[int, list]] = []
        lo = 0
        for i in range(n_shards):
            size = base + (1 if i < rem else 0)
            shards.append((lo, payloads[lo: lo + size]))
            lo += size
        targets = sorted(healthy, key=lambda r: r.outstanding)

        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(max_workers=32)
        results: list[Any] = [None] * len(payloads)
        futs = [self._pool.submit(self._run_shard, targets[i], items)
                for i, (_, items) in enumerate(shards)]
        for (off, items), f in zip(shards, futs):
            try:
                out = f.result()
            except ReplicaUnavailable:
                raise
            except Exception:
                out = [self(p) for p in items]   # per-item re-route
            results[off: off + len(items)] = out
        return results

    def call_sharded(self, payload: Any, merge: Callable[[list], Any],
                     *, replicas: Optional[Sequence[str]] = None) -> Any:
        """Broadcast ONE payload to every healthy replica and merge.

        The partitioned-index path: when each replica holds a SHARD of the
        index (rows partitioned, e.g. one ``add_replica_from_store`` per
        shard store), a query — in particular a complex-query plan — must
        run on every shard and the per-shard results must be combined
        (``plan.merge_grouped`` for grouped plan results: send
        ``plan.shard_plan(p)`` as the payload so grouped reductions run
        once, over the merged set).  ``replicas`` restricts the broadcast
        to a named subset (one replica per shard when extra pure replicas
        are registered).

        Unlike ``call_batch``, a faulting OR already-demoted replica here
        means a MISSING SHARD — the merged answer would be silently
        incomplete — so the broadcast refuses to run without every shard
        and a mid-call fault is demoted and re-raised, never degraded.
        With a ``RoutingTable`` installed (``install_routing``), the
        default targets come from the table (one per shard) and any target
        stamped with an older generation is refused the same way — a
        straggler from before a migration/split must not be merged.
        """
        with self._lock:
            routing = self._routing
            if replicas is None and routing is not None:
                replicas = routing.replicas()
            targets = [r for r in self._replicas.values()
                       if replicas is None or r.name in replicas]
            if not targets:
                raise ReplicaUnavailable("no shard replicas registered")
            dead = [r.name for r in targets if not r.healthy]
            if dead:
                raise ReplicaUnavailable(
                    f"shard replicas unhealthy (merge would be "
                    f"incomplete): {dead}")
            if routing is not None:
                stale = [r.name for r in targets
                         if r.generation != routing.generation]
                if stale:
                    raise ReplicaUnavailable(
                        f"shard replicas stale (routing generation "
                        f"{routing.generation}, merge would be "
                        f"incomplete): {stale}")
            if self._pool is None:
                self._pool = ThreadPoolExecutor(max_workers=32)
        futs = [self._pool.submit(self._run_shard, r, [payload])
                for r in targets]
        outs = [f.result()[0] for f in futs]   # _run_shard demotes on fault
        return merge(outs)

    def _run_shard(self, r: Replica, items: list) -> list:
        t0 = time.perf_counter()
        with self._lock:
            r.outstanding += len(items)
        try:
            if r.batch_fn is not None:
                out = list(r.batch_fn(items))
            else:
                out = [r.fn(p) for p in items]
            if len(out) != len(items):
                raise RuntimeError(
                    f"replica {r.name!r} batch_fn returned {len(out)} "
                    f"results for {len(items)} payloads")
            self.latency.record(time.perf_counter() - t0)
            with self._lock:
                r.failures = 0
                r.healthy = True
            return out
        except Exception as e:
            with self._lock:
                r.failures += 1
                r.last_error = repr(e)
                if r.failures >= self.unhealthy_after:
                    r.healthy = False
            raise
        finally:
            with self._lock:
                r.outstanding -= len(items)

    def close(self) -> None:
        """Release the scatter/gather worker pool (idempotent)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=False)

    def stats(self) -> dict:
        with self._lock:
            return {name: {"healthy": r.healthy, "failures": r.failures,
                           "outstanding": r.outstanding}
                    for name, r in self._replicas.items()}
