"""Elastic query router: replica groups over index shards, failure handling.

The pod-level picture for a 1000+-node LOVO deployment: the index is split
into S logical shards; each REPLICA GROUP (a pod or sub-mesh) holds every
shard once and can answer any query; the router

  * load-balances queries across healthy replica groups (power-of-two
    choices on outstanding load),
  * retires replicas on failure and restores them on recovery (health
    callbacks), rejecting only when NO replica is healthy,
  * hedges stragglers through serving.batcher.HedgedExecutor,
  * supports elastic scale-out: `add_replica()` at runtime, and
    `add_replica_from_store()` — a new pod joins by reopening a persisted
    `repro.store.VectorStore` (mmap segments + WAL replay, no rebuild).

Replicas are callables (in production: per-pod jitted search fns behind an
RPC stub; in tests: functions).  Pure host-side logic — the module imports
no jax; `add_replica_from_store` pulls the store in lazily so the router
can still front any backend.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, Callable, Optional

from repro.serving.batcher import HedgedExecutor, LatencyTracker


@dataclasses.dataclass
class Replica:
    name: str
    fn: Callable[[Any], Any]
    healthy: bool = True
    outstanding: int = 0
    failures: int = 0
    last_error: Optional[str] = None


class ReplicaUnavailable(RuntimeError):
    pass


class QueryRouter:
    def __init__(self, *, unhealthy_after: int = 3,
                 recovery_probe_s: float = 5.0, hedge: bool = True):
        self._replicas: dict[str, Replica] = {}
        self._lock = threading.Lock()
        self.unhealthy_after = unhealthy_after
        self.recovery_probe_s = recovery_probe_s
        self.hedge = hedge
        self.latency = LatencyTracker()
        self._rng = random.Random(0)
        self._last_probe: dict[str, float] = {}

    # -- membership -----------------------------------------------------------
    def add_replica(self, name: str, fn: Callable[[Any], Any]) -> None:
        with self._lock:
            self._replicas[name] = Replica(name=name, fn=fn)

    def add_replica_from_store(self, name: str, store_dir: str, *,
                               search_cfg: Any = None,
                               verify: bool = False) -> Any:
        """Elastic join: restore a replica's search fn from a persisted
        ``VectorStore`` (open = mmap + WAL replay; no encode, no k-means).

        Returns the opened store so the caller can keep feeding it inserts.
        ``verify=False`` by default — joining pods favor open latency and
        trust the medium; pass True to checksum every segment first.
        """
        from repro.core import anns
        from repro.store import VectorStore

        store = VectorStore.open(store_dir, verify=verify)
        cfg = search_cfg or anns.SearchConfig()
        self.add_replica(name, lambda q: store.search(q, cfg))
        return store

    def remove_replica(self, name: str) -> None:
        with self._lock:
            self._replicas.pop(name, None)

    def mark_recovered(self, name: str) -> None:
        with self._lock:
            r = self._replicas.get(name)
            if r:
                r.healthy, r.failures = True, 0

    def healthy_replicas(self) -> list[Replica]:
        with self._lock:
            return [r for r in self._replicas.values() if r.healthy]

    # -- routing ----------------------------------------------------------------
    def _pick(self) -> Replica:
        healthy = self.healthy_replicas()
        if not healthy:
            # probe one unhealthy replica occasionally (self-healing)
            with self._lock:
                for r in self._replicas.values():
                    last = self._last_probe.get(r.name, 0.0)
                    if time.monotonic() - last > self.recovery_probe_s:
                        self._last_probe[r.name] = time.monotonic()
                        return r
            raise ReplicaUnavailable("no healthy replicas")
        if len(healthy) == 1:
            return healthy[0]
        a, b = self._rng.sample(healthy, 2)  # power of two choices
        return a if a.outstanding <= b.outstanding else b

    def __call__(self, payload: Any) -> Any:
        last_exc: Optional[BaseException] = None
        for _ in range(max(2, len(self._replicas))):
            r = self._pick()
            t0 = time.perf_counter()
            with self._lock:
                r.outstanding += 1
            try:
                out = r.fn(payload)
                self.latency.record(time.perf_counter() - t0)
                with self._lock:
                    r.failures = 0
                    r.healthy = True
                return out
            except ReplicaUnavailable:
                raise
            except BaseException as e:  # replica fault -> demote, retry next
                last_exc = e
                with self._lock:
                    r.failures += 1
                    r.last_error = repr(e)
                    if r.failures >= self.unhealthy_after:
                        r.healthy = False
            finally:
                with self._lock:
                    r.outstanding -= 1
        raise ReplicaUnavailable(f"all replicas failing; last: {last_exc!r}")

    def stats(self) -> dict:
        with self._lock:
            return {name: {"healthy": r.healthy, "failures": r.failures,
                           "outstanding": r.outstanding}
                    for name, r in self._replicas.items()}
