"""Request batching + straggler mitigation for the LOVO query service.

Production posture pieces the paper's Milvus deployment gets for free and a
TPU serving stack must provide itself:

  * ``MicroBatcher`` — collects concurrent queries into batches of up to
    ``batch_size`` with a max-wait deadline and hands the whole list to a
    batch-native backend (e.g. ``QueryEngine.query_batch``, which pads the
    tail up to its static jit shape — DESIGN.md §8).  Results come back in
    submit order via per-request futures.
  * ``HedgedExecutor`` — straggler mitigation: if a backend replica does not
    answer within the p99-tracking hedge deadline, the SAME request is issued
    to the next replica and the first answer wins (Dean & Barroso, "The Tail
    at Scale").  Replicas here are callables (e.g. per-pod search fns).
  * ``LatencyTracker`` — streaming p50/p9x estimates driving the hedge delay.
"""
from __future__ import annotations

import dataclasses
import inspect
import queue
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro import chaos
from repro.core.resilience import Deadline, DeadlineExceeded


class LatencyTracker:
    def __init__(self, window: int = 512):
        self.window = window
        self._lat: list[float] = []
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._lat.append(seconds)
            if len(self._lat) > self.window:
                self._lat = self._lat[-self.window:]

    def quantile(self, q: float, default: float = 0.05) -> float:
        with self._lock:
            if len(self._lat) < 8:
                return default
            return float(np.quantile(self._lat, q))


@dataclasses.dataclass
class _Pending:
    payload: Any
    future: Future
    t_enqueue: float
    deadline: Optional[Deadline] = None


def _accepts_deadline(fn: Callable) -> bool:
    """Does ``fn`` take a ``deadline=`` keyword?  Inspected once at
    construction; backends that don't are called without it."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    p = sig.parameters.get("deadline")
    if p is not None and p.kind in (p.KEYWORD_ONLY,
                                    p.POSITIONAL_OR_KEYWORD):
        return True
    return any(q.kind == q.VAR_KEYWORD for q in sig.parameters.values())


class MicroBatcher:
    """Groups requests into batches of UP TO ``batch_size``.

    ``run_batch(payloads: list) -> list`` of results (same order/length);
    the backend owns any padding to a static device shape (the engine's
    ``query_batch``/``fast_search_batch`` pad to ``query_batch_size``).
    A batch is dispatched when full or when the oldest request has waited
    ``max_wait_ms`` — the latency/throughput knob of the serving front door.

    ``default_deadline_ms`` stamps every request with a
    :class:`~repro.core.resilience.Deadline` at ``submit`` time (a
    ``submit(..., deadline=...)`` override wins).  Requests already expired
    when their batch is assembled are failed with ``DeadlineExceeded``
    instead of being dispatched — shedding dead work before it reaches the
    backend — and, when the backend's ``run_batch`` accepts a ``deadline=``
    keyword (inspected once), the tightest surviving deadline is passed
    through so the router/shard layer below can keep honoring it.
    """

    def __init__(self, run_batch: Callable[[list], list], batch_size: int,
                 max_wait_ms: float = 5.0,
                 default_deadline_ms: Optional[float] = None):
        self.run_batch = run_batch
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.default_deadline_ms = default_deadline_ms
        self._pass_deadline = _accepts_deadline(run_batch)
        self.expired = 0               # requests shed before dispatch
        self._q: "queue.Queue[_Pending]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self.latency = LatencyTracker()

    def submit(self, payload: Any,
               deadline: Optional[Deadline] = None) -> Future:
        if deadline is None and self.default_deadline_ms is not None:
            deadline = Deadline.after(self.default_deadline_ms / 1e3)
        f: Future = Future()
        self._q.put(_Pending(payload, f, time.perf_counter(), deadline))
        return f

    def close(self) -> None:
        self._stop.set()
        self._worker.join(timeout=2)

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch: list[_Pending] = []
            try:
                batch.append(self._q.get(timeout=0.05))
            except queue.Empty:
                continue
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.batch_size:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            # shed requests whose budget ran out while queued
            live: list[_Pending] = []
            for p in batch:
                if p.deadline is not None and p.deadline.expired():
                    self.expired += 1
                    p.future.set_exception(DeadlineExceeded(
                        "request expired in batch queue"))
                else:
                    live.append(p)
            batch = live
            if not batch:
                continue
            try:
                chaos.failpoint("serving.batcher.dispatch")
                kwargs = {}
                if self._pass_deadline:
                    budgets = [p.deadline for p in batch
                               if p.deadline is not None]
                    if budgets:
                        kwargs["deadline"] = min(
                            budgets, key=lambda d: d.expires_at)
                results = self.run_batch([p.payload for p in batch],
                                         **kwargs)
                if len(results) != len(batch):
                    # a silent zip would strand the tail futures forever
                    raise RuntimeError(
                        f"run_batch returned {len(results)} results for "
                        f"{len(batch)} payloads")
                for p, r in zip(batch, results):
                    self.latency.record(time.perf_counter() - p.t_enqueue)
                    p.future.set_result(r)
            except BaseException as e:
                for p in batch:
                    p.future.set_exception(e)


class HedgedExecutor:
    """Issue to replica 0; after the hedge deadline (tracked p-quantile),
    duplicate to the next replica; first success wins."""

    def __init__(self, replicas: Sequence[Callable[[Any], Any]],
                 hedge_quantile: float = 0.95, max_hedges: int = 1):
        assert replicas
        self.replicas = list(replicas)
        self.hedge_quantile = hedge_quantile
        self.max_hedges = min(max_hedges, len(self.replicas) - 1)
        self.latency = LatencyTracker()
        self.hedges_issued = 0
        self.hedges_won = 0
        self._pool = ThreadPoolExecutor(max_workers=2 * len(self.replicas))

    def __call__(self, payload: Any) -> Any:
        t0 = time.perf_counter()
        futs = {self._pool.submit(self.replicas[0], payload): 0}
        unresolved = set(futs)         # issued, not yet seen completed
        hedges = 0
        first_exc: Optional[BaseException] = None
        while True:
            delay = self.latency.quantile(self.hedge_quantile)
            done, _ = wait(list(unresolved), timeout=delay,
                           return_when=FIRST_COMPLETED)
            # inspect COMPLETED futures only — Future.exception() on a
            # pending future blocks indefinitely; failed ones leave the
            # wait set so a straggler doesn't turn this into a spin loop
            winner = None
            for f in done:
                unresolved.discard(f)
                if f.cancelled():
                    continue
                exc = f.exception()
                if exc is None:
                    winner = f
                    break
                if first_exc is None:
                    first_exc = exc
            if winner is not None:
                self.latency.record(time.perf_counter() - t0)
                if futs[winner] != 0:
                    self.hedges_won += 1
                for f in futs:
                    f.cancel()
                return winner.result()
            if not unresolved and hedges >= self.max_hedges:
                # every issued attempt completed and failed; no hedges left
                raise first_exc if first_exc is not None else \
                    RuntimeError("all replicas failed without an exception")
            if hedges < self.max_hedges:
                hedges += 1
                self.hedges_issued += 1
                nxt = self.replicas[hedges % len(self.replicas)]
                nf = self._pool.submit(nxt, payload)
                futs[nf] = hedges
                unresolved.add(nf)
