"""Request batching + straggler mitigation for the LOVO query service.

Production posture pieces the paper's Milvus deployment gets for free and a
TPU serving stack must provide itself:

  * ``MicroBatcher`` — collects concurrent queries into fixed-size device
    batches (jit shapes are static) with a max-wait deadline; pads the tail.
  * ``HedgedExecutor`` — straggler mitigation: if a backend replica does not
    answer within the p99-tracking hedge deadline, the SAME request is issued
    to the next replica and the first answer wins (Dean & Barroso, "The Tail
    at Scale").  Replicas here are callables (e.g. per-pod search fns).
  * ``LatencyTracker`` — streaming p50/p9x estimates driving the hedge delay.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Optional, Sequence

import numpy as np


class LatencyTracker:
    def __init__(self, window: int = 512):
        self.window = window
        self._lat: list[float] = []
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._lat.append(seconds)
            if len(self._lat) > self.window:
                self._lat = self._lat[-self.window:]

    def quantile(self, q: float, default: float = 0.05) -> float:
        with self._lock:
            if len(self._lat) < 8:
                return default
            return float(np.quantile(self._lat, q))


@dataclasses.dataclass
class _Pending:
    payload: Any
    future: Future
    t_enqueue: float


class MicroBatcher:
    """Groups requests into batches of exactly ``batch_size`` (padded).

    run_batch(payloads: list) -> list of results (same order/length).
    """

    def __init__(self, run_batch: Callable[[list], list], batch_size: int,
                 max_wait_ms: float = 5.0):
        self.run_batch = run_batch
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self._q: "queue.Queue[_Pending]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self.latency = LatencyTracker()

    def submit(self, payload: Any) -> Future:
        f: Future = Future()
        self._q.put(_Pending(payload, f, time.perf_counter()))
        return f

    def close(self) -> None:
        self._stop.set()
        self._worker.join(timeout=2)

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch: list[_Pending] = []
            try:
                batch.append(self._q.get(timeout=0.05))
            except queue.Empty:
                continue
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.batch_size:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            t0 = time.perf_counter()
            try:
                results = self.run_batch([p.payload for p in batch])
                dt = time.perf_counter() - t0
                for p, r in zip(batch, results):
                    self.latency.record(time.perf_counter() - p.t_enqueue)
                    p.future.set_result(r)
            except BaseException as e:
                for p in batch:
                    p.future.set_exception(e)


class HedgedExecutor:
    """Issue to replica 0; after the hedge deadline (tracked p-quantile),
    duplicate to the next replica; first success wins."""

    def __init__(self, replicas: Sequence[Callable[[Any], Any]],
                 hedge_quantile: float = 0.95, max_hedges: int = 1):
        assert replicas
        self.replicas = list(replicas)
        self.hedge_quantile = hedge_quantile
        self.max_hedges = min(max_hedges, len(self.replicas) - 1)
        self.latency = LatencyTracker()
        self.hedges_issued = 0
        self.hedges_won = 0
        self._pool = ThreadPoolExecutor(max_workers=2 * len(self.replicas))

    def __call__(self, payload: Any) -> Any:
        t0 = time.perf_counter()
        futs = {self._pool.submit(self.replicas[0], payload): 0}
        hedges = 0
        while True:
            delay = self.latency.quantile(self.hedge_quantile)
            done, _ = wait(list(futs), timeout=delay,
                           return_when=FIRST_COMPLETED)
            winner = next((f for f in done if f.exception() is None), None)
            if winner is not None:
                self.latency.record(time.perf_counter() - t0)
                if futs[winner] != 0:
                    self.hedges_won += 1
                for f in futs:
                    f.cancel()
                return winner.result()
            if done and all(f.exception() is not None for f in futs):
                raise next(iter(done)).exception()
            if hedges < self.max_hedges:
                hedges += 1
                self.hedges_issued += 1
                nxt = self.replicas[hedges % len(self.replicas)]
                futs[self._pool.submit(nxt, payload)] = hedges
