"""``VectorStore`` — the single-writer persistence facade for the LOVO index.

Composition (LSM-flavored, DESIGN.md §4):

    MANIFEST.json      atomic root: names everything live (manifest.py)
    codebooks.npz      frozen coarse + PQ codebooks (trained once)
    segments/seg-*/    immutable mmap segments: one base + ordered deltas
    sidecar segment    keyframes + metadata side-table (BuiltIndex extras)
    wal.log            fsync-on-commit WAL of raw inserts/deletes (wal.py)

The in-memory view is ``repro.core.incremental.SegmentedIndex``; the store
registers itself as that view's persistence hook, so EVERY mutation —
including auto-compaction triggered deep inside ``insert`` — is durably
logged (WAL-first) or persisted (segment swap) without callers having to
know the store exists.  ``to_segmented_index`` / ``to_built_index`` hand
jax arrays back to the unchanged search path.

Write path:  insert/delete -> WAL append+fsync -> apply to view
             (WAL rows >= flush_rows) -> flush(): rewrite delta segments,
             swap manifest, reset WAL
             compact() -> view folds deltas -> rewrite base, swap manifest
Open path:   manifest -> codebooks -> base (mmap) -> deltas (mmap)
             -> WAL replay of records with seq > manifest.last_seq
Crash safety: see DESIGN.md §5 — the manifest swap is the commit point;
WAL replay is idempotent via per-record sequence numbers.
"""
from __future__ import annotations

import os
import pathlib
import shutil
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro import chaos
from repro.core import imi as imimod
from repro.core.imi import IMIIndex
from repro.core.incremental import DeltaSegment, SegmentedIndex
from repro.core.pq import PQ
from repro.store import manifest as manifestmod
from repro.store import segment as segmentmod
from repro.store import wal as walmod

CODEBOOKS = "codebooks.npz"


def _savez_synced(path: pathlib.Path, **arrays: np.ndarray) -> None:
    """``np.savez`` + flush + fsync: codebook files are named by the
    manifest, so their bytes must be on disk before the manifest swap
    commits a reference to them (DESIGN.md §5; lint rule DS202)."""
    with open(path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
WAL_FILE = "wal.log"
SEGMENTS_DIR = "segments"
SIDECAR = "sidecar"
PLAN_STATS = "plan_stats.npz"


class StoreError(RuntimeError):
    pass


def _base_arrays(index: IMIIndex) -> dict[str, np.ndarray]:
    return {
        "codes": np.asarray(index.codes),
        "vectors": np.asarray(index.vectors),        # bf16 -> uint16 bits
        "ids": np.asarray(index.ids, imimod.ID_DTYPE),
        "cells": np.asarray(index.cell_of, np.int32),
        "offsets": np.asarray(index.cell_offsets, np.int32),
    }


class VectorStore:
    """Single-writer persistent vector store.  Use :meth:`create` /
    :meth:`open`, not the constructor."""

    def __init__(self, root: str | pathlib.Path, *, fsync: bool = True,
                 flush_rows: int = 4096):
        self.root = pathlib.Path(root)
        self.fsync = fsync
        self.flush_rows = flush_rows
        self.seg: SegmentedIndex = None  # type: ignore[assignment]
        self.manifest: dict = {}
        self.wal: walmod.WriteAheadLog = None  # type: ignore[assignment]
        self._sidecar: Optional[dict[str, np.ndarray]] = None
        self._sidecar_extra: dict[str, Any] = {}
        self._seq = 0
        self._wal_rows = 0
        self._replaying = False
        self._needs_base_rewrite = False
        # (name, rows) of each on-disk delta, position-aligned with
        # seg.segments: deltas are append-only and only the last one grows,
        # so same index + same rowcount == unchanged == reusable on flush
        self._delta_names: list[tuple[str, int]] = []

    # -- lifecycle ------------------------------------------------------------
    @classmethod
    def create(cls, root: str | pathlib.Path, built: Any, *,
               max_segments: int = 4, segment_capacity: int = 65_536,
               flush_rows: int = 4096, fsync: bool = True,
               meta: Optional[dict] = None) -> "VectorStore":
        """Persist ``built`` (a ``BuiltIndex`` or bare ``IMIIndex``) into a
        fresh store directory and return the open store."""
        from repro.core.index_builder import BuiltIndex  # avoid import cycle

        root = pathlib.Path(root)
        if manifestmod.exists(root):
            raise StoreError(f"store already exists at {root}")
        # no manifest == nothing here is live; clear leftovers from a crash
        # mid-create so retries don't trip over half-written segment dirs
        for leftover in (root / SEGMENTS_DIR, root / SIDECAR):
            shutil.rmtree(leftover, ignore_errors=True)
        (root / WAL_FILE).unlink(missing_ok=True)
        (root / SEGMENTS_DIR).mkdir(parents=True, exist_ok=True)

        index = built.index if isinstance(built, BuiltIndex) else built
        if not isinstance(index, IMIIndex):
            raise StoreError(f"cannot create a store from {type(built)}")

        cb_arrays = dict(coarse1=np.asarray(index.coarse1, np.float32),
                         coarse2=np.asarray(index.coarse2, np.float32),
                         pq=np.asarray(index.pq.centroids, np.float32))
        if index.pq.rotation is not None:   # OPQ rotation rides along
            cb_arrays["rotation"] = np.asarray(index.pq.rotation, np.float32)
        _savez_synced(root / CODEBOOKS, **cb_arrays)
        base_name = "seg-000001"
        segmentmod.write_segment(root / SEGMENTS_DIR / base_name,
                                 _base_arrays(index), {"kind": "base"})

        m = manifestmod.new_manifest(base=base_name, codebooks=CODEBOOKS,
                                     meta=dict(meta or {}))
        m["meta"].update({"max_segments": max_segments,
                          "segment_capacity": segment_capacity,
                          "id_dtype": np.dtype(imimod.ID_DTYPE).name,
                          "has_sidecar": isinstance(built, BuiltIndex)})
        store = cls(root, fsync=fsync, flush_rows=flush_rows)
        if isinstance(built, BuiltIndex):
            m["meta"]["patches_per_frame"] = int(built.patches_per_frame)
            segmentmod.write_segment(root / SIDECAR, {
                "keyframes": np.asarray(built.keyframes),
                "video_of": np.asarray(built.metadata.video_of, np.int32),
                "frame_of": np.asarray(built.metadata.frame_of, np.int32),
                "bbox_of": np.asarray(built.metadata.bbox_of, np.float32),
                "kf_video": np.asarray(built.keyframe_video, np.int32),
                "kf_frame": np.asarray(built.keyframe_frame, np.int32),
            }, {"kind": "sidecar",
                "patches_per_frame": int(built.patches_per_frame)})
        manifestmod.write_manifest(root, m)
        store.manifest = m
        store.wal = walmod.WriteAheadLog.open(root / WAL_FILE, fsync=fsync)
        store.seg = SegmentedIndex(index, max_segments=max_segments,
                                   segment_capacity=segment_capacity,
                                   persistence=store)
        if isinstance(built, BuiltIndex):
            store._sidecar, store._sidecar_extra = segmentmod.open_segment(
                root / SIDECAR)
            store._write_plan_stats()
        return store

    @classmethod
    def open(cls, root: str | pathlib.Path, *, verify: bool = True,
             fsync: bool = True, flush_rows: int = 4096) -> "VectorStore":
        """Crash-consistent open: manifest -> segments (mmap) -> WAL replay."""
        root = pathlib.Path(root)
        m = manifestmod.read_manifest(root)
        store = cls(root, fsync=fsync, flush_rows=flush_rows)
        store.manifest = m

        cb = np.load(root / m["codebooks"])
        base_arrays, _ = segmentmod.open_segment(
            root / SEGMENTS_DIR / m["base"], verify=verify)
        base = IMIIndex(
            coarse1=jnp.asarray(cb["coarse1"]),
            coarse2=jnp.asarray(cb["coarse2"]),
            pq=PQ(centroids=jnp.asarray(cb["pq"]),
                  rotation=(jnp.asarray(cb["rotation"])
                            if "rotation" in cb.files else None)),
            codes=jnp.asarray(base_arrays["codes"]),
            vectors=jnp.asarray(base_arrays["vectors"]),
            ids=jnp.asarray(base_arrays["ids"]),
            cell_of=jnp.asarray(base_arrays["cells"]),
            cell_offsets=jnp.asarray(base_arrays["offsets"]),
        )
        meta = m.get("meta", {})
        store.seg = SegmentedIndex(
            base, max_segments=int(meta.get("max_segments", 4)),
            segment_capacity=int(meta.get("segment_capacity", 65_536)),
            persistence=store)
        for name in m["deltas"]:
            arrays, extra = segmentmod.open_segment(
                root / SEGMENTS_DIR / name, verify=verify)
            store.seg.segments.append(DeltaSegment(
                codes=arrays["codes"], vectors=arrays["vectors"],
                ids=arrays["ids"], cell_of=arrays["cells"],
                resid_energy=float(extra.get("resid_energy", 0.0))))
            store._delta_names.append((name, len(arrays["ids"])))
        store.seg.tombstones = set(int(i) for i in m["tombstones"])

        scan = walmod.scan(root / WAL_FILE)
        store.wal = walmod.WriteAheadLog.open(
            root / WAL_FILE, fsync=fsync,
            truncate_at=scan.good_end if scan.damaged_tail else None)
        store._seq = int(m["last_seq"])
        store._replaying = True
        try:
            for rec in scan.records:
                if rec.seq <= int(m["last_seq"]):
                    continue  # already folded into the segments we loaded
                if rec.kind == walmod.KIND_INSERT:
                    store.seg.insert(rec.vectors, rec.ids)
                    store._wal_rows += len(rec.ids)
                else:
                    store.seg.delete(rec.ids)
                    store._wal_rows += len(rec.ids)
                store._seq = max(store._seq, rec.seq)
        finally:
            store._replaying = False
        if store._sidecar is None and meta.get("has_sidecar"):
            store._sidecar, store._sidecar_extra = segmentmod.open_segment(
                root / SIDECAR, verify=verify)
        return store

    def close(self) -> None:
        if self.wal is not None:
            self.wal.close()

    def __enter__(self) -> "VectorStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- persistence hook (called by SegmentedIndex) --------------------------
    def log_insert(self, vectors: np.ndarray, ids: np.ndarray) -> None:
        if self._replaying:
            return
        self._seq += 1
        self.wal.append_insert(self._seq, vectors, ids)
        self._wal_rows += len(ids)

    def log_delete(self, ids: np.ndarray) -> None:
        if self._replaying:
            return
        self._seq += 1
        self.wal.append_delete(self._seq, ids)
        self._wal_rows += len(ids)

    def on_compact(self, seg: SegmentedIndex) -> None:
        if self._replaying:
            # compaction during WAL replay must not touch disk: the tail of
            # the WAL is still unapplied and resetting it would lose records.
            self._needs_base_rewrite = True
            self._delta_names = []  # the on-disk deltas were folded away
            return
        self._checkpoint(rewrite_base=True)

    # -- writes ---------------------------------------------------------------
    def insert(self, x, ids) -> None:
        self.seg.insert(x, ids)
        if self._wal_rows >= self.flush_rows:
            self.flush()

    def delete(self, ids) -> None:
        self.seg.delete(ids)
        if self._wal_rows >= self.flush_rows:
            self.flush()

    def compact(self) -> None:
        self.seg.compact()          # fires on_compact -> _checkpoint
        if self._needs_base_rewrite:  # compact() no-oped after replay-compact
            self._checkpoint(rewrite_base=True)

    def refresh_codebooks(self, *, seed: int = 0, M: Optional[int] = None,
                          coarse_cells: int = 1, kmeans_iters: int = 8,
                          opq_iters: int = 0) -> None:
        """Codebook drift remedy (DESIGN.md §12.4): retrain coarse + PQ
        codebooks on the CURRENT vectors, re-encode every row, and commit
        base + codebooks together.

        The expensive work (k-means, re-encode, cell sort) happens off
        the read path; readers see the old generation until the O(1)
        ``swap_base``.  Durability: the new codebooks file is written
        under a fresh versioned name (unreferenced until commit), then
        one manifest swap publishes new base + new codebooks atomically
        — a crash anywhere leaves the store consistent on either side.

        ``M`` defaults to the current expanded table size with a flat
        residual codebook (``coarse_cells=1``), preserving code width
        and ADC cost across the refresh.
        """
        import jax

        self.compact()  # fold deltas so the new base covers every row
        base = self.seg.base
        vecs = jnp.asarray(np.asarray(base.vectors).astype(np.float32))
        M = int(M if M is not None else base.pq.M)
        new_base = imimod.build_imi(
            jax.random.PRNGKey(seed), vecs, jnp.asarray(base.ids),
            K=base.K, P=base.pq.P, M=M, kmeans_iters=kmeans_iters,
            opq_iters=opq_iters, coarse_cells=coarse_cells)
        self.seg.swap_base(new_base)

        name = f"codebooks-{self.manifest['next_segment_id']:06d}.npz"
        cb_arrays = dict(coarse1=np.asarray(new_base.coarse1, np.float32),
                         coarse2=np.asarray(new_base.coarse2, np.float32),
                         pq=np.asarray(new_base.pq.centroids, np.float32))
        if new_base.pq.rotation is not None:
            cb_arrays["rotation"] = np.asarray(new_base.pq.rotation,
                                               np.float32)
        _savez_synced(self.root / name, **cb_arrays)
        # the window where the new codebooks file exists but nothing
        # references it: a crash here must leave the OLD generation live
        chaos.failpoint("store.codebooks.write")
        old = self.manifest["codebooks"]
        self.manifest = {**self.manifest, "codebooks": name}
        self._checkpoint(rewrite_base=True)   # <- the atomic commit
        if old != name:
            (self.root / old).unlink(missing_ok=True)

    def flush(self) -> None:
        """Fold the WAL into on-disk segments and reset it.  Rewrites the
        base too if a compaction happened during replay and is still
        un-persisted."""
        self._checkpoint(rewrite_base=self._needs_base_rewrite)

    def _checkpoint(self, *, rewrite_base: bool) -> None:
        """Make the manifest-reachable state equal the in-memory state:
        (optionally) a fresh base segment, ALL current delta segments
        (unchanged ones keep their on-disk name — deltas are append-only,
        so same position + same rowcount means same bytes), tombstones,
        and last_seq; then reset the WAL and prune dead segment dirs."""
        m = dict(self.manifest)
        if rewrite_base:
            name = f"seg-{m['next_segment_id']:06d}"
            m["next_segment_id"] += 1
            segmentmod.write_segment(self.root / SEGMENTS_DIR / name,
                                     _base_arrays(self.seg.base),
                                     {"kind": "base"})
            m["base"] = name
            self._write_plan_stats()  # stats track the rewritten base
        names = []
        for i, delta in enumerate(self.seg.segments):
            if i < len(self._delta_names) \
                    and self._delta_names[i][1] == len(delta.ids):
                names.append(self._delta_names[i][0])
                continue
            name = f"seg-{m['next_segment_id']:06d}"
            m["next_segment_id"] += 1
            segmentmod.write_segment(
                self.root / SEGMENTS_DIR / name,
                {"codes": np.ascontiguousarray(delta.codes),
                 "vectors": np.ascontiguousarray(delta.vectors, np.float32),
                 "ids": np.ascontiguousarray(delta.ids, imimod.ID_DTYPE),
                 "cells": np.ascontiguousarray(delta.cell_of, np.int32)},
                {"kind": "delta", "resid_energy": float(delta.resid_energy)})
            names.append(name)
        m["deltas"] = names
        m["tombstones"] = sorted(self.seg.tombstones)
        m["last_seq"] = self._seq
        # every new segment is written but unreferenced: a crash in this
        # window must reopen on the OLD manifest, replaying the un-reset WAL
        chaos.failpoint("store.checkpoint.pre_manifest")
        manifestmod.write_manifest(self.root, m)   # <- commit point
        self.manifest = m
        self._delta_names = [(n, len(s.ids))
                             for n, s in zip(names, self.seg.segments)]
        self.wal.reset()
        self._wal_rows = 0
        self._needs_base_rewrite = False
        self._prune_segments()

    def _prune_segments(self) -> None:
        live = {self.manifest["base"], *self.manifest["deltas"]}
        seg_root = self.root / SEGMENTS_DIR
        for p in seg_root.iterdir():
            if p.is_dir() and p.name not in live:
                shutil.rmtree(p, ignore_errors=True)

    # -- planner statistics sidecar -------------------------------------------
    def _plan_meta(self):
        """Planner metadata view over the CURRENT base rows (sidecar-backed).
        None when the store has no sidecar or inserted ids have outrun it."""
        from repro.core import plan as planmod

        if self._sidecar is None:
            return None
        sc = self._sidecar
        ids = np.asarray(self.seg.base.ids)
        if ids.size and int(ids.max()) >= len(sc["video_of"]):
            return None  # ingested rows with no metadata: stats would lie
        kp = int(self._sidecar_extra.get(
            "patches_per_frame",
            self.manifest.get("meta", {}).get("patches_per_frame", 1)))
        return planmod.PlanMeta(
            row_video=np.asarray(sc["video_of"])[ids],
            row_time=np.asarray(sc["frame_of"])[ids],
            frame_video=np.asarray(sc["kf_video"]),
            frame_time=np.asarray(sc["kf_frame"]),
            patches_per_frame=kp)

    def _write_plan_stats(self) -> None:
        """Refresh the statistics sidecar (``plan_stats.npz``) from the
        current base — called at create and on every base rewrite
        (compaction / codebook refresh), so persisted statistics track the
        rows the cost model will plan over.  Synced before the manifest
        swap that may reference the new base (DS202)."""
        from repro.core import optimizer as optmod

        meta = self._plan_meta()
        if meta is None:
            return
        stats = optmod.PlanStats.from_meta(
            meta, cell_offsets=np.asarray(self.seg.base.cell_offsets),
            index=self.seg.base)
        _savez_synced(self.root / PLAN_STATS, **stats.to_arrays())

    def plan_stats(self):
        """Persisted planner statistics (falls back to recomputing when the
        sidecar file predates this store version).  None without metadata."""
        from repro.core import optimizer as optmod

        p = self.root / PLAN_STATS
        if p.exists():
            with np.load(p) as z:
                return optmod.PlanStats.from_arrays(dict(z))
        meta = self._plan_meta()
        if meta is None:
            return None
        return optmod.PlanStats.from_meta(
            meta, cell_offsets=np.asarray(self.seg.base.cell_offsets))

    def cache_token(self) -> tuple:
        """Data-version token for :class:`repro.core.optimizer.ResultCache`.

        Combines the durable identity (manifest base + codebooks names,
        last folded WAL seq, delta names, tombstones) with the live
        in-memory version (``SegmentedIndex.data_version``): any ingest
        append/delete, compaction, or ``refresh_codebooks`` — flushed or
        not — changes it, and two opens of different on-disk states never
        collide.  Wall-clock never enters the token.
        """
        m = self.manifest
        return (m.get("base"), m.get("codebooks"), int(m.get("last_seq", 0)),
                tuple(m.get("deltas", ())), self.seg.data_version())

    # -- reads / bridges ------------------------------------------------------
    def search(self, q, cfg) -> dict:
        return self.seg.search(q, cfg)

    def extract_rows(self, ids) -> tuple[np.ndarray, np.ndarray]:
        """Gather the stored (normalized) vectors for ``ids`` from the base
        and any delta segments, skipping tombstoned rows.

        Returns ``(vectors (m, D') f32, found_ids (m,))`` in the order
        found — the read half of :func:`migrate_rows`.  Unknown ids are
        simply absent from the result (a shard move wants "whatever this
        store still holds of these rows", not an error).
        """
        want = set(int(i) for i in np.asarray(ids).ravel())
        want -= {int(t) for t in self.seg.tombstones}
        vecs, found = [], []
        pools = [(np.asarray(self.seg.base.ids),
                  np.asarray(self.seg.base.vectors, np.float32))]
        pools += [(np.asarray(s.ids), np.asarray(s.vectors, np.float32))
                  for s in self.seg.segments]
        for pids, pvecs in pools:
            hit = np.asarray([i for i, pid in enumerate(pids)
                              if int(pid) in want], np.int64)
            if hit.size:
                vecs.append(pvecs[hit])
                found.append(pids[hit])
                want -= set(int(p) for p in pids[hit])
        if not found:
            d = np.asarray(self.seg.base.vectors).shape[-1]
            return (np.zeros((0, d), np.float32),
                    np.zeros((0,), np.asarray(self.seg.base.ids).dtype))
        return np.concatenate(vecs), np.concatenate(found)

    @property
    def n(self) -> int:
        return self.seg.n

    def to_segmented_index(self) -> SegmentedIndex:
        """The live in-memory view (base + deltas), persistence attached."""
        return self.seg

    def to_built_index(self):
        """Reassemble a ``BuiltIndex`` (index + keyframes + metadata).

        Outstanding deltas/tombstones are folded (and persisted) first so
        the returned index is the complete current state.
        """
        from repro.core.index_builder import BuiltIndex, MetadataStore

        if self._sidecar is None:
            raise StoreError(
                "store has no sidecar (created from a bare IMIIndex); "
                "use to_segmented_index() instead")
        if self.seg.segments or self.seg.tombstones:
            self.compact()
        sc = self._sidecar
        ids = np.asarray(self.seg.base.ids)
        if ids.size and int(ids.max()) >= len(sc["video_of"]):
            # inserted rows carry ids with no sidecar row; a BuiltIndex
            # lookup would index past the metadata arrays (or silently
            # mis-attribute) — fail loudly instead
            raise StoreError(
                "index contains inserted ids beyond the sidecar metadata; "
                "use to_segmented_index() (metadata-free search) or extend "
                "the sidecar before exporting a BuiltIndex")
        kp = int(self._sidecar_extra.get(
            "patches_per_frame",
            self.manifest.get("meta", {}).get("patches_per_frame", 1)))
        return BuiltIndex(
            index=self.seg.base,
            metadata=MetadataStore(video_of=sc["video_of"],
                                   frame_of=sc["frame_of"],
                                   bbox_of=sc["bbox_of"]),
            keyframes=sc["keyframes"],
            keyframe_video=sc["kf_video"],
            keyframe_frame=sc["kf_frame"],
            patches_per_frame=kp,
        )


def migrate_rows(src: VectorStore, dst: VectorStore, ids) -> int:
    """Move rows between shard stores: the data plane of a shard
    migration/split (``core.distributed.RoutingTable`` is the control
    plane — bump its generation AFTER this returns, then
    ``QueryRouter.install_routing`` the new table).

    Copy-then-delete, both halves WAL-logged on their own store: the
    insert lands in ``dst``'s WAL before the delete lands in ``src``'s, so
    a crash at any point loses no rows (the worst case is a transient
    duplicate, which the stale-generation refusal keeps out of merged
    results).  Returns the number of rows moved.
    """
    vecs, found = src.extract_rows(ids)
    if len(found) == 0:
        return 0
    dst.insert(vecs, found)
    src.delete(found)
    return len(found)
