"""Append-only write-ahead log for streaming inserts/deletes.

Framing (little-endian, see DESIGN.md §4.2)::

    file   := magic "LVWL" | u32 version | record*
    record := u32 body_len | u32 crc32(body) | body
    body   := u64 seq | u8 kind | payload
    INSERT (kind=1) payload := u32 n | u32 d | ids int32[n] | vectors f32[n,d]
    DELETE (kind=2) payload := u32 n | ids int32[n]

``seq`` increases monotonically across the store's lifetime; the manifest
records the highest seq already folded into on-disk segments, so replay
after a crash between segment-flush and WAL-truncate is idempotent.

Durability: ``append_*`` writes then ``flush + fsync`` before returning
("fsync on commit").  ``scan`` tolerates a truncated tail — a crash mid-
append loses at most the record being written, never earlier ones — and
reports the byte offset of the last good record so the caller can trim.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import struct
import zlib
from typing import Iterator, Optional

import numpy as np

from repro import chaos

MAGIC = b"LVWL"
VERSION = 1
_HDR = struct.Struct("<II")      # body_len, crc32
_BODY = struct.Struct("<QB")     # seq, kind
KIND_INSERT, KIND_DELETE = 1, 2
ID_DTYPE = np.int32              # matches repro.core.imi.ID_DTYPE


@dataclasses.dataclass
class WalRecord:
    seq: int
    kind: int
    ids: np.ndarray                       # (n,) int32
    vectors: Optional[np.ndarray] = None  # (n, d) f32 for INSERT


@dataclasses.dataclass
class ScanResult:
    records: list[WalRecord]
    good_end: int        # byte offset just past the last intact record
    damaged_tail: bool   # True if trailing bytes failed length/CRC checks


def _encode_insert(seq: int, vectors: np.ndarray, ids: np.ndarray) -> bytes:
    vectors = np.ascontiguousarray(vectors, np.float32)
    ids = np.ascontiguousarray(ids, ID_DTYPE)
    n, d = vectors.shape
    return (_BODY.pack(seq, KIND_INSERT) + struct.pack("<II", n, d)
            + ids.tobytes() + vectors.tobytes())


def _encode_delete(seq: int, ids: np.ndarray) -> bytes:
    ids = np.ascontiguousarray(ids, ID_DTYPE).reshape(-1)
    return (_BODY.pack(seq, KIND_DELETE) + struct.pack("<I", ids.size)
            + ids.tobytes())


def _decode(body: bytes) -> WalRecord:
    seq, kind = _BODY.unpack_from(body, 0)
    off = _BODY.size
    if kind == KIND_INSERT:
        n, d = struct.unpack_from("<II", body, off)
        off += 8
        ids = np.frombuffer(body, ID_DTYPE, count=n, offset=off).copy()
        off += n * 4
        vecs = np.frombuffer(body, np.float32, count=n * d,
                             offset=off).reshape(n, d).copy()
        return WalRecord(seq=seq, kind=kind, ids=ids, vectors=vecs)
    if kind == KIND_DELETE:
        (n,) = struct.unpack_from("<I", body, off)
        ids = np.frombuffer(body, ID_DTYPE, count=n, offset=off + 4).copy()
        return WalRecord(seq=seq, kind=kind, ids=ids)
    raise ValueError(f"unknown WAL record kind {kind}")


def scan(path: str | pathlib.Path) -> ScanResult:
    """Read every intact record; stop (without raising) at a damaged tail."""
    path = pathlib.Path(path)
    records: list[WalRecord] = []
    data = path.read_bytes() if path.exists() else b""
    head = len(MAGIC) + 4
    if len(data) < head or data[:4] != MAGIC:
        return ScanResult(records=[], good_end=0,
                          damaged_tail=bool(data))
    off = head
    while True:
        if off + _HDR.size > len(data):
            break
        body_len, crc = _HDR.unpack_from(data, off)
        body = data[off + _HDR.size: off + _HDR.size + body_len]
        if len(body) < body_len or zlib.crc32(body) != crc:
            break
        try:
            records.append(_decode(body))
        except (ValueError, struct.error):
            break
        off += _HDR.size + body_len
    return ScanResult(records=records, good_end=off,
                      damaged_tail=off < len(data))


class WriteAheadLog:
    """Single-writer append handle.  Create/open with :meth:`open`."""

    def __init__(self, path: str | pathlib.Path, *, fsync: bool = True):
        self.path = pathlib.Path(path)
        self.fsync = fsync
        self._f = None  # type: ignore[assignment]

    @classmethod
    def open(cls, path: str | pathlib.Path, *, fsync: bool = True,
             truncate_at: Optional[int] = None) -> "WriteAheadLog":
        """Open for append, creating (with header) if absent.  If
        ``truncate_at`` is given, trim a damaged tail first.

        An existing file whose header is unreadable (crash between create
        and header write, or truncation to < 8 bytes) holds no replayable
        records, so it is rewritten fresh — appending after a broken header
        would make every future record unreplayable."""
        wal = cls(path, fsync=fsync)
        head = len(MAGIC) + 4
        exists = wal.path.exists()
        header_ok = False
        if exists:
            with open(wal.path, "rb") as f:
                header_ok = f.read(head)[:4] == MAGIC
        if not exists or not header_ok \
                or (truncate_at is not None and truncate_at < head):
            with open(wal.path, "wb") as f:
                f.write(MAGIC + struct.pack("<I", VERSION))
                f.flush()
                os.fsync(f.fileno())
        elif truncate_at is not None:
            with open(wal.path, "r+b") as f:
                f.truncate(truncate_at)
                f.flush()
                os.fsync(f.fileno())
        wal._f = open(wal.path, "ab")
        return wal

    def _commit(self, blob: bytes) -> None:
        assert self._f is not None, "WAL is closed"
        if chaos.failpoint("store.wal.append.pre_fsync") == "torn":
            # crash mid-append: a prefix of the framed record reaches the
            # file (the CRC check makes scan() treat it as a damaged tail)
            self._f.write(blob[: max(1, len(blob) // 2)])
            self._f.flush()
            chaos.crash_now()
        self._f.write(blob)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def append_insert(self, seq: int, vectors: np.ndarray,
                      ids: np.ndarray) -> None:
        body = _encode_insert(seq, vectors, ids)
        self._commit(_HDR.pack(len(body), zlib.crc32(body)) + body)

    def append_delete(self, seq: int, ids: np.ndarray) -> None:
        body = _encode_delete(seq, ids)
        self._commit(_HDR.pack(len(body), zlib.crc32(body)) + body)

    def reset(self) -> None:
        """Drop all records (after they were folded into segments)."""
        assert self._f is not None, "WAL is closed"
        chaos.failpoint("store.wal.reset")
        self._f.close()
        with open(self.path, "wb") as f:
            f.write(MAGIC + struct.pack("<I", VERSION))
            f.flush()
            os.fsync(f.fileno())
        self._f = open(self.path, "ab")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
