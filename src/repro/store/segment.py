"""Immutable on-disk segments — the store's leaf data unit.

A segment is a directory of raw ``.npy`` files (one per named array) plus a
``footer.json`` recording, per array, the logical dtype, storage dtype,
shape, and a CRC-32 of the data bytes.  Segments are written once and never
modified; readers open them with ``np.load(..., mmap_mode="r")`` so the OS
page cache — not the Python heap — owns the bytes (zero-copy until a row is
actually touched).

bfloat16 has no stable ``.npy`` representation across numpy versions, so
bf16 arrays are stored as their uint16 bit pattern with logical dtype
``"bfloat16"`` in the footer; ``open_segment`` views them back — a metadata
reinterpretation, not a copy, so round-trips are bit-exact.
"""
from __future__ import annotations

import json
import os
import pathlib
import zlib
from typing import Any, Mapping

import ml_dtypes
import numpy as np

from repro import chaos

FOOTER = "footer.json"
_CRC_CHUNK = 1 << 22  # rows per crc chunk (bounded memory on mmap reads)


class SegmentCorrupt(RuntimeError):
    """Checksum / footer mismatch — the segment must not be served."""


def _crc32(a: np.ndarray) -> int:
    flat = a.reshape(-1)
    crc = 0
    for i in range(0, flat.size, _CRC_CHUNK):
        crc = zlib.crc32(flat[i: i + _CRC_CHUNK].tobytes(), crc)
    return crc


def _fsync_dir(path: pathlib.Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # platform without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_segment(seg_dir: str | pathlib.Path,
                  arrays: Mapping[str, np.ndarray],
                  extra: dict[str, Any] | None = None) -> None:
    """Write ``arrays`` + footer to ``seg_dir`` (created; must not exist).

    Files are fsynced before the footer is written, and the footer before
    the directory entry is fsynced — a segment with a readable footer is
    guaranteed complete.
    """
    seg_dir = pathlib.Path(seg_dir)
    seg_dir.mkdir(parents=True, exist_ok=False)
    footer: dict[str, Any] = {"version": 1, "arrays": {}, "extra": extra or {}}
    last_path: pathlib.Path | None = None
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        logical = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:
            arr = arr.view(np.uint16)
        path = seg_dir / f"{name}.npy"
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        footer["arrays"][name] = {
            "dtype": logical, "storage_dtype": str(arr.dtype),
            "shape": list(arr.shape), "crc32": _crc32(arr),
        }
        last_path = path
    if chaos.failpoint("store.segment.write.torn") == "torn":
        # crash between array files and footer: truncate the last .npy so
        # the dir is visibly incomplete (no footer -> SegmentCorrupt, and
        # nothing references it until a manifest swap commits the name)
        if last_path is not None:
            with open(last_path, "r+b") as f:
                f.truncate(max(1, last_path.stat().st_size // 2))
        chaos.crash_now()
    fpath = seg_dir / FOOTER
    with open(fpath, "w") as f:
        json.dump(footer, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(seg_dir)


def open_segment(seg_dir: str | pathlib.Path, *, mmap: bool = True,
                 verify: bool = True
                 ) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    """Open a segment -> ({name: array}, extra).  Arrays are read-only
    memmaps (``mmap=True``) with logical dtypes restored by view.

    ``verify=True`` recomputes each array's CRC-32 against the footer and
    raises :class:`SegmentCorrupt` on mismatch (this touches every page —
    pass ``verify=False`` for latency-critical reopen paths that trust the
    medium).
    """
    seg_dir = pathlib.Path(seg_dir)
    fpath = seg_dir / FOOTER
    if not fpath.exists():
        raise SegmentCorrupt(f"segment {seg_dir} has no footer (incomplete?)")
    footer = json.loads(fpath.read_text())
    out: dict[str, np.ndarray] = {}
    for name, meta in footer["arrays"].items():
        try:
            arr = np.load(seg_dir / f"{name}.npy",
                          mmap_mode="r" if mmap else None)
        except (ValueError, OSError) as e:
            # damage inside the .npy header/frame surfaces as numpy parse
            # errors — refuse with the segment-corruption type, loudly
            raise SegmentCorrupt(
                f"{seg_dir}/{name}: unreadable array file ({e})") from e
        if str(arr.dtype) != meta["storage_dtype"] \
                or list(arr.shape) != meta["shape"]:
            raise SegmentCorrupt(
                f"{seg_dir}/{name}: footer says {meta['storage_dtype']}"
                f"{meta['shape']}, file has {arr.dtype}{list(arr.shape)}")
        if verify and _crc32(arr) != meta["crc32"]:
            raise SegmentCorrupt(f"{seg_dir}/{name}: CRC-32 mismatch")
        if meta["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        out[name] = arr
    return out, footer.get("extra", {})
