"""Atomic store manifest — the single source of truth for what is live.

``MANIFEST.json`` names the live base segment, the ordered delta segments,
the codebook blob, the tombstone set, and ``last_seq`` (the highest WAL
sequence number already folded into the named segments).  It is replaced
atomically (write tmp, fsync, ``os.replace``, fsync dir), so a reader —
including a crash-recovering writer — always observes either the old or the
new store state, never a mix.  Everything not reachable from the manifest
is garbage and may be pruned.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any

from repro import chaos

MANIFEST = "MANIFEST.json"
VERSION = 1


class ManifestError(RuntimeError):
    pass


def new_manifest(*, base: str, codebooks: str, meta: dict[str, Any]) -> dict:
    return {
        "version": VERSION,
        "base": base,              # segment dir name under segments/
        "deltas": [],              # ordered delta segment dir names
        "codebooks": codebooks,    # npz file name under the store root
        "tombstones": [],          # flushed deleted ids (int)
        "last_seq": 0,             # WAL records with seq <= this are folded
        "next_segment_id": 2,      # monotone counter for segment names
        "meta": meta,              # sidecar/meta: patches_per_frame, ...
    }


def read_manifest(root: str | pathlib.Path) -> dict:
    path = pathlib.Path(root) / MANIFEST
    if not path.exists():
        raise ManifestError(f"no {MANIFEST} under {root}")
    m = json.loads(path.read_text())
    if m.get("version") != VERSION:
        raise ManifestError(f"manifest version {m.get('version')} != {VERSION}")
    return m


def exists(root: str | pathlib.Path) -> bool:
    return (pathlib.Path(root) / MANIFEST).exists()


def _fsync_dir(path: pathlib.Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_manifest(root: str | pathlib.Path, m: dict) -> None:
    root = pathlib.Path(root)
    tmp = root / (MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(m, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    chaos.failpoint("store.manifest.replace")
    os.replace(tmp, root / MANIFEST)
    _fsync_dir(root)
