"""repro.store — persistent segment-based vector store backing the LOVO index.

The durability layer the paper assumes ("embeddings organized in an inverted
multi-index structure within a vector database"): immutable mmap-able
segments + an append-only WAL + an atomic manifest, composed by the
``VectorStore`` facade.  See DESIGN.md §4 for the on-disk format and §5 for
the crash-consistency guarantees.
"""
from repro.store.store import VectorStore, StoreError, migrate_rows

__all__ = ["VectorStore", "StoreError", "migrate_rows"]
