"""Gradient compression for cross-DCN data parallelism.

On the multi-pod mesh the 'pod' axis crosses data-center networking, which is
an order of magnitude slower than ICI.  Standard mitigation: compress the
pod-axis gradient all-reduce to int8 with ERROR FEEDBACK (Seide et al. 2014;
1-bit SGD lineage) — quantization error is carried into the next step, so
convergence is preserved (contractive-compressor guarantee).

``compressed_psum`` is shard_map-friendly: quantize -> psum int32 -> dequant,
with the residual returned to the caller to feed back.  For jit-SPMD callers,
``EFState`` + ``compress_grads`` wraps whole gradient pytrees.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any


def _q8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_init(grads_like: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compress_grads(grads: Params, ef: Params
                   ) -> tuple[Params, Params, Params]:
    """-> (quantized int8 tree, scales tree, new error-feedback tree).

    caller all-reduces (q * scale) across the slow axis; the difference
    between the true gradient and its quantized form rides in ``ef``.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _q8(corrected)
        dq = q.astype(jnp.float32) * scale
        return q, scale, corrected - dq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]),
            tdef.unflatten([o[2] for o in out]))


def decompress_grads(q: Params, scales: Params) -> Params:
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def compressed_psum(x: jax.Array, axis_name: str, ef: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map: int8-quantized psum over ``axis_name`` with error
    feedback.  Scales are max-combined so the shared dequant is conservative.
    """
    corrected = x.astype(jnp.float32) + ef
    q, scale = _q8(corrected)
    scale = jax.lax.pmax(scale, axis_name)           # shared scale
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    dq_local = q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = summed.astype(jnp.float32) * scale / n
    return mean, corrected - dq_local
