"""Contrastive alignment training for the LOVO encoders (DESIGN.md §3(c)).

Pre-trained ViT-B/32 + BERT weights are unavailable offline, so the decoupled
encoders are trained in-framework on the synthetic paired data:

  * CLIP-style InfoNCE between the caption embedding and the class embedding
    of the patch whose anchor box contains the object center (Owl-ViT's
    bipartite matching reduced to center assignment — exact here because the
    synthetic world has one object per training image);
  * box L1 on the matched patch's predicted box;
  * rerank supervision: BCE on the frame score for (matched, shuffled)
    caption pairs + box L1 through the decoder.

One optimizer over all three parameter trees — a ~100M-param end-to-end
train step used by examples/train_alignment.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import rerank as RR
from repro.models import text_encoder as TE
from repro.models import vit as V

Params = Any


@dataclasses.dataclass(frozen=True)
class AlignConfig:
    vit: V.ViTConfig
    txt: TE.TextConfig
    rerank: RR.RerankConfig
    temperature: float = 0.07
    box_coef: float = 2.0
    rerank_coef: float = 1.0


def init_all(rng: jax.Array, cfg: AlignConfig) -> dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    return {"vit": V.init_vit(r1, cfg.vit)[0],
            "txt": TE.init_text(r2, cfg.txt)[0],
            "rerank": RR.init_rerank(r3, cfg.rerank)[0]}


def _match_patches(boxes_gt: jax.Array, cfg: V.ViTConfig) -> jax.Array:
    """GT box centers -> patch index on the grid (center assignment)."""
    g = cfg.grid
    cx = jnp.clip((boxes_gt[:, 0] * g).astype(jnp.int32), 0, g - 1)
    cy = jnp.clip((boxes_gt[:, 1] * g).astype(jnp.int32), 0, g - 1)
    return cy * g + cx


def alignment_loss(params: dict, batch: dict, cfg: AlignConfig
                   ) -> tuple[jax.Array, dict]:
    imgs, toks = batch["images"], batch["tokens"]
    mask, boxes_gt = batch["txt_mask"], batch["boxes"]
    B = imgs.shape[0]

    cls, boxes, tokens = V.vit_encode(params["vit"], imgs, cfg.vit)
    q, txt_feats = TE.text_encode(params["txt"], toks, mask, cfg.txt)

    match = _match_patches(boxes_gt, cfg.vit)                 # (B,)
    obj = jnp.take_along_axis(cls, match[:, None, None], axis=1)[:, 0]

    # InfoNCE both directions
    logits = (obj @ q.T) / cfg.temperature                    # (B, B)
    labels = jnp.arange(B)
    def ce(lg):
        return jnp.mean(jax.nn.logsumexp(lg, axis=-1)
                        - jnp.take_along_axis(lg, labels[:, None], 1)[:, 0])
    nce = 0.5 * (ce(logits) + ce(logits.T))

    # box regression on the matched patch
    box_pred = jnp.take_along_axis(boxes, match[:, None, None], axis=1)[:, 0]
    box_l1 = jnp.mean(jnp.abs(box_pred - boxes_gt))

    # rerank: positives (aligned) vs negatives (captions rolled by 1)
    score_pos, dec_boxes = RR.rerank_frame(
        params["rerank"], tokens, txt_feats, mask, cfg.rerank)
    score_neg, _ = RR.rerank_frame(
        params["rerank"], tokens, jnp.roll(txt_feats, 1, axis=0),
        jnp.roll(mask, 1, axis=0), cfg.rerank)
    s = jnp.concatenate([score_pos, score_neg])
    y = jnp.concatenate([jnp.ones((B,)), jnp.zeros((B,))])
    bce = jnp.mean(jnp.maximum(s, 0) - s * y + jnp.log1p(jnp.exp(-jnp.abs(s))))
    dec_l1 = jnp.mean(jnp.abs(dec_boxes[:, 0] - boxes_gt))

    loss = nce + cfg.box_coef * (box_l1 + dec_l1) + cfg.rerank_coef * bce
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    rerank_aucish = jnp.mean(score_pos > score_neg)
    return loss, {"nce": nce, "box_l1": box_l1, "bce": bce,
                  "contrastive_acc": acc, "rerank_acc": rerank_aucish}
