"""AdamW with dtype-configurable moment states (+ 8-bit quantized option).

For the very large archs (llama3-405b, kimi-k2-1T) full-f32 Adam states do not
fit v5e HBM at 256 chips; ``state_dtype='bfloat16'`` halves them and
``state_dtype='int8'`` (blockwise absmax quantization, Dettmers-style
[arXiv:2110.02861]) quarters them.  The quantization block is the last axis
row, keeping the scale tensor tiny and the update jit-friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "float32"  # 'float32' | 'bfloat16' | 'int8'
    grad_clip: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"  # 'constant' | 'cosine'
    total_steps: int = 10_000


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _encode(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(dtype)


def _decode(enc, dtype: str) -> jax.Array:
    if dtype == "int8":
        return _dequantize(*enc)
    return enc.astype(jnp.float32)


def adam_init(params: Params, cfg: AdamConfig) -> dict:
    def zero_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, cfg.state_dtype)
    return {
        "m": jax.tree.map(zero_state, params),
        "v": jax.tree.map(zero_state, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs: Any, cfg: AdamConfig) -> dict:
    """Logical specs for optimizer state, mirroring param sharding."""
    def per_param(sp):
        sp = tuple(sp)
        if cfg.state_dtype == "int8":
            return (sp, sp)  # (quantized, per-row scale) share leading axes
        return sp
    leaf = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    mapped = jax.tree.map(per_param, param_specs, is_leaf=leaf)
    return {"m": mapped, "v": mapped, "step": ()}


def lr_at(step: jax.Array, cfg: AdamConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        frac = jnp.clip(step / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree: Params) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adam_update(params: Params, grads: Params, state: dict, cfg: AdamConfig
                ) -> tuple[Params, dict, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * clip.astype(g.dtype), grads)
    lr = lr_at(state["step"], cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m_enc, v_enc):
        g32 = g.astype(jnp.float32)
        m = b1 * _decode(m_enc, cfg.state_dtype) + (1 - b1) * g32
        v = b2 * _decode(v_enc, cfg.state_dtype) + (1 - b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _encode(m, cfg.state_dtype), _encode(v, cfg.state_dtype)

    is_enc = lambda x: isinstance(x, tuple)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tdef.flatten_up_to(state["m"]) if cfg.state_dtype == "int8" \
        else jax.tree.leaves(state["m"])
    flat_v = tdef.flatten_up_to(state["v"]) if cfg.state_dtype == "int8" \
        else jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
