"""Train-step factory: gradient accumulation + AdamW + metrics.

``make_train_step(loss_fn, adam_cfg)`` returns a pure
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` where
``batch`` leaves have leading dims ``(accum, micro_batch, ...)``; grads are
averaged over microsteps with a lax.scan so only one microbatch of
activations is live at a time.  Donate params/opt_state when jitting.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamConfig, adam_update

Params = Any
LossFn = Callable[..., tuple[jax.Array, dict]]  # (params, **batch) -> (loss, metrics)


def make_train_step(loss_fn: LossFn, adam_cfg: AdamConfig, *,
                    unroll_accum: bool = False, grad_shardings: Any = None):
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(tree):
        if grad_shardings is None:
            return tree
        # pin the f32 accumulators to the param shardings — without this the
        # SPMD partitioner may replicate them (8.4 GB/dev for a 405B head)
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def step(params: Params, opt_state: dict, batch: dict):
        accum = jax.tree.leaves(batch)[0].shape[0]

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, _aux), grads = grad_fn(params, **mb)
            gsum = constrain(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, lsum + loss), None

        zeros = constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        carry = (zeros, jnp.zeros(()))
        if unroll_accum:
            # dry-run cost probes: XLA cost_analysis counts scan bodies once
            for a in range(accum):
                carry, _ = micro(carry, jax.tree.map(lambda x: x[a], batch))
            gsum, lsum = carry
        else:
            (gsum, lsum), _ = jax.lax.scan(micro, carry, batch)
        grads = jax.tree.map(lambda g: (g / accum).astype(jnp.bfloat16), gsum)
        new_params, new_opt, opt_metrics = adam_update(
            params, grads, opt_state, adam_cfg)
        metrics = {"loss": lsum / accum, **opt_metrics}
        return new_params, new_opt, metrics

    return step


def make_eval_step(loss_fn: LossFn):
    def step(params: Params, batch: dict):
        loss, aux = loss_fn(params, **batch)
        return {"loss": loss, **aux}
    return step
