"""Cross-modality rerank transformer — LOVO §VI-B / Fig. 5.

Grounding-DINO-style (arXiv:2303.05499) but sized for the rerank budget:

  FeatureEnhancer x L:  image self-attn -> img2txt cross-attn (Q=img, K/V=txt)
                        -> txt2img cross-attn (Q=txt, K/V=img) -> FFNs
  frame score:          l_s = max_j (X_I X_T^T)[j, eos]  (Algorithm 2 line 6)
  CrossModalityDecoder: top-n_q enhanced image tokens as object queries ->
                        self-attn -> cross-attn(text) -> cross-attn(image)
                        -> box MLP (refined boxes, Algorithm 2 line 10)

Inputs are the ViT patch tokens and text-encoder token features of one
candidate frame + the query; outputs (score, boxes) drive the final rerank.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = Any


@dataclasses.dataclass(frozen=True)
class RerankConfig:
    n_layers: int = 6
    d_model: int = 256
    n_heads: int = 8
    d_ff: int = 1024
    n_queries: int = 16
    img_dim: int = 768   # ViT token dim
    txt_dim: int = 512   # text token dim
    decoder_layers: int = 3
    norm_eps: float = 1e-6

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(n_heads=self.n_heads, n_kv_heads=self.n_heads,
                            head_dim=self.d_model // self.n_heads,
                            qkv_bias=True)


def _init_block(b: L.ParamBuilder, p: str, cfg: RerankConfig,
                cross: bool = True):
    b.param(f"{p}/ln1_s", (cfg.d_model,), ("embed",), init="ones")
    b.param(f"{p}/ln1_b", (cfg.d_model,), ("embed",), init="zeros")
    L.init_attention(b, f"{p}/self_attn", cfg.d_model, cfg.attn)
    if cross:
        b.param(f"{p}/lnx_s", (cfg.d_model,), ("embed",), init="ones")
        b.param(f"{p}/lnx_b", (cfg.d_model,), ("embed",), init="zeros")
        L.init_attention(b, f"{p}/cross_attn", cfg.d_model, cfg.attn)
    b.param(f"{p}/ln2_s", (cfg.d_model,), ("embed",), init="ones")
    b.param(f"{p}/ln2_b", (cfg.d_model,), ("embed",), init="zeros")
    L.init_mlp(b, f"{p}/mlp", (cfg.d_model, cfg.d_ff, cfg.d_model))


def init_rerank(rng: jax.Array, cfg: RerankConfig, dtype: str = "float32"
                ) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, dtype)
    b.param("img_proj", (cfg.img_dim, cfg.d_model), (None, "embed"))
    b.param("txt_proj", (cfg.txt_dim, cfg.d_model), (None, "embed"))
    for i in range(cfg.n_layers):
        _init_block(b, f"enh_img_{i}", cfg)   # img self + img2txt cross
        _init_block(b, f"enh_txt_{i}", cfg)   # txt self + txt2img cross
    for i in range(cfg.decoder_layers):
        _init_block(b, f"dec_{i}", cfg)                  # self + cross(txt)
        L.init_attention(b, f"dec_{i}/cross_img", cfg.d_model, cfg.attn)
        b.param(f"dec_{i}/lnz_s", (cfg.d_model,), ("embed",), init="ones")
        b.param(f"dec_{i}/lnz_b", (cfg.d_model,), ("embed",), init="zeros")
    L.init_mlp(b, "box_head", (cfg.d_model, cfg.d_model, 4))
    b.param("score_scale", (), (), init="ones")
    return b.build()


def _block(p: Params, x: jax.Array, cfg: RerankConfig, *,
           kv: jax.Array | None = None,
           kv_mask: jax.Array | None = None,
           self_mask: jax.Array | None = None) -> jax.Array:
    h = L.layer_norm(x, p["ln1_s"], p["ln1_b"], eps=cfg.norm_eps)
    x = x + L.encoder_attention(p["self_attn"], h, cfg.attn,
                                pad_mask=self_mask)
    if kv is not None:
        h = L.layer_norm(x, p["lnx_s"], p["lnx_b"], eps=cfg.norm_eps)
        x = x + L.cross_attention(p["cross_attn"], h, kv, cfg.attn,
                                  kv_mask=kv_mask)
    h = L.layer_norm(x, p["ln2_s"], p["ln2_b"], eps=cfg.norm_eps)
    return x + L.mlp(p["mlp"], h, act="gelu")


def feature_enhancer(params: Params, x_img: jax.Array, x_txt: jax.Array,
                     txt_mask: jax.Array, cfg: RerankConfig
                     ) -> tuple[jax.Array, jax.Array]:
    """(B, N_I, D) img tokens + (B, N_T, D) txt tokens -> enhanced pair."""
    for i in range(cfg.n_layers):
        x_img = _block(params[f"enh_img_{i}"], x_img, cfg,
                       kv=x_txt, kv_mask=txt_mask)
        x_txt = _block(params[f"enh_txt_{i}"], x_txt, cfg,
                       kv=x_img, self_mask=txt_mask)
    return x_img, x_txt


def rerank_frame(params: Params, img_tokens: jax.Array, txt_tokens: jax.Array,
                 txt_mask: jax.Array, cfg: RerankConfig
                 ) -> tuple[jax.Array, jax.Array]:
    """One (frame, query) pair -> (score (B,), boxes (B, n_q, 4)).

    img_tokens: (B, N_I, img_dim) ViT outputs; txt_tokens: (B, N_T, txt_dim).
    """
    x_img = jnp.einsum("bnd,de->bne", img_tokens, params["img_proj"])
    x_txt = jnp.einsum("bnd,de->bne", txt_tokens, params["txt_proj"])
    x_img, x_txt = feature_enhancer(params, x_img, x_txt, txt_mask, cfg)

    # Algorithm 2 line 6: l_s = max over image tokens of similarity to the
    # pooled (last-valid) text feature.
    last = jnp.sum(txt_mask, axis=-1).astype(jnp.int32) - 1    # (B,)
    eos = jnp.take_along_axis(x_txt, last[:, None, None], axis=1)[:, 0]
    sim = jnp.einsum("bnd,bd->bn", x_img, eos) * params["score_scale"]
    score = jnp.max(sim, axis=-1) / jnp.sqrt(float(cfg.d_model))

    # decoder: top-n_q image tokens as object queries
    _, top_idx = jax.lax.top_k(sim, cfg.n_queries)              # (B, n_q)
    z = jnp.take_along_axis(x_img, top_idx[..., None], axis=1)  # (B, n_q, D)
    for i in range(cfg.decoder_layers):
        p = params[f"dec_{i}"]
        z = _block(p, z, cfg, kv=x_txt, kv_mask=txt_mask)
        h = L.layer_norm(z, p["lnz_s"], p["lnz_b"], eps=cfg.norm_eps)
        z = z + L.cross_attention(p["cross_img"], h, x_img, cfg.attn)
    boxes = jax.nn.sigmoid(L.mlp(params["box_head"], z, act="gelu"))
    return score, boxes
