"""E(n)-Equivariant GNN (Satorras et al., arXiv:2102.09844).

Message passing over an explicit edge index via ``jax.ops.segment_sum`` —
JAX has no sparse message-passing primitive, so the gather/scatter IS the
implementation (kernel_taxonomy §GNN):

  m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2)
  x_i'  = x_i + (1/deg_i) sum_j (x_i - x_j) * phi_x(m_ij)
  h_i'  = phi_h(h_i, sum_j m_ij)

All graphs are padded to static (n_nodes, n_edges) with validity masks;
invalid edges point at node 0 with zero weight.  Heads: node classification
(full-graph / sampled shapes) and pooled graph regression (molecule shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GNNArch
from repro.launch.context import shard
from repro.models import layers as L

Params = Any


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 16
    coord_dim: int = 3
    graph_readout: bool = False  # molecule shape: pooled regression
    # full-graph distributed mode: constrain edge-level tensors to the
    # 'edges' sharding (OFF under vmap — the sampled-subgraph path)
    shard_edges: bool = False
    # aggregate (segment_sum -> cross-device psum) in bf16: halves the
    # dominant collective at full-graph scale; fp32 accumulation retained
    # inside each shard's partial sum (§Perf egnn iteration 3)
    agg_dtype: str = "float32"


def init_egnn(rng: jax.Array, cfg: EGNNConfig) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, "float32")
    d = cfg.d_hidden
    b.param("in_proj", (cfg.d_feat, d), (None, "embed"))
    b.param("in_bias", (d,), ("embed",), init="zeros")
    for i in range(cfg.n_layers):
        p = f"layers_{i}"
        L.init_mlp(b, f"{p}/phi_e", (2 * d + 1, d, d))
        L.init_mlp(b, f"{p}/phi_x", (d, d, 1))
        L.init_mlp(b, f"{p}/phi_h", (2 * d, d, d))
    if cfg.graph_readout:
        L.init_mlp(b, "head", (d, d, 1))
    else:
        L.init_mlp(b, "head", (d, cfg.n_classes))
    return b.build()


def egnn_layer(p: Params, h: jax.Array, x: jax.Array,
               edge_index: jax.Array, edge_mask: jax.Array,
               *, shard_edges: bool = False,
               agg_dtype: str = "float32") -> tuple[jax.Array, jax.Array]:
    """h: (N, d), x: (N, 3), edge_index: (2, E) [src, dst], edge_mask: (E,)."""
    n = h.shape[0]
    se = (lambda t: shard(t, ("edges",) + (None,) * (t.ndim - 1))) \
        if shard_edges else (lambda t: t)
    at = jnp.dtype(agg_dtype)
    src, dst = edge_index[0], edge_index[1]
    h_i, h_j = se(h[dst]), se(h[src])
    x_i, x_j = se(x[dst]), se(x[src])
    diff = x_i - x_j                                   # (E, 3)
    d2 = jnp.sum(jnp.square(diff), axis=-1, keepdims=True)
    m = L.mlp(p["phi_e"], jnp.concatenate([h_i, h_j, d2], -1),
              act="silu", final_act=True)              # (E, d)
    m = se(m * edge_mask[:, None])
    # coordinate update (E(n)-equivariant): weighted relative vectors
    w = L.mlp(p["phi_x"], m, act="silu")               # (E, 1)
    w = jnp.tanh(w) * edge_mask[:, None]               # bounded for stability
    deg = jax.ops.segment_sum(edge_mask, dst, num_segments=n)
    dx = jax.ops.segment_sum((diff * w).astype(at), dst, num_segments=n)
    x = x + (dx.astype(jnp.float32)
             / jnp.maximum(deg, 1.0)[:, None]).astype(x.dtype)
    # feature update
    agg = jax.ops.segment_sum(m.astype(at), dst,
                              num_segments=n).astype(h.dtype)  # (N, d)
    h = h + L.mlp(p["phi_h"], jnp.concatenate([h, agg], -1), act="silu")
    return h, x


def egnn_forward(params: Params, cfg: EGNNConfig, *,
                 node_feats: jax.Array, coords: jax.Array,
                 edge_index: jax.Array, edge_mask: jax.Array,
                 node_mask: jax.Array,
                 graph_ids: Optional[jax.Array] = None,
                 n_graphs: int = 1) -> jax.Array:
    """Returns logits (N, C) for node tasks or (n_graphs, 1) for readout."""
    h = node_feats @ params["in_proj"] + params["in_bias"]
    h = h * node_mask[:, None]
    x = coords
    for i in range(cfg.n_layers):
        h, x = egnn_layer(params[f"layers_{i}"], h, x, edge_index, edge_mask,
                          shard_edges=cfg.shard_edges,
                          agg_dtype=cfg.agg_dtype)
        h = h * node_mask[:, None]
    if cfg.graph_readout:
        gid = graph_ids if graph_ids is not None \
            else jnp.zeros((h.shape[0],), jnp.int32)
        pooled = jax.ops.segment_sum(h * node_mask[:, None], gid,
                                     num_segments=n_graphs)
        counts = jax.ops.segment_sum(node_mask, gid, num_segments=n_graphs)
        pooled = pooled / jnp.maximum(counts, 1.0)[:, None]
        return L.mlp(params["head"], pooled, act="silu")
    return L.mlp(params["head"], h, act="silu")


def egnn_node_loss(params: Params, cfg: EGNNConfig, batch: dict
                   ) -> tuple[jax.Array, dict]:
    logits = egnn_forward(
        params, cfg, node_feats=batch["node_feats"], coords=batch["coords"],
        edge_index=batch["edge_index"], edge_mask=batch["edge_mask"],
        node_mask=batch["node_mask"])
    labels = batch["labels"]
    lmask = batch.get("label_mask", batch["node_mask"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.sum((logz - gold) * lmask) / jnp.maximum(jnp.sum(lmask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * lmask) \
        / jnp.maximum(jnp.sum(lmask), 1.0)
    return nll, {"acc": acc}


def egnn_graph_loss(params: Params, cfg: EGNNConfig, batch: dict
                    ) -> tuple[jax.Array, dict]:
    pred = egnn_forward(
        params, cfg, node_feats=batch["node_feats"], coords=batch["coords"],
        edge_index=batch["edge_index"], edge_mask=batch["edge_mask"],
        node_mask=batch["node_mask"], graph_ids=batch["graph_ids"],
        n_graphs=batch["targets"].shape[0])
    mse = jnp.mean(jnp.square(pred[:, 0] - batch["targets"]))
    return mse, {"mse": mse}
