"""Mixture-of-Experts with sort-based capacity dispatch.

TPU-native design (EP-as-TP): experts are sharded over the 'model' mesh axis;
each shard computes its local experts for the tokens routed to them (gathered
with *static* capacity bounds so everything jits), and the scatter-add combine
reduces over the expert axis — XLA SPMD turns that into a single psum, the
same collective shape as a tensor-parallel MLP.  No GShard dense-dispatch
(T x E x C one-hot) tensor is ever materialized, which is what makes 384-expert
kimi-k2 lowerable.

Dispatch mechanics (dropping, GShard-style counting but via sort):
  1. router top-k -> (token, expert, weight) triples, T*k of them
  2. stable argsort by expert id groups triples per expert
  3. exclusive-cumsum of expert counts -> each expert's segment start
  4. expert e takes its first C triples (C = capacity), rest dropped
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MoESpec
from repro.models.layers import ParamBuilder, gated_mlp, init_gated_mlp, _ACTS

Params = Any


def init_moe(b: ParamBuilder, path: str, d_model: int, spec: MoESpec):
    E, ff = spec.n_experts, spec.expert_ff
    b.param(f"{path}/router", (d_model, E), ("embed", None), scale=d_model ** -0.5)
    b.param(f"{path}/w_gate", (E, d_model, ff), ("experts", "embed", "expert_ff"))
    b.param(f"{path}/w_in", (E, d_model, ff), ("experts", "embed", "expert_ff"))
    b.param(f"{path}/w_out", (E, ff, d_model), ("experts", "expert_ff", "embed"))
    if spec.n_shared_experts:
        init_gated_mlp(b, f"{path}/shared", d_model,
                       spec.n_shared_experts * ff)


def capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(math.ceil(n_tokens * spec.top_k / spec.n_experts
                      * spec.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_apply(p: Params, x: jax.Array, spec: MoESpec, *, act: str = "silu"
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    k = spec.top_k
    E = spec.n_experts
    C = capacity(T, spec)
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    top_w, top_e = jax.lax.top_k(probs, k)                      # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)      # renormalize

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_mean)

    # --- sort-based grouping -------------------------------------------------
    flat_e = top_e.reshape(T * k)                               # (Tk,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = top_w.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sort_e = flat_e[order]
    sort_t = flat_t[order]
    sort_w = flat_w[order]
    counts = jnp.bincount(flat_e, length=E)                     # (E,)
    starts = jnp.cumsum(counts) - counts                        # exclusive

    slot = starts[:, None] + jnp.arange(C)[None, :]             # (E, C)
    valid = jnp.arange(C)[None, :] < counts[:, None]
    slot = jnp.clip(slot, 0, T * k - 1)
    tok = jnp.where(valid, sort_t[slot], 0)                     # (E, C)
    w = jnp.where(valid, sort_w[slot], 0.0)
    # guard: a clipped slot may alias another expert's segment
    valid = valid & (sort_e[slot] == jnp.arange(E)[:, None])
    w = jnp.where(valid, w, 0.0)

    xe = xf[tok] * valid[..., None].astype(xf.dtype)            # (E, C, d)
    g = _ACTS[act](jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = g * jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])              # (E, C, d)
    ye = ye * w[..., None].astype(ye.dtype)

    out = jnp.zeros((T, d), ye.dtype).at[tok.reshape(-1)].add(
        ye.reshape(E * C, d))
    if spec.n_shared_experts:
        out = out + gated_mlp(p["shared"], xf, act).astype(out.dtype)
    return out.reshape(B, S, d), aux
