"""Owl-ViT-style visual encoder — LOVO §IV-B/C.

Standard ViT over S x S patches with token pooling and the final projection
*removed*; every output patch token keeps its own embedding (spatial detail
preserved).  Two lightweight heads attach to the tokens:

  * box head:    b_hat = MLP(z) + default anchor box (cxcywh, patch-grid)
  * class head:  c = Linear(z) -> R^{D'} (the indexed class embedding)

vit_encode returns (class_embeds (B,K,D'), boxes (B,K,4), tokens (B,K,D)).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Any


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    patch: int = 32
    img_res: int = 768
    embed_dim: int = 512   # D' class-embedding dim
    norm_eps: float = 1e-6

    @property
    def grid(self) -> int:
        return self.img_res // self.patch

    @property
    def n_patches(self) -> int:
        return self.grid * self.grid

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(n_heads=self.n_heads, n_kv_heads=self.n_heads,
                            head_dim=self.d_model // self.n_heads,
                            qkv_bias=True)


def default_boxes(cfg: ViTConfig) -> np.ndarray:
    """Anchor boxes (cx, cy, w, h) on the patch grid, normalized to [0,1]."""
    g = cfg.grid
    xs = (np.arange(g) + 0.5) / g
    cy, cx = np.meshgrid(xs, xs, indexing="ij")
    wh = np.full_like(cx, 1.0 / g)
    return np.stack([cx.ravel(), cy.ravel(), wh.ravel(), wh.ravel()],
                    axis=-1).astype(np.float32)  # (K, 4)


def init_vit(rng: jax.Array, cfg: ViTConfig, dtype: str = "float32"
             ) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, dtype)
    pdim = cfg.patch * cfg.patch * 3
    b.param("patch_proj", (pdim, cfg.d_model), (None, "embed"))
    b.param("patch_bias", (cfg.d_model,), ("embed",), init="zeros")
    b.param("pos_embed", (cfg.n_patches, cfg.d_model), (None, "embed"),
            scale=0.02)
    for i in range(cfg.n_layers):
        p = f"layers_{i}"
        b.param(f"{p}/ln1_s", (cfg.d_model,), ("embed",), init="ones")
        b.param(f"{p}/ln1_b", (cfg.d_model,), ("embed",), init="zeros")
        L.init_attention(b, f"{p}/attn", cfg.d_model, cfg.attn)
        b.param(f"{p}/ln2_s", (cfg.d_model,), ("embed",), init="ones")
        b.param(f"{p}/ln2_b", (cfg.d_model,), ("embed",), init="zeros")
        L.init_mlp(b, f"{p}/mlp", (cfg.d_model, cfg.d_ff, cfg.d_model))
    b.param("final_ln_s", (cfg.d_model,), ("embed",), init="ones")
    b.param("final_ln_b", (cfg.d_model,), ("embed",), init="zeros")
    # heads
    L.init_mlp(b, "box_head", (cfg.d_model, cfg.d_model, 4))
    b.param("class_proj", (cfg.d_model, cfg.embed_dim), ("embed", None))
    b.param("class_bias", (cfg.embed_dim,), (None,), init="zeros")
    b.param("logit_scale", (), (), init="zeros")
    return b.build()


def patchify(images: jax.Array, patch: int) -> jax.Array:
    """(B, H, W, 3) -> (B, K, patch*patch*3)."""
    B, H, W, C = images.shape
    gh, gw = H // patch, W // patch
    x = images.reshape(B, gh, patch, gw, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, gh * gw, patch * patch * C)


def vit_tokens(params: Params, images: jax.Array, cfg: ViTConfig) -> jax.Array:
    """(B, H, W, 3) float in [0,1] -> patch tokens (B, K, D)."""
    x = patchify(images, cfg.patch)
    x = jnp.einsum("bkp,pd->bkd", x, params["patch_proj"]) + params["patch_bias"]
    x = x + params["pos_embed"]
    for i in range(cfg.n_layers):
        p = params[f"layers_{i}"]
        h = L.layer_norm(x, p["ln1_s"], p["ln1_b"], eps=cfg.norm_eps)
        x = x + L.encoder_attention(p["attn"], h, cfg.attn)
        h = L.layer_norm(x, p["ln2_s"], p["ln2_b"], eps=cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h, act="gelu")
    return L.layer_norm(x, params["final_ln_s"], params["final_ln_b"],
                        eps=cfg.norm_eps)


def vit_encode(params: Params, images: jax.Array, cfg: ViTConfig
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (class_embeds (B,K,D') unit-norm, boxes (B,K,4) cxcywh, tokens)."""
    tokens = vit_tokens(params, images, cfg)
    offsets = L.mlp(params["box_head"], tokens, act="gelu")
    boxes = jax.nn.sigmoid(offsets + _logit(jnp.asarray(default_boxes(cfg))))
    cls = jnp.einsum("bkd,de->bke", tokens, params["class_proj"]) \
        + params["class_bias"]
    cls = cls / jnp.maximum(jnp.linalg.norm(cls, axis=-1, keepdims=True), 1e-9)
    return cls, boxes, tokens


def _logit(p: jax.Array, eps: float = 1e-4) -> jax.Array:
    p = jnp.clip(p, eps, 1 - eps)
    return jnp.log(p) - jnp.log1p(-p)
