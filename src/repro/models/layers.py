"""Shared neural-net layers (functional, no framework).

Params are nested dicts of jnp arrays; every param has a parallel *logical
axis spec* (tuple of names, one per dim) used by the sharding engine.  A
``ParamBuilder`` accumulates both trees during init.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any
Specs = Any


class ParamBuilder:
    """Accumulates params + logical specs under nested name paths."""

    def __init__(self, rng: jax.Array, dtype: str = "float32"):
        self._rng = rng
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def _put(self, path: str, value, spec):
        parts = path.split("/")
        p, s = self.params, self.specs
        for key in parts[:-1]:
            p = p.setdefault(key, {})
            s = s.setdefault(key, {})
        p[parts[-1]] = value
        s[parts[-1]] = spec

    def param(self, path: str, shape: tuple[int, ...],
              logical: tuple[Optional[str], ...],
              init: str = "normal", scale: float | None = None,
              dtype: str | None = None):
        assert len(shape) == len(logical), (path, shape, logical)
        dtype = dtype or self.dtype
        if init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        else:
            fan_in = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
            std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(self.rng(), shape, jnp.float32) * std).astype(dtype)
        self._put(path, v, logical)
        return v

    def build(self) -> tuple[Params, Specs]:
        return self.params, self.specs


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             scale_plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if scale_plus_one:  # gemma convention: weight stored as (scale - 1)
        s = s + 1.0
    return (x * s).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Attention (GQA; full-sequence and single-token-decode paths)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0
    qkv_bias: bool = False
    query_scale: float | None = None  # default 1/sqrt(hd)


def init_attention(b: ParamBuilder, path: str, d_model: int, cfg: AttnConfig):
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    b.param(f"{path}/wq", (d_model, H, hd), ("embed", "heads", "qkv"))
    b.param(f"{path}/wk", (d_model, KV, hd), ("embed", "kv_heads", "qkv"))
    b.param(f"{path}/wv", (d_model, KV, hd), ("embed", "kv_heads", "qkv"))
    b.param(f"{path}/wo", (H, hd, d_model), ("heads", "qkv", "embed"))
    if cfg.qkv_bias:
        b.param(f"{path}/bq", (H, hd), ("heads", "qkv"), init="zeros")
        b.param(f"{path}/bk", (KV, hd), ("kv_heads", "qkv"), init="zeros")
        b.param(f"{path}/bv", (KV, hd), ("kv_heads", "qkv"), init="zeros")


def _qkv(p: Params, x: jax.Array, cfg: AttnConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _scores_to_out(scores: jax.Array, v: jax.Array, p: Params) -> jax.Array:
    # scores: (B, H, S, T) f32; v: (B, T, KV, hd)
    H = scores.shape[1]
    KV = v.shape[2]
    group = H // KV
    B, _, S, T = scores.shape
    sc = scores.reshape(B, KV, group, S, T)
    out = jnp.einsum("bkgst,btkh->bsgkh", sc.astype(v.dtype), v)
    out = out.reshape(B, S, H, v.shape[-1])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention(p: Params, x: jax.Array, cfg: AttnConfig, *,
              positions: jax.Array, window: jax.Array | int | None = None,
              kv_cache: tuple[jax.Array, jax.Array] | None = None,
              cache_positions: jax.Array | None = None) -> tuple[jax.Array, tuple]:
    """Full-sequence attention (train / prefill).

    x: (B, S, d).  window: scalar (possibly traced) — attend only to keys with
    ``0 <= i - j < window``; None/0 means full causal.  Returns (out, (k, v))
    so prefill can persist the cache.
    """
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    H, KV = cfg.n_heads, cfg.n_kv_heads
    group = H // KV
    B, S = x.shape[:2]
    qg = q.reshape(B, S, KV, group, cfg.head_dim)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = logits.reshape(B, H, S, S)
    logits = softcap(logits, cfg.attn_softcap)
    i = positions[..., :, None]  # (B?, S, 1)
    j = positions[..., None, :]
    mask = j <= i
    if window is not None:
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, (i - j) < w, True)
    logits = jnp.where(mask[:, None] if mask.ndim == 3 else mask[None, None],
                       logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = _scores_to_out(probs, v, p)
    return out, (k, v)


def encoder_attention(p: Params, x: jax.Array, cfg: AttnConfig, *,
                      pad_mask: jax.Array | None = None,
                      use_rope: bool = False,
                      positions: jax.Array | None = None) -> jax.Array:
    """Bidirectional self-attention (ViT / BERT-style encoders).

    x: (B, S, d); pad_mask: (B, S) 1=valid.  No KV cache, no causality.
    """
    q, k, v = _qkv(p, x, cfg)
    if use_rope:
        B, S = x.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    H, KV = cfg.n_heads, cfg.n_kv_heads
    group = H // KV
    B, S = x.shape[:2]
    qg = q.reshape(B, S, KV, group, cfg.head_dim)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = logits.reshape(B, H, S, S)
    if pad_mask is not None:
        logits = jnp.where(pad_mask[:, None, None, :].astype(bool), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return _scores_to_out(probs, v, p)


def cross_attention(p: Params, xq: jax.Array, xkv: jax.Array, cfg: AttnConfig,
                    *, kv_mask: jax.Array | None = None) -> jax.Array:
    """Cross-attention: queries from xq (B, Sq, d), keys/values from xkv
    (B, Sk, d).  Used by the LOVO cross-modality feature enhancer/decoder."""
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bshk,bthk->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :].astype(bool), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthk->bshk", probs.astype(v.dtype), v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_chunked(p: Params, x: jax.Array, cfg: AttnConfig, *,
                      positions: jax.Array, window: jax.Array | int | None = None,
                      chunk: int = 512, remat_chunk: bool = False,
                      unroll: bool = False) -> tuple[jax.Array, tuple]:
    """Query-chunked attention: never materializes the full (S, S) score
    matrix — live memory is (B, H, chunk, S).  With ``remat_chunk`` the chunk
    body is checkpointed so the backward pass also peaks at one chunk's
    probabilities (flash-attention memory behavior; the Pallas kernel is the
    real-TPU implementation, this is its XLA-lowerable twin).  ``unroll``
    replaces the scan with a python loop — used by the dry-run cost probes
    because XLA's cost_analysis counts scan bodies once."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    H, KV = cfg.n_heads, cfg.n_kv_heads
    group = H // KV
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos_p = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    qc = qp.reshape(B, n_chunks, chunk, H, cfg.head_dim).transpose(1, 0, 2, 3, 4)
    pc = pos_p.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    j = positions[:, None, :]  # (B, 1, S)

    def body_fn(qi, pi):
        qg = qi.reshape(B, chunk, KV, group, cfg.head_dim)
        lg = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
        lg = lg.reshape(B, H, chunk, S)
        lg = softcap(lg, cfg.attn_softcap)
        i = pi[:, :, None]                    # (B, chunk, 1)
        mask = (j <= i) & (i >= 0)
        if window is not None:
            w = jnp.asarray(window)
            mask = mask & jnp.where(w > 0, (i - j) < w, True)
        lg = jnp.where(mask[:, None], lg, -1e30)
        probs = jax.nn.softmax(lg, axis=-1)
        return _scores_to_out_noproj(probs, v)  # (B, chunk, H, hd)

    if remat_chunk:
        body_fn = jax.checkpoint(body_fn)

    if unroll:
        outs = jnp.stack([body_fn(qc[i], pc[i]) for i in range(n_chunks)])
    else:
        _, outs = jax.lax.scan(lambda _, xs: (None, body_fn(*xs)),
                               None, (qc, pc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, H,
                                                cfg.head_dim)[:, :S]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def _scores_to_out_noproj(scores: jax.Array, v: jax.Array) -> jax.Array:
    H = scores.shape[1]
    KV = v.shape[2]
    group = H // KV
    B, _, S, T = scores.shape
    sc = scores.reshape(B, KV, group, S, T)
    out = jnp.einsum("bkgst,btkh->bsgkh", sc.astype(v.dtype), v)
    return out.reshape(B, S, H, v.shape[-1])


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) absmax int8 quantization: (..., hd) ->
    (int8 codes, f32 scale (..., 1))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attention_decode(p: Params, x: jax.Array, cfg: AttnConfig, *,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, window: jax.Array | int | None = None,
                     cache_scales: tuple[jax.Array, jax.Array] | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array, Any]:
    """Single-token decode.  x: (B, 1, d); cache_[kv]: (B, T, KV, hd);
    pos: (B,) current position per sequence.  With ``cache_scales`` the
    caches are int8 (KIVI-class) and dequantized for the attention compute
    (tile-local in VMEM under the real-TPU flash-decode kernel).
    Returns (out, new_k, new_v, new_scales)."""
    B, _, _ = x.shape
    T = cache_k.shape[1]
    q, k_new, v_new = _qkv(p, x, cfg)            # (B,1,H,hd)/(B,1,KV,hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
    bidx = jnp.arange(B)
    if cache_scales is not None:
        sk, sv = cache_scales
        kq, ks_new = quantize_kv(k_new[:, 0])
        vq, vs_new = quantize_kv(v_new[:, 0])
        cache_k = cache_k.at[bidx, pos].set(kq)
        cache_v = cache_v.at[bidx, pos].set(vq)
        sk = sk.at[bidx, pos].set(ks_new)
        sv = sv.at[bidx, pos].set(vs_new)
        cache_scales = (sk, sv)
        k_full = dequantize_kv(cache_k, sk, k_new.dtype)
        v_full = dequantize_kv(cache_v, sv, v_new.dtype)
    else:
        cache_k = cache_k.at[bidx, pos].set(k_new[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, pos].set(v_new[:, 0].astype(cache_v.dtype))
        k_full, v_full = cache_k, cache_v
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    H, KV = cfg.n_heads, cfg.n_kv_heads
    group = H // KV
    qg = q.reshape(B, 1, KV, group, cfg.head_dim)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_full,
                        preferred_element_type=jnp.float32) * scale
    logits = logits.reshape(B, H, 1, T)
    logits = softcap(logits, cfg.attn_softcap)
    j = jnp.arange(T)[None, :]                    # (1, T)
    mask = j <= pos[:, None]
    if window is not None:
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, (pos[:, None] - j) < w, True)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = _scores_to_out(probs, v_full, p)
    return out, cache_k, cache_v, cache_scales


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
_ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu, "tanh": jnp.tanh,
}


def init_gated_mlp(b: ParamBuilder, path: str, d_model: int, d_ff: int):
    b.param(f"{path}/w_gate", (d_model, d_ff), ("embed", "ff"))
    b.param(f"{path}/w_in", (d_model, d_ff), ("embed", "ff"))
    b.param(f"{path}/w_out", (d_ff, d_model), ("ff", "embed"))


def gated_mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    g = _ACTS[act](jnp.einsum("...d,df->...f", x, p["w_gate"]))
    h = g * jnp.einsum("...d,df->...f", x, p["w_in"])
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


def init_mlp(b: ParamBuilder, path: str, dims: tuple[int, ...], *,
             bias: bool = True, logical_in: str = "embed",
             logical_hidden: str = "ff"):
    for i in range(len(dims) - 1):
        li = logical_in if i == 0 else logical_hidden
        lo = logical_hidden
        b.param(f"{path}/w{i}", (dims[i], dims[i + 1]), (li, lo))
        if bias:
            b.param(f"{path}/b{i}", (dims[i + 1],), (lo,), init="zeros")


def mlp(p: Params, x: jax.Array, *, act: str = "relu",
        final_act: bool = False) -> jax.Array:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = jnp.einsum("...d,df->...f", x, p[f"w{i}"])
        if f"b{i}" in p:
            x = x + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = _ACTS[act](x)
    return x
