"""Decoder-only LM family: gemma2 / llama3 / qwen2 (dense) + phi3.5-moe /
kimi-k2 (MoE).  Scan-over-layers with per-layer window schedule; train,
prefill, and KV-cache decode paths.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMArch
from repro.launch.context import shard
from repro.models import layers as L
from repro.models import moe as M

Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _attn_cfg(arch: LMArch) -> L.AttnConfig:
    return L.AttnConfig(
        n_heads=arch.n_heads, n_kv_heads=arch.n_kv_heads,
        head_dim=arch.resolved_head_dim, rope_theta=arch.rope_theta,
        attn_softcap=arch.attn_softcap, qkv_bias=arch.qkv_bias)


def _init_layer(rng: jax.Array, arch: LMArch) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, arch.param_dtype)
    d = arch.d_model
    b.param("pre_attn_norm", (d,), ("embed",),
            init="zeros" if _gemma_norm(arch) else "ones")
    L.init_attention(b, "attn", d, _attn_cfg(arch))
    if arch.post_norms:
        b.param("post_attn_norm", (d,), ("embed",), init="zeros")
        b.param("post_mlp_norm", (d,), ("embed",), init="zeros")
    b.param("pre_mlp_norm", (d,), ("embed",),
            init="zeros" if _gemma_norm(arch) else "ones")
    if arch.moe is not None:
        M.init_moe(b, "moe", d, arch.moe)
        if arch.moe.first_k_dense:
            L.init_gated_mlp(b, "dense_mlp", d, arch.d_ff)
    else:
        L.init_gated_mlp(b, "mlp", d, arch.d_ff)
    return b.build()


def _gemma_norm(arch: LMArch) -> bool:
    # gemma stores RMSNorm weights as (scale - 1)
    return arch.post_norms


def init_lm(rng: jax.Array, arch: LMArch) -> tuple[Params, Any]:
    k_embed, k_layers, k_head = jax.random.split(rng, 3)
    b = L.ParamBuilder(k_embed, arch.param_dtype)
    b.param("embed", (arch.vocab, arch.d_model), ("vocab", "embed"),
            scale=1.0)
    b.param("final_norm", (arch.d_model,), ("embed",),
            init="zeros" if _gemma_norm(arch) else "ones")
    if not arch.tie_embeddings:
        b.param("lm_head", (arch.d_model, arch.vocab), ("embed", "vocab"))
    params, specs = b.build()

    layer_keys = jax.random.split(k_layers, arch.n_layers)
    # vmap stacks params along a leading 'layers' axis; logical specs are
    # rebuilt from a tiny structural twin (specs are string tuples, which
    # vmap cannot stack).
    lp = jax.vmap(lambda k: _init_layer(k, arch)[0])(layer_keys)
    _, one_spec = _layer_spec(arch)
    lp_specs = jax.tree.map(lambda sp: ("layers",) + tuple(sp), one_spec,
                            is_leaf=lambda x: isinstance(x, tuple))
    params["layers"] = lp
    specs["layers"] = lp_specs
    return params, specs


@functools.lru_cache(maxsize=None)
def _layer_spec(arch: LMArch):
    """Single-layer param spec tree (shapes discarded)."""
    p, s = _init_layer(jax.random.PRNGKey(0), dataclass_small(arch))
    return p, s


def dataclass_small(arch: LMArch) -> LMArch:
    """Tiny twin of ``arch`` (same param *structure*) for cheap spec builds."""
    import dataclasses
    moe = arch.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=max(2, min(moe.n_experts, 2)),
                                  top_k=1, expert_ff=8,
                                  n_shared_experts=min(moe.n_shared_experts, 1))
    hd = 4
    return dataclasses.replace(
        arch, n_layers=1, d_model=8, n_heads=2, n_kv_heads=1, head_dim=hd,
        d_ff=16, vocab=32, moe=moe)


# ---------------------------------------------------------------------------
# Window schedule
# ---------------------------------------------------------------------------
def window_schedule(arch: LMArch) -> np.ndarray:
    """Per-layer attention window (0 == full causal)."""
    if arch.sliding_window and arch.local_global_pattern:
        # gemma2: even layers local, odd layers global
        return np.array([arch.sliding_window if (i % 2 == 0) else 0
                         for i in range(arch.n_layers)], np.int32)
    if arch.sliding_window:
        return np.full((arch.n_layers,), arch.sliding_window, np.int32)
    return np.zeros((arch.n_layers,), np.int32)


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _constrain_layer_params(lp: Params, arch: LMArch) -> Params:
    """Pin each (sliced) layer weight to its logical sharding inside the
    scan body — keeps FSDP all-gathers at per-layer lifetime instead of
    letting the scheduler batch/hoist them (no-op outside a mesh context)."""
    _, spec = _layer_spec(arch)
    # lp drives the tree structure; spec tuples stay intact as leaves
    return jax.tree.map(lambda w, lg: shard(w, tuple(lg)), lp, spec)


def _layer_fwd(lp: Params, x: jax.Array, arch: LMArch, *, window,
               positions) -> tuple[jax.Array, jax.Array]:
    cfg = _attn_cfg(arch)
    gp = _gemma_norm(arch)
    if arch.constrain_layer_weights:
        lp = _constrain_layer_params(lp, arch)
    h = L.rms_norm(x, lp["pre_attn_norm"], eps=arch.norm_eps, scale_plus_one=gp)
    S = x.shape[1]
    if arch.attn_chunk and S > arch.attn_chunk:
        attn_out, _ = L.attention_chunked(
            lp["attn"], h, cfg, positions=positions, window=window,
            chunk=arch.attn_chunk, remat_chunk=True, unroll=arch.attn_unroll)
    else:
        attn_out, _ = L.attention(lp["attn"], h, cfg, positions=positions,
                                  window=window)
    if arch.post_norms:
        attn_out = L.rms_norm(attn_out, lp["post_attn_norm"],
                              eps=arch.norm_eps, scale_plus_one=gp)
    x = x + attn_out
    h = L.rms_norm(x, lp["pre_mlp_norm"], eps=arch.norm_eps, scale_plus_one=gp)
    aux = jnp.zeros((), jnp.float32)
    if arch.moe is not None:
        mlp_out, aux = M.moe_apply(lp["moe"], h, arch.moe, act=arch.act)
    else:
        mlp_out = L.gated_mlp(lp["mlp"], h, arch.act)
    if arch.post_norms:
        mlp_out = L.rms_norm(mlp_out, lp["post_mlp_norm"],
                             eps=arch.norm_eps, scale_plus_one=gp)
    out = shard(x + mlp_out, ("batch", "seq_act", "act_embed"))
    return out, aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'full': save nothing


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params: Params, tokens: jax.Array, arch: LMArch, *,
            positions: Optional[jax.Array] = None,
            last_token_only: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (logits, aux_loss).  Scan over layers."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = params["embed"][tokens].astype(arch.param_dtype)
    if arch.post_norms:  # gemma scales embeddings
        x = x * jnp.asarray(math.sqrt(arch.d_model), x.dtype)
    x = shard(x, ("batch", "seq_act", "act_embed"))
    windows = jnp.asarray(window_schedule(arch))

    def body(carry, scanned):
        x, aux = carry
        lp, w = scanned
        x, a = _layer_fwd(lp, x, arch, window=w, positions=positions)
        return (x, aux + a), None

    body = _remat(body, arch.remat_policy)
    if arch.scan_layers:
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], windows))
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(arch.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux), _ = body((x, aux), (lp, windows[i]))
    x = L.rms_norm(x, params["final_norm"], eps=arch.norm_eps,
                   scale_plus_one=_gemma_norm(arch))
    if last_token_only:
        x = x[:, -1:]
    head = params["embed"].T if arch.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = shard(logits, ("batch", "seq_act", "vocab_out"))
    logits = L.softcap(logits, arch.final_softcap)
    return logits, aux


def lm_loss(params: Params, tokens: jax.Array, labels: jax.Array,
            arch: LMArch, *, aux_coef: float = 0.01) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, tokens, arch)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + aux_coef * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------
def cache_dtype(arch: LMArch):
    if arch.kv_quant:
        return jnp.int8  # KIVI-class int8 cache + per-(token,head) scales
    # cache precision follows param precision (bf16 prod / f32 tests)
    return jnp.bfloat16 if arch.param_dtype == "bfloat16" else jnp.float32


def init_cache(arch: LMArch, batch: int, max_len: int,
               dtype=None) -> dict:
    dtype = dtype or cache_dtype(arch)
    shape = (arch.n_layers, batch, max_len, arch.n_kv_heads,
             arch.resolved_head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if arch.kv_quant:
        sshape = shape[:-1] + (1,)
        cache["k_scale"] = jnp.ones(sshape, jnp.float32)
        cache["v_scale"] = jnp.ones(sshape, jnp.float32)
    return cache


def cache_specs(arch: LMArch | None = None) -> dict:
    lg = ("layers", "batch", "seq", "act_kv_heads", "qkv")
    out = {"k": lg, "v": lg}
    if arch is not None and arch.kv_quant:
        out["k_scale"] = lg
        out["v_scale"] = lg
    return out


def prefill(params: Params, tokens: jax.Array, arch: LMArch
            ) -> tuple[jax.Array, dict]:
    """Returns (last-token logits (B, vocab), filled cache)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = params["embed"][tokens].astype(arch.param_dtype)
    if arch.post_norms:
        x = x * jnp.asarray(math.sqrt(arch.d_model), x.dtype)
    windows = jnp.asarray(window_schedule(arch))
    cfg = _attn_cfg(arch)
    gp = _gemma_norm(arch)

    use_chunk = bool(arch.attn_chunk) and S > arch.attn_chunk

    def attn_fn(p, h, cfg, positions, window):
        if use_chunk:
            return L.attention_chunked(p, h, cfg, positions=positions,
                                       window=window, chunk=arch.attn_chunk,
                                       unroll=arch.attn_unroll)
        return L.attention(p, h, cfg, positions=positions, window=window)

    def body(x, scanned):
        lp, w = scanned
        h = L.rms_norm(x, lp["pre_attn_norm"], eps=arch.norm_eps,
                       scale_plus_one=gp)
        attn_out, (k, v) = attn_fn(lp["attn"], h, cfg,
                                   positions=positions, window=w)
        if arch.post_norms:
            attn_out = L.rms_norm(attn_out, lp["post_attn_norm"],
                                  eps=arch.norm_eps, scale_plus_one=gp)
        x = x + attn_out
        h = L.rms_norm(x, lp["pre_mlp_norm"], eps=arch.norm_eps,
                       scale_plus_one=gp)
        if arch.moe is not None:
            mlp_out, _ = M.moe_apply(lp["moe"], h, arch.moe, act=arch.act)
        else:
            mlp_out = L.gated_mlp(lp["mlp"], h, arch.act)
        if arch.post_norms:
            mlp_out = L.rms_norm(mlp_out, lp["post_mlp_norm"],
                                 eps=arch.norm_eps, scale_plus_one=gp)
        cd = cache_dtype(arch)
        return x + mlp_out, (k.astype(cd), v.astype(cd))

    if arch.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
    else:
        outs = []
        for i in range(arch.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, kv = body(x, (lp, windows[i]))
            outs.append(kv)
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
    x = L.rms_norm(x, params["final_norm"], eps=arch.norm_eps,
                   scale_plus_one=gp)
    head = params["embed"].T if arch.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return L.softcap(logits, arch.final_softcap), {"k": ks, "v": vs}


def prepare_cache(cache: dict, arch: LMArch) -> dict:
    """Bridge a full-precision (prefill) cache into decode's expected form:
    under ``kv_quant`` the fp cache is quantized once here."""
    if not arch.kv_quant or "k_scale" in cache:
        return cache
    kq, ks = L.quantize_kv(cache["k"])
    vq, vs = L.quantize_kv(cache["v"])
    return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                pos: jax.Array, arch: LMArch) -> tuple[jax.Array, dict]:
    """tokens: (B,) next token ids; pos: (B,) write positions.
    Returns (logits (B, vocab), updated cache)."""
    cache = prepare_cache(cache, arch)
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None].astype(arch.param_dtype)  # (B,1,d)
    if arch.post_norms:
        x = x * jnp.asarray(math.sqrt(arch.d_model), x.dtype)
    windows = jnp.asarray(window_schedule(arch))
    cfg = _attn_cfg(arch)
    gp = _gemma_norm(arch)

    def body(x, scanned):
        lp, w, ck, cv, scales = scanned
        h = L.rms_norm(x, lp["pre_attn_norm"], eps=arch.norm_eps,
                       scale_plus_one=gp)
        attn_out, ck, cv, scales = L.attention_decode(
            lp["attn"], h, cfg, cache_k=ck, cache_v=cv, pos=pos, window=w,
            cache_scales=scales)
        if arch.post_norms:
            attn_out = L.rms_norm(attn_out, lp["post_attn_norm"],
                                  eps=arch.norm_eps, scale_plus_one=gp)
        x = x + attn_out
        h = L.rms_norm(x, lp["pre_mlp_norm"], eps=arch.norm_eps,
                       scale_plus_one=gp)
        if arch.moe is not None:
            mlp_out, _ = M.moe_apply(lp["moe"], h, arch.moe, act=arch.act)
        else:
            mlp_out = L.gated_mlp(lp["mlp"], h, arch.act)
        if arch.post_norms:
            mlp_out = L.rms_norm(mlp_out, lp["post_mlp_norm"],
                                 eps=arch.norm_eps, scale_plus_one=gp)
        return x + mlp_out, (ck, cv, scales)

    qscales = (cache["k_scale"], cache["v_scale"]) if arch.kv_quant else None
    if arch.scan_layers:
        xs = (params["layers"], windows, cache["k"], cache["v"], qscales)
        x, (ks, vs, scales) = jax.lax.scan(body, x, xs)
    else:
        outs = []
        for i in range(arch.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            sc = (qscales[0][i], qscales[1][i]) if qscales else None
            x, kv = body(x, (lp, windows[i], cache["k"][i], cache["v"][i],
                             sc))
            outs.append(kv)
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
        scales = (jnp.stack([o[2][0] for o in outs]),
                  jnp.stack([o[2][1] for o in outs])) if qscales else None
    x = L.rms_norm(x, params["final_norm"], eps=arch.norm_eps,
                   scale_plus_one=gp)
    head = params["embed"].T if arch.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, 0], head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    new_cache = {"k": ks, "v": vs}
    if arch.kv_quant:
        new_cache["k_scale"], new_cache["v_scale"] = scales
    return L.softcap(logits, arch.final_softcap), new_cache
