"""BERT-style bidirectional text encoder — LOVO §VI-A.

Fast-search path: the whole query sentence is encoded into ONE feature vector
(the paper stresses this: no cross-word fine structure, optimized for rapid
preliminary retrieval).  We mean-pool valid tokens and project into the
shared D' embedding space (aligned with the ViT class embeddings by
contrastive training — train/alignment.py).

The token-level outputs (B, S, D) are also returned for the cross-modality
rerank stage, which DOES use fine-grained text features.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = Any


@dataclasses.dataclass(frozen=True)
class TextConfig:
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    d_ff: int = 2048
    vocab: int = 32_000
    max_len: int = 64
    embed_dim: int = 512
    norm_eps: float = 1e-6

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(n_heads=self.n_heads, n_kv_heads=self.n_heads,
                            head_dim=self.d_model // self.n_heads,
                            qkv_bias=True)


def init_text(rng: jax.Array, cfg: TextConfig, dtype: str = "float32"
              ) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, dtype)
    b.param("tok_embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
            scale=0.02)
    b.param("pos_embed", (cfg.max_len, cfg.d_model), (None, "embed"),
            scale=0.02)
    for i in range(cfg.n_layers):
        p = f"layers_{i}"
        b.param(f"{p}/ln1_s", (cfg.d_model,), ("embed",), init="ones")
        b.param(f"{p}/ln1_b", (cfg.d_model,), ("embed",), init="zeros")
        L.init_attention(b, f"{p}/attn", cfg.d_model, cfg.attn)
        b.param(f"{p}/ln2_s", (cfg.d_model,), ("embed",), init="ones")
        b.param(f"{p}/ln2_b", (cfg.d_model,), ("embed",), init="zeros")
        L.init_mlp(b, f"{p}/mlp", (cfg.d_model, cfg.d_ff, cfg.d_model))
    b.param("final_ln_s", (cfg.d_model,), ("embed",), init="ones")
    b.param("final_ln_b", (cfg.d_model,), ("embed",), init="zeros")
    b.param("out_proj", (cfg.d_model, cfg.embed_dim), ("embed", None))
    return b.build()


def text_tokens(params: Params, tokens: jax.Array, mask: jax.Array,
                cfg: TextConfig) -> jax.Array:
    """(B, S) ids + (B, S) validity -> token features (B, S, D)."""
    S = tokens.shape[1]
    x = params["tok_embed"][tokens] + params["pos_embed"][:S]
    for i in range(cfg.n_layers):
        p = params[f"layers_{i}"]
        h = L.layer_norm(x, p["ln1_s"], p["ln1_b"], eps=cfg.norm_eps)
        x = x + L.encoder_attention(p["attn"], h, cfg.attn, pad_mask=mask)
        h = L.layer_norm(x, p["ln2_s"], p["ln2_b"], eps=cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h, act="gelu")
    return L.layer_norm(x, params["final_ln_s"], params["final_ln_b"],
                        eps=cfg.norm_eps)


def text_encode(params: Params, tokens: jax.Array, mask: jax.Array,
                cfg: TextConfig) -> tuple[jax.Array, jax.Array]:
    """-> (query embedding (B, D') unit-norm, token features (B, S, D))."""
    feats = text_tokens(params, tokens, mask, cfg)
    m = mask[..., None].astype(feats.dtype)
    pooled = jnp.sum(feats * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    q = pooled @ params["out_proj"]
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    return q, feats
