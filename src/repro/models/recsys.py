"""RecSys model zoo: DLRM-RM2, xDeepFM (CIN), MIND (multi-interest capsules),
BERT4Rec — plus the shared sparse-embedding substrate.

JAX has no native EmbeddingBag or CSR sparse; the embedding layer here IS the
implementation (kernel_taxonomy §RecSys): one row-concatenated mega-table
(sum(vocab) x dim), per-feature offsets, ``jnp.take`` gather, masked-sum bag
reduce.  The mega-table shards row-wise over the 'model' mesh axis (classic
DLRM model-parallel embeddings); XLA SPMD turns the gather into the
all-to-all-equivalent collective.

``retrieval_cand`` (1 user x 1e6 candidates) is LOVO's fast-search regime:
``retrieval_scores`` does the batched dot; ``retrieval_scores_pq`` scores the
same candidates through the paper's PQ-ADC path (technique transfer —
DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecArch
from repro.models import layers as L

Params = Any


# ---------------------------------------------------------------------------
# Sparse embedding substrate
# ---------------------------------------------------------------------------
def table_offsets(vocab_sizes: tuple[int, ...]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(vocab_sizes)]).astype(np.int32)


def init_embedding(b: L.ParamBuilder, path: str,
                   vocab_sizes: tuple[int, ...], dim: int):
    total = int(sum(vocab_sizes))
    b.param(path, (total, dim), ("table_rows", None), scale=0.01)


def embedding_lookup(table: jax.Array, offsets: jax.Array,
                     idx: jax.Array) -> jax.Array:
    """idx: (B, F) per-feature local ids -> (B, F, dim)."""
    flat = idx + offsets[None, : idx.shape[1]]
    return jnp.take(table, flat, axis=0)


def embedding_bag(table: jax.Array, offsets: jax.Array, idx: jax.Array,
                  mask: jax.Array, *, combiner: str = "sum") -> jax.Array:
    """Multi-hot bags.  idx: (B, F, nnz), mask: (B, F, nnz) -> (B, F, dim).

    take + masked segment-style reduce (EmbeddingBag semantics)."""
    B, F, Z = idx.shape
    flat = idx + offsets[None, :F, None]
    emb = jnp.take(table, flat, axis=0)                 # (B, F, Z, dim)
    emb = emb * mask[..., None]
    out = jnp.sum(emb, axis=2)
    if combiner == "mean":
        out = out / jnp.maximum(jnp.sum(mask, axis=2, keepdims=False),
                                1.0)[..., None]
    return out


# ---------------------------------------------------------------------------
# DLRM-RM2 (arXiv:1906.00091)
# ---------------------------------------------------------------------------
def init_dlrm(rng: jax.Array, arch: RecArch) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, arch.param_dtype)
    init_embedding(b, "tables", arch.vocab_sizes, arch.embed_dim)
    L.init_mlp(b, "bot_mlp", (arch.n_dense,) + arch.bot_mlp[1:])
    n_f = arch.n_sparse + 1
    n_inter = n_f * (n_f - 1) // 2
    top_in = n_inter + arch.bot_mlp[-1]
    L.init_mlp(b, "top_mlp", (top_in,) + arch.top_mlp)
    return b.build()


def dlrm_forward(params: Params, arch: RecArch, *, dense: jax.Array,
                 sparse: jax.Array) -> jax.Array:
    """dense: (B, 13); sparse: (B, 26) ids -> logits (B,)."""
    offs = jnp.asarray(table_offsets(arch.vocab_sizes)[:-1])
    emb = embedding_lookup(params["tables"], offs, sparse)   # (B, 26, d)
    bot = L.mlp(params["bot_mlp"], dense, act="relu", final_act=True)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, 27, d)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]                                  # (B, 351)
    top_in = jnp.concatenate([flat, bot], axis=-1)
    return L.mlp(params["top_mlp"], top_in, act="relu")[:, 0]


# ---------------------------------------------------------------------------
# xDeepFM / CIN (arXiv:1803.05170)
# ---------------------------------------------------------------------------
def init_xdeepfm(rng: jax.Array, arch: RecArch) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, arch.param_dtype)
    init_embedding(b, "tables", arch.vocab_sizes, arch.embed_dim)
    b.param("linear", (int(sum(arch.vocab_sizes)),), ("table_rows",),
            init="zeros")
    h_prev, f0 = arch.n_sparse, arch.n_sparse
    for i, h in enumerate(arch.cin_layers):
        b.param(f"cin_w{i}", (h_prev * f0, h), (None, None))
        h_prev = h
    L.init_mlp(b, "deep", (arch.n_sparse * arch.embed_dim,) + arch.mlp_layers
               + (1,))
    b.param("cin_out", (int(sum(arch.cin_layers)), 1), (None, None))
    return b.build()


def xdeepfm_forward(params: Params, arch: RecArch, *,
                    sparse: jax.Array) -> jax.Array:
    """sparse: (B, 39) ids -> logits (B,)."""
    offs = jnp.asarray(table_offsets(arch.vocab_sizes)[:-1])
    flat_ids = sparse + offs[None]
    emb = jnp.take(params["tables"], flat_ids, axis=0)       # (B, F, d)
    linear = jnp.sum(jnp.take(params["linear"], flat_ids, axis=0), axis=1)
    # CIN: x^{k+1}_h = sum over (i,j) of W[h,i,j] (x^k_i * x^0_j)  per dim d
    x0, xk = emb, emb
    cin_outs = []
    for i in range(len(arch.cin_layers)):
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)              # (B, Hk, F, d)
        B, Hk, F, D = z.shape
        xk = jnp.einsum("bqd,qh->bhd", z.reshape(B, Hk * F, D),
                        params[f"cin_w{i}"])                 # (B, Hk+1, d)
        cin_outs.append(jnp.sum(xk, axis=-1))                # (B, Hk+1)
    cin = jnp.concatenate(cin_outs, axis=-1) @ params["cin_out"]
    deep = L.mlp(params["deep"], emb.reshape(emb.shape[0], -1), act="relu")
    return (linear + cin[:, 0] + deep[:, 0])


# ---------------------------------------------------------------------------
# MIND multi-interest (arXiv:1904.08030)
# ---------------------------------------------------------------------------
def init_mind(rng: jax.Array, arch: RecArch) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, arch.param_dtype)
    init_embedding(b, "items", arch.vocab_sizes, arch.embed_dim)
    b.param("cap_bilinear", (arch.embed_dim, arch.embed_dim), (None, None))
    L.init_mlp(b, "interest_mlp",
               (arch.embed_dim, 2 * arch.embed_dim, arch.embed_dim))
    return b.build()


def mind_interests(params: Params, arch: RecArch, *, history: jax.Array,
                   hist_mask: jax.Array) -> jax.Array:
    """history: (B, L) item ids -> interest capsules (B, n_interests, d).

    B2I dynamic routing, `capsule_iters` iterations; routing logits are
    detached (stop_gradient) per the paper."""
    offs = jnp.asarray(table_offsets(arch.vocab_sizes)[:-1])
    emb = jnp.take(params["items"], history + offs[0], axis=0)  # (B, L, d)
    u = jnp.einsum("bld,de->ble", emb, params["cap_bilinear"])
    B, Lh, d = u.shape
    K = arch.n_interests
    logits = jnp.zeros((B, K, Lh), jnp.float32)
    caps = jnp.zeros((B, K, d), u.dtype)
    for _ in range(arch.capsule_iters):
        w = jax.nn.softmax(logits, axis=1)                  # over interests
        w = w * hist_mask[:, None, :]
        s = jnp.einsum("bkl,bld->bkd", w, u)
        # squash
        n2 = jnp.sum(jnp.square(s), axis=-1, keepdims=True)
        caps = s * (n2 / (1 + n2)) / jnp.sqrt(n2 + 1e-9)
        logits = logits + jax.lax.stop_gradient(
            jnp.einsum("bkd,bld->bkl", caps, u))
    caps = caps + L.mlp(params["interest_mlp"], caps, act="relu")
    return caps


def mind_loss(params: Params, arch: RecArch, batch: dict
              ) -> tuple[jax.Array, dict]:
    """Label-aware attention + sampled softmax vs in-batch negatives."""
    caps = mind_interests(params, arch, history=batch["history"],
                          hist_mask=batch["hist_mask"])     # (B, K, d)
    offs = jnp.asarray(table_offsets(arch.vocab_sizes)[:-1])
    target = jnp.take(params["items"], batch["target"] + offs[0], axis=0)
    att = jax.nn.softmax(
        jnp.einsum("bkd,bd->bk", caps, target) * 2.0, axis=-1)
    user = jnp.einsum("bk,bkd->bd", att, caps)              # (B, d)
    logits = user @ target.T                                 # in-batch
    labels = jnp.arange(logits.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    nll = jnp.mean(logz - jnp.take_along_axis(
        logits, labels[:, None], axis=-1)[:, 0])
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# BERT4Rec (arXiv:1904.06690)
# ---------------------------------------------------------------------------
def init_bert4rec(rng: jax.Array, arch: RecArch) -> tuple[Params, Any]:
    b = L.ParamBuilder(rng, arch.param_dtype)
    init_embedding(b, "items", arch.vocab_sizes, arch.embed_dim)
    b.param("pos", (arch.seq_len, arch.embed_dim), (None, None), scale=0.02)
    cfg = _bert4rec_attn(arch)
    for i in range(arch.n_blocks):
        p = f"blocks_{i}"
        b.param(f"{p}/ln1_s", (arch.embed_dim,), (None,), init="ones")
        b.param(f"{p}/ln1_b", (arch.embed_dim,), (None,), init="zeros")
        L.init_attention(b, f"{p}/attn", arch.embed_dim, cfg)
        b.param(f"{p}/ln2_s", (arch.embed_dim,), (None,), init="ones")
        b.param(f"{p}/ln2_b", (arch.embed_dim,), (None,), init="zeros")
        L.init_mlp(b, f"{p}/mlp",
                   (arch.embed_dim, 4 * arch.embed_dim, arch.embed_dim))
    b.param("final_ln_s", (arch.embed_dim,), (None,), init="ones")
    b.param("final_ln_b", (arch.embed_dim,), (None,), init="zeros")
    return b.build()


def _bert4rec_attn(arch: RecArch) -> L.AttnConfig:
    return L.AttnConfig(n_heads=arch.n_heads, n_kv_heads=arch.n_heads,
                        head_dim=arch.embed_dim // arch.n_heads,
                        qkv_bias=True)


def bert4rec_hidden(params: Params, arch: RecArch, *, seq: jax.Array,
                    seq_mask: jax.Array) -> jax.Array:
    """seq: (B, L) item ids (0 = mask token) -> hidden (B, L, d)."""
    offs = jnp.asarray(table_offsets(arch.vocab_sizes)[:-1])
    x = jnp.take(params["items"], seq + offs[0], axis=0) + params["pos"]
    cfg = _bert4rec_attn(arch)
    for i in range(arch.n_blocks):
        p = params[f"blocks_{i}"]
        h = L.layer_norm(x, p["ln1_s"], p["ln1_b"])
        x = x + L.encoder_attention(p["attn"], h, cfg, pad_mask=seq_mask)
        h = L.layer_norm(x, p["ln2_s"], p["ln2_b"])
        x = x + L.mlp(p["mlp"], h, act="gelu")
    return L.layer_norm(x, params["final_ln_s"], params["final_ln_b"])


def bert4rec_loss(params: Params, arch: RecArch, batch: dict, *,
                  n_sampled: int = 8192, max_masked: int = 40
                  ) -> tuple[jax.Array, dict]:
    """Masked-item prediction with SAMPLED softmax.

    The naive tied softmax materializes (B, L, |V|) logits — 205 GB/device
    at the train_batch shape with a 1M-item vocab (the 40-cell baseline
    table records exactly that).  Production recsys uses sampled softmax
    (Jean et al. '15 / logQ two-tower practice): per step one shared set of
    ``n_sampled`` uniform negatives + the in-batch labels, and only the
    top-``max_masked`` masked positions per row are scored.  Uniform
    sampling needs no logQ correction (constant shifts cancel in softmax).
    """
    h = bert4rec_hidden(params, arch, seq=batch["seq"],
                        seq_mask=batch["seq_mask"])          # (B, L, d)
    labels = batch["labels"]                                 # (B, L)
    lmask = batch["label_mask"]                              # (B, L)
    B, L, d = h.shape
    V = int(sum(arch.vocab_sizes))

    # gather the (static) max_masked highest-weight masked positions
    k = min(max_masked, L)
    mvals, midx = jax.lax.top_k(lmask, k)                    # (B, k)
    hm = jnp.take_along_axis(h, midx[..., None], axis=1)     # (B, k, d)
    gold_ids = jnp.take_along_axis(labels, midx, axis=1)     # (B, k)
    wm = mvals                                               # 1 for real masks

    # shared negative set: uniform over the vocab via a multiplicative-hash
    # stream (deterministic per batch; avoids threading rng through the step)
    seed = jnp.sum(batch["seq"][0, :2]).astype(jnp.uint32)
    neg = (jnp.arange(n_sampled, dtype=jnp.uint32) * jnp.uint32(2654435761)
           + seed) % jnp.uint32(V)
    neg_emb = jnp.take(params["items"], neg.astype(jnp.int32), axis=0)
    gold_emb = jnp.take(params["items"], gold_ids, axis=0)   # (B, k, d)

    pos_logit = jnp.sum(hm * gold_emb, axis=-1)              # (B, k)
    neg_logit = jnp.einsum("bkd,sd->bks", hm, neg_emb)       # (B, k, S)
    # mask accidental hits (negative == gold)
    hit = neg[None, None, :].astype(jnp.int32) == gold_ids[..., None]
    neg_logit = jnp.where(hit, -1e30, neg_logit)
    logz = jnp.logaddexp(
        pos_logit, jax.nn.logsumexp(neg_logit, axis=-1))
    nll = jnp.sum((logz - pos_logit) * wm) / jnp.maximum(jnp.sum(wm), 1.0)
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand shape) — incl. the LOVO-PQ path
# ---------------------------------------------------------------------------
def retrieval_scores(user_vecs: jax.Array, cand_emb: jax.Array) -> jax.Array:
    """user_vecs: (K, d) interests (K=1 for single-vector models);
    cand_emb: (C, d) -> (C,) max-over-interests dot scores."""
    s = jnp.einsum("kd,cd->kc", user_vecs, cand_emb)
    return jnp.max(s, axis=0)


def retrieval_scores_pq(user_vecs: jax.Array, pq_centroids,
                        cand_codes: jax.Array) -> jax.Array:
    """Same scoring through LOVO's PQ-ADC scan (candidates pre-quantized):
    the paper's technique applied to recsys retrieval (DESIGN.md §5).

    ``pq_centroids``: either a raw (P, M, m) codebook array — implies no
    OPQ rotation — or a full ``repro.core.pq.PQ``.  Codes from an
    OPQ-trained quantizer live in the rotated space, so the PQ object
    (which carries the rotation) MUST be passed for them.
    """
    from repro.core import pq as pqmod
    pq = (pq_centroids if isinstance(pq_centroids, pqmod.PQ)
          else pqmod.PQ(pq_centroids))
    luts = jax.vmap(lambda u: pqmod.similarity_lut(pq, u))(user_vecs)
    scores = jax.vmap(lambda l: pqmod.adc_scores(l, cand_codes))(luts)
    return jnp.max(scores, axis=0)
