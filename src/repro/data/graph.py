"""Graph data: synthetic graph generation + a REAL layer-wise neighbor
sampler (GraphSAGE-style, required by the ``minibatch_lg`` shape).

The sampler operates on a host-side CSR adjacency and emits padded,
static-shape subgraph batches (relabelled node ids, [src, dst] edge index,
masks) ready for ``egnn_forward``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,)
    feats: np.ndarray     # (N, F)
    labels: np.ndarray    # (N,)
    coords: np.ndarray    # (N, 3) synthetic spatial positions

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]


def synthetic_graph(seed: int, n_nodes: int, avg_degree: int, d_feat: int,
                    n_classes: int = 16) -> CSRGraph:
    """Degree-skewed random graph with class-correlated features (fast,
    memory-light: builds CSR directly)."""
    rng = np.random.default_rng(seed)
    deg = np.maximum(1, rng.poisson(avg_degree, n_nodes)).astype(np.int64)
    indptr = np.concatenate([[0], np.cumsum(deg)])
    indices = rng.integers(0, n_nodes, indptr[-1]).astype(np.int32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    class_centers = rng.normal(0, 1, (n_classes, d_feat)).astype(np.float32)
    feats = (class_centers[labels]
             + rng.normal(0, 0.5, (n_nodes, d_feat))).astype(np.float32)
    coords = rng.normal(0, 1, (n_nodes, 3)).astype(np.float32)
    return CSRGraph(indptr=indptr.astype(np.int64), indices=indices,
                    feats=feats, labels=labels, coords=coords)


def full_graph_batch(g: CSRGraph, *, pad_nodes: Optional[int] = None,
                     pad_edges: Optional[int] = None) -> dict:
    """Whole graph as one padded batch (full_graph shapes)."""
    n, e = g.n_nodes, g.n_edges
    pn = pad_nodes or n
    pe = pad_edges or e
    src = g.indices.astype(np.int32)
    dst = np.repeat(np.arange(n, dtype=np.int32),
                    np.diff(g.indptr).astype(np.int32))
    edge_index = np.zeros((2, pe), np.int32)
    edge_index[0, :e] = src
    edge_index[1, :e] = dst
    edge_mask = np.zeros((pe,), np.float32)
    edge_mask[:e] = 1.0
    node_feats = np.zeros((pn, g.feats.shape[1]), np.float32)
    node_feats[:n] = g.feats
    coords = np.zeros((pn, 3), np.float32)
    coords[:n] = g.coords
    node_mask = np.zeros((pn,), np.float32)
    node_mask[:n] = 1.0
    labels = np.zeros((pn,), np.int32)
    labels[:n] = g.labels
    return {"node_feats": node_feats, "coords": coords,
            "edge_index": edge_index, "edge_mask": edge_mask,
            "node_mask": node_mask, "labels": labels}


@dataclasses.dataclass
class SamplerSpec:
    batch_nodes: int
    fanouts: tuple[int, ...]          # e.g. (15, 10)

    @property
    def max_nodes(self) -> int:
        n, tot = self.batch_nodes, self.batch_nodes
        for f in self.fanouts:
            n = n * f
            tot += n
        return tot

    @property
    def max_edges(self) -> int:
        n, tot = self.batch_nodes, 0
        for f in self.fanouts:
            tot += n * f
            n = n * f
        return tot


def sample_subgraph(g: CSRGraph, spec: SamplerSpec,
                    rng: np.random.Generator) -> dict:
    """Layer-wise uniform neighbor sampling (GraphSAGE).  Seeds get labels;
    messages flow sampled-neighbor -> seed over `len(fanouts)` hops."""
    seeds = rng.integers(0, g.n_nodes, spec.batch_nodes).astype(np.int64)
    node_ids = [seeds]
    edges_src_g, edges_dst_local = [], []
    frontier = seeds
    for fanout in spec.fanouts:
        starts = g.indptr[frontier]
        degs = g.indptr[frontier + 1] - starts
        # uniform sample `fanout` neighbors per frontier node (with repl.)
        r = rng.random((len(frontier), fanout))
        pick = starts[:, None] + np.minimum(
            (r * np.maximum(degs, 1)[:, None]).astype(np.int64),
            np.maximum(degs, 1)[:, None] - 1)
        nbrs = g.indices[pick].astype(np.int64)            # (F, fanout)
        # local id of frontier nodes = position in the concatenated list
        base = sum(len(x) for x in node_ids[:-1])
        dst_local = np.repeat(np.arange(len(frontier), dtype=np.int64),
                              fanout)
        edges_dst_local.append(base + dst_local)
        edges_src_g.append(nbrs.reshape(-1))
        node_ids.append(nbrs.reshape(-1))
        frontier = nbrs.reshape(-1)
    all_nodes = np.concatenate(node_ids)
    # relabel: src nodes are appended in order, so local src ids are just
    # their position in all_nodes (duplicates allowed — cheaper than unique
    # and harmless for message passing)
    pn, pe = spec.max_nodes, spec.max_edges
    n, e = len(all_nodes), sum(len(s) for s in edges_src_g)
    src_local = np.arange(spec.batch_nodes, n, dtype=np.int64)
    dst_local = np.concatenate(edges_dst_local)
    edge_index = np.zeros((2, pe), np.int32)
    edge_index[0, :e] = src_local[: e]
    edge_index[1, :e] = dst_local[: e]
    edge_mask = np.zeros((pe,), np.float32)
    edge_mask[:e] = 1.0
    node_feats = np.zeros((pn, g.feats.shape[1]), np.float32)
    node_feats[:n] = g.feats[all_nodes]
    coords = np.zeros((pn, 3), np.float32)
    coords[:n] = g.coords[all_nodes]
    node_mask = np.zeros((pn,), np.float32)
    node_mask[:n] = 1.0
    labels = np.zeros((pn,), np.int32)
    labels[:n] = g.labels[all_nodes]
    label_mask = np.zeros((pn,), np.float32)
    label_mask[: spec.batch_nodes] = 1.0                 # only seeds scored
    return {"node_feats": node_feats, "coords": coords,
            "edge_index": edge_index, "edge_mask": edge_mask,
            "node_mask": node_mask, "labels": labels,
            "label_mask": label_mask}


def molecule_batch(seed: int, batch: int, n_nodes: int = 30,
                   n_edges: int = 64, d_feat: int = 16) -> dict:
    """Batched small graphs (molecule shape): one big disjoint union."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    gid = np.repeat(np.arange(batch, dtype=np.int32), n_nodes)
    src = (rng.integers(0, n_nodes, E)
           + np.repeat(np.arange(batch), n_edges) * n_nodes).astype(np.int32)
    dst = (rng.integers(0, n_nodes, E)
           + np.repeat(np.arange(batch), n_edges) * n_nodes).astype(np.int32)
    feats = rng.normal(0, 1, (N, d_feat)).astype(np.float32)
    coords = rng.normal(0, 1, (N, 3)).astype(np.float32)
    targets = rng.normal(0, 1, (batch,)).astype(np.float32)
    return {"node_feats": feats, "coords": coords,
            "edge_index": np.stack([src, dst]),
            "edge_mask": np.ones((E,), np.float32),
            "node_mask": np.ones((N,), np.float32),
            "graph_ids": gid, "targets": targets}
