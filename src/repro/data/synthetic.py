"""Synthetic video world with controllable object semantics.

No real video corpora ship offline, so the data engine renders procedurally:
objects are (shape, color, size) triples moving across textured backgrounds;
captions are templated natural-language descriptions ("a large red square in
the center of the frame", "two cars side by side").  Ground truth (object
attributes + boxes per frame) is exact, which makes AveP / IoU evaluation
and the paper's ablation orderings measurable without labels.

Everything here is host-side numpy (the data-pipeline layer); jax sees only
the resulting batches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Optional

import numpy as np

SHAPES = ("square", "circle", "triangle", "bar")
COLORS = {
    "red": (0.9, 0.15, 0.1), "green": (0.1, 0.8, 0.2), "blue": (0.15, 0.2, 0.9),
    "yellow": (0.95, 0.9, 0.1), "white": (0.95, 0.95, 0.95),
    "black": (0.05, 0.05, 0.05), "orange": (0.95, 0.55, 0.1),
    "purple": (0.6, 0.15, 0.8),
}
SIZES = {"small": 0.08, "medium": 0.14, "large": 0.22}
POSITIONS = ("left", "center", "right")


@dataclasses.dataclass
class ObjectSpec:
    shape: str
    color: str
    size: str
    x: float  # center, [0,1]
    y: float
    vx: float = 0.0
    vy: float = 0.0

    def caption(self, with_pos: bool = False) -> str:
        s = f"a {self.size} {self.color} {self.shape}"
        if with_pos:
            s += f" in the {self.position} of the frame"
        return s

    @property
    def position(self) -> str:
        return POSITIONS[min(2, int(self.x * 3))]

    def bbox(self) -> tuple[float, float, float, float]:
        """(cx, cy, w, h) normalized."""
        r = SIZES[self.size]
        return (self.x, self.y, 2 * r, 2 * r)


def _texture(rng: np.random.Generator, h: int, w: int) -> np.ndarray:
    base = rng.uniform(0.25, 0.55)
    noise = rng.normal(0, 0.03, (h // 8, w // 8, 3))
    tex = np.repeat(np.repeat(noise, 8, axis=0), 8, axis=1)
    return np.clip(base + tex[:h, :w], 0, 1).astype(np.float32)


def render_frame(objs: list[ObjectSpec], res: int,
                 rng: np.random.Generator) -> np.ndarray:
    img = _texture(rng, res, res)
    yy, xx = np.mgrid[0:res, 0:res] / res
    for o in objs:
        r = SIZES[o.size]
        col = np.asarray(COLORS[o.color], np.float32)
        dx, dy = xx - o.x, yy - o.y
        if o.shape == "square":
            m = (np.abs(dx) < r) & (np.abs(dy) < r)
        elif o.shape == "circle":
            m = dx * dx + dy * dy < r * r
        elif o.shape == "triangle":
            m = (dy > -r) & (dy < r) & (np.abs(dx) < (r - dy) / 2)
        else:  # bar
            m = (np.abs(dx) < 1.6 * r) & (np.abs(dy) < 0.5 * r)
        img[m] = col
    return img


def random_object(rng: np.random.Generator) -> ObjectSpec:
    return ObjectSpec(
        shape=str(rng.choice(SHAPES)),
        color=str(rng.choice(list(COLORS))),
        size=str(rng.choice(list(SIZES))),
        x=float(rng.uniform(0.15, 0.85)), y=float(rng.uniform(0.15, 0.85)),
        vx=float(rng.uniform(-0.02, 0.02)), vy=float(rng.uniform(-0.02, 0.02)),
    )


@dataclasses.dataclass
class Video:
    frames: np.ndarray                 # (T, H, W, 3) float32
    objects: list[list[ObjectSpec]]    # per-frame object lists


def make_video(rng: np.random.Generator, n_frames: int = 32,
               res: int = 128, max_objects: int = 3) -> Video:
    objs = [random_object(rng) for _ in range(rng.integers(1, max_objects + 1))]
    frames, per_frame = [], []
    for t in range(n_frames):
        stepped = []
        for o in objs:
            o = dataclasses.replace(
                o, x=float(np.clip(o.x + o.vx * t, 0.1, 0.9)),
                y=float(np.clip(o.y + o.vy * t, 0.1, 0.9)))
            stepped.append(o)
        # occasional scene change: object swap mid-video
        if t == n_frames // 2 and rng.uniform() < 0.4:
            objs = [random_object(rng) for _ in range(len(objs))]
        frames.append(render_frame(stepped, res, rng))
        per_frame.append(stepped)
    return Video(frames=np.stack(frames), objects=per_frame)


def make_dataset(seed: int, n_videos: int = 8, n_frames: int = 32,
                 res: int = 128) -> list[Video]:
    rng = np.random.default_rng(seed)
    return [make_video(rng, n_frames, res) for _ in range(n_videos)]


# ---------------------------------------------------------------------------
# Tokenizer (hash-based word-level; deterministic, no external vocab)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Tokenizer:
    """Word-level hash tokenizer.  Uses crc32, NOT python hash() — hash() is
    salted per process, which would bind trained text encoders to the
    training process (found the hard way; see EXPERIMENTS.md errata)."""

    vocab: int = 32_000
    max_len: int = 64

    def encode(self, text: str) -> tuple[np.ndarray, np.ndarray]:
        import zlib
        words = text.lower().replace(",", " ").replace(".", " ").split()
        ids = [1 + (zlib.crc32(w.encode()) % (self.vocab - 2))
               for w in words][: self.max_len]
        toks = np.zeros((self.max_len,), np.int32)
        mask = np.zeros((self.max_len,), np.int32)
        toks[: len(ids)] = ids
        mask[: len(ids)] = 1
        return toks, mask

    def encode_batch(self, texts: list[str]) -> tuple[np.ndarray, np.ndarray]:
        out = [self.encode(t) for t in texts]
        return (np.stack([o[0] for o in out]),
                np.stack([o[1] for o in out]))


# ---------------------------------------------------------------------------
# Paired (image, caption, box) batches for alignment training
# ---------------------------------------------------------------------------
def alignment_batches(seed: int, batch: int, res: int, tokenizer: Tokenizer,
                      with_negatives: bool = True) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        imgs, caps, boxes = [], [], []
        for _ in range(batch):
            o = random_object(rng)
            imgs.append(render_frame([o], res, rng))
            caps.append(o.caption(with_pos=rng.uniform() < 0.5))
            boxes.append(o.bbox())
        toks, mask = tokenizer.encode_batch(caps)
        yield {
            "images": np.stack(imgs).astype(np.float32),
            "tokens": toks, "txt_mask": mask,
            "boxes": np.asarray(boxes, np.float32),
        }


def iou_cxcywh(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """IoU for (..., 4) cxcywh boxes."""
    ax1, ay1 = a[..., 0] - a[..., 2] / 2, a[..., 1] - a[..., 3] / 2
    ax2, ay2 = a[..., 0] + a[..., 2] / 2, a[..., 1] + a[..., 3] / 2
    bx1, by1 = b[..., 0] - b[..., 2] / 2, b[..., 1] - b[..., 3] / 2
    bx2, by2 = b[..., 0] + b[..., 2] / 2, b[..., 1] + b[..., 3] / 2
    ix = np.maximum(0, np.minimum(ax2, bx2) - np.maximum(ax1, bx1))
    iy = np.maximum(0, np.minimum(ay2, by2) - np.maximum(ay1, by1))
    inter = ix * iy
    union = (ax2 - ax1) * (ay2 - ay1) + (bx2 - bx1) * (by2 - by1) - inter
    return inter / np.maximum(union, 1e-9)
