"""Host data pipeline: deterministic, resumable, prefetching.

Training input at pod scale must (a) never stall the accelerator — batches
are materialized on a background thread into a bounded prefetch queue; (b) be
exactly resumable — every source is a pure function of (seed, cursor), so
``skip(cursor)`` after restart replays to the same stream position the
checkpoint recorded; (c) shard deterministically across data-parallel hosts
via (host_id, num_hosts) striding.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import numpy as np


class DeterministicSource:
    """batch_fn(seed, index) -> batch dict.  Pure; index is the cursor."""

    def __init__(self, batch_fn: Callable[[int, int], dict], seed: int,
                 host_id: int = 0, num_hosts: int = 1):
        self.batch_fn = batch_fn
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts

    def __call__(self, cursor: int) -> dict:
        return self.batch_fn(self.seed, cursor * self.num_hosts + self.host_id)

    def iterate(self, start_cursor: int = 0) -> Iterator[dict]:
        c = start_cursor
        while True:
            yield self(c)
            c += 1


class Prefetcher:
    """Bounded background prefetch; exceptions propagate to the consumer."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()

        def work():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def lm_batch_fn(vocab: int, accum: int, micro: int, seq: int
                ) -> Callable[[int, int], dict]:
    """Synthetic next-token LM batches: structured integer sequences so the
    loss actually falls (affine-recurrence tokens, learnable by a LM)."""
    def fn(seed: int, index: int) -> dict:
        rng = np.random.default_rng((seed, index))
        starts = rng.integers(0, vocab, (accum, micro, 1))
        steps = rng.integers(1, 7, (accum, micro, 1))
        pos = np.arange(seq + 1)[None, None, :]
        toks = (starts + steps * pos) % vocab
        return {"tokens": toks[..., :-1].astype(np.int32),
                "labels": toks[..., 1:].astype(np.int32)}
    return fn


def rec_batch_fn(arch: Any, batch: int, accum: int = 1
                 ) -> Callable[[int, int], dict]:
    """Synthetic CTR batches with a planted logistic rule (learnable)."""
    def fn(seed: int, index: int) -> dict:
        rng = np.random.default_rng((seed, index))
        out: dict[str, np.ndarray] = {}
        shape = (accum, batch) if accum > 1 else (batch,)
        if arch.family in ("dlrm",):
            dense = rng.normal(0, 1, shape + (arch.n_dense,)).astype(np.float32)
            out["dense"] = dense
        if arch.family in ("dlrm", "xdeepfm"):
            sparse = np.stack(
                [rng.integers(0, v, shape) for v in arch.vocab_sizes],
                axis=-1).astype(np.int32)
            out["sparse"] = sparse
            signal = (sparse[..., 0] % 2).astype(np.float32)
            if "dense" in out:
                signal = signal + (out["dense"][..., 0] > 0)
            out["labels"] = (signal >= 1).astype(np.float32)
        elif arch.family == "mind":
            hist = rng.integers(1, arch.vocab_sizes[0],
                                shape + (arch.seq_len,)).astype(np.int32)
            out["history"] = hist
            out["hist_mask"] = np.ones(shape + (arch.seq_len,), np.float32)
            out["target"] = hist[..., -1].astype(np.int32)
        elif arch.family == "bert4rec":
            seqs = rng.integers(1, arch.vocab_sizes[0],
                                shape + (arch.seq_len,)).astype(np.int32)
            mask_pos = rng.random(shape + (arch.seq_len,)) < 0.15
            out["labels"] = seqs.copy()
            seqs = np.where(mask_pos, 0, seqs)
            out["seq"] = seqs.astype(np.int32)
            out["seq_mask"] = np.ones(shape + (arch.seq_len,), np.float32)
            out["label_mask"] = mask_pos.astype(np.float32)
        return out
    return fn
