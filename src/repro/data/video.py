"""Key-frame extraction — LOVO §IV-A.

The paper uses MVmed (compressed-domain motion vectors).  Codecs are
unavailable offline, so we compute the same *signal* — inter-frame motion
energy — from decoded frames: per-frame mean |f_t - f_{t-1}|, then select

  * temporal stride frames (fixed-interval strategy), plus
  * motion peaks (content strategy: local maxima above mean + k*std, which
    MVmed would flag as scene shifts / high activity).

Deviation from paper recorded in DESIGN.md §3 (b).
"""
from __future__ import annotations

import numpy as np


def motion_energy(frames: np.ndarray) -> np.ndarray:
    """(T, H, W, 3) -> (T,) mean abs inter-frame delta; e[0] = 0."""
    d = np.abs(np.diff(frames.astype(np.float32), axis=0)).mean(axis=(1, 2, 3))
    return np.concatenate([[0.0], d])


def extract_keyframes(frames: np.ndarray, *, stride: int = 8,
                      peak_sigma: float = 1.0,
                      max_keyframes: int | None = None) -> np.ndarray:
    """Returns sorted key-frame indices (always includes frame 0)."""
    T = frames.shape[0]
    energy = motion_energy(frames)
    picks = set(range(0, T, stride))
    thresh = energy.mean() + peak_sigma * energy.std()
    for t in range(1, T - 1):
        if energy[t] > thresh and energy[t] >= energy[t - 1] \
                and energy[t] >= energy[t + 1]:
            picks.add(t)
    idx = np.asarray(sorted(picks), np.int32)
    if max_keyframes is not None and len(idx) > max_keyframes:
        # keep the highest-energy subset but always frame 0
        order = np.argsort(-energy[idx])
        keep = set(idx[order[: max_keyframes - 1]].tolist()) | {0}
        idx = np.asarray(sorted(keep), np.int32)
    return idx


def keyframe_summary(frames: np.ndarray, **kw) -> tuple[np.ndarray, np.ndarray]:
    idx = extract_keyframes(frames, **kw)
    return frames[idx], idx
