"""Key-frame extraction — LOVO §IV-A.

The paper uses MVmed (compressed-domain motion vectors).  Codecs are
unavailable offline, so we compute the same *signal* — inter-frame motion
energy — from decoded frames: per-frame mean |f_t - f_{t-1}|, then select

  * temporal stride frames (fixed-interval strategy), plus
  * motion peaks (content strategy: local maxima above mean + k*std, which
    MVmed would flag as scene shifts / high activity).

Deviation from paper recorded in DESIGN.md §3 (b).
"""
from __future__ import annotations

import numpy as np


def motion_energy(frames: np.ndarray,
                  prev_frame: np.ndarray | None = None) -> np.ndarray:
    """(T, H, W, 3) -> (T,) mean abs inter-frame delta.

    ``prev_frame``: the last frame of the PRECEDING chunk, for streaming
    callers that feed a video in pieces (the ingest pipeline) — with it,
    e[0] is the real motion across the chunk boundary instead of the
    batch-mode 0 sentinel, so a scene cut landing exactly on a boundary is
    still a peak."""
    d = np.abs(np.diff(frames.astype(np.float32), axis=0)).mean(axis=(1, 2, 3))
    if prev_frame is None:
        e0 = 0.0
    else:
        e0 = float(np.abs(frames[0].astype(np.float32)
                          - prev_frame.astype(np.float32)).mean())
    return np.concatenate([[e0], d])


def extract_keyframes(frames: np.ndarray, *, stride: int = 8,
                      peak_sigma: float = 1.0,
                      max_keyframes: int | None = None,
                      prev_frame: np.ndarray | None = None,
                      offset: int = 0,
                      always_first: bool = True) -> np.ndarray:
    """Returns sorted key-frame indices (always includes frame 0 in batch
    mode).

    Streaming extension (DESIGN.md §12.1): the ingest pipeline feeds one
    video in chunks, so three knobs make chunked extraction equal to the
    batch pass over the concatenated frames:

      * ``prev_frame`` — last frame of the previous chunk; gives e[0] its
        real cross-boundary motion energy (see :func:`motion_energy`).
      * ``offset`` — the chunk's global start index; temporal-stride picks
        stay phase-locked to the video, not to chunk boundaries.
      * ``always_first`` — False drops the unconditional frame-0 pick, so
        a chunk's first frame competes on energy like any other (only the
        true start of a stream should keep the guarantee).

    ``max_keyframes`` is the sampling BUDGET: when the candidate set
    exceeds it, the highest-energy subset is kept.  The ingest bandit
    (``repro.ingest.sampler``) allocates this budget across cameras.
    """
    T = frames.shape[0]
    energy = motion_energy(frames, prev_frame)
    picks = {t for t in range(T) if (t + offset) % stride == 0}
    if always_first:
        picks.add(0)
    thresh = energy.mean() + peak_sigma * energy.std()
    lo = 0 if prev_frame is not None else 1
    for t in range(lo, T - 1):
        left = energy[t - 1] if t > 0 else 0.0
        if energy[t] > thresh and energy[t] >= left \
                and energy[t] >= energy[t + 1]:
            picks.add(t)
    idx = np.asarray(sorted(picks), np.int32)
    if max_keyframes is not None and len(idx) > max_keyframes:
        # keep the highest-energy subset (plus frame 0 where guaranteed)
        order = np.argsort(-energy[idx])
        if always_first:
            keep = set(idx[order[: max_keyframes - 1]].tolist()) | {0}
        else:
            keep = set(idx[order[: max_keyframes]].tolist())
        idx = np.asarray(sorted(keep), np.int32)
    return idx


def keyframe_summary(frames: np.ndarray, **kw) -> tuple[np.ndarray, np.ndarray]:
    idx = extract_keyframes(frames, **kw)
    return frames[idx], idx
