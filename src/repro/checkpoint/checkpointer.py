"""Sharded checkpointing: topology-independent save/restore with async I/O.

No orbax/tensorstore offline, so the format is deliberately simple and
durable: one ``.npz`` per (host-local) array shard plus a JSON manifest
holding the tree structure, global shapes, dtypes and the step counter.

Key properties for fault tolerance at scale:
  * topology-independent: arrays are saved as GLOBAL arrays (gathered per
    leaf, streamed one leaf at a time to bound host memory); restore re-shards
    onto whatever mesh the restarted job has — elastic re-mesh for free.
  * async: ``save_async`` snapshots device arrays then writes on a worker
    thread; training continues immediately (the paper's one-time-indexing
    economics applies to training too: never stall the accelerator on I/O).
  * atomic: writes go to ``<dir>.tmp`` then ``os.replace`` — a crash mid-save
    never corrupts the latest-good checkpoint.
  * self-describing: ``latest_step`` scans the directory, so restart needs no
    external coordination state.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Params = Any


def _flatten(tree: Params) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str | pathlib.Path, tree: Params, step: int) -> None:
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {"step": int(step), "treedef": str(treedef),
                "n_leaves": len(leaves), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8, ...)
            arr = arr.astype(np.float32)
        np.savez_compressed(tmp / f"leaf_{i:05d}.npz", arr=arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": true_dtype})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if path.exists():
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore(path: str | pathlib.Path, like: Params,
            shardings: Optional[Params] = None) -> tuple[Params, int]:
    """Restore into the structure of ``like``; re-shard with ``shardings``
    (tree of NamedSharding) if given — the mesh may differ from save time."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, model has {len(leaves)}"
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(leaves)
    import jax.numpy as jnp
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(path / f"leaf_{i:05d}.npz")["arr"]
        target = jnp.dtype(getattr(ref, "dtype", None)
                           or manifest["leaves"][i]["dtype"])
        casted = jnp.asarray(arr).astype(target)
        if sh is not None:
            out.append(jax.device_put(casted, sh))
        else:
            out.append(jax.device_put(casted))
    return jax.tree.unflatten(treedef, out), manifest["step"]


class Checkpointer:
    """Directory layout: <root>/step_<N>/ ; keeps the newest ``keep``."""

    def __init__(self, root: str | pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def _dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:08d}"

    def steps(self) -> list[int]:
        out = []
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, tree: Params, step: int) -> None:
        save(self._dir(step), tree, step)
        self._gc()

    def save_async(self, tree: Params, step: int) -> None:
        self.wait()  # one in-flight save at a time
        # snapshot to host NOW so training can mutate device buffers
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self._dir(step), host, step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: Params, shardings: Optional[Params] = None
                       ) -> tuple[Optional[Params], int]:
        step = self.latest_step()
        if step is None:
            return None, 0
        tree, s = restore(self._dir(step), like, shardings)
        return tree, s

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
