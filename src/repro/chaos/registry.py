"""Central catalog of failpoint injection sites (DESIGN.md §16.1).

Every ``chaos.failpoint(<name>)`` call threaded through the tree must name
a :class:`Site` declared here — the analysis rule CH401 cross-checks call
sites against this registry the same way RG301 cross-checks kernels
against their oracles, and CH402 requires every ``durability``-kind site
to be exercised by the kill-at-every-failpoint harness
(``repro.chaos.harness``).

A site is a *seam*, not a fault: it marks the exact instruction boundary
where the system's crash-consistency or RPC contract is supposed to hold,
so a deterministic schedule can raise / delay / tear / hard-kill there
and the invariant catalog can be asserted on the other side.

Kinds:
  * ``durability`` — sits inside a write→fsync→rename commit chain; a
    crash here must be recoverable by reopen (store WAL/segment/manifest,
    ingest meta-log/state, compaction and codebook refresh).
  * ``rpc`` — a delivery or dispatch seam (replica calls, shard
    broadcast, alert sink, batcher dispatch); a fault here must be
    absorbed by the retry/breaker/degradation layer, never corrupt state.

``supports`` lists the legal actions per site.  ``torn`` (write a prefix
of the payload, then hard-exit) is only meaningful where the call site
cooperates by writing partial bytes — offering it elsewhere would inject
*bugs* (e.g. atomically renaming a half-written manifest) rather than
simulate crashes.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

ACTIONS = ("raise", "delay", "torn", "crash")


@dataclasses.dataclass(frozen=True)
class Site:
    name: str                      # dotted site id, e.g. "store.wal.append.pre_fsync"
    kind: str                      # "durability" | "rpc"
    module: str                    # module that hosts the failpoint() call
    supports: tuple[str, ...]      # subset of ACTIONS
    doc: str


SITES: tuple[Site, ...] = (
    # -- store durability chain (DESIGN.md §5) ------------------------------
    Site("store.wal.append.pre_fsync", "durability", "repro.store.wal",
         ("raise", "delay", "torn", "crash"),
         "between writing a WAL record and its fsync; torn = half the "
         "framed record reaches the file"),
    Site("store.wal.reset", "durability", "repro.store.wal",
         ("raise", "delay", "crash"),
         "before the post-checkpoint WAL truncation rewrites the header"),
    Site("store.segment.write.torn", "durability", "repro.store.segment",
         ("raise", "delay", "torn", "crash"),
         "after the segment's array files, before the footer; torn = the "
         "last .npy is truncated (footer never written)"),
    Site("store.manifest.replace", "durability", "repro.store.manifest",
         ("raise", "delay", "crash"),
         "after the tmp manifest is fsync'd, before os.replace publishes "
         "it (the §5 commit point)"),
    Site("store.checkpoint.pre_manifest", "durability", "repro.store.store",
         ("raise", "delay", "crash"),
         "segments written, manifest swap not yet attempted — the widest "
         "window where new segment dirs are unreferenced garbage"),
    Site("store.codebooks.write", "durability", "repro.store.store",
         ("raise", "delay", "crash"),
         "versioned codebooks file synced, commit checkpoint not yet run "
         "(refresh_codebooks must be atomic across both)"),
    # -- ingest durability chain (DESIGN.md §12.3) --------------------------
    Site("ingest.meta_log.append", "durability", "repro.ingest.pipeline",
         ("raise", "delay", "torn", "crash"),
         "meta-first frame attribution append; torn = half a JSON line"),
    Site("ingest.state.replace", "durability", "repro.ingest.pipeline",
         ("raise", "delay", "crash"),
         "before os.replace publishes ingest-state.json (watermarks, "
         "bandit, pending alerts)"),
    Site("ingest.compaction.run", "durability", "repro.ingest.compaction",
         ("raise", "delay", "crash"),
         "a maintenance slot decided to compact/refresh but has not yet "
         "taken the writer lock"),
    # -- RPC / delivery seams ----------------------------------------------
    Site("ingest.sink.deliver", "rpc", "repro.ingest.alerts",
         ("raise", "delay", "crash"),
         "before the sink emit attempt (at-least-once delivery retry "
         "loop)"),
    Site("router.replica.call", "rpc", "repro.serving.router",
         ("raise", "delay", "crash"),
         "before a replica fn/batch_fn invocation (per-call and shard "
         "paths share it)"),
    Site("serving.batcher.dispatch", "rpc", "repro.serving.batcher",
         ("raise", "delay", "crash"),
         "before the micro-batch is handed to run_batch"),
    Site("distributed.shard.rpc", "rpc", "repro.core.distributed",
         ("raise", "delay", "crash"),
         "host-side dispatch of the sharded fused scan (fires per "
         "untraced invocation: under jit it runs at trace time and "
         "leaves nothing in the jaxpr)"),
)


@lru_cache(maxsize=1)
def site_names() -> frozenset[str]:
    return frozenset(s.name for s in SITES)


@lru_cache(maxsize=None)
def site(name: str) -> Site:
    for s in SITES:
        if s.name == name:
            return s
    raise KeyError(f"unregistered failpoint site {name!r} "
                   f"(declare it in repro.chaos.registry.SITES)")


def durability_sites() -> tuple[str, ...]:
    """The sites the kill-at-every-failpoint harness must cover (CH402)."""
    return tuple(s.name for s in SITES if s.kind == "durability")


def rpc_sites() -> tuple[str, ...]:
    return tuple(s.name for s in SITES if s.kind == "rpc")
