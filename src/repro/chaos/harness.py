"""Kill-at-every-failpoint crash-consistency harness (DESIGN.md §16.5).

For each registered DURABILITY failpoint site, run a deterministic
insert/ingest/query workload in a SUBPROCESS armed (via
``REPRO_CHAOS_SPEC``) to hard-crash — ``os._exit``, no atexit, no
flushing — at that site, then reopen the survivors in the parent and
assert the invariant catalog:

  * no acknowledged row is lost, no acked delete resurrects (the
    workload writes an INTENT record before and an ACK record after
    every op to a fsync'd ops log OUTSIDE the store root, so "acked" is
    crash-survivable ground truth);
  * the one in-flight op may have landed or not — live state must equal
    ``apply(acked)`` or ``apply(acked + inflight)``, nothing else;
  * reopen is idempotent (a second open sees the identical state);
  * ``VectorStore.open(verify=True)`` succeeds — the manifest never
    names a missing or corrupt file;
  * the store's ``cache_token()`` differs from the pre-mutation token
    (cached plan results can never survive a crash-recovery cycle);
  * ingest alerts are exactly-once-effect: after crash + recovery, the
    key-deduplicated alert set equals the no-crash expectation.

``EXERCISED_SITES`` is a LITERAL list so the CH402 analysis rule can
cross-check it against the registry without executing anything: every
registered durability site must appear here, and :func:`check_coverage`
re-asserts the same at runtime.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
from typing import Optional

import numpy as np

from repro import chaos
from repro.chaos import registry as chaos_registry
from repro.chaos.failpoints import CRASH_EXIT, ENV_SPEC, ChaosSchedule

# Every registered durability site, as literals (CH402 parses this list).
EXERCISED_SITES = [
    "store.wal.append.pre_fsync",
    "store.wal.reset",
    "store.segment.write.torn",
    "store.manifest.replace",
    "store.checkpoint.pre_manifest",
    "store.codebooks.write",
    "ingest.meta_log.append",
    "ingest.state.replace",
    "ingest.compaction.run",
]


@dataclasses.dataclass(frozen=True)
class SitePlan:
    """Which workload reaches the site, and where in it to kill."""

    workload: str   # "store" | "ingest"
    action: str     # "torn" | "crash"
    hit: int        # Nth arrival at the site (see workload op order)


# Hit numbers follow the fixed op order of the workloads below — e.g.
# manifest hit 1 is VectorStore.create, hit 2 the first flush commit.
SITE_PLANS: dict[str, SitePlan] = {
    "store.wal.append.pre_fsync": SitePlan("store", "torn", 4),
    "store.wal.reset": SitePlan("store", "crash", 1),
    "store.segment.write.torn": SitePlan("store", "torn", 2),
    "store.manifest.replace": SitePlan("store", "crash", 2),
    "store.checkpoint.pre_manifest": SitePlan("store", "crash", 2),
    "store.codebooks.write": SitePlan("store", "crash", 1),
    "ingest.meta_log.append": SitePlan("ingest", "torn", 3),
    "ingest.state.replace": SitePlan("ingest", "crash", 2),
    "ingest.compaction.run": SitePlan("ingest", "crash", 1),
}


def check_coverage() -> None:
    """Every registered durability site must be exercised (CH402's
    runtime twin)."""
    registered = set(chaos_registry.durability_sites())
    exercised = set(EXERCISED_SITES)
    if registered != exercised:
        raise AssertionError(
            f"kill-harness coverage drift: unexercised="
            f"{sorted(registered - exercised)} "
            f"unregistered={sorted(exercised - registered)}")
    missing = exercised - set(SITE_PLANS)
    if missing:
        raise AssertionError(f"sites without a kill plan: {sorted(missing)}")


# ---------------------------------------------------------------------------
# Fsync'd intent/ack ops log (lives OUTSIDE the store root)
# ---------------------------------------------------------------------------
class _OpsLog:
    def __init__(self, path: pathlib.Path):
        self._f = open(path, "a", encoding="utf-8")

    def write(self, obj: dict) -> None:
        self._f.write(json.dumps(obj, sort_keys=True) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())


def _read_ops(path: pathlib.Path) -> tuple[list[dict], Optional[dict]]:
    """-> (acked ops in order, the single un-acked in-flight op or None)."""
    intents: dict[int, dict] = {}
    acked: set[int] = set()
    with open(path, encoding="utf-8") as f:
        for line in f:
            rec = json.loads(line)
            if "ack" in rec:
                acked.add(rec["ack"])
            else:
                intents[rec["i"]] = rec
    inflight = [intents[i] for i in sorted(intents) if i not in acked]
    assert len(inflight) <= 1, f"more than one in-flight op: {inflight}"
    return ([intents[i] for i in sorted(intents) if i in acked],
            inflight[0] if inflight else None)


def _apply_ops(base_ids: set[int], ops: list[dict]) -> set[int]:
    live = set(base_ids)
    for op in ops:
        if op["kind"] == "insert":
            live |= set(op["ids"])
        elif op["kind"] == "delete":
            live -= set(op["ids"])
    return live


# ---------------------------------------------------------------------------
# Store-flavored workload: insert / delete / flush / compact / refresh
# ---------------------------------------------------------------------------
N_BASE = 256
D_STORE = 16


def _store_index(seed: int = 0):
    import jax
    import jax.numpy as jnp
    from repro.core import imi as imimod

    x = np.random.default_rng(seed).normal(
        0, 1, (N_BASE, D_STORE)).astype(np.float32)
    return imimod.build_imi(jax.random.PRNGKey(seed), jnp.asarray(x),
                            jnp.arange(N_BASE), K=4, P=2, M=8,
                            kmeans_iters=2)


def _batch(lo: int, n: int = 10) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(lo)
    return (rng.normal(0, 1, (n, D_STORE)).astype(np.float32),
            np.arange(lo, lo + n))


def run_store_workload(workdir: pathlib.Path) -> None:
    """The crashing side: a fixed op sequence crossing every store
    durability seam, each op intent/ack-logged."""
    from repro.store import VectorStore

    chaos.install_from_env()
    workdir = pathlib.Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    log = _OpsLog(workdir / "ops.jsonl")
    store = VectorStore.create(workdir / "store", _store_index(),
                               flush_rows=10 ** 9)
    log.write({"i": 0, "kind": "create", "n": N_BASE,
               "token": repr(store.cache_token())})
    log.write({"ack": 0})

    i = 0

    def op(kind: str, fn, ids=None) -> None:
        nonlocal i
        i += 1
        rec = {"i": i, "kind": kind}
        if ids is not None:
            rec["ids"] = [int(v) for v in ids]
        log.write(rec)
        fn()
        log.write({"ack": i})

    xa, ia = _batch(10_000)
    op("insert", lambda: store.insert(xa, ia), ia)          # wal hit 1
    xb, ib = _batch(10_010)
    op("insert", lambda: store.insert(xb, ib), ib)          # wal hit 2
    dels = [10_003, 5]
    op("delete", lambda: store.delete(dels), dels)          # wal hit 3
    op("flush", store.flush)         # seg hit 2, manifest hit 2, reset hit 1
    xc, ic = _batch(10_020)
    op("insert", lambda: store.insert(xc, ic), ic)          # wal hit 4
    op("compact", store.compact)     # checkpoint hit 2 (new base)
    op("refresh", lambda: store.refresh_codebooks(kmeans_iters=2))
    xd, idd = _batch(10_030)
    op("insert", lambda: store.insert(xd, idd), idd)        # wal hit 5
    op("flush", store.flush)
    store.close()


def _live_ids(store) -> set[int]:
    seg = store.seg
    ids = [int(v) for v in np.asarray(seg.base.ids) if int(v) >= 0]
    for s in seg.segments:
        ids.extend(int(v) for v in np.asarray(s.ids))
    tomb = {int(t) for t in seg.tombstones}
    return {v for v in ids if v not in tomb}


def verify_store(workdir: pathlib.Path) -> dict:
    """Parent-side invariant checks after the subprocess died."""
    from repro.store import VectorStore

    workdir = pathlib.Path(workdir)
    acked, inflight = _read_ops(workdir / "ops.jsonl")
    assert acked and acked[0]["kind"] == "create", "create never acked"
    base = set(range(N_BASE))
    must = _apply_ops(base, acked)
    may = _apply_ops(base, acked + ([inflight] if inflight else []))

    # open(verify=True): the manifest must never name a missing or
    # corrupt file, whatever instant the process died at
    with VectorStore.open(workdir / "store", verify=True) as store:
        live = _live_ids(store)
        n1, token1 = store.n, repr(store.cache_token())
    assert live in (must, may), (
        f"acked-row invariant violated at {workdir}: "
        f"live-must={sorted(live - must)[:8]} "
        f"must-live={sorted(must - live)[:8]} inflight={inflight}")

    # double reopen: recovery itself must be idempotent
    with VectorStore.open(workdir / "store", verify=True) as store2:
        assert _live_ids(store2) == live and store2.n == n1, \
            "second reopen disagrees with first (non-idempotent recovery)"
        token2 = repr(store2.cache_token())

    mutated = any(op["kind"] in ("insert", "delete") for op in acked)
    if mutated:
        assert token1 != acked[0]["token"], \
            "cache_token did not flip across acked mutations + crash"
    assert token1 == token2, "cache_token differs between identical opens"
    return {"ok": True, "workload": "store", "live_rows": len(live),
            "inflight": inflight["kind"] if inflight else None,
            "inflight_applied": (live == may and must != may)
            if inflight else None}


# ---------------------------------------------------------------------------
# Ingest-flavored workload: deterministic 2-camera world, standing
# queries, durable JSONL alert sink, terminal compaction
# ---------------------------------------------------------------------------
D_ING = 16
KP = 2
_LABELS = ["red square", "blue circle", "nothing"]
_BASIS = np.random.default_rng(7).normal(
    0, 1, (16, D_ING)).astype(np.float32)

# ground truth by construction: cam0 shows "red square" on frames 6..8,
# cam1 shows "blue circle" on frames 0..1 and 14..15
EXPECTED_KEYS = ({("red@0", 0, t) for t in range(6, 9)}
                 | {("blue@1", 1, t) for t in (0, 1, 14, 15)})


def _dir(text: str) -> np.ndarray:
    import zlib
    return _BASIS[zlib.crc32(text.encode()) % 16]


def encode_texts(texts):
    return np.stack([_dir(t) for t in texts])


def _label_frames(labels, res=4) -> np.ndarray:
    out = np.zeros((len(labels), res, res, 3), np.float32)
    for i, lab in enumerate(labels):
        out[i, :, :, 0] = _LABELS.index(lab) / 10.0
    return out


def encode_frames(frames):
    out = np.zeros((frames.shape[0], KP, D_ING), np.float32)
    for i in range(frames.shape[0]):
        lab = _LABELS[int(round(float(frames[i, 0, 0, 0]) * 10))]
        for p in range(KP):
            out[i, p] = _dir(lab) + 0.01 * _BASIS[(p + 3) % 16]
    return out


def _ingest_world(workdir: pathlib.Path):
    from repro.ingest import (CameraBandit, IngestService, JsonlSink,
                              ReplayCamera, RetryingSink,
                              StandingQueryRegistry)
    from repro.store import VectorStore

    workdir = pathlib.Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    store_dir = workdir / "store"
    if (store_dir / "MANIFEST.json").exists():
        store = VectorStore.open(store_dir)
    else:
        import jax
        import jax.numpy as jnp
        from repro.core import imi as imimod

        x = np.random.default_rng(1).normal(
            0, 1, (128, D_ING)).astype(np.float32)
        idx = imimod.build_imi(jax.random.PRNGKey(1), jnp.asarray(x),
                               jnp.arange(128), K=4, P=2, M=8,
                               kmeans_iters=2)
        store = VectorStore.create(store_dir, idx, flush_rows=10 ** 9)

    reg = StandingQueryRegistry(encode_texts, patches_per_frame=KP,
                                pad_rows=64)
    reg.register("red@0", {"and": [{"text": "red square"},
                                   {"videos": [0]}]},
                 threshold=0.5, top_k=32)
    reg.register("blue@1", {"and": [{"text": "blue circle"},
                                    {"videos": [1]}]},
                 threshold=0.5, top_k=32)
    cam0 = ReplayCamera(_label_frames(
        ["nothing"] * 6 + ["red square"] * 3 + ["nothing"] * 7))
    cam1 = ReplayCamera(_label_frames(
        ["blue circle"] * 2 + ["nothing"] * 12 + ["blue circle"] * 2))
    fps = 8
    svc = IngestService(
        store, [cam0, cam1], encode_frames, reg,
        sink=RetryingSink(JsonlSink(workdir / "alerts.jsonl")),
        bandit=CameraBandit(2, min_per_camera=fps),
        frames_per_step=fps, keyframe_stride=1, keyframe_budget=fps * 2,
        checkpoint_every_steps=1)
    return store, svc


def run_ingest_workload(workdir: pathlib.Path) -> None:
    from repro.ingest import CompactionPolicy, CompactionScheduler

    chaos.install_from_env()
    store, svc = _ingest_world(workdir)
    svc.run()
    # terminal maintenance slot: pending in-memory deltas force a compact
    CompactionScheduler(store, CompactionPolicy(max_segments=0,
                                                max_delta_rows=0),
                        lock=svc.write_lock).maybe_run()
    svc.close()
    store.close()


def verify_ingest(workdir: pathlib.Path) -> dict:
    """Reopen the crashed world, resume to completion, and require the
    deduplicated alert key set to equal the no-crash expectation."""
    from repro.ingest import JsonlSink, dedup_by_key
    from repro.store import VectorStore

    workdir = pathlib.Path(workdir)
    store, svc = _ingest_world(workdir)   # auto_recover replays the tail
    svc.run()
    svc.close()
    store.close()

    alerts = dedup_by_key(JsonlSink.read(workdir / "alerts.jsonl"))
    keys = {(a.subscription, a.camera, a.frame) for a in alerts}
    assert keys == EXPECTED_KEYS, (
        f"alert exactly-once-effect violated: missing="
        f"{sorted(EXPECTED_KEYS - keys)} extra={sorted(keys - EXPECTED_KEYS)}")
    # the store itself must still reopen clean
    with VectorStore.open(workdir / "store", verify=True) as s2:
        n = s2.n
    return {"ok": True, "workload": "ingest", "alerts": len(alerts),
            "rows": int(n)}


_WORKLOADS = {"store": run_store_workload, "ingest": run_ingest_workload}
_VERIFIERS = {"store": verify_store, "ingest": verify_ingest}


# ---------------------------------------------------------------------------
# Orchestration (parent side)
# ---------------------------------------------------------------------------
def kill_at_site(site: str, workdir, *, seed: int = 0,
                 timeout_s: float = 600.0) -> dict:
    """Run the site's workload in a subprocess armed to die at ``site``,
    assert it died THERE (exit code ``CRASH_EXIT``), then verify the
    invariant catalog over what survived.  Returns a report dict."""
    plan = SITE_PLANS[site]
    d = pathlib.Path(workdir) / site.replace(".", "_")
    d.mkdir(parents=True, exist_ok=True)
    schedule = ChaosSchedule(seed=seed).on(site, plan.action, hit=plan.hit)
    env = dict(os.environ)
    env[ENV_SPEC] = json.dumps(schedule.to_spec())
    src_root = pathlib.Path(__file__).resolve().parents[2]
    env["PYTHONPATH"] = os.pathsep.join(
        [str(src_root)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH")
                           else []))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.chaos.harness",
         "--workload", plan.workload, "--dir", str(d)],
        env=env, capture_output=True, text=True, timeout=timeout_s)
    if proc.returncode != CRASH_EXIT:
        raise AssertionError(
            f"site {site!r}: expected the workload to crash at the "
            f"failpoint (exit {CRASH_EXIT}), got exit {proc.returncode}\n"
            f"stderr tail:\n{proc.stderr[-2000:]}")
    report = _VERIFIERS[plan.workload](d)
    report.update(site=site, action=plan.action, hit=plan.hit, seed=seed)
    return report


def run_all(workdir, *, seed: int = 0) -> list[dict]:
    check_coverage()
    return [kill_at_site(site, workdir, seed=seed)
            for site in EXERCISED_SITES]


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="kill-at-every-failpoint crash-consistency harness")
    ap.add_argument("--workload", choices=sorted(_WORKLOADS),
                    help="run ONE workload in-process (the subprocess "
                         "side; arm via REPRO_CHAOS_SPEC)")
    ap.add_argument("--all", action="store_true",
                    help="kill + verify every durability site")
    ap.add_argument("--dir", required=True, help="working directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.workload:
        _WORKLOADS[args.workload](pathlib.Path(args.dir))
        return 0
    if args.all:
        for rep in run_all(args.dir, seed=args.seed):
            print(json.dumps(rep, sort_keys=True))
        return 0
    ap.error("pass --workload or --all")
    return 2


if __name__ == "__main__":
    sys.exit(main())
