"""repro.chaos — deterministic failpoint injection (DESIGN.md §16).

Named injection sites are threaded through every durability and RPC seam
(``repro.chaos.registry.SITES``); a seeded :class:`ChaosSchedule` decides
which hits raise, delay, tear, or hard-kill the process, so every failure
is replayable from ``(seed, rules)``.  ``repro.chaos.harness`` runs the
kill-at-every-failpoint property harness over the durability sites.

With no schedule installed, ``failpoint()`` is a global load + None check
— the zero-cost-off contract gated by the ``retry_overhead`` benchmark.
"""
from repro.chaos import registry  # noqa: F401
from repro.chaos.failpoints import (  # noqa: F401
    CRASH_EXIT,
    ChaosSchedule,
    FailpointError,
    Rule,
    active,
    crash_now,
    failpoint,
    fired,
    hits,
    install,
    install_from_env,
    is_active,
    uninstall,
)

__all__ = [
    "CRASH_EXIT", "ChaosSchedule", "FailpointError", "Rule", "active",
    "crash_now", "failpoint", "fired", "hits", "install",
    "install_from_env", "is_active", "uninstall", "registry",
]
