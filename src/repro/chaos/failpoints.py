"""Deterministic, seeded failpoint engine (DESIGN.md §16.1).

A :class:`ChaosSchedule` is a list of rules — "on the Nth hit of site X,
do ACTION" — plus a seed that fixes every random choice (delay jitter),
so any observed failure replays exactly from ``(seed, rules)``.  Install
one with :func:`install` / the :func:`active` context manager, or from
the ``REPRO_CHAOS_SPEC`` environment variable (the subprocess crash-test
path).

``failpoint(name)`` is the only call threaded through production code.
With no schedule installed it is a single global load + ``is None``
check returning ``None`` — the zero-cost-off contract the
``retry_overhead`` benchmark gates.  With a schedule installed it counts
the hit and, when a rule matches:

  * ``raise``  — raises :class:`FailpointError` (exercises retry /
    breaker / recovery paths in-process);
  * ``delay``  — sleeps a seeded-jittered ``delay_s`` (deadline and
    hedging paths);
  * ``crash``  — ``os._exit(CRASH_EXIT)``: no atexit, no flushing, the
    closest userspace gets to yanking the power cord;
  * ``torn``   — RETURNS ``"torn"`` so the call site can write the
    partial bytes only it knows how to construct, then call
    :func:`crash_now`.  Sites that support ``torn`` are marked in
    ``repro.chaos.registry``.

Hit counters are per-install and queryable (:func:`hits`) so the kill
harness can verify a site actually fired before trusting a "survived"
run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import threading
import time
from contextlib import contextmanager
from typing import Iterator, Optional

from repro.chaos import registry

CRASH_EXIT = 42        # the harness asserts this exact exit code
ENV_SPEC = "REPRO_CHAOS_SPEC"


class FailpointError(RuntimeError):
    """The injected fault for ``raise`` rules — distinct type so tests and
    breakers can assert provenance."""

    def __init__(self, site: str, hit: int):
        super().__init__(f"injected failpoint fault at {site} (hit {hit})")
        self.site = site
        self.hit = hit


@dataclasses.dataclass(frozen=True)
class Rule:
    site: str
    action: str            # "raise" | "delay" | "torn" | "crash"
    hit: int = 1           # fire on the Nth hit of the site (1-based)
    every: bool = False    # fire on hit, hit+1, hit+2, ... (raise/delay)
    delay_s: float = 0.01

    def matches(self, count: int) -> bool:
        return count == self.hit or (self.every and count >= self.hit)


class ChaosSchedule:
    """A seed plus an ordered rule list; JSON round-trippable."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.rules: list[Rule] = []

    def on(self, site: str, action: str, *, hit: int = 1,
           every: bool = False, delay_s: float = 0.01) -> "ChaosSchedule":
        """Add a rule (chainable).  Validates the site is registered and
        the action is one the site supports — a typo'd site name or an
        impossible action is a schedule bug, caught at build time."""
        s = registry.site(site)
        if action not in registry.ACTIONS:
            raise ValueError(f"unknown action {action!r}")
        if action not in s.supports:
            raise ValueError(
                f"site {site!r} does not support action {action!r} "
                f"(supports: {s.supports})")
        if hit < 1:
            raise ValueError("hit is 1-based")
        self.rules.append(Rule(site=site, action=action, hit=int(hit),
                               every=bool(every), delay_s=float(delay_s)))
        return self

    def to_spec(self) -> dict:
        return {"seed": self.seed,
                "rules": [dataclasses.asdict(r) for r in self.rules]}

    @classmethod
    def from_spec(cls, spec: dict) -> "ChaosSchedule":
        sched = cls(seed=int(spec.get("seed", 0)))
        for r in spec.get("rules", ()):
            sched.on(r["site"], r["action"], hit=int(r.get("hit", 1)),
                     every=bool(r.get("every", False)),
                     delay_s=float(r.get("delay_s", 0.01)))
        return sched


class _Runtime:
    """One installed schedule: hit counters + fired log, thread-safe."""

    def __init__(self, schedule: ChaosSchedule):
        self.schedule = schedule
        self.hit_counts: dict[str, int] = {}
        self.fired: list[tuple[str, str, int]] = []   # (site, action, hit)
        self.lock = threading.Lock()

    def jitter(self, site: str, hit: int) -> float:
        # derived, not shared: replayable without cross-thread ordering
        return random.Random((self.schedule.seed, site, hit)).random()


_ACTIVE: Optional[_Runtime] = None


def install(schedule: ChaosSchedule) -> None:
    global _ACTIVE
    _ACTIVE = _Runtime(schedule)


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def is_active() -> bool:
    return _ACTIVE is not None


def hits() -> dict[str, int]:
    """Per-site hit counters of the installed schedule ({} when off)."""
    rt = _ACTIVE
    if rt is None:
        return {}
    with rt.lock:
        return dict(rt.hit_counts)


def fired() -> list[tuple[str, str, int]]:
    """(site, action, hit) log of every rule that fired ([] when off)."""
    rt = _ACTIVE
    if rt is None:
        return []
    with rt.lock:
        return list(rt.fired)


@contextmanager
def active(schedule: ChaosSchedule) -> Iterator[_Runtime]:
    """Install for the duration of a with-block (test scoping)."""
    install(schedule)
    try:
        yield _ACTIVE  # type: ignore[misc]
    finally:
        uninstall()


def install_from_env(environ=os.environ) -> bool:
    """Install a schedule from ``REPRO_CHAOS_SPEC`` (JSON) if present —
    how harness subprocesses arm themselves before running a workload.
    Returns True when a schedule was installed."""
    spec = environ.get(ENV_SPEC)
    if not spec:
        return False
    install(ChaosSchedule.from_spec(json.loads(spec)))
    return True


def crash_now(code: int = CRASH_EXIT) -> None:
    """Hard process death: no atexit handlers, no buffer flushing.  Call
    sites use it to finish a ``torn`` action after writing partial bytes."""
    os._exit(code)


def failpoint(name: str) -> Optional[str]:
    """The injection seam.  Returns None (no action / action handled
    here) or ``"torn"`` (the call site must write partial bytes and call
    :func:`crash_now`).  See module docstring for the action semantics."""
    rt = _ACTIVE
    if rt is None:
        return None
    if name not in registry.site_names():
        raise KeyError(f"failpoint {name!r} is not a registered site "
                       "(repro.chaos.registry.SITES)")
    with rt.lock:
        count = rt.hit_counts.get(name, 0) + 1
        rt.hit_counts[name] = count
        rule = next((r for r in rt.schedule.rules
                     if r.site == name and r.matches(count)), None)
        if rule is not None:
            rt.fired.append((name, rule.action, count))
    if rule is None:
        return None
    if rule.action == "raise":
        raise FailpointError(name, count)
    if rule.action == "delay":
        time.sleep(rule.delay_s * (0.5 + rt.jitter(name, count)))
        return None
    if rule.action == "crash":
        crash_now()
    return "torn"
