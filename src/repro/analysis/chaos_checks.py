"""Failpoint registry cross-checks (``CH4xx``, DESIGN.md §16.1/§16.5).

Two rules, pure ``ast`` over the tree — the same philosophy as RG301's
kernel/oracle cross-check, applied to the chaos subsystem:

**CH401 — call sites vs the registry.**  Every ``chaos.failpoint(<name>)``
call threaded through ``src/repro/`` must pass a STRING LITERAL naming a
site declared in ``repro.chaos.registry.SITES`` (a computed name cannot be
cross-checked statically and is itself a finding), and — the converse —
every registered site must have at least one call site: a registry entry
nobody calls is dead configuration that silently exempts its seam from
the kill harness's coverage guarantee.

**CH402 — kill-harness coverage.**  Every ``durability``-kind site must
appear in the harness's ``EXERCISED_SITES`` literal
(``repro.chaos.harness``), and every entry there must be a registered
durability site.  Proves "no durability seam is unexercised by the
kill-at-every-failpoint battery" without importing (or running) the
harness.

The chaos package itself (engine, registry, harness) is excluded from the
call-site scan — it defines ``failpoint`` and manipulates site names as
data, not as injection seams.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.findings import Finding, finding_at

RULE_FAILPOINT_SITE = "CH401"   # failpoint call / registry mismatch
RULE_KILL_COVERAGE = "CH402"    # durability site not kill-harness-exercised

REGISTRY_REL = "src/repro/chaos/registry.py"
HARNESS_REL = "src/repro/chaos/harness.py"
SCAN_ROOT = "src/repro"
_EXCLUDE_PREFIX = "src/repro/chaos/"


def _callee_tail(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def registry_sites(registry_src: str) -> dict[str, tuple[int, str]]:
    """Parse ``Site(...)`` literals -> ``{name: (lineno, kind)}``."""
    out: dict[str, tuple[int, str]] = {}
    for node in ast.walk(ast.parse(registry_src)):
        if not (isinstance(node, ast.Call) and _callee_tail(node) == "Site"):
            continue
        name = kind = None
        if node.args and isinstance(node.args[0], ast.Constant):
            name = node.args[0].value
        if len(node.args) > 1 and isinstance(node.args[1], ast.Constant):
            kind = node.args[1].value
        for kw in node.keywords:
            if kw.arg == "name" and isinstance(kw.value, ast.Constant):
                name = kw.value.value
            if kw.arg == "kind" and isinstance(kw.value, ast.Constant):
                kind = kw.value.value
        if isinstance(name, str):
            out[name] = (node.lineno, kind if isinstance(kind, str) else "?")
    return out


def failpoint_calls(src: str) -> list[tuple[int, str | None]]:
    """Every ``*.failpoint(...)`` call -> ``(lineno, literal_name_or_None)``
    (None = the site name is not a plain string literal)."""
    out: list[tuple[int, str | None]] = []
    for node in ast.walk(ast.parse(src)):
        if not (isinstance(node, ast.Call)
                and _callee_tail(node) == "failpoint"):
            continue
        name = None
        if node.args and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str):
            name = node.args[0].value
        out.append((node.lineno, name))
    return out


def check_failpoint_source(src: str, path: str,
                           sites: dict[str, tuple[int, str]]
                           ) -> tuple[list[Finding], set[str]]:
    """CH401 per-file half: non-literal or unregistered site names.
    Returns ``(findings, site names called in this file)``."""
    out: list[Finding] = []
    called: set[str] = set()
    for lineno, name in failpoint_calls(src):
        if name is None:
            out.append(finding_at(
                RULE_FAILPOINT_SITE, path, lineno,
                "failpoint() name must be a string literal — a computed "
                "site name cannot be cross-checked against "
                "repro.chaos.registry (CH401)", src))
        elif name not in sites:
            out.append(finding_at(
                RULE_FAILPOINT_SITE, path, lineno,
                f"failpoint site {name!r} is not declared in "
                "repro.chaos.registry.SITES — register the seam (with its "
                "kind and supported actions) before injecting there", src))
        else:
            called.add(name)
    return out, called


def harness_exercised(harness_src: str) -> dict[str, int]:
    """Parse the harness's ``EXERCISED_SITES`` literal -> name -> lineno."""
    out: dict[str, int] = {}
    for node in ast.parse(harness_src).body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "EXERCISED_SITES"):
            continue
        if isinstance(node.value, (ast.List, ast.Tuple)):
            for elt in node.value.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    out[elt.value] = elt.lineno
    return out


def check_kill_coverage(registry_src: str, harness_src: str, *,
                        registry_path: str = REGISTRY_REL,
                        harness_path: str = HARNESS_REL) -> list[Finding]:
    """CH402 both ways: durability sites missing from the harness, and
    harness entries that are not registered durability sites."""
    sites = registry_sites(registry_src)
    exercised = harness_exercised(harness_src)
    out: list[Finding] = []
    for name, (lineno, kind) in sorted(sites.items(),
                                       key=lambda kv: kv[1][0]):
        if kind == "durability" and name not in exercised:
            out.append(finding_at(
                RULE_KILL_COVERAGE, registry_path, lineno,
                f"durability site {name!r} is not exercised by the kill "
                "harness — add a SitePlan and EXERCISED_SITES entry in "
                "repro.chaos.harness (DESIGN.md §16.5)", registry_src))
    for name, lineno in sorted(exercised.items(), key=lambda kv: kv[1]):
        if name not in sites:
            out.append(finding_at(
                RULE_KILL_COVERAGE, harness_path, lineno,
                f"EXERCISED_SITES entry {name!r} is not a registered "
                "site — stale after a registry rename?", harness_src))
        elif sites[name][1] != "durability":
            out.append(finding_at(
                RULE_KILL_COVERAGE, harness_path, lineno,
                f"EXERCISED_SITES entry {name!r} is kind "
                f"{sites[name][1]!r}, not 'durability' — the kill harness "
                "covers crash-consistency seams only", harness_src))
    return out


def run_chaos_checks(root: str | pathlib.Path,
                     files: set[str] | None = None
                     ) -> tuple[list[Finding], dict[str, str]]:
    """CH401 + CH402 over the repo at ``root``.

    ``files`` restricts the per-file CH401 half (``--changed-only``); the
    global halves (never-called sites, kill coverage) need the whole tree
    and run on full-tree passes or when a chaos/ file is in scope — same
    gating shape as RG301.
    """
    root = pathlib.Path(root)
    findings: list[Finding] = []
    sources: dict[str, str] = {}

    def read(rel: str) -> str:
        if rel not in sources:
            sources[rel] = (root / rel).read_text(encoding="utf-8")
        return sources[rel]

    registry_src = read(REGISTRY_REL)
    sites = registry_sites(registry_src)

    called_anywhere: set[str] = set()
    for p in sorted((root / SCAN_ROOT).rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        if rel.startswith(_EXCLUDE_PREFIX):
            continue
        per_file, called = check_failpoint_source(read(rel), rel, sites)
        called_anywhere |= called
        if files is None or rel in files:
            findings.extend(per_file)

    chaos_in_scope = files is not None and any(
        f.startswith(_EXCLUDE_PREFIX) for f in files)
    if files is None or chaos_in_scope:
        for name, (lineno, _) in sorted(sites.items(),
                                        key=lambda kv: kv[1][0]):
            if name not in called_anywhere:
                findings.append(finding_at(
                    RULE_FAILPOINT_SITE, REGISTRY_REL, lineno,
                    f"registered site {name!r} has no "
                    "chaos.failpoint() call site under src/repro/ — dead "
                    "registry entry (its seam is never injectable)",
                    registry_src))
        findings.extend(check_kill_coverage(registry_src, read(HARNESS_REL)))
    return findings, sources
