"""Jaxpr contract audits: trace the hot-path entry points with canonical
abstract shapes and assert structural invariants on the traced program
(DESIGN.md §14).

Everything here runs ``jax.make_jaxpr``/``jax.eval_shape`` on
``jax.ShapeDtypeStruct`` leaves — no data, no device execution, a few
hundred ms for the whole battery — so CI can audit the *compiled program's
shape* on every commit without running a benchmark:

``JX001`` — the materialization-regression detector.  The fused scan's
whole point is that the ``(Q, N)`` score matrix never exists (DESIGN.md
§11); a refactor that quietly reintroduces it still returns correct
results, so only a structural check catches it.  We walk every
intermediate of the traced program (recursing into pjit/scan/pallas_call
sub-jaxprs) and fail on any float-dtype value of shape exactly ``(Q, N)``.
Canonical ``N`` is chosen a non-multiple of every internal block size, so
legitimate ``(Q, block)`` tiles and padded ``(Q, N_pad)`` buffers never
alias the forbidden shape.

``JX002`` — no float64 anywhere in the trace (x64 is disabled repo-wide;
an f64 that survives to lowering means someone re-enabled it locally).

``JX003`` — id-carrying outputs are exactly ``imi.ID_DTYPE`` (the
persisted-segment round-trip contract).

``JX004`` — no host callbacks on the hot path (a stray ``jax.debug.print``
serializes every batch through the host).

``JX005`` — recompile-hazard check: re-trace at a second ``(Q, N, k)``
setting and require the two jaxprs be isomorphic up to shape constants
(same recursive primitive sequence).  A Python value leaking into a
trace-time branch (PR 5's stale ``use_kernel`` default was one) shows up
as a structural diff between the settings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.analysis.findings import Finding, SEV_ERROR

RULE_TRACE = "JX000"         # entry point failed to trace at all
RULE_QN_MAT = "JX001"        # (Q, N) float intermediate on a fused path
RULE_F64 = "JX002"           # float64 value in the trace
RULE_ID_DTYPE = "JX003"      # id-carrying output not ID_DTYPE
RULE_CALLBACK = "JX004"      # host callback on the hot path
RULE_RETRACE = "JX005"       # trace structure varies with (Q, N, k)

_CALLBACK_PRIMS = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
}

# canonical trace geometry — N=6001 is prime-ish on purpose: not a multiple
# of any kernel block (1024) or jnp fallback block (4096), so padded/tiled
# buffers never collide with the forbidden (Q, N) shape
CANON = dict(Q=7, N=6001, D=32, P=8, M=16, K=4)
RETRACE = dict(Q=5, N=6500, D=32, P=8, M=16, K=4)   # both settings pad


def _sds(shape: tuple, dtype: Any) -> Any:
    import jax
    return jax.ShapeDtypeStruct(shape, np.dtype(dtype))


# ---------------------------------------------------------------------------
# canonical abstract inputs
# ---------------------------------------------------------------------------
def canonical_index(*, n: int, d: int, p: int, m: int, k: int) -> Any:
    """An ``IMIIndex`` whose leaves are ``ShapeDtypeStruct``s — enough for
    ``make_jaxpr``/``eval_shape``, never touches a device."""
    import jax.numpy as jnp
    from repro.core import imi as imimod
    from repro.core import pq as pqmod
    pq = pqmod.PQ(centroids=_sds((p, m, d // p), np.float32), rotation=None)
    return imimod.IMIIndex(
        coarse1=_sds((k, d // 2), np.float32),
        coarse2=_sds((k, d // 2), np.float32),
        pq=pq,
        codes=_sds((n, p), np.uint8),
        vectors=_sds((n, d), jnp.bfloat16),
        ids=_sds((n,), imimod.ID_DTYPE),
        cell_of=_sds((n,), np.int32),
        cell_offsets=_sds((k * k + 1,), np.int32),
    )


def canonical_sharded(*, n: int, d: int, p: int, m: int, k: int) -> Any:
    """A 1-shard ``ShardedIndex`` of ``ShapeDtypeStruct`` leaves (the
    per-shard body is what we audit; shard count only changes collectives)."""
    import jax.numpy as jnp
    from repro.core import distributed as dist
    from repro.core import imi as imimod
    return dist.ShardedIndex(
        codes=_sds((1, n, p), np.uint8),
        vectors=_sds((1, n, d), jnp.bfloat16),
        ids=_sds((1, n), imimod.ID_DTYPE),
        cell_of=_sds((1, n), np.int32),
        row_valid=_sds((1, n), np.uint8),
        row_start=_sds((1, 1), np.int32),
        cell_offsets=_sds((1, k * k + 1), np.int32),
        global_offsets=_sds((k * k + 1,), np.int32),
        coarse1=_sds((k, d // 2), np.float32),
        coarse2=_sds((k, d // 2), np.float32),
        pq_centroids=_sds((p, m, d // p), np.float32),
        pq_rotation=None,
    )


# ---------------------------------------------------------------------------
# jaxpr walking (duck-typed: survives jax.core module reshuffles)
# ---------------------------------------------------------------------------
def _is_jaxpr(v: Any) -> bool:
    return hasattr(v, "eqns") and hasattr(v, "invars")


def _as_jaxpr(v: Any) -> Any:
    if _is_jaxpr(v):
        return v
    inner = getattr(v, "jaxpr", None)          # ClosedJaxpr
    return inner if _is_jaxpr(inner) else None


def iter_eqns(jaxpr: Any):
    """Every equation of ``jaxpr`` and, recursively, of every sub-jaxpr in
    its equations' params (pjit bodies, scan/cond branches, pallas_call
    kernels, shard_map bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for item in (v if isinstance(v, (list, tuple)) else (v,)):
                sub = _as_jaxpr(item)
                if sub is not None:
                    yield from iter_eqns(sub)


def trace_jaxpr(fn: Callable, args: Sequence[Any]) -> Any:
    """``jax.make_jaxpr`` over abstract args; returns the (open) jaxpr."""
    import jax
    return jax.make_jaxpr(fn)(*args).jaxpr


def primitive_signature(jaxpr: Any) -> list[str]:
    """Recursive primitive-name sequence — the shape-free skeleton JX005
    compares across trace settings."""
    return [eqn.primitive.name for eqn in iter_eqns(jaxpr)]


# ---------------------------------------------------------------------------
# per-rule checks (each usable standalone; tests drive them directly)
# ---------------------------------------------------------------------------
def check_qn_materialization(jaxpr: Any, q: int, n: int, label: str,
                             path: str) -> list[Finding]:
    """JX001: no float-dtype intermediate of shape exactly ``(q, n)``."""
    out: list[Finding] = []
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", None)
            dtype = getattr(aval, "dtype", None)
            if shape == (q, n) and dtype is not None \
                    and np.issubdtype(dtype, np.floating):
                out.append(Finding(
                    rule=RULE_QN_MAT, path=path, line=0, severity=SEV_ERROR,
                    message=f"{label}: traced program materializes a "
                            f"({q}, {n}) {dtype} intermediate "
                            f"(primitive '{eqn.primitive.name}') — the "
                            "fused path must never build the (Q, N) score "
                            "matrix (DESIGN.md §11)",
                    snippet=label))
                return out          # one finding per entry point is enough
    return out


def check_no_f64(jaxpr: Any, label: str, path: str) -> list[Finding]:
    """JX002: no float64 output anywhere in the trace (conversions
    included — a convert_element_type to f64 produces an f64 outvar)."""
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            dtype = getattr(getattr(var, "aval", None), "dtype", None)
            if dtype is not None and dtype == np.float64:
                return [Finding(
                    rule=RULE_F64, path=path, line=0, severity=SEV_ERROR,
                    message=f"{label}: trace contains a float64 value "
                            f"(primitive '{eqn.primitive.name}'); x64 is "
                            "disabled repo-wide and kernels have no f64 "
                            "path", snippet=label)]
    return []


def check_no_callbacks(jaxpr: Any, label: str, path: str) -> list[Finding]:
    """JX004: no host-callback primitives on the hot path."""
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name in _CALLBACK_PRIMS:
            return [Finding(
                rule=RULE_CALLBACK, path=path, line=0, severity=SEV_ERROR,
                message=f"{label}: trace contains host callback "
                        f"'{eqn.primitive.name}' — every batch would "
                        "round-trip through the host", snippet=label)]
    return []


def check_id_dtype(fn: Callable, args: Sequence[Any],
                   id_outputs: Sequence[Any], label: str, path: str
                   ) -> list[Finding]:
    """JX003: outputs named in ``id_outputs`` (dict keys or positional
    indices) have dtype exactly ``imi.ID_DTYPE``."""
    import jax
    from repro.core import imi as imimod
    out_shape = jax.eval_shape(fn, *args)
    findings: list[Finding] = []
    for key in id_outputs:
        leaf = out_shape[key]
        if np.dtype(leaf.dtype) != np.dtype(imimod.ID_DTYPE):
            findings.append(Finding(
                rule=RULE_ID_DTYPE, path=path, line=0, severity=SEV_ERROR,
                message=f"{label}: id-carrying output {key!r} has dtype "
                        f"{leaf.dtype}, contract is "
                        f"{np.dtype(imimod.ID_DTYPE).name} "
                        "(imi.ID_DTYPE; segments round-trip int32)",
                snippet=label))
    return findings


def check_retrace_stable(fn_a: Callable, args_a: Sequence[Any],
                         fn_b: Callable, args_b: Sequence[Any],
                         label: str, path: str) -> list[Finding]:
    """JX005: the two traces must share one primitive skeleton."""
    sig_a = primitive_signature(trace_jaxpr(fn_a, args_a))
    sig_b = primitive_signature(trace_jaxpr(fn_b, args_b))
    if sig_a == sig_b:
        return []
    # first structural divergence, for the message
    i = next((j for j, (x, y) in enumerate(zip(sig_a, sig_b)) if x != y),
             min(len(sig_a), len(sig_b)))
    at = (f"position {i}: "
          f"{sig_a[i] if i < len(sig_a) else '<end>'} vs "
          f"{sig_b[i] if i < len(sig_b) else '<end>'}")
    return [Finding(
        rule=RULE_RETRACE, path=path, line=0, severity=SEV_ERROR,
        message=f"{label}: trace structure differs between shape settings "
                f"({len(sig_a)} vs {len(sig_b)} primitives; first diff at "
                f"{at}) — a Python value is leaking into a trace-time "
                "branch (recompile hazard)", snippet=label)]


# ---------------------------------------------------------------------------
# entry-point registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceEntry:
    """One audited entry point: how to build it abstractly at a geometry,
    and which rules apply."""

    label: str
    path: str                                   # module anchoring findings
    # geometry dict -> (callable, abstract args)
    build: Callable[[dict], tuple[Callable, tuple]]
    check_qn: bool = True
    qn_q: Optional[int] = None                  # JX001 Q override (single-
    #                                             query entries score (1, N))
    id_outputs: tuple = ()                      # JX003 output keys/indices
    retrace: bool = False                       # JX005 at CANON vs RETRACE


def _search_cfg(**kw):
    from repro.core import anns
    return anns.SearchConfig(**kw)


def _entry_search_batch(fused: bool, shared: bool, masked: bool,
                        use_kernel: str):
    def build(g: dict) -> tuple[Callable, tuple]:
        from repro.core import anns
        idx = canonical_index(n=g["N"], d=g["D"], p=g["P"], m=g["M"],
                              k=g["K"])
        qs = _sds((g["Q"], g["D"]), np.float32)
        # shared branch iff top_a * max_cell_size >= N
        cfg = _search_cfg(top_a=4, max_cell_size=2048,
                          top_k=g.get("k", 25), use_kernel=use_kernel,
                          fused_topk=fused) if shared else \
            _search_cfg(top_a=2, max_cell_size=512, top_k=g.get("k", 25),
                        use_kernel=use_kernel, fused_topk=fused)
        args = (idx, qs) if not masked \
            else (idx, qs, _sds((g["Q"], g["N"]), np.uint8))
        return (lambda *a: anns.search_batch(a[0], a[1], cfg, *a[2:])), args
    return build


def _entry_exhaustive(use_kernel: str):
    def build(g: dict) -> tuple[Callable, tuple]:
        from repro.core import anns
        idx = canonical_index(n=g["N"], d=g["D"], p=g["P"], m=g["M"],
                              k=g["K"])
        q = _sds((g["D"],), np.float32)
        k = g.get("k", 25)
        return (lambda i, q_: anns.exhaustive_adc(
            i, q_, k=k, use_kernel=use_kernel, fused_topk=True)), (idx, q)
    return build


def _entry_ops_topk(name: str, masked: bool, windowed: bool, paired: bool):
    def build(g: dict) -> tuple[Callable, tuple]:
        from repro.kernels import ops as kops
        fn = getattr(kops, name)
        Q, N, P, M, k = g["Q"], g["N"], g["P"], g["M"], g.get("k", 25)
        luts = _sds((Q, P, M), np.float32)
        codes = _sds((Q, N, P) if paired else (N, P), np.uint8)
        mask = _sds((Q, N), np.uint8)
        if windowed:
            A = 4
            st = _sds((Q, A), np.int32)
            ct = _sds((Q, A), np.int32)
            bs = _sds((Q, A), np.float32)
            args = (luts, codes, st, ct, bs, mask) if masked \
                else (luts, codes, st, ct, bs)
        elif masked:
            args = (luts, codes, mask)
        else:
            args = (luts, codes)
        # k is a static (shape-determining) arg — close over it so
        # make_jaxpr only sees array args
        return (lambda *a: fn(*a, k)), args
    return build


def _entry_sharded(mode: str):
    def build(g: dict) -> tuple[Callable, tuple]:
        import jax
        from jax.sharding import Mesh
        from repro.core import distributed as dist
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("shard",))
        cfg = _search_cfg(top_a=4, max_cell_size=2048, top_k=g.get("k", 25),
                          use_kernel="jnp")
        search = dist.make_sharded_search(mesh, cfg=cfg, mode=mode)
        sidx = canonical_sharded(n=g["N"], d=g["D"], p=g["P"], m=g["M"],
                                 k=g["K"])
        qs = _sds((g["Q"], g["D"]), np.float32)
        return search, (sidx, qs)
    return build


ANNS = "src/repro/core/anns.py"
OPS = "src/repro/kernels/ops.py"
DIST = "src/repro/core/distributed.py"


def default_entries() -> list[TraceEntry]:
    """The audited hot-path surface.  The legacy ``fused_topk=False`` path
    is deliberately NOT here — it materializes (Q, N) by design and exists
    only as the parity reference; tests assert JX001 fires on it."""
    entries = [
        TraceEntry("trace:search_batch/fused-shared", ANNS,
                   _entry_search_batch(True, True, False, "jnp"),
                   id_outputs=("ids", "rows"), retrace=True),
        TraceEntry("trace:search_batch/fused-shared-masked", ANNS,
                   _entry_search_batch(True, True, True, "jnp"),
                   id_outputs=("ids", "rows")),
        TraceEntry("trace:search_batch/fused-paired", ANNS,
                   _entry_search_batch(True, False, False, "jnp"),
                   id_outputs=("ids", "rows"), retrace=True),
        TraceEntry("trace:search_batch/fused-paired-masked", ANNS,
                   _entry_search_batch(True, False, True, "jnp"),
                   id_outputs=("ids", "rows")),
        TraceEntry("trace:exhaustive_adc/fused", ANNS,
                   _entry_exhaustive("jnp"), qn_q=1,
                   id_outputs=("ids", "rows"), retrace=True),
        TraceEntry("trace:sharded_search/probe", DIST,
                   _entry_sharded("probe"),
                   id_outputs=("ids", "rows"), retrace=True),
    ]
    for name, masked, windowed, paired in [
            ("pq_scan_topk_batched", False, False, False),
            ("pq_scan_topk_batched_masked", True, False, False),
            ("pq_scan_topk_windowed", False, True, False),
            ("pq_scan_topk_windowed_masked", True, True, False),
            ("pq_scan_topk_paired", False, False, True),
            ("pq_scan_topk_paired_masked", True, False, True)]:
        entries.append(TraceEntry(
            f"trace:ops.{name}", OPS,
            _entry_ops_topk(name, masked, windowed, paired),
            id_outputs=(1,),        # (scores, rows): rows carries ids/rows
            retrace=(name == "pq_scan_topk_windowed")))
    return entries


def check_entry(entry: TraceEntry, geometry: Optional[dict] = None
                ) -> list[Finding]:
    """Run every applicable rule on one entry point."""
    g = dict(CANON if geometry is None else geometry)
    findings: list[Finding] = []
    try:
        fn, args = entry.build(g)
        jaxpr = trace_jaxpr(fn, args)
    except Exception as e:  # noqa: BLE001 - any trace failure is a finding
        return [Finding(
            rule=RULE_TRACE, path=entry.path, line=0, severity=SEV_ERROR,
            message=f"{entry.label}: failed to trace with canonical "
                    f"abstract shapes: {type(e).__name__}: {e}",
            snippet=entry.label)]
    if entry.check_qn:
        findings += check_qn_materialization(
            jaxpr, entry.qn_q if entry.qn_q is not None else g["Q"],
            g["N"], entry.label, entry.path)
    findings += check_no_f64(jaxpr, entry.label, entry.path)
    findings += check_no_callbacks(jaxpr, entry.label, entry.path)
    if entry.id_outputs:
        try:
            findings += check_id_dtype(fn, args, entry.id_outputs,
                                       entry.label, entry.path)
        except Exception as e:  # noqa: BLE001
            findings.append(Finding(
                rule=RULE_TRACE, path=entry.path, line=0,
                severity=SEV_ERROR,
                message=f"{entry.label}: eval_shape failed: "
                        f"{type(e).__name__}: {e}", snippet=entry.label))
    if entry.retrace:
        try:
            g2 = dict(RETRACE)
            g2["k"] = 50
            fn2, args2 = entry.build(g2)
            findings += check_retrace_stable(fn, args, fn2, args2,
                                             entry.label, entry.path)
        except Exception as e:  # noqa: BLE001
            findings.append(Finding(
                rule=RULE_TRACE, path=entry.path, line=0,
                severity=SEV_ERROR,
                message=f"{entry.label}: retrace at second geometry "
                        f"failed: {type(e).__name__}: {e}",
                snippet=entry.label))
    return findings


def run_jaxpr_checks(entries: Optional[list[TraceEntry]] = None
                     ) -> list[Finding]:
    """The full jaxpr audit battery (layer 1 of ``tools.lint``)."""
    findings: list[Finding] = []
    for entry in (default_entries() if entries is None else entries):
        findings.extend(check_entry(entry))
    return findings
