"""Committed findings baseline — pre-existing accepted findings don't block.

The baseline file (``tools/lint_baseline.json``) pins the findings the tree
is *allowed* to have: CI's ``--strict`` gate fails only on findings that are
not in it.  Each entry carries a mandatory human justification; an entry
whose justification is empty or still the ``--write-baseline`` placeholder
fails ``--strict`` — baselining a finding is an explicit, reviewed decision,
not an escape hatch.

Entries are keyed by a *content fingerprint* — ``sha256(rule | path |
normalized flagged line)`` — not by line number, so unrelated edits that
shift a file do not invalidate the baseline, while editing the flagged line
itself (the thing the rule looked at) does.

Stale entries (fingerprints no longer produced by the tree) are reported so
the baseline shrinks as findings get fixed; ``--strict`` fails on them too,
keeping the committed file an exact mirror of the accepted debt.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import re

from repro.analysis.findings import Finding

VERSION = 1
PLACEHOLDER = "FIXME: justify this baseline entry"


def fingerprint(f: Finding) -> str:
    """Content fingerprint: stable under line moves, invalidated by edits
    to the flagged line (or, for trace-level findings, the trace label)."""
    norm = re.sub(r"\s+", " ", f.snippet).strip()
    blob = f"{f.rule}|{f.path}|{norm}".encode()
    return hashlib.sha256(blob).hexdigest()[:16]


@dataclasses.dataclass
class BaselineEntry:
    rule: str
    path: str
    fingerprint: str
    justification: str
    snippet: str = ""


@dataclasses.dataclass
class BaselineMatch:
    new: list[Finding]              # findings not covered by the baseline
    accepted: list[Finding]         # findings the baseline covers
    stale: list[BaselineEntry]      # entries no current finding matches
    unjustified: list[BaselineEntry]  # entries with empty/placeholder why


def load(path: str | pathlib.Path) -> list[BaselineEntry]:
    path = pathlib.Path(path)
    if not path.exists():
        return []
    data = json.loads(path.read_text(encoding="utf-8"))
    if data.get("version") != VERSION:
        raise ValueError(f"baseline version {data.get('version')} != "
                         f"{VERSION}; regenerate with --write-baseline")
    return [BaselineEntry(**e) for e in data["entries"]]


def save(path: str | pathlib.Path, findings: list[Finding],
         previous: list[BaselineEntry] | None = None) -> list[BaselineEntry]:
    """Write ``findings`` as the new baseline, keeping the justification of
    any previous entry with the same fingerprint (new entries get the
    placeholder, which ``--strict`` rejects until a human edits it)."""
    prev = {e.fingerprint: e for e in (previous or [])}
    entries = []
    seen = set()
    for f in sorted(findings, key=lambda f: (f.path, f.rule, f.line)):
        fp = fingerprint(f)
        if fp in seen:
            continue
        seen.add(fp)
        old = prev.get(fp)
        entries.append(BaselineEntry(
            rule=f.rule, path=f.path, fingerprint=fp,
            justification=old.justification if old else PLACEHOLDER,
            snippet=f.snippet))
    payload = {"version": VERSION,
               "entries": [dataclasses.asdict(e) for e in entries]}
    pathlib.Path(path).write_text(json.dumps(payload, indent=1) + "\n",
                                  encoding="utf-8")
    return entries


def match(findings: list[Finding], entries: list[BaselineEntry]
          ) -> BaselineMatch:
    """Split ``findings`` into new vs baseline-accepted, and the baseline
    into live vs stale entries."""
    by_fp: dict[str, BaselineEntry] = {e.fingerprint: e for e in entries}
    new, accepted, live = [], [], set()
    for f in findings:
        fp = fingerprint(f)
        if fp in by_fp:
            accepted.append(f)
            live.add(fp)
        else:
            new.append(f)
    stale = [e for e in entries if e.fingerprint not in live]
    unjustified = [e for e in entries
                   if not e.justification.strip()
                   or e.justification.strip() == PLACEHOLDER]
    return BaselineMatch(new=new, accepted=accepted, stale=stale,
                         unjustified=unjustified)
