"""Static invariant checker: jaxpr contract audits, kernel-purity and
durability-ordering AST lints, and the findings/baseline/suppression
infrastructure (DESIGN.md §14).  Run via ``python -m tools.lint``."""
from repro.analysis.findings import (  # noqa: F401
    Finding,
    SEV_ERROR,
    SEV_WARNING,
    apply_suppressions,
    scan_suppressions,
)
from repro.analysis import (  # noqa: F401
    ast_checks,
    baseline,
    chaos_checks,
    jaxpr_checks,
)
