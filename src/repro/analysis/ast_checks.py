"""AST lints: kernel-purity rules, the kernel/oracle registry cross-check,
and the store/ingest durability-ordering analysis (DESIGN.md §14).

Three rule families, all pure ``ast`` — no imports of the checked code:

**Kernel purity** (``KN1xx``, over ``src/repro/kernels/``): a *kernel body*
is any function handed to ``pl.pallas_call`` (resolved through
``functools.partial`` and local aliases) or, by convention, any function
with a ``*_ref``/``*_scr``/``*_out`` parameter — the Mosaic-lowered subset.
Inside one, Python control flow on traced refs, numpy calls, ``.item()``
escapes, and float64 dtypes all fail to lower on TPU (or silently de-trace);
each is a rule.

**Registry cross-check** (``RG301``): every public ``pq_scan_*`` kernel must
be registered in :data:`KERNEL_ORACLES` with a ``ref.py`` oracle (the parity
tests' ground truth) and a jnp fallback (the off-TPU production path), and
the named functions must actually exist — a new kernel variant cannot land
oracle-less (the PR 5 regression class).

**Durability ordering** (``DS2xx``, over ``src/repro/store/`` +
``src/repro/ingest/``): statement-order dominance checks of the §5/§12.3
crash-consistency chain — ``os.replace`` dominated by ``flush``+``fsync``,
durable ``np.savez``/``np.save`` artifacts fsync'd before the function
returns, renames followed by a directory fsync, and the meta-log append
preceding the store/WAL insert.  The walk is linear per function body
(source order), a sound approximation for this codebase's straight-line
durability helpers.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.findings import Finding, SEV_ERROR, finding_at

# -- rule ids ---------------------------------------------------------------
RULE_KERNEL_BRANCH = "KN101"    # Python if/for/while on a traced ref
RULE_KERNEL_NUMPY = "KN102"     # numpy call inside a kernel body
RULE_KERNEL_ITEM = "KN103"      # .item()/.tolist() host escape
RULE_KERNEL_F64 = "KN104"       # float64 dtype in a kernel body
RULE_REGISTRY = "RG301"         # kernel without oracle/fallback registration
RULE_REPLACE_FSYNC = "DS201"    # os.replace not dominated by flush+fsync
RULE_WRITE_FSYNC = "DS202"      # durable artifact written without fsync
RULE_META_ORDER = "DS203"       # store/WAL insert not preceded by meta log
RULE_DIR_FSYNC = "DS204"        # os.replace without directory fsync after

KERNEL_DIRS = ("src/repro/kernels",)
DURABILITY_DIRS = ("src/repro/store", "src/repro/ingest")
_REF_SUFFIXES = ("_ref", "_scr", "_out")

# Every public pq_scan_* kernel entry point -> (oracle def in kernels/ref.py,
# jnp fallback: a def in kernels/pq_scan.py, or "module:name" elsewhere).
# RG301 checks three ways: unregistered kernels, dangling oracle names,
# dangling fallback names.
KERNEL_ORACLES: dict[str, tuple[str, str]] = {
    "pq_scan_batched": ("pq_scan_ref", "repro.core.pq:adc_scores"),
    "pq_scan_batched_masked": ("pq_scan_masked_ref",
                               "repro.core.pq:adc_scores"),
    "pq_scan_paired": ("pq_scan_ref", "repro.core.pq:adc_scores"),
    "pq_scan_paired_masked": ("pq_scan_masked_ref",
                              "repro.core.pq:adc_scores"),
    "pq_scan_topk_batched": ("pq_scan_topk_ref", "pq_scan_topk_jnp"),
    "pq_scan_topk_batched_masked": ("pq_scan_topk_ref", "pq_scan_topk_jnp"),
    "pq_scan_topk_windowed": ("pq_scan_topk_windowed_ref",
                              "pq_scan_topk_windowed_jnp"),
    "pq_scan_topk_windowed_masked": ("pq_scan_topk_windowed_ref",
                                     "pq_scan_topk_windowed_jnp"),
    "pq_scan_topk_paired": ("pq_scan_topk_ref", "pq_scan_topk_paired_jnp"),
    "pq_scan_topk_paired_masked": ("pq_scan_topk_ref",
                                   "pq_scan_topk_paired_jnp"),
}


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> str:
    """'os.replace' for Attribute chains, 'open' for Names, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif parts:
        parts.append("?")          # e.g. call().attr — keep the attr chain
    return ".".join(reversed(parts))


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _calls_in_order(fn: ast.FunctionDef) -> list[tuple[ast.Call, str]]:
    """Every Call in ``fn``, with its dotted callee name, in source order."""
    calls = [(node, _dotted(node.func)) for node in ast.walk(fn)
             if isinstance(node, ast.Call)]
    calls.sort(key=lambda pair: (pair[0].lineno, pair[0].col_offset))
    return calls


def _function_defs(tree: ast.Module) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


# ---------------------------------------------------------------------------
# kernel-body discovery
# ---------------------------------------------------------------------------
def _partial_target(call: ast.Call) -> str | None:
    """functools.partial(F, ...) -> 'F'."""
    name = _dotted(call.func)
    if name.split(".")[-1] == "partial" and call.args:
        return _dotted(call.args[0]) or None
    return None


def kernel_body_names(tree: ast.Module) -> set[str]:
    """Names of functions that are Pallas kernel bodies in this module.

    Union of (a) first arguments of ``pl.pallas_call`` calls, unwrapping
    ``functools.partial`` and resolving single-assignment local aliases
    (``kern = functools.partial(_body, ...)``), and (b) the signature
    convention: any function with a ``*_ref``/``*_scr``/``*_out`` parameter
    (shared block helpers called from kernel bodies use it too).
    """
    # local aliases: name -> partial target, anywhere in the module
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            tgt = _partial_target(node.value)
            if tgt and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                aliases[node.targets[0].id] = tgt
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func).split(".")[-1] != "pallas_call" or not node.args:
            continue
        first = node.args[0]
        if isinstance(first, ast.Call):
            tgt = _partial_target(first)
            if tgt:
                out.add(tgt.split(".")[-1])
        else:
            name = _dotted(first).split(".")[-1]
            out.add(aliases.get(name, name))
    for fn in _function_defs(tree):
        params = [a.arg for a in fn.args.args + fn.args.kwonlyargs]
        if any(p.endswith(_REF_SUFFIXES) for p in params):
            out.add(fn.name)
    return out


# ---------------------------------------------------------------------------
# KN1xx: kernel purity
# ---------------------------------------------------------------------------
_F64_NAMES = {"float64", "f64", "double"}


def _check_kernel_body(fn: ast.FunctionDef, path: str, src: str
                       ) -> list[Finding]:
    refs = {a.arg for a in fn.args.args + fn.args.kwonlyargs
            if a.arg.endswith(_REF_SUFFIXES)}
    out: list[Finding] = []

    for node in ast.walk(fn):
        # KN101: Python control flow branching on a traced ref — the body
        # must stay in the compare/reduce/where subset (use jnp.where /
        # lax.fori_loop / pl.when); a Python `if codes_ref[...]` either
        # fails to trace or silently bakes in one branch.
        if isinstance(node, (ast.If, ast.While)) \
                and _names_in(node.test) & refs:
            out.append(finding_at(
                RULE_KERNEL_BRANCH, path, node.lineno,
                f"kernel body '{fn.name}' branches on traced ref(s) "
                f"{sorted(_names_in(node.test) & refs)} with Python "
                f"{'if' if isinstance(node, ast.If) else 'while'}; use "
                "jnp.where / pl.when", src))
        if isinstance(node, ast.For) and _names_in(node.iter) & refs:
            out.append(finding_at(
                RULE_KERNEL_BRANCH, path, node.lineno,
                f"kernel body '{fn.name}' iterates a traced ref with a "
                "Python for; use lax.fori_loop", src))
        # KN102: numpy inside a kernel body runs at trace time on the host —
        # a constant-folding bug at best, a tracer leak at worst.
        if isinstance(node, ast.Call):
            callee = _dotted(node.func)
            root = callee.split(".")[0]
            if root in ("np", "numpy") and "." in callee:
                out.append(finding_at(
                    RULE_KERNEL_NUMPY, path, node.lineno,
                    f"kernel body '{fn.name}' calls numpy ({callee}); "
                    "use jnp/lax so the op lowers with the kernel", src))
            # KN103: .item()/.tolist() forces a device->host sync and cannot
            # appear in traced code at all.
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "tolist"):
                out.append(finding_at(
                    RULE_KERNEL_ITEM, path, node.lineno,
                    f"kernel body '{fn.name}' calls .{node.func.attr}() — "
                    "host escape inside a kernel", src))
        # KN104: float64 anywhere in a kernel body — Mosaic has no f64 path
        # and x64 is globally disabled (imi.ID_DTYPE rationale).
        is_f64 = (isinstance(node, ast.Attribute)
                  and node.attr in _F64_NAMES) \
            or (isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and node.value in ("float64", "double"))
        if is_f64:
            out.append(finding_at(
                RULE_KERNEL_F64, path, node.lineno,
                f"kernel body '{fn.name}' references float64; kernels are "
                "f32/bf16/int only (x64 is disabled repo-wide)", src))
    return out


def check_kernel_source(src: str, path: str) -> list[Finding]:
    """KN101–KN104 over one kernels/ module."""
    tree = ast.parse(src)
    bodies = kernel_body_names(tree)
    out: list[Finding] = []
    for fn in _function_defs(tree):
        if fn.name in bodies:
            out.extend(_check_kernel_body(fn, path, src))
    return out


# ---------------------------------------------------------------------------
# RG301: kernel/oracle/fallback registry cross-check
# ---------------------------------------------------------------------------
def _module_def_names(src: str) -> set[str]:
    return {n.name for n in ast.parse(src).body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def check_registry(kernel_src: str, ref_src: str, *,
                   kernel_path: str = "src/repro/kernels/pq_scan.py",
                   fallback_srcs: dict[str, str] | None = None,
                   registry: dict[str, tuple[str, str]] | None = None
                   ) -> list[Finding]:
    """Every public ``pq_scan_*`` def in ``kernel_src`` must be registered
    with an existing oracle (in ``ref_src``) and an existing jnp fallback
    (in ``kernel_src`` or, for ``module:name`` specs, in
    ``fallback_srcs[module]``)."""
    registry = KERNEL_ORACLES if registry is None else registry
    fallback_srcs = fallback_srcs or {}
    tree = ast.parse(kernel_src)
    kernel_defs = {n.name: n.lineno for n in tree.body
                   if isinstance(n, ast.FunctionDef)}
    ref_defs = _module_def_names(ref_src)
    out: list[Finding] = []
    public = [(name, line) for name, line in kernel_defs.items()
              if name.startswith("pq_scan") and not name.startswith("_")
              and not name.endswith("_jnp") and not name.endswith("_ref")]
    for name, line in sorted(public, key=lambda p: p[1]):
        if name not in registry:
            out.append(finding_at(
                RULE_REGISTRY, kernel_path, line,
                f"kernel '{name}' has no KERNEL_ORACLES entry — register "
                "its ref.py oracle and jnp fallback "
                "(repro.analysis.ast_checks.KERNEL_ORACLES)", kernel_src))
            continue
        oracle, fallback = registry[name]
        if oracle not in ref_defs:
            out.append(finding_at(
                RULE_REGISTRY, kernel_path, line,
                f"kernel '{name}' registers oracle '{oracle}' which does "
                "not exist in kernels/ref.py", kernel_src))
        if ":" in fallback:
            mod, fb_name = fallback.split(":", 1)
            fb_defs = _module_def_names(fallback_srcs[mod]) \
                if mod in fallback_srcs else None
            if fb_defs is not None and fb_name not in fb_defs:
                out.append(finding_at(
                    RULE_REGISTRY, kernel_path, line,
                    f"kernel '{name}' registers fallback '{fallback}' "
                    f"but {mod} has no def '{fb_name}'", kernel_src))
        elif fallback not in kernel_defs:
            out.append(finding_at(
                RULE_REGISTRY, kernel_path, line,
                f"kernel '{name}' registers jnp fallback '{fallback}' "
                "which does not exist in the kernel module", kernel_src))
    return out


# ---------------------------------------------------------------------------
# DS2xx: durability ordering
# ---------------------------------------------------------------------------
def _is_fsync(callee: str) -> bool:
    return callee.split(".")[-1] == "fsync"


def _is_flush(callee: str) -> bool:
    return callee.split(".")[-1] == "flush"


def _is_dir_fsync(callee: str) -> bool:
    # os.fsync on a directory fd, or the module-local _fsync_dir helper
    last = callee.split(".")[-1]
    return last in ("_fsync_dir", "fsync_dir") or last == "fsync"


_DURABLE_WRITERS = {"savez", "savez_compressed", "save"}


def _check_durability_fn(fn: ast.FunctionDef, path: str, src: str, *,
                         ingest: bool) -> list[Finding]:
    calls = _calls_in_order(fn)
    out: list[Finding] = []
    for i, (call, callee) in enumerate(calls):
        last = callee.split(".")[-1]
        before = calls[:i]
        after = calls[i + 1:]
        if callee in ("os.replace", "os.rename"):
            # DS201: the §5 commit-point rule — whatever os.replace
            # publishes must be ON DISK first: a flush AND an fsync must
            # dominate the rename in this body.
            if not any(_is_flush(c) for _, c in before) \
                    or not any(_is_fsync(c) for _, c in before):
                out.append(finding_at(
                    RULE_REPLACE_FSYNC, path, call.lineno,
                    f"'{callee}' in '{fn.name}' is not dominated by "
                    "flush+fsync — a crash can publish a name whose bytes "
                    "never hit disk (DESIGN.md §5)", src))
            # DS204: the rename itself is only durable once the directory
            # entry is fsync'd (manifest.write_manifest's _fsync_dir).
            if not any(_is_dir_fsync(c) for _, c in after):
                out.append(finding_at(
                    RULE_DIR_FSYNC, path, call.lineno,
                    f"'{callee}' in '{fn.name}' has no directory fsync "
                    "after it — the rename may not survive a crash "
                    "(DESIGN.md §5)", src))
        # DS202: numpy artifact writers don't fsync; a durable file written
        # via np.savez/np.save must be fsync'd before the function returns
        # (or the manifest can name a file with no bytes behind it).
        if last in _DURABLE_WRITERS and callee.split(".")[0] in ("np",
                                                                "numpy"):
            if not any(_is_fsync(c) for _, c in after):
                out.append(finding_at(
                    RULE_WRITE_FSYNC, path, call.lineno,
                    f"'{callee}' in '{fn.name}' writes a durable artifact "
                    "with no fsync before return — commit points may "
                    "reference unsynced bytes (DESIGN.md §5)", src))
        # DS203 (ingest only): meta-log-then-WAL — the frame-attribution
        # record must be durable BEFORE the rows enter the store WAL
        # (DESIGN.md §12.3); an insert with no preceding meta append can
        # strand unattributable rows after a crash.
        if ingest and last == "insert" \
                and callee.split(".")[-2:-1] == ["store"]:
            if not any("append_meta" in c or "meta_log" in c
                       for _, c in before):
                out.append(finding_at(
                    RULE_META_ORDER, path, call.lineno,
                    f"'{callee}' in '{fn.name}' appends rows to the store "
                    "WAL without a preceding meta-log append — crash "
                    "recovery cannot re-attribute these rows "
                    "(DESIGN.md §12.3)", src))
    return out


def check_durability_source(src: str, path: str, *, ingest: bool
                            ) -> list[Finding]:
    """DS201–DS204 over one store/ or ingest/ module."""
    tree = ast.parse(src)
    out: list[Finding] = []
    for fn in _function_defs(tree):
        out.extend(_check_durability_fn(fn, path, src, ingest=ingest))
    return out


# ---------------------------------------------------------------------------
# tree-level driver
# ---------------------------------------------------------------------------
def run_ast_checks(root: str | pathlib.Path,
                   files: set[str] | None = None
                   ) -> tuple[list[Finding], dict[str, str]]:
    """All AST rules over the repo at ``root``.  ``files`` (repo-relative
    posix paths) restricts the per-file rules (``--changed-only``); the
    registry cross-check always runs when any kernels/ file is in scope.
    Returns ``(findings, sources)`` — sources feed suppression scanning.
    """
    root = pathlib.Path(root)
    findings: list[Finding] = []
    sources: dict[str, str] = {}

    def in_scope(rel: str) -> bool:
        return files is None or rel in files

    def read(rel: str) -> str:
        if rel not in sources:
            sources[rel] = (root / rel).read_text(encoding="utf-8")
        return sources[rel]

    kernel_files = []
    for d in KERNEL_DIRS:
        kernel_files += sorted((root / d).glob("*.py"))
    any_kernel_in_scope = False
    for p in kernel_files:
        rel = p.relative_to(root).as_posix()
        if not in_scope(rel):
            continue
        any_kernel_in_scope = True
        findings.extend(check_kernel_source(read(rel), rel))

    if any_kernel_in_scope or files is None:
        pq_rel = "src/repro/kernels/pq_scan.py"
        ref_rel = "src/repro/kernels/ref.py"
        pq_src, ref_src = read(pq_rel), read(ref_rel)
        fb_srcs = {"repro.core.pq": read("src/repro/core/pq.py")}
        findings.extend(check_registry(pq_src, ref_src, kernel_path=pq_rel,
                                       fallback_srcs=fb_srcs))

    for d in DURABILITY_DIRS:
        for p in sorted((root / d).glob("*.py")):
            rel = p.relative_to(root).as_posix()
            if not in_scope(rel):
                continue
            findings.extend(check_durability_source(
                read(rel), rel, ingest="ingest" in d))

    # CH401/CH402: failpoint-call vs chaos registry, kill-harness coverage
    from repro.analysis import chaos_checks
    ch_findings, ch_sources = chaos_checks.run_chaos_checks(root, files=files)
    findings.extend(ch_findings)
    for rel, text in ch_sources.items():
        sources.setdefault(rel, text)
    return findings, sources
