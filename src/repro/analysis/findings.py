"""Structured findings for the static invariant checker (DESIGN.md §14).

A :class:`Finding` is one rule violation at one site: ``(rule, path, line,
severity, message)`` plus the flagged source line (``snippet``), which the
baseline layer fingerprints so accepted findings survive unrelated line
shifts.  Jaxpr-level findings anchor to the traced entry point instead of a
source line (``path`` is the module of the entry point, ``line`` 0, and the
``snippet`` is the entry-point label — stable across edits that do not
change the traced program).

Per-site suppressions: a source line (or the dedicated comment line right
above it) may carry

    # repro-lint: allow[RULE_ID] <mandatory justification>

which drops findings of that rule on that line.  A suppression with no
justification text is itself a finding (``SUP001``) — silencing a rule
requires saying why, in the diff, where review sees it.
"""
from __future__ import annotations

import dataclasses
import re

SEV_ERROR = "error"
SEV_WARNING = "warning"

# '# repro-lint: allow[DS201] reason...'  (multiple rules comma-separated)
_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*allow\[(?P<rules>[A-Z0-9_,\s]+)\]\s*"
    r"(?P<why>.*?)\s*$")

RULE_SUPPRESSION = "SUP001"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    rule: str          # e.g. "KN101", "JX001"
    path: str          # repo-relative posix path (or module for jaxpr rules)
    line: int          # 1-based source line; 0 = not line-anchored
    severity: str      # SEV_ERROR | SEV_WARNING
    message: str
    snippet: str = ""  # flagged source line / trace label (baseline anchor)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{self.severity}[{self.rule}] {loc}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    rules: tuple[str, ...]
    line: int            # line the suppression comment sits on
    justification: str


def scan_suppressions(source: str) -> list[Suppression]:
    """All ``# repro-lint: allow[...]`` comments in ``source``."""
    out = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        why = m.group("why").strip().lstrip("-—:").strip()
        out.append(Suppression(rules=rules, line=i, justification=why))
    return out


def apply_suppressions(findings: list[Finding], sources: dict[str, str]
                       ) -> tuple[list[Finding], list[Finding]]:
    """Drop findings covered by a suppression comment on the same line or
    the line directly above; return ``(kept, suppressed)``.

    Bare suppressions (no justification) are re-injected as ``SUP001``
    findings, and suppressions cannot silence ``SUP001`` itself.
    """
    by_path: dict[str, list[Suppression]] = {}
    kept: list[Finding] = []
    suppressed: list[Finding] = []
    for path, src in sources.items():
        sups = scan_suppressions(src)
        by_path[path] = sups
        for s in sups:
            if not s.justification:
                kept.append(Finding(
                    rule=RULE_SUPPRESSION, path=path, line=s.line,
                    severity=SEV_ERROR,
                    message="suppression without justification: "
                            f"allow[{','.join(s.rules)}] must say why",
                    snippet=_line_at(src, s.line)))
    for f in findings:
        covering = [s for s in by_path.get(f.path, ())
                    if f.rule in s.rules and f.rule != RULE_SUPPRESSION
                    and s.justification
                    and s.line in (f.line, f.line - 1)]
        (suppressed if covering else kept).append(f)
    return kept, suppressed


def _line_at(source: str, line: int) -> str:
    lines = source.splitlines()
    return lines[line - 1].strip() if 0 < line <= len(lines) else ""


def finding_at(rule: str, path: str, line: int, message: str, source: str,
               severity: str = SEV_ERROR) -> Finding:
    """Build a line-anchored finding, capturing the source line as the
    baseline fingerprint anchor."""
    return Finding(rule=rule, path=path, line=line, severity=severity,
                   message=message, snippet=_line_at(source, line))
