"""Logical-axis sharding engine.

Models annotate every param / activation with a tuple of *logical* axis names
(one per array dim, None for unsharded dims).  ``logical_to_sharding`` turns
those annotations into ``NamedSharding``s under a rules table, with
production-grade fallbacks:

  * mesh axes absent from the mesh are dropped (single-pod vs multi-pod);
  * a dim not divisible by its mesh-axes product drops trailing axes until it
    divides (never fails to lower because a head count is 8 on a 16-way axis);
  * one mesh axis is never assigned twice in the same sharding.

On multi-pod meshes the 'pod' axis is automatically prepended to the 'batch'
(and index/candidates) mappings so DP crosses the DCN axis, unless a rule
already mentions 'pod'.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axes that absorb the 'pod' axis on multi-pod meshes
_POD_ABSORBERS = ("batch", "index_rows", "candidates", "edges", "fsdp")


def effective_rules(rules: Mapping[str, Optional[tuple[str, ...]]],
                    mesh: Mesh) -> dict[str, Optional[tuple[str, ...]]]:
    out: dict[str, Optional[tuple[str, ...]]] = {}
    has_pod = "pod" in mesh.axis_names
    for k, v in rules.items():
        if v is None:
            out[k] = None
            continue
        axes = tuple(a for a in v if a in mesh.axis_names)
        if has_pod and k in _POD_ABSORBERS and "pod" not in axes and axes:
            axes = ("pod",) + axes
        out[k] = axes or None
    return out


def spec_for(logical: Sequence[Optional[str]],
             rules: Mapping[str, Optional[tuple[str, ...]]],
             mesh: Mesh,
             shape: Optional[Sequence[int]] = None) -> P:
    """PartitionSpec for one array given per-dim logical names."""
    used: set[str] = set()
    parts: list[Any] = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for i, name in enumerate(logical):
        if name is None:
            parts.append(None)
            continue
        axes = rules.get(name)
        if not axes:
            parts.append(None)
            continue
        picked: list[str] = []
        prod = 1
        for a in axes:
            if a in used:
                continue
            if shape is not None:
                if shape[i] % (prod * sizes[a]) != 0:
                    continue
            picked.append(a)
            prod *= sizes[a]
        used.update(picked)
        if not picked:
            parts.append(None)
        elif len(picked) == 1:
            parts.append(picked[0])
        else:
            parts.append(tuple(picked))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_to_sharding(logical_tree: Any,
                        rules: Mapping[str, Optional[tuple[str, ...]]],
                        mesh: Mesh,
                        shape_tree: Any = None) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings.

    ``shape_tree`` (same structure, of jax.ShapeDtypeStruct / arrays) enables
    divisibility fallbacks.
    """
    eff = effective_rules(rules, mesh)

    def is_leaf(x):
        return x is None or (isinstance(x, tuple)
                             and all(e is None or isinstance(e, str) for e in x))

    if shape_tree is None:
        return jax.tree.map(
            lambda lg: NamedSharding(mesh, spec_for(lg, eff, mesh) if lg else P()),
            logical_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda lg, arr: NamedSharding(
            mesh, spec_for(lg, eff, mesh, np.shape(arr)) if lg else P()),
        logical_tree, shape_tree, is_leaf=is_leaf)


def constrain(x: jax.Array, logical: Sequence[Optional[str]],
              rules: Mapping[str, Optional[tuple[str, ...]]],
              mesh: Optional[Mesh]) -> jax.Array:
    """with_sharding_constraint under logical names (no-op without mesh)."""
    if mesh is None or len(mesh.devices.ravel()) == 1:
        return x
    eff = effective_rules(rules, mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(logical, eff, mesh, x.shape)))
