"""LOVO serving driver: build the index over synthetic videos, then serve
batched text queries through the full two-stage pipeline.

  PYTHONPATH=src python -m repro.launch.serve --videos 6 --queries 8
  PYTHONPATH=src python -m repro.launch.serve --store-dir /tmp/lovo-store
  PYTHONPATH=src python -m repro.launch.serve --batch-size 8 --max-wait-ms 5
  PYTHONPATH=src python -m repro.launch.serve \
      --plan '{"and": [{"text": "a red square"}, {"time_range": [0, 32]}]}'
  PYTHONPATH=src python -m repro.launch.serve --videos 2 \
      --ingest --ingest-cameras 2 --expect-exactly-once

``--plan`` switches to the complex-query path: the JSON plan tree
(conjunction/negation, time windows, per-video grouping — DESIGN.md §10)
is answered index-only through ``QueryEngine.query_plan``.

``--ingest`` switches to the live path (DESIGN.md §12): synthetic
cameras stream frames into the WAL-backed store through adaptive
key-frame sampling, standing plans (``--standing-plan``, or ground-truth
captions by default) are evaluated at ingest time against only the new
delta rows, and matches emit alerts (``--alerts-out`` for a durable
JSONL sink).  Shutdown drains the alert queue and folds the WAL.  The
full flag reference lives in README.md §"Serving flags".

The ``MicroBatcher`` is the front door: concurrent submissions are grouped
into batches of up to ``--batch-size`` (or whatever arrived within
``--max-wait-ms``) and each batch runs as ONE device batch through
``QueryEngine.query_batch`` — batched tokenize/encode, one batched ANN
search, union-of-frames rerank (DESIGN.md §8).

With ``--store-dir``: the first launch builds (keyframes -> ViT -> k-means
-> IMI) and persists the result as a ``repro.store.VectorStore``; every
later launch REOPENS it — no encoding, no codebook training — and reports
store-open time separately from (and far below) the recorded build time.

Exercises the real serving substrate: index build or store reopen,
MicroBatcher for query batching, HedgedExecutor for straggler mitigation,
and the two-stage batch-native QueryEngine.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np


def build_engine(*, seed: int = 0, n_videos: int = 6, res: int = 96,
                 vit_layers: int = 2, d_model: int = 64,
                 imi_k: int = 8, pq_p: int = 8, pq_m: int = 32,
                 rerank_layers: int = 2, trained_params: dict | None = None,
                 built=None, streaming: bool = False,
                 build_chunk_frames: int = 32):
    """Small-but-real engine (CPU-sized encoders, full pipeline).

    ``built``: a prebuilt ``BuiltIndex`` (e.g. from ``load_built``) skips the
    encode + k-means build entirely — the store-reopen path.
    ``streaming``: build via the bounded-memory chunked path (reservoir
    codebook training + spill-segment encode, DESIGN.md §9) instead of the
    monolithic in-memory build.
    """
    from repro.core import anns
    from repro.core.index_builder import (build_from_videos,
                                          build_from_videos_streaming)
    from repro.core.query import QueryEngine
    from repro.data.synthetic import Tokenizer, make_dataset
    from repro.models import rerank as RR
    from repro.models import text_encoder as TE
    from repro.models import vit as V

    vcfg = V.ViTConfig(n_layers=vit_layers, d_model=d_model,
                       n_heads=max(2, d_model // 32), d_ff=4 * d_model,
                       patch=16, img_res=res, embed_dim=64)
    tcfg = TE.TextConfig(n_layers=vit_layers, d_model=d_model,
                         n_heads=max(2, d_model // 32), d_ff=4 * d_model,
                         vocab=32_000, max_len=16, embed_dim=64)
    rcfg = RR.RerankConfig(n_layers=rerank_layers, d_model=64,
                           n_heads=4, d_ff=128, n_queries=4,
                           img_dim=d_model, txt_dim=d_model,
                           decoder_layers=1)
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    if trained_params is not None:
        vit_p = trained_params["vit"]
        txt_p = trained_params["txt"]
        rer_p = trained_params["rerank"]
    else:
        vit_p = V.init_vit(r1, vcfg)[0]
        txt_p = TE.init_text(r2, tcfg)[0]
        rer_p = RR.init_rerank(r3, rcfg)[0]

    videos = make_dataset(seed, n_videos=n_videos, res=res)
    if built is None:
        if streaming:
            built = build_from_videos_streaming(
                r4, videos, vit_p, vcfg, K=imi_k, P=pq_p, M=pq_m,
                chunk_frames=build_chunk_frames)
        else:
            built = build_from_videos(r4, videos, vit_p, vcfg,
                                      K=imi_k, P=pq_p, M=pq_m)
    engine = QueryEngine(
        built, text_params=txt_p, text_cfg=tcfg, vit_params=vit_p,
        vit_cfg=vcfg, rerank_params=rer_p, rerank_cfg=rcfg,
        search_cfg=anns.SearchConfig(top_a=16, max_cell_size=512, top_k=64),
        tokenizer=Tokenizer(vocab=32_000, max_len=16))
    return engine, videos


def run_ingest(engine, args) -> int:
    """The ``--ingest`` path: cameras -> pipeline -> standing queries ->
    alerts, wired next to the ad-hoc query engine.  Returns an exit code
    (nonzero when ``--expect-exactly-once`` finds duplicates)."""
    import tempfile

    from repro.core.index_builder import encode_keyframes
    from repro.ingest import (CompactionPolicy, CompactionScheduler,
                              IngestService, JsonlSink, MemorySink,
                              StandingQueryRegistry, dedup_by_key,
                              synthetic_camera)
    from repro.store import VectorStore, manifest as storemanifest

    res = engine.vit_cfg.img_res
    cameras, captions = [], []
    for ci in range(args.ingest_cameras):
        cam, caps = synthetic_camera(1000 + ci, n_frames=args.ingest_frames,
                                     res=res)
        cameras.append(cam)
        captions.append(caps)

    store_dir = args.store_dir or tempfile.mkdtemp(prefix="lovo-ingest-")
    if storemanifest.exists(store_dir):
        store = VectorStore.open(store_dir)
    else:
        store = VectorStore.create(store_dir, engine.built)

    def encode_frames(frames):
        return encode_keyframes(engine.vit_params, frames,
                                engine.vit_cfg)[0]

    def encode_texts(texts):
        return engine._encode_texts(texts)[0]

    registry = StandingQueryRegistry(
        encode_texts, patches_per_frame=engine.built.patches_per_frame)
    if args.standing_plan:
        for i, spec in enumerate(args.standing_plan):
            registry.register(f"plan-{i}", spec,
                              threshold=args.alert_threshold)
    else:
        # ground truth: one plan per camera, its first object's caption
        # scoped to that camera (VideoIn doubles as camera-id predicate)
        for ci, caps in enumerate(captions):
            registry.register(
                f"cam{ci}-{caps[0]}",
                {"and": [{"text": caps[0]}, {"videos": [ci]}]},
                threshold=args.alert_threshold, top_k=4)

    sink = JsonlSink(args.alerts_out) if args.alerts_out else MemorySink()
    scheduler = CompactionScheduler(store,
                                    CompactionPolicy(max_segments=2))
    service = IngestService(store, cameras, encode_frames, registry,
                            sink=sink, scheduler=scheduler,
                            frames_per_step=args.ingest_frames_per_step)
    scheduler.start()
    t0 = time.perf_counter()
    service.run(max_steps=args.ingest_steps)
    wall = time.perf_counter() - t0
    scheduler.stop()
    service.close()

    st = service.stats
    lat = sorted(service.latencies)
    p50 = lat[len(lat) // 2] * 1e3 if lat else float("nan")
    print(f"ingested {st.frames_in} frames -> {st.keyframes} key frames "
          f"-> {st.rows} rows across {len(cameras)} cameras "
          f"({st.frames_in / max(wall, 1e-9):.1f} frames/s)")
    print(f"standing queries: {len(registry.subs)} plans, "
          f"{st.evaluations} delta evaluations scanning "
          f"{st.rows_scanned} rows (index holds {store.n}); "
          f"{st.alerts} alerts, append->emit p50 {p50:.1f}ms; "
          f"compactions: {scheduler.compactions}")
    alerts = sink.alerts if isinstance(sink, MemorySink) \
        else JsonlSink.read(args.alerts_out)
    for a in alerts[:10]:
        print(f"  ALERT {a.subscription}: camera {a.camera} frame "
              f"{a.frame} score {a.score:.3f}")
    if len(alerts) > 10:
        print(f"  ... and {len(alerts) - 10} more")
    if args.expect_exactly_once:
        uniq = dedup_by_key(alerts)
        if not alerts:
            print("exactly-once check FAILED: no alerts fired")
            return 1
        if len(uniq) != len(alerts):
            print(f"exactly-once check FAILED: {len(alerts) - len(uniq)} "
                  f"duplicate alert keys")
            return 1
        if st.rows_scanned >= store.n * st.evaluations:
            print("delta-only check FAILED: standing queries scanned as "
                  "many rows as full rescans would")
            return 1
        print(f"exactly-once check passed: {len(alerts)} alerts, all "
              f"unique; delta evaluations scanned {st.rows_scanned} rows "
              f"vs {store.n * st.evaluations} for full rescans")
    return 0


def run_sharded(engine, n_shards: int) -> int:
    """Shard the engine's index across the device mesh and prove the
    distributed fused scan farm against the single-host path (DESIGN.md
    §13): real text queries, bit-compared ids/scores, and the O(k·S)
    interconnect model printed.  Returns a process exit code."""
    import dataclasses as _dc

    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core import anns, distributed as dist

    devs = jax.devices()
    S = min(n_shards, len(devs))
    if S < n_shards:
        print(f"only {len(devs)} device(s); clamping --sharded "
              f"{n_shards} -> {S} (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={n_shards} "
              f"before launch, or pass --sharded-reexec)")
    index = engine.built.index
    # shared-coverage config: the farm's bit-parity contract is against
    # the single-host windowed branch (top_a * max_cell_size >= n)
    top_a = min(32, index.K * index.K)
    cfg = _dc.replace(engine.search_cfg, top_a=top_a,
                      max_cell_size=max(64, -(-index.n // top_a)),
                      top_k=min(engine.search_cfg.top_k, index.n))
    texts = ["a large red square", "a small blue circle",
             "a medium green triangle", "a white bar in the center"]
    qs, _, _ = engine._encode_texts(texts)
    qs = jnp.asarray(qs)
    ref = jax.jit(lambda q: anns.search_batch(index, q, cfg))(qs)

    mesh = Mesh(np.array(devs[:S]), ("shards",))
    t0 = time.perf_counter()
    sidx = dist.shard_put(dist.shard_index(index, S), mesh)
    t_shard = time.perf_counter() - t0
    search = jax.jit(dist.make_sharded_search(mesh, cfg=cfg))
    out = search(sidx, qs)
    ok = all(np.array_equal(np.asarray(ref[k]), np.asarray(out[k]))
             for k in ("ids", "scores", "rows"))
    fetch_k = min(cfg.top_k * max(cfg.rerank_overfetch, 1),
                  cfg.top_a * cfg.max_cell_size)
    # butterfly traffic: log2(S) rounds x fetch_k slots x
    # (f32 score + i32 row + f32 exact + i32 id) per query
    rounds = max(S - 1, 0).bit_length()
    per_q = rounds * fetch_k * 16
    print(f"sharded scan farm: S={S} shards "
          f"({index.n} rows, {t_shard*1e3:.0f}ms to place), "
          f"{len(texts)} text queries")
    print(f"  parity vs single-host fused scan: "
          f"{'BIT-IDENTICAL' if ok else 'MISMATCH'}")
    print(f"  interconnect per query: {per_q} B "
          f"({rounds} butterfly rounds x {fetch_k} slots x 16 B) — "
          f"independent of N; a (Q, N) scatter would ship "
          f"{index.n * 4} B/query")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--videos", type=int, default=6)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--hedge", action="store_true")
    ap.add_argument("--batch-size", type=int, default=4,
                    help="micro-batch size: queries grouped into one device "
                         "batch through QueryEngine.query_batch")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="max time the oldest queued query waits for the "
                         "batch to fill before dispatch")
    ap.add_argument("--store-dir", default=None,
                    help="persist/reopen the index as a VectorStore here; "
                         "a second launch skips the build entirely")
    ap.add_argument("--streaming-build", action="store_true",
                    help="bounded-memory build: reservoir codebook training "
                         "+ chunked encode spilled to store segments "
                         "(DESIGN.md §9); identical codes, flat memory")
    ap.add_argument("--build-chunk", type=int, default=32,
                    help="key frames ViT-encoded per streaming-build chunk "
                         "(the encode-phase memory high-water mark)")
    ap.add_argument("--plan", action="append", default=None,
                    metavar="JSON",
                    help="answer a compound query plan (repeatable) instead "
                         "of the text-query demo; JSON plan-tree syntax, "
                         'e.g. \'{"and": [{"text": "a red square"}, '
                         '{"time_range": [0, 32]}]}\' — see DESIGN.md §10')
    ap.add_argument("--ingest", action="store_true",
                    help="live path: synthetic cameras stream into the "
                         "WAL-backed store, standing plans evaluate at "
                         "ingest time, matches emit alerts (DESIGN.md §12)")
    ap.add_argument("--ingest-cameras", type=int, default=2,
                    help="number of synthetic camera streams")
    ap.add_argument("--ingest-frames", type=int, default=64,
                    help="frames per camera stream")
    ap.add_argument("--ingest-frames-per-step", type=int, default=16,
                    help="frames consumed per camera per ingest step")
    ap.add_argument("--ingest-steps", type=int, default=None,
                    help="max ingest steps (default: until cameras drain)")
    ap.add_argument("--standing-plan", action="append", default=None,
                    metavar="JSON",
                    help="standing plan to register (repeatable; default: "
                         "one ground-truth caption plan per camera)")
    ap.add_argument("--alert-threshold", type=float, default=-1e30,
                    help="per-subscription score threshold (default: fire "
                         "on any top match — untrained demo encoders give "
                         "uncalibrated scores)")
    ap.add_argument("--alerts-out", default=None,
                    help="durable JSONL alert sink path (default: "
                         "in-memory, printed at exit)")
    ap.add_argument("--expect-exactly-once", action="store_true",
                    help="CI gate: exit 1 unless alerts fired, carried no "
                         "duplicate keys, and evaluation stayed delta-only")
    ap.add_argument("--sharded", type=int, default=None, metavar="S",
                    help="shard the index across S devices and prove the "
                         "distributed fused scan bit-identical to the "
                         "single-host path (DESIGN.md §13)")
    ap.add_argument("--optimize", action="store_true",
                    help="with --plan: run plans through the cost-based "
                         "optimizer (catalog bind, canonicalize, pushdown "
                         "vs post-filter by selectivity, probe tightening) "
                         "and a predicate-aware result cache; each plan "
                         "runs twice to demonstrate the cache hit, and "
                         "hit/miss/invalidation counters are printed")
    ap.add_argument("--sharded-reexec", action="store_true",
                    help="with --sharded S: if fewer than S devices exist, "
                         "relaunch this process with XLA_FLAGS forcing S "
                         "simulated host devices")
    ap.add_argument("--request-deadline-ms", type=float, default=None,
                    help="per-request time budget: every submitted query "
                         "carries a Deadline; queries that expire in the "
                         "batcher queue fail fast with DeadlineExceeded "
                         "instead of occupying a device batch "
                         "(DESIGN.md §16.2)")
    ap.add_argument("--chaos", default=None, metavar="JSON",
                    help="install a deterministic failpoint schedule "
                         "(repro.chaos spec JSON, e.g. "
                         '\'{"seed": 0, "rules": [{"site": '
                         '"serving.batcher.dispatch", "action": "raise", '
                         '"hit": 1}]}\'); equivalently set the '
                         "REPRO_CHAOS_SPEC env var — DESIGN.md §16.1")
    args = ap.parse_args()

    if args.chaos:
        import json as _json

        from repro import chaos
        chaos.install(chaos.ChaosSchedule.from_spec(_json.loads(args.chaos)))
        print(f"chaos schedule installed: {args.chaos}")

    if args.sharded and args.sharded_reexec \
            and len(jax.devices()) < args.sharded \
            and os.environ.get("REPRO_SHARDED_REEXEC") != "1":
        import subprocess
        env = dict(os.environ,
                   REPRO_SHARDED_REEXEC="1",
                   XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                              " --xla_force_host_platform_device_count="
                              f"{args.sharded}").strip())
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.launch.serve", *sys.argv[1:]],
            env=env))

    from repro.serving.batcher import HedgedExecutor, MicroBatcher

    built = None
    open_s = None
    if args.store_dir:
        from repro.store import manifest as storemanifest
        if storemanifest.exists(args.store_dir):
            from repro.core.index_builder import load_built
            t0 = time.perf_counter()
            built = load_built(args.store_dir)
            open_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine, videos = build_engine(n_videos=args.videos, built=built,
                                  streaming=args.streaming_build,
                                  build_chunk_frames=args.build_chunk)
    wall = time.perf_counter() - t0

    if built is not None:
        from repro.store import manifest as storemanifest
        meta = storemanifest.read_manifest(args.store_dir).get("meta", {})
        first_build = meta.get("build_seconds")
        vs = f" (first launch built in {first_build:.1f}s)" if first_build \
            else ""
        print(f"store reopened: {engine.built.index.n} vectors from "
              f"{len(engine.built.keyframes)} key frames — "
              f"open {open_s:.2f}s{vs}, no re-encode / no k-means")
    else:
        print(f"index built: {engine.built.index.n} vectors from "
              f"{len(engine.built.keyframes)} key frames "
              f"({wall:.1f}s)")
        if args.store_dir:
            from repro.core.index_builder import save_built
            t0 = time.perf_counter()
            save_built(args.store_dir, engine.built,
                       meta={"build_seconds": wall})
            print(f"store created at {args.store_dir} "
                  f"({time.perf_counter()-t0:.2f}s); next launch reopens it")

    if args.sharded:
        raise SystemExit(run_sharded(engine, args.sharded))

    if args.ingest:
        raise SystemExit(run_ingest(engine, args))

    if args.plan:
        # complex-query path: plans are answered index-only (one batched
        # leaf search with filter pushdown + host merge, DESIGN.md §10).
        # --optimize routes them through the cost-based planner + result
        # cache (DESIGN.md §15) and repeats each plan to show the hit.
        if args.optimize:
            engine.enable_result_cache()
        runs = 2 if args.optimize else 1
        for spec in args.plan:
            for attempt in range(runs):
                t0 = time.perf_counter()
                res = engine.query_plan(spec, top_n=5,
                                        optimize=args.optimize)
                ms = (time.perf_counter() - t0) * 1e3
                if attempt + 1 < runs:
                    print(f"plan {spec}: warmed in {ms:.0f}ms (cold)")
            print(f"plan {spec}")
            for f, s, v, t in zip(res.frames, res.scores, res.videos,
                                  res.times):
                print(f"  video {v} frame {t} (kf row {f}): score {s:.3f}")
            if res.moments is not None:
                for i in range(len(res.moments["video"])):
                    print(f"  moment: video {res.moments['video'][i]} "
                          f"frames [{res.moments['start'][i]}, "
                          f"{res.moments['end'][i]}] "
                          f"({res.moments['n_frames'][i]} key frames, "
                          f"score {res.moments['score'][i]:.3f})")
            print(f"  answered index-only in {ms:.0f}ms")
        if args.optimize:
            cs = engine.cache_stats()
            print(f"result cache: {cs['hits']} hits / {cs['misses']} misses"
                  f" / {cs['invalidations']} invalidations")
        return

    queries = ["a large red square", "a small blue circle",
               "a medium green triangle", "a white bar in the center",
               "a yellow circle on the left", "a black square",
               "a purple triangle", "an orange bar"][: args.queries]

    # batch-native backend: the whole micro-batch is ONE device batch
    def run_texts(texts: list[str]):
        return engine.query_batch(texts, top_n=3)

    backend = run_texts
    if args.hedge:
        backend = HedgedExecutor([run_texts, run_texts])

    batcher = MicroBatcher(backend, batch_size=args.batch_size,
                           max_wait_ms=args.max_wait_ms,
                           default_deadline_ms=args.request_deadline_ms)
    t0 = time.perf_counter()
    futures = [batcher.submit(q) for q in queries]
    failed = 0
    for q, f in zip(queries, futures):
        try:
            r = f.result()
        except Exception as e:             # expired deadline / injected fault
            failed += 1
            print(f"  {q!r}: FAILED ({type(e).__name__}: {e})")
            continue
        print(f"  {q!r}: frames {r.frames.tolist()} "
              f"scores {np.round(r.scores, 3).tolist()} "
              f"timings {{{', '.join(f'{k}: {v*1e3:.0f}ms' for k, v in r.timings.items())}}}")
    wall = time.perf_counter() - t0
    batcher.close()
    extras = ""
    if args.request_deadline_ms is not None:
        extras += (f", deadline={args.request_deadline_ms:.0f}ms "
                   f"({batcher.expired} expired)")
    print(f"served {len(queries) - failed}/{len(queries)} queries "
          f"(batch_size={args.batch_size}, "
          f"max_wait={args.max_wait_ms:.0f}ms{extras}); "
          f"p50 {batcher.latency.quantile(0.5)*1e3:.0f}ms, "
          f"{len(queries)/wall:.1f} QPS")


if __name__ == "__main__":
    main()
