"""Fault-tolerant training runner: checkpoint/restart, elastic re-mesh,
step-level failure containment.

At 1000+ nodes the dominant events are (a) preemption/node loss — handled by
frequent async checkpoints + exact restart (params, opt state, RNG, data
cursor all restored), (b) slow/hung steps — handled by a step deadline that
logs and re-dispatches, (c) topology changes on restart — the checkpoint
format is topology-independent (global arrays), so a job that comes back
with a different device count simply re-shards (``elastic re-mesh``).

This module is hardware-agnostic: failures are injected in tests via the
``failure_hook`` (we cannot kill real TPU hosts in CI), which exercises the
same code paths a real preemption would.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    step_deadline_s: float = 0.0   # 0 = no deadline
    max_retries_per_step: int = 2
    log_every: int = 10


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int
    rng: jax.Array
    data_cursor: int  # how many batches consumed (data determinism)


class StepFailure(RuntimeError):
    pass


class TrainRunner:
    """step_fn(params, opt, batch) -> (params, opt, metrics)."""

    def __init__(self, step_fn: Callable, ckpt: Checkpointer,
                 cfg: RunnerConfig,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.failure_hook = failure_hook
        self.metrics_log: list[dict] = []

    # -- restart logic -------------------------------------------------------
    def restore_or_init(self, init_state: TrainState,
                        shardings: Any = None) -> TrainState:
        like = {"params": init_state.params, "opt": init_state.opt_state,
                "rng": init_state.rng,
                "cursor": np.zeros((), np.int64)}
        tree, step = self.ckpt.restore_latest(like, shardings)
        if tree is None:
            return init_state
        log.info("restored checkpoint at step %d (elastic re-mesh ok)", step)
        return TrainState(params=tree["params"], opt_state=tree["opt"],
                          step=step, rng=tree["rng"],
                          data_cursor=int(tree["cursor"]))

    def _save(self, state: TrainState) -> None:
        tree = {"params": state.params, "opt": state.opt_state,
                "rng": state.rng,
                "cursor": np.asarray(state.data_cursor, np.int64)}
        self.ckpt.save_async(tree, state.step)

    # -- main loop -----------------------------------------------------------
    def run(self, state: TrainState, batches: Iterator[dict]) -> TrainState:
        cfg = self.cfg
        while state.step < cfg.total_steps:
            batch = next(batches)
            t0 = time.perf_counter()
            for attempt in range(cfg.max_retries_per_step + 1):
                try:
                    if self.failure_hook is not None:
                        self.failure_hook(state.step)
                    params, opt, metrics = self.step_fn(
                        state.params, state.opt_state, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.perf_counter() - t0
                    if cfg.step_deadline_s and dt > cfg.step_deadline_s:
                        log.warning("straggler step %d: %.2fs > deadline "
                                    "%.2fs (logged, not retried)",
                                    state.step, dt, cfg.step_deadline_s)
                    break
                except StepFailure as e:
                    log.warning("step %d attempt %d failed: %s",
                                state.step, attempt, e)
                    if attempt == cfg.max_retries_per_step:
                        # persist best-known state before surfacing
                        self.ckpt.wait()
                        self._save(state)
                        self.ckpt.wait()
                        raise
            state = TrainState(params=params, opt_state=opt,
                               step=state.step + 1, rng=state.rng,
                               data_cursor=state.data_cursor + 1)
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = state.step
            m["step_time_s"] = time.perf_counter() - t0
            self.metrics_log.append(m)
            if state.step % cfg.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", state.step,
                         m.get("loss", float("nan")),
                         1e3 * m["step_time_s"])
            if state.step % cfg.checkpoint_every == 0:
                self._save(state)
        self.ckpt.wait()
        self._save(state)
        self.ckpt.wait()
        return state
