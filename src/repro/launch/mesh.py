"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (1-device CPU) platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices actually exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def mesh_axis_names(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def has_pod_axis(mesh) -> bool:
    return "pod" in mesh.axis_names
