"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 50 --scale 0.05 --ckpt-dir /tmp/ckpt

Runs the REAL substrate stack — config -> model -> sharded train step ->
deterministic resumable data pipeline -> fault-tolerant runner with async
checkpoints — on whatever devices exist (a reduced-width model on CPU; the
full config on a real pod: same code, different ``--scale``/mesh).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import numpy as np


def scaled_lm_arch(arch, scale: float):
    """Width/depth-reduced twin for CPU runs (structure preserved)."""
    if scale >= 1.0:
        return arch
    def r(x, lo=1):
        return max(lo, int(round(x * scale)))
    moe = arch.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=max(2, r(moe.n_experts)),
                                  expert_ff=r(moe.expert_ff, 8))
    return dataclasses.replace(
        arch, n_layers=max(2, r(arch.n_layers)),
        d_model=r(arch.d_model, 16) // 8 * 8 or 16,
        n_heads=max(2, r(arch.n_heads)),
        n_kv_heads=max(1, min(arch.n_kv_heads, r(arch.n_heads) // 2 or 1)),
        head_dim=32, d_ff=r(arch.d_ff, 32),
        vocab=min(arch.vocab, 2048), moe=moe, param_dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import get_arch
    from repro.data.pipeline import (DeterministicSource, Prefetcher,
                                     lm_batch_fn)
    from repro.launch.fault_tolerance import (RunnerConfig, TrainRunner,
                                              TrainState)
    from repro.models import transformer as T
    from repro.train.optimizer import AdamConfig, adam_init
    from repro.train.train_loop import make_train_step

    arch = scaled_lm_arch(get_arch(args.arch), args.scale)
    print(f"arch {arch.name}: {arch.n_layers}L d={arch.d_model} "
          f"vocab={arch.vocab} params~{arch.n_params()/1e6:.1f}M")

    rng = jax.random.PRNGKey(args.seed)
    params, _ = T.init_lm(rng, arch)
    adam = AdamConfig(lr=args.lr, total_steps=args.steps, warmup_steps=10)
    opt = adam_init(params, adam)
    loss_fn = lambda p, tokens, labels: T.lm_loss(p, tokens, labels, arch)
    step = jax.jit(make_train_step(loss_fn, adam), donate_argnums=(0, 1))

    src = DeterministicSource(
        lm_batch_fn(arch.vocab, args.accum, args.batch, args.seq), args.seed)
    ckpt = Checkpointer(args.ckpt_dir)
    runner = TrainRunner(step, ckpt, RunnerConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every))
    state = runner.restore_or_init(TrainState(
        params=params, opt_state=opt, step=0, rng=rng, data_cursor=0))
    batches = Prefetcher(src.iterate(state.data_cursor))
    state = runner.run(state, iter(batches))
    losses = [m["loss"] for m in runner.metrics_log]
    print(f"done at step {state.step}: first-loss {losses[0]:.4f} "
          f"last-loss {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
