"""Cell builder: (arch x shape x mesh) -> lowerable step.

For every architecture family this module provides
  * ``input_specs(arch, spec)``  — ShapeDtypeStruct stand-ins for all inputs
    (weak-type-correct, shardable, zero allocation),
  * a pure step function (train / prefill / decode / serve / search ...),
  * in_shardings derived from the logical-axis rules,
  * an analytic MODEL_FLOPS estimate for §Roofline.

``build_cell`` is what dryrun.py and benchmarks/roofline.py consume.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (GNNArch, LMArch, LovoArch, RecArch, ShapeSpec,
                                merged_rules)
from repro.launch import sharding as shardlib
from repro.launch.context import sharding_context
from repro.train.optimizer import AdamConfig, adam_init, state_specs

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch_name: str
    shape_name: str
    fn: Callable            # jit-able step
    inputs: tuple           # ShapeDtypeStruct pytree(s), positional
    in_shardings: tuple
    donate: tuple           # argnums to donate
    model_flops: float
    rules: dict
    notes: str = ""


def _sharding(tree_logical, mesh, rules, shape_tree):
    return shardlib.logical_to_sharding(tree_logical, rules, mesh, shape_tree)


def _rep(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------
def lm_attn_flops(arch: LMArch, batch: int, seq: int, kv_len: int | None = None
                  ) -> float:
    """Useful attention FLOPs per forward: 2 matmuls x 2MNK, causal-halved,
    window-aware per layer (gemma2 alternates local/global)."""
    from repro.models.transformer import window_schedule
    hd = arch.resolved_head_dim
    total = 0.0
    for w in window_schedule(arch):
        if kv_len is None:  # self-attention over seq, causal
            eff = min(int(w), seq) if w > 0 else seq
            total += 2.0 * batch * arch.n_heads * seq * eff * hd
        else:  # decode: one token vs kv_len
            eff = min(int(w), kv_len) if w > 0 else kv_len
            total += 4.0 * batch * arch.n_heads * eff * hd
    return total


def lm_model_flops(arch: LMArch, spec: ShapeSpec) -> float:
    seq = spec.dim("seq_len")
    B = spec.dim("global_batch")
    if spec.kind == "train":
        return 6.0 * arch.n_active_params() * B * seq \
            + 3.0 * lm_attn_flops(arch, B, seq)
    if spec.kind == "prefill":
        return 2.0 * arch.n_active_params() * B * seq \
            + lm_attn_flops(arch, B, seq)
    return 2.0 * arch.n_active_params() * B \
        + lm_attn_flops(arch, B, 1, kv_len=seq)


def effective_accum(spec: ShapeSpec, mesh: Mesh, rules) -> int:
    """grad-accum capped so the microbatch divides the DP width."""
    eff = shardlib.effective_rules(rules, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    width = 1
    for ax in (eff.get("batch") or ()):
        width *= sizes[ax]
    gbatch = spec.dim("global_batch")
    A = spec.grad_accum
    while A > 1 and (gbatch // A) % width != 0:
        A //= 2
    return A


def lm_cell(arch: LMArch, spec: ShapeSpec, mesh: Mesh) -> Cell:
    from repro.models import transformer as T
    rules = merged_rules(arch, spec)
    seq = spec.dim("seq_len")
    gbatch = spec.dim("global_batch")

    param_shapes = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), arch)[0])
    # logical specs come from the structural twin (cheap, concrete)
    _, param_logical = T.init_lm(jax.random.PRNGKey(0),
                                 T.dataclass_small(arch))
    param_shard = _sharding(param_logical, mesh, rules, param_shapes)

    if spec.kind == "train":
        adam = AdamConfig(state_dtype=arch.opt_state_dtype)
        opt_shapes = jax.eval_shape(
            functools.partial(adam_init, cfg=adam), param_shapes)
        opt_logical = state_specs(param_logical, adam)
        opt_shard = _sharding(opt_logical, mesh, rules, opt_shapes)
        eff = shardlib.effective_rules(rules, mesh)
        A = effective_accum(spec, mesh, rules)
        micro = gbatch // A
        batch = {
            "tokens": SDS((A, micro, seq), jnp.int32),
            "labels": SDS((A, micro, seq), jnp.int32),
        }
        bshard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, shardlib.spec_for((None, "batch", None), eff, mesh,
                                        s.shape)), batch)
        from repro.train.train_loop import make_train_step

        def loss_fn(p, tokens, labels):
            return T.lm_loss(p, tokens, labels, arch)

        # attn_unroll doubles as the dry-run probe flag: probes unroll every
        # loop so XLA cost_analysis counts all iterations
        inner = make_train_step(loss_fn, adam, unroll_accum=arch.attn_unroll,
                                grad_shardings=param_shard)

        def step(params, opt, batch):
            with sharding_context(mesh, rules):
                return inner(params, opt, batch)

        flops = lm_model_flops(arch, spec)
        return Cell(arch.name, spec.name, step,
                    (param_shapes, opt_shapes, batch),
                    (param_shard, opt_shard, bshard),
                    donate=(0, 1), model_flops=flops, rules=rules)

    if spec.kind == "prefill":
        tokens = SDS((gbatch, seq), jnp.int32)
        tshard = NamedSharding(mesh, shardlib.spec_for(
            ("batch", None), shardlib.effective_rules(rules, mesh), mesh))

        def step(params, tokens):
            with sharding_context(mesh, rules):
                return T.prefill(params, tokens, arch)

        flops = lm_model_flops(arch, spec)
        return Cell(arch.name, spec.name, step, (param_shapes, tokens),
                    (param_shard, tshard), donate=(),
                    model_flops=flops, rules=rules)

    if spec.kind == "decode":
        cache_shapes = jax.eval_shape(
            functools.partial(T.init_cache, arch, gbatch, seq))
        cache_logical = T.cache_specs(arch)
        cache_shard = _sharding(cache_logical, mesh, rules, cache_shapes)
        toks = SDS((gbatch,), jnp.int32)
        pos = SDS((gbatch,), jnp.int32)
        eff = shardlib.effective_rules(rules, mesh)
        tshard = NamedSharding(mesh, shardlib.spec_for(("batch",), eff, mesh,
                                                       (gbatch,)))

        def step(params, cache, tokens, pos):
            with sharding_context(mesh, rules):
                return T.decode_step(params, cache, tokens, pos, arch)

        flops = lm_model_flops(arch, spec)
        return Cell(arch.name, spec.name, step,
                    (param_shapes, cache_shapes, toks, pos),
                    (param_shard, cache_shard, tshard, tshard),
                    donate=(1,), model_flops=flops, rules=rules,
                    notes=spec.notes)
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# EGNN
# ---------------------------------------------------------------------------
def _egnn_flops(cfg, n_edges: int, n_nodes: int, train: bool) -> float:
    d = cfg.d_hidden
    per_edge = 2 * ((2 * d + 1) * d + d * d) + 2 * (d * d + d)
    per_node = 2 * (2 * d * d + d * d)
    fwd = cfg.n_layers * (n_edges * per_edge + n_nodes * per_node) \
        + 2 * n_nodes * cfg.d_feat * d
    return float(fwd * (3 if train else 1))


def egnn_cell(arch: GNNArch, spec: ShapeSpec, mesh: Mesh) -> Cell:
    from repro.models import egnn as E
    rules = merged_rules(arch, spec)
    eff = shardlib.effective_rules(rules, mesh)
    d_feat = spec.dim("d_feat")
    adam = AdamConfig()

    if spec.kind == "gnn_sampled":
        pn, pe = spec.dim("pad_nodes"), spec.dim("pad_edges")
        G = spec.dim("graphs_per_step")
        cfg = E.EGNNConfig(n_layers=arch.n_layers, d_hidden=arch.d_hidden,
                           d_feat=d_feat, n_classes=spec.dim("n_classes"))
        batch = {
            "node_feats": SDS((G, pn, d_feat), jnp.float32),
            "coords": SDS((G, pn, 3), jnp.float32),
            "edge_index": SDS((G, 2, pe), jnp.int32),
            "edge_mask": SDS((G, pe), jnp.float32),
            "node_mask": SDS((G, pn), jnp.float32),
            "labels": SDS((G, pn), jnp.int32),
            "label_mask": SDS((G, pn), jnp.float32),
        }

        def batched_loss(p, **b):
            losses, aux = jax.vmap(
                lambda mb: E.egnn_node_loss(p, cfg, mb))(b)
            return jnp.mean(losses), jax.tree.map(jnp.mean, aux)
        n_nodes, n_edges, train = pn * G, pe * G, True
    elif spec.kind == "gnn_molecule":
        B = spec.dim("batch")
        n, e = spec.dim("n_nodes"), spec.dim("n_edges")
        N, Epad = B * n, B * e
        cfg = E.EGNNConfig(n_layers=arch.n_layers, d_hidden=arch.d_hidden,
                           d_feat=d_feat, graph_readout=True,
                           shard_edges=True, agg_dtype=arch.agg_dtype)
        batch = {
            "node_feats": SDS((N, d_feat), jnp.float32),
            "coords": SDS((N, 3), jnp.float32),
            "edge_index": SDS((2, Epad), jnp.int32),
            "edge_mask": SDS((Epad,), jnp.float32),
            "node_mask": SDS((N,), jnp.float32),
            "graph_ids": SDS((N,), jnp.int32),
            "targets": SDS((B,), jnp.float32),
        }

        def batched_loss(p, **b):
            return E.egnn_graph_loss(p, cfg, b)
        n_nodes, n_edges, train = N, Epad, True
    else:  # gnn_train full batch
        n, e = spec.dim("n_nodes"), spec.dim("n_edges")
        # pad the edge list to the full mesh width so the 'edges' sharding
        # actually applies (61,859,140 % 256 != 0 would silently replicate
        # every edge tensor — the §Perf log documents this)
        width = int(np.prod(mesh.devices.shape))
        e = -(-e // width) * width
        cfg = E.EGNNConfig(n_layers=arch.n_layers, d_hidden=arch.d_hidden,
                           d_feat=d_feat, n_classes=spec.dim("n_classes"),
                           shard_edges=True, agg_dtype=arch.agg_dtype)
        batch = {
            "node_feats": SDS((n, d_feat), jnp.float32),
            "coords": SDS((n, 3), jnp.float32),
            "edge_index": SDS((2, e), jnp.int32),
            "edge_mask": SDS((e,), jnp.float32),
            "node_mask": SDS((n,), jnp.float32),
            "labels": SDS((n,), jnp.int32),
        }

        def batched_loss(p, **b):
            return E.egnn_node_loss(p, cfg, b)
        n_nodes, n_edges, train = n, e, True

    param_shapes = jax.eval_shape(
        lambda: E.init_egnn(jax.random.PRNGKey(0), cfg)[0])
    _, plog = E.init_egnn(jax.random.PRNGKey(0),
                          dataclasses.replace(cfg, d_feat=8, d_hidden=8))
    pshard = _sharding(plog, mesh, rules, param_shapes)
    opt_shapes = jax.eval_shape(functools.partial(adam_init, cfg=adam),
                                param_shapes)
    oshard = _sharding(state_specs(plog, adam), mesh, rules, opt_shapes)

    def bspec(key, arr):
        nd = len(arr.shape)
        if spec.kind == "gnn_sampled":
            lg = ("batch",) + (None,) * (nd - 1)
        elif key in ("edge_index",):
            lg = (None, "edges")
        elif key in ("edge_mask",):
            lg = ("edges",)
        elif key in ("targets",):
            lg = ("batch",)
        else:
            lg = ("nodes",) + (None,) * (nd - 1)
        return NamedSharding(mesh, shardlib.spec_for(lg, eff, mesh, arr.shape))

    bshard = {k: bspec(k, v) for k, v in batch.items()}

    from repro.train.train_loop import make_train_step
    accum_batch = jax.tree.map(lambda s: SDS((1,) + s.shape, s.dtype), batch)
    accum_bshard = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*((None,) + tuple(s.spec)))),
        bshard)
    inner = make_train_step(lambda p, **mb: batched_loss(p, **mb), adam)

    def step(params, opt, batch):
        with sharding_context(mesh, rules):
            return inner(params, opt, batch)

    flops = _egnn_flops(cfg, n_edges, n_nodes, train)
    return Cell(arch.name, spec.name, step,
                (param_shapes, opt_shapes, accum_batch),
                (pshard, oshard, accum_bshard),
                donate=(0, 1), model_flops=flops, rules=rules)


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------
def _rec_fwd_flops(arch: RecArch, batch: int) -> float:
    d = arch.embed_dim
    if arch.family == "dlrm":
        mlp = sum(a * b for a, b in zip((arch.n_dense,) + arch.bot_mlp[1:-1],
                                        arch.bot_mlp[1:]))
        n_f = arch.n_sparse + 1
        top_in = n_f * (n_f - 1) // 2 + arch.bot_mlp[-1]
        mlp += sum(a * b for a, b in zip((top_in,) + arch.top_mlp[:-1],
                                         arch.top_mlp))
        inter = n_f * n_f * d
        return 2.0 * batch * (mlp + inter)
    if arch.family == "xdeepfm":
        f0 = arch.n_sparse
        h_prev, cin = f0, 0
        for h in arch.cin_layers:
            cin += h_prev * f0 * d + h_prev * f0 * h * d
            h_prev = h
        deep_dims = (f0 * d,) + arch.mlp_layers + (1,)
        deep = sum(a * b for a, b in zip(deep_dims[:-1], deep_dims[1:]))
        return 2.0 * batch * (cin + deep)
    if arch.family == "mind":
        L = arch.seq_len
        route = arch.capsule_iters * 2 * arch.n_interests * L * d
        return 2.0 * batch * (L * d * d + route + 3 * d * d)
    if arch.family == "bert4rec":
        L, db = arch.seq_len, arch.embed_dim
        per_block = 4 * L * db * db + 2 * L * L * db + 8 * L * db * db
        # train uses sampled softmax (40 masked pos x 8193 candidates);
        # serve scores no vocab (hidden state only) — see recsys.bert4rec_loss
        sampled = min(L, 40) * (8192 + 1) * db
        return 2.0 * batch * (arch.n_blocks * per_block + sampled)
    raise ValueError(arch.family)


def _rec_batch_specs(arch: RecArch, B: int) -> dict:
    if arch.family == "dlrm":
        return {"dense": SDS((B, arch.n_dense), jnp.float32),
                "sparse": SDS((B, arch.n_sparse), jnp.int32),
                "labels": SDS((B,), jnp.float32)}
    if arch.family == "xdeepfm":
        return {"sparse": SDS((B, arch.n_sparse), jnp.int32),
                "labels": SDS((B,), jnp.float32)}
    if arch.family == "mind":
        return {"history": SDS((B, arch.seq_len), jnp.int32),
                "hist_mask": SDS((B, arch.seq_len), jnp.float32),
                "target": SDS((B,), jnp.int32)}
    return {"seq": SDS((B, arch.seq_len), jnp.int32),
            "seq_mask": SDS((B, arch.seq_len), jnp.float32),
            "labels": SDS((B, arch.seq_len), jnp.int32),
            "label_mask": SDS((B, arch.seq_len), jnp.float32)}


def _rec_loss(arch: RecArch):
    from repro.models import recsys as R

    if arch.family == "dlrm":
        def loss(p, dense, sparse, labels):
            logit = R.dlrm_forward(p, arch, dense=dense, sparse=sparse)
            l = jnp.mean(jnp.maximum(logit, 0) - logit * labels
                         + jnp.log1p(jnp.exp(-jnp.abs(logit))))
            return l, {"bce": l}
        return loss
    if arch.family == "xdeepfm":
        def loss(p, sparse, labels):
            logit = R.xdeepfm_forward(p, arch, sparse=sparse)
            l = jnp.mean(jnp.maximum(logit, 0) - logit * labels
                         + jnp.log1p(jnp.exp(-jnp.abs(logit))))
            return l, {"bce": l}
        return loss
    if arch.family == "mind":
        return lambda p, **b: R.mind_loss(p, arch, b)
    return lambda p, **b: R.bert4rec_loss(p, arch, b)


def _rec_init(arch: RecArch):
    from repro.models import recsys as R
    return {"dlrm": R.init_dlrm, "xdeepfm": R.init_xdeepfm,
            "mind": R.init_mind, "bert4rec": R.init_bert4rec}[arch.family]


def rec_cell(arch: RecArch, spec: ShapeSpec, mesh: Mesh) -> Cell:
    from repro.models import recsys as R
    rules = merged_rules(arch, spec)
    eff = shardlib.effective_rules(rules, mesh)
    init = _rec_init(arch)
    param_shapes = jax.eval_shape(
        lambda: init(jax.random.PRNGKey(0), arch)[0])
    small = dataclasses.replace(
        arch, vocab_sizes=tuple(min(64, v) for v in arch.vocab_sizes))
    _, plog = init(jax.random.PRNGKey(0), small)
    pshard = _sharding(plog, mesh, rules, param_shapes)

    def bshard_of(batch):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, shardlib.spec_for(
                ("batch",) + (None,) * (len(s.shape) - 1), eff, mesh,
                s.shape)), batch)

    if spec.kind == "rec_train":
        adam = AdamConfig()
        opt_shapes = jax.eval_shape(functools.partial(adam_init, cfg=adam),
                                    param_shapes)
        oshard = _sharding(state_specs(plog, adam), mesh, rules, opt_shapes)
        A = spec.grad_accum
        B = spec.dim("batch") // A
        batch = jax.tree.map(lambda s: SDS((A,) + s.shape, s.dtype),
                             _rec_batch_specs(arch, B))
        inner_shard = bshard_of(_rec_batch_specs(arch, B))
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(*((None,) + tuple(s.spec)))),
            inner_shard)
        from repro.train.train_loop import make_train_step
        inner = make_train_step(_rec_loss(arch), adam)

        def step(params, opt, batch):
            with sharding_context(mesh, rules):
                return inner(params, opt, batch)

        flops = 3.0 * _rec_fwd_flops(arch, spec.dim("batch"))
        return Cell(arch.name, spec.name, step,
                    (param_shapes, opt_shapes, batch),
                    (pshard, oshard, bshard), donate=(0, 1),
                    model_flops=flops, rules=rules)

    if spec.kind == "rec_serve":
        B = spec.dim("batch")
        batch = _rec_batch_specs(arch, B)
        batch.pop("labels", None)
        batch.pop("label_mask", None)
        if arch.family == "mind":
            batch.pop("target", None)
        bshard = bshard_of(batch)
        loss_less = {
            "dlrm": lambda p, dense, sparse: R.dlrm_forward(
                p, arch, dense=dense, sparse=sparse),
            "xdeepfm": lambda p, sparse: R.xdeepfm_forward(
                p, arch, sparse=sparse),
            "mind": lambda p, history, hist_mask, target=None:
                R.mind_interests(p, arch, history=history,
                                 hist_mask=hist_mask),
            "bert4rec": lambda p, seq, seq_mask: R.bert4rec_hidden(
                p, arch, seq=seq, seq_mask=seq_mask)[:, -1],
        }[arch.family]

        def step(params, batch):
            with sharding_context(mesh, rules):
                return loss_less(params, **batch)

        flops = _rec_fwd_flops(arch, B)
        return Cell(arch.name, spec.name, step, (param_shapes, batch),
                    (pshard, bshard), donate=(), model_flops=flops,
                    rules=rules)

    # rec_retrieval: 1 user x n_candidates
    C = spec.dim("n_candidates")
    cand = SDS((C,), jnp.int32)
    cshard = NamedSharding(mesh, shardlib.spec_for(("candidates",), eff,
                                                   mesh, (C,)))
    if arch.family in ("mind", "bert4rec"):
        user = {"history": SDS((1, arch.seq_len), jnp.int32),
                "hist_mask": SDS((1, arch.seq_len), jnp.float32)} \
            if arch.family == "mind" else \
               {"seq": SDS((1, arch.seq_len), jnp.int32),
                "seq_mask": SDS((1, arch.seq_len), jnp.float32)}
        ushard = jax.tree.map(lambda s: _rep(mesh), user)

        def step(params, user, cand_ids):
            with sharding_context(mesh, rules):
                if arch.family == "mind":
                    uv = R.mind_interests(params, arch, **user)[0]
                else:
                    uv = R.bert4rec_hidden(params, arch, **user)[:, -1]
                emb = jnp.take(params["items"], cand_ids, axis=0)
                scores = R.retrieval_scores(uv, emb)
                return jax.lax.top_k(scores, 100)

        flops = 2.0 * C * arch.embed_dim * max(arch.n_interests, 1)
        return Cell(arch.name, spec.name, step, (param_shapes, user, cand),
                    (pshard, ushard, cshard), donate=(),
                    model_flops=flops, rules=rules)

    # ranking models: full forward at C with broadcast user features
    if arch.family == "dlrm":
        user = {"dense": SDS((1, arch.n_dense), jnp.float32),
                "sparse": SDS((1, arch.n_sparse - 1), jnp.int32)}

        def step(params, user, cand_ids):
            with sharding_context(mesh, rules):
                C_ = cand_ids.shape[0]
                dense = jnp.broadcast_to(user["dense"], (C_, arch.n_dense))
                us = jnp.broadcast_to(user["sparse"],
                                      (C_, arch.n_sparse - 1))
                sparse = jnp.concatenate(
                    [us, (cand_ids % arch.vocab_sizes[-1])[:, None]], axis=1)
                scores = R.dlrm_forward(params, arch, dense=dense,
                                        sparse=sparse)
                return jax.lax.top_k(scores, 100)
    else:  # xdeepfm
        user = {"sparse": SDS((1, arch.n_sparse - 1), jnp.int32)}

        def step(params, user, cand_ids):
            with sharding_context(mesh, rules):
                C_ = cand_ids.shape[0]
                us = jnp.broadcast_to(user["sparse"],
                                      (C_, arch.n_sparse - 1))
                sparse = jnp.concatenate(
                    [us, (cand_ids % arch.vocab_sizes[-1])[:, None]], axis=1)
                scores = R.xdeepfm_forward(params, arch, sparse=sparse)
                return jax.lax.top_k(scores, 100)

    ushard = jax.tree.map(lambda s: _rep(mesh), user)
    flops = _rec_fwd_flops(arch, C)
    return Cell(arch.name, spec.name, step, (param_shapes, user, cand),
                (pshard, ushard, cshard), donate=(), model_flops=flops,
                rules=rules)


# ---------------------------------------------------------------------------
# LOVO (the paper's own pipeline)
# ---------------------------------------------------------------------------
def lovo_cell(arch: LovoArch, spec: ShapeSpec, mesh: Mesh) -> Cell:
    from repro.core import distributed as dist
    from repro.models import rerank as RR
    from repro.models import vit as V
    rules = merged_rules(arch, spec)
    eff = shardlib.effective_rules(rules, mesh)
    Dp = arch.embed_dim
    n_dev = int(np.prod(mesh.devices.shape))

    if spec.kind == "lovo_build":
        F = spec.dim("frames")
        vcfg = V.ViTConfig(n_layers=arch.vit_layers, d_model=arch.vit_d_model,
                           n_heads=arch.vit_heads, patch=arch.vit_patch,
                           img_res=arch.img_res, embed_dim=Dp)
        vp = jax.eval_shape(lambda: V.init_vit(jax.random.PRNGKey(0), vcfg)[0])
        small_v = dataclasses.replace(vcfg, d_model=16, d_ff=32, patch=8,
                                      img_res=16, embed_dim=8)
        _, plog = V.init_vit(jax.random.PRNGKey(0), small_v)
        pshard = _sharding(plog, mesh, rules, vp)
        frames = SDS((F, arch.img_res, arch.img_res, 3), jnp.float32)
        fshard = NamedSharding(mesh, shardlib.spec_for(
            ("index_rows", None, None, None), eff, mesh, frames.shape))
        cents = SDS((arch.pq_subspaces, arch.pq_centroids,
                     Dp // arch.pq_subspaces), jnp.float32)

        def step(params, frames, centroids):
            with sharding_context(mesh, rules):
                from repro.core import pq as pqmod
                cls, boxes, _ = V.vit_encode(params, frames, vcfg)
                flat = cls.reshape(-1, Dp)
                codes = pqmod.pq_encode(pqmod.PQ(centroids), flat)
                return codes, boxes

        K = vcfg.n_patches
        vit_flops = 2.0 * F * (
            K * (vcfg.patch ** 2 * 3 * vcfg.d_model)
            + vcfg.n_layers * (4 * K * vcfg.d_model ** 2
                               + 2 * K * K * vcfg.d_model
                               + 2 * K * vcfg.d_model * vcfg.d_ff))
        return Cell(arch.name, spec.name, step, (vp, frames, cents),
                    (pshard, fshard, _rep(mesh)), donate=(),
                    model_flops=vit_flops, rules=rules)

    if spec.kind == "lovo_query":
        N = spec.dim("n_rows")
        Q = spec.dim("queries")
        P_, M = arch.pq_subspaces, arch.pq_centroids
        K = arch.imi_k
        n_local = N // n_dev
        sidx = dist.ShardedIndex(
            codes=SDS((n_dev, n_local, P_), jnp.uint8),
            vectors=SDS((n_dev, n_local, Dp), jnp.bfloat16),
            ids=SDS((n_dev, n_local), jnp.int32),
            cell_of=SDS((n_dev, n_local), jnp.int32),
            cell_offsets=SDS((n_dev, K * K + 1), jnp.int32),
            coarse1=SDS((K, Dp // 2), jnp.float32),
            coarse2=SDS((K, Dp // 2), jnp.float32),
            pq_centroids=SDS((P_, M, Dp // P_), jnp.float32),
            pq_rotation=SDS((Dp, Dp), jnp.float32),
        )
        ishard = dist.index_shardings(mesh)
        qs = SDS((Q, Dp), jnp.float32)
        search = dist.make_sharded_search(
            mesh, top_k=100, mode="cell_probe", top_a=arch.top_a_cells,
            max_cell_size=min(arch.max_cell_size, n_local))

        def step(sidx, qs):
            return search(sidx, qs)

        flops = 2.0 * Q * (N / (K * K) * arch.top_a_cells * P_  # ADC probed
                           + 2 * K * (Dp // 2)                  # cell scores
                           + 100 * Dp)                          # exact rerank
        return Cell(arch.name, spec.name, step, (sidx, qs),
                    (ishard, _rep(mesh)), donate=(), model_flops=flops,
                    rules=rules, notes=spec.notes)

    # lovo_rerank
    C = spec.dim("candidates")
    rcfg = RR.RerankConfig(n_layers=arch.rerank_layers,
                           d_model=arch.rerank_d_model,
                           n_heads=arch.rerank_heads,
                           img_dim=arch.vit_d_model, txt_dim=arch.txt_d_model)
    rp = jax.eval_shape(lambda: RR.init_rerank(jax.random.PRNGKey(0), rcfg)[0])
    _, plog = RR.init_rerank(jax.random.PRNGKey(0), rcfg)
    pshard = _sharding(plog, mesh, rules, rp)
    n_img = (arch.img_res // arch.vit_patch) ** 2
    img = SDS((C, n_img, arch.vit_d_model), jnp.float32)
    txt = SDS((C, arch.txt_seq, arch.txt_d_model), jnp.float32)
    msk = SDS((C, arch.txt_seq), jnp.float32)
    bsh = lambda s: NamedSharding(mesh, shardlib.spec_for(
        ("batch",) + (None,) * (len(s.shape) - 1), eff, mesh, s.shape))

    def step(params, img_tokens, txt_tokens, txt_mask):
        with sharding_context(mesh, rules):
            return RR.rerank_frame(params, img_tokens, txt_tokens, txt_mask,
                                   rcfg)

    d = rcfg.d_model
    per_layer = 2 * (4 * n_img * d * d + 2 * n_img * n_img * d) \
        + 2 * (4 * arch.txt_seq * d * d) \
        + 4 * n_img * arch.txt_seq * d
    flops = 2.0 * C * rcfg.n_layers * per_layer
    return Cell(arch.name, spec.name, step, (rp, img, txt, msk),
                (pshard, bsh(img), bsh(txt), bsh(msk)), donate=(),
                model_flops=flops, rules=rules)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------
def build_cell(arch: Any, spec: ShapeSpec, mesh: Mesh) -> Cell:
    if isinstance(arch, LMArch):
        return lm_cell(arch, spec, mesh)
    if isinstance(arch, GNNArch):
        return egnn_cell(arch, spec, mesh)
    if isinstance(arch, RecArch):
        return rec_cell(arch, spec, mesh)
    if isinstance(arch, LovoArch):
        return lovo_cell(arch, spec, mesh)
    raise TypeError(type(arch))


def input_specs(arch: Any, spec: ShapeSpec, mesh: Mesh) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return build_cell(arch, spec, mesh).inputs
