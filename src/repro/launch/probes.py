"""Loop-aware cost extrapolation for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE,
not x trip-count — so a 126-layer scanned model reports ~1 layer of FLOPs,
and collectives inside the scan appear once in the HLO text.  Unrolling the
real configs (126 layers x 512 partitions) is not compilable in reasonable
time, so we fit a linear cost model from small UNROLLED probes:

  C(L, A) = C(L1, A1)                      # probe baseline
          + (L - L1)/s * [C(L2,A1) - C(L1,A1)]        # per-layer(-pair)
          + (A - A1)   * per_accum(L)                  # per-microstep
  per_accum(L) linear in L from the (L1,A2), (L2,A2) probes.

Probe Ls are (2, 4) for layer-alternating archs (gemma2 local/global period
2) and (1, 2) for uniform stacks.  Probes run with scan_layers=False,
unrolled grad-accum, and unrolled attention chunks, on the SAME mesh and
sharding rules, so collective counts extrapolate too.  Applies to LM cells
only — every other family is already loop-free in its step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.configs.base import LMArch, ShapeSpec
from repro.launch import roofline as RL

METRICS = ("flops", "bytes", "wire", "operand")


def _measure(arch: LMArch, spec: ShapeSpec, mesh) -> dict[str, float]:
    from repro.launch.steps import build_cell
    cell = build_cell(arch, spec, mesh)
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate).lower(
            *cell.inputs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    stats = RL.collective_bytes(compiled.as_text(), int(mesh.devices.size))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": stats.wire_bytes,
            "operand": float(stats.operand_bytes)}


def probe_corrected_costs(arch: LMArch, spec: ShapeSpec, mesh,
                          verbose: bool = True) -> dict[str, float]:
    """Returns corrected per-device {flops, bytes, wire, operand} for the
    real (n_layers, grad_accum)."""
    # L probes at (2, 4): one full local/global period for gemma2, and far
    # enough from degenerate L=1 that XLA's collective strategy is stable.
    L1, L2 = 2, 4
    step = L2 - L1
    Lr = arch.n_layers
    if spec.kind == "train":
        from repro.configs.base import merged_rules
        from repro.launch.steps import effective_accum
        Ar = effective_accum(spec, mesh, merged_rules(arch, spec))
    else:
        Ar = 1

    def probe_arch(L):
        return dataclasses.replace(arch, n_layers=L, scan_layers=False,
                                   attn_unroll=True)

    def probe_spec(A):
        if spec.kind != "train":
            return spec
        return dataclasses.replace(spec, grad_accum=A)

    out: dict[str, float] = {}
    c_l1a1 = _measure(probe_arch(L1), probe_spec(1), mesh)
    c_l2a1 = _measure(probe_arch(L2), probe_spec(1), mesh)
    if Ar > 1:
        c_l1a2 = _measure(probe_arch(L1), probe_spec(2), mesh)
        c_l2a2 = _measure(probe_arch(L2), probe_spec(2), mesh)
    for m in METRICS:
        # negative slopes mean XLA changed strategy between probe sizes;
        # clamp to 0 (conservative: never extrapolate downward)
        per_layer = max((c_l2a1[m] - c_l1a1[m]) / step, 0.0)
        c_at_l_a1 = c_l1a1[m] + (Lr - L1) * per_layer
        if Ar > 1:
            pa1 = c_l1a2[m] - c_l1a1[m]
            pa2 = c_l2a2[m] - c_l2a1[m]
            pa_slope = (pa2 - pa1) / step
            per_accum = max(pa1 + (Lr - L1) * pa_slope, 0.0)
            out[m] = c_at_l_a1 + (Ar - 1) * per_accum
        else:
            out[m] = c_at_l_a1
        out[m] = max(out[m], c_l1a1[m])
    if verbose:
        print(f"  probes (L={L1},{L2}; A<=2 -> L={Lr}, A={Ar}): "
              f"flops/dev {c_l1a1['flops']:.3e} -> {out['flops']:.3e}")
    return out
