import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell, builds the production mesh
((16,16) single-pod / (2,16,16) multi-pod), lowers + compiles the step with
the cell's shardings against ShapeDtypeStruct inputs (no allocation), prints
``memory_analysis`` / ``cost_analysis``, derives the §Roofline terms, and
writes a JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path) -> dict:
    import jax
    from repro.configs.base import get_arch
    from repro.launch import roofline as RL
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    arch = get_arch(arch_name)
    spec = next(s for s in arch.shapes if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(map(str, mesh.devices.shape))
    chips = int(mesh.devices.size)

    t0 = time.time()
    cell = build_cell(arch, spec, mesh)
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rl = RL.analyse(arch_name, shape_name, mesh_name, chips, compiled,
                    cell.model_flops)
    raw = {"flops": rl.hlo_flops, "bytes": rl.hlo_bytes,
           "wire": rl.coll_wire_bytes}
    # LM steps scan over layers/accum; XLA cost_analysis counts scan bodies
    # once -> correct via small unrolled probes (launch/probes.py)
    from repro.configs.base import LMArch
    corrected = None
    if isinstance(arch, LMArch):
        from repro.launch.probes import probe_corrected_costs
        corrected = probe_corrected_costs(arch, spec, mesh)
        rl.hlo_flops = corrected["flops"]
        rl.hlo_bytes = corrected["bytes"]
        rl.coll_wire_bytes = corrected["wire"]
        rl.coll_operand_bytes = corrected["operand"]
    record = rl.row()
    record["raw_scan_counted"] = raw
    record["probe_corrected"] = bool(corrected)
    record.update({
        "ok": True,
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")},
        "cost_analysis": {k: float(v) for k, v in dict(cost).items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "optimal_seconds")},
        "coll_by_op": rl.by_op,
        "coll_wire_bytes": rl.coll_wire_bytes,
        "coll_operand_bytes": rl.coll_operand_bytes,
        "notes": cell.notes,
    })
    print(f"== {arch_name} / {shape_name} / mesh {mesh_name} "
          f"({chips} chips) ==")
    print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print(f"  memory_analysis: "
          + ", ".join(f"{k}={v/1e9:.3f}GB"
                      for k, v in record["memory_analysis"].items()
                      if k.endswith("bytes") and v))
    print(f"  cost_analysis: flops/dev={rl.hlo_flops:.3e} "
          f"bytes/dev={rl.hlo_bytes:.3e}")
    print(f"  collectives: n={rl.collective_count} "
          f"wire_bytes/dev={rl.coll_wire_bytes:.3e} by_op={rl.by_op}")
    print(f"  roofline: compute={RL.fmt_seconds(rl.t_compute)} "
          f"memory={RL.fmt_seconds(rl.t_memory)} "
          f"collective={RL.fmt_seconds(rl.t_collective)} "
          f"-> bottleneck={rl.bottleneck}")
    print(f"  model_flops={rl.model_flops:.3e} "
          f"useful_ratio={rl.useful_flops_ratio:.3f} "
          f"roofline_fraction={rl.roofline_fraction:.3f}")

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch_name}__{shape_name}__{mesh_name}.json"
    (out_dir / tag).write_text(json.dumps(record, indent=1))
    return record


def all_cells() -> list[tuple[str, str]]:
    from repro.configs.base import get_arch
    names = ["gemma2-9b", "llama3-405b", "qwen2-0.5b",
             "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b", "egnn",
             "xdeepfm", "mind", "dlrm-rm2", "bert4rec", "lovo"]
    cells = []
    for n in names:
        for s in get_arch(n).shapes:
            cells.append((n, s.name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.list:
        for a, s in all_cells():
            print(f"{a:24s} {s}")
        return

    if args.all:
        failures = []
        for a, s in all_cells():
            for mp in (False, True):
                mesh_name = "2x16x16" if mp else "16x16"
                tag = out_dir / f"{a}__{s}__{mesh_name}.json"
                if tag.exists() and not args.force:
                    print(f"skip (cached) {a}/{s}/{mesh_name}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", str(out_dir)]
                if mp:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd, capture_output=True, text=True)
                sys.stdout.write(r.stdout)
                if r.returncode != 0:
                    failures.append((a, s, mesh_name))
                    sys.stderr.write(r.stderr[-4000:])
                    (out_dir / f"{a}__{s}__{mesh_name}.json").write_text(
                        json.dumps({"ok": False, "arch": a, "shape": s,
                                    "mesh": mesh_name,
                                    "error": r.stderr[-2000:]}, indent=1))
        print(f"\n{len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        try:
            run_cell(args.arch, args.shape, mp, out_dir)
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
