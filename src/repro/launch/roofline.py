"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
  memory     = HLO_bytes        / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  XLA reports
these for the *per-device* SPMD module, so we multiply by chip count to get
global work, then divide back — i.e. per-device analysis is used directly
against per-chip peaks.

collective_bytes is not in cost_analysis: we parse the post-optimization HLO
text and account every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Two accountings are produced:

  * ``operand`` — plain sum of collective operand sizes (the spec definition);
  * ``wire``    — ring-algorithm bytes actually serialized per device
                  (all-reduce 2x(g-1)/g, all-gather/reduce-scatter (g-1)/g ...),

and the roofline term uses ``wire`` (documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# -- TPU v5e hardware constants (per chip) ----------------------------------
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.  %x = bf16[8,128]{1,0} all-gather(%y), ... replica_groups={{0,1},{2,3}}
_OP_RE = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9]+\[[0-9,]*\][^ ]*\)?[^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota format
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: int = 0          # spec definition: sum of operand sizes
    wire_bytes: float = 0.0         # ring-model bytes serialized per device
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, nbytes: int, g: int):
        self.count += 1
        if op == "all-reduce":
            operand, wire = nbytes, 2.0 * nbytes * (g - 1) / max(g, 1)
        elif op == "all-gather":
            # result shape is the gathered (full) tensor
            operand, wire = nbytes // max(g, 1), nbytes * (g - 1) / max(g, 1) ** 2 * g
        elif op == "reduce-scatter":
            # result shape is the scattered shard; input was g x larger
            operand, wire = nbytes * g, nbytes * (g - 1)
        elif op == "all-to-all":
            operand, wire = nbytes, nbytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            operand, wire = nbytes, float(nbytes)
        self.operand_bytes += operand
        self.wire_bytes += wire
        d = self.by_op.setdefault(op, [0, 0.0])
        d[0] += 1
        d[1] += wire


def collective_bytes(hlo_text: str, world: int) -> CollectiveStats:
    """Parse post-optimization HLO; account every collective op."""
    stats = CollectiveStats()
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        # async pairs appear as -start/-done: count the start only
        if "-done(" in line:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        g = _group_size(line, world)
        stats.add(op, nbytes, g)
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # per-device
    hlo_bytes: float              # per-device
    coll_wire_bytes: float        # per-device
    coll_operand_bytes: float
    model_flops: float            # 6*N*D (global)
    per_device_peak_bytes: int    # memory_analysis temp+args
    collective_count: int = 0
    by_op: dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-roofline-bound step time that is useful
        compute: t_useful_compute / max(terms).  1.0 == at the roofline."""
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.hlo_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.per_device_peak_bytes,
            "collectives": self.collective_count,
        }


def analyse(arch: str, shape: str, mesh_name: str, chips: int,
            compiled, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    stats = collective_bytes(hlo, chips)
    mem = compiled.memory_analysis()
    peak = 0
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            peak += int(getattr(mem, attr, 0) or 0)
        # arguments+outputs alias for donated params; temp is the adder
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=nbytes,
                    coll_wire_bytes=stats.wire_bytes,
                    coll_operand_bytes=stats.operand_bytes,
                    model_flops=model_flops,
                    per_device_peak_bytes=peak,
                    collective_count=stats.count,
                    by_op=dict(stats.by_op))


def fmt_seconds(t: float) -> str:
    if t == 0:
        return "0"
    if t < 1e-3:
        return f"{t*1e6:.1f}us"
    if t < 1:
        return f"{t*1e3:.2f}ms"
    return f"{t:.3f}s"
