"""Ambient sharding context.

Model code calls ``shard(x, logical_dims)`` on key activations; when a mesh +
rules context is active (set by the step builders / dryrun driver) this turns
into ``with_sharding_constraint`` — otherwise it is a no-op, so the same model
code runs in single-device tests and 512-chip lowering unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Optional, Sequence

import jax

_STATE = threading.local()


def _get() -> tuple[Optional[Any], Optional[Mapping]]:
    return getattr(_STATE, "mesh", None), getattr(_STATE, "rules", None)


@contextlib.contextmanager
def sharding_context(mesh, rules: Mapping[str, Optional[tuple[str, ...]]]):
    prev = _get()
    _STATE.mesh, _STATE.rules = mesh, rules
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def shard(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    mesh, rules = _get()
    if mesh is None or rules is None:
        return x
    from repro.launch.sharding import constrain
    return constrain(x, logical, rules, mesh)


def active_mesh():
    return _get()[0]
