"""Pallas TPU kernel: FlashAttention forward (blockwise online softmax).

Used by the LOVO cross-modality rerank (cross-attention over 576 image x 64
text tokens per candidate) and by LM serve paths.  O(S) memory: the (S, T)
score matrix never exists; each (block_q, block_k) tile lives in VMEM with
running (max, sum, acc) statistics carried across the k-block grid axis.

Grid: (batch*heads, n_q_blocks, n_k_blocks), k innermost; out/acc blocks are
revisited across the k axis (standard Pallas TPU flash pattern with
VMEM scratch accumulators).  Supports causal and full (cross) attention and
a gemma-style logit softcap.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, softcap: float,
            block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bQ, d)
    k = k_ref[0].astype(jnp.float32)                  # (bK, d)
    v = v_ref[0].astype(jnp.float32)                  # (bK, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    # mask: kv padding + causality (global indices)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # (bQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        out_ref[0] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, S, d); k, v: (B, H, T, d) -> (B, H, S, d).

    GQA callers repeat k/v heads before the call (wrapper in ops.py).
    """
    B, H, S, d = q.shape
    T = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    bq, bk = min(block_q, S), min(block_k, T)
    pad_q, pad_k = (-S) % bq, (-T) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Tk = S + pad_q, T + pad_k
    qf = q.reshape(B * H, Sq, d)
    kf = k.reshape(B * H, Tk, d)
    vf = v.reshape(B * H, Tk, d)
    grid = (B * H, Sq // bq, Tk // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          softcap=softcap, block_q=bq, block_k=bk, kv_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d)[:, :, :S]
