"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_scan_ref(luts: jax.Array, codes: jax.Array) -> jax.Array:
    """luts: (Q, P, M), codes: (N, P) -> (Q, N).

    scores[q, n] = sum_p luts[q, p, codes[n, p]]  (take_along_axis gather)."""
    c = codes.astype(jnp.int32)                    # (N, P)

    def one(lut):                                  # (P, M)
        per = jax.vmap(lambda l, idx: l[idx], in_axes=(0, 1))(lut, c)  # (P, N)
        return jnp.sum(per, axis=0)
    return jax.vmap(one)(luts)


def pq_scan_masked_ref(luts: jax.Array, codes: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """luts: (Q, P, M), codes: (N, P), mask: (Q, N) nonzero=valid -> (Q, N).

    Same contraction as ``pq_scan_ref`` with the planner's filter-pushdown
    sentinel: masked-out rows are exactly ``-inf`` so they cannot survive a
    downstream top-k (all-filtered rows stay -inf, never NaN)."""
    return jnp.where(mask != 0, pq_scan_ref(luts, codes), -jnp.inf)


def pq_scan_topk_ref(luts: jax.Array, codes: jax.Array, k: int,
                     bias: jax.Array | None = None,
                     mask: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Materialize-then-select oracle for every fused ``pq_scan_topk_*``
    kernel: full (Q, N) scores (+ optional per-row ``bias`` (N,) or per-
    (query, row) bias (Q, N); optional ``mask`` (Q, N) nonzero=selectable),
    then ``lax.top_k`` — which fixes the tie rule the kernels must
    reproduce: equal scores select the lower index first.  Slots whose
    score is ``-inf`` (masked out, or fewer than k rows) read index ``-1``.

    ``codes`` may be (N, P) shared (indices are row ids) or (Q, N, P)
    per-query (indices are candidate positions).
    """
    if codes.ndim == 3:
        scores = jax.vmap(pq_scan_ref)(
            jnp.expand_dims(luts, 1), jnp.asarray(codes))[:, 0]
    else:
        scores = pq_scan_ref(luts, codes)
    if bias is not None:
        b = jnp.asarray(bias, jnp.float32)
        scores = scores + (b[None, :] if b.ndim == 1 else b)
    if mask is not None:
        scores = jnp.where(jnp.asarray(mask) != 0, scores, -jnp.inf)
    if k > scores.shape[1]:                       # k > rows: pad dead slots
        scores = jnp.pad(scores, ((0, 0), (0, k - scores.shape[1])),
                         constant_values=-jnp.inf)
    top, idx = jax.lax.top_k(scores, k)
    return top, jnp.where(jnp.isfinite(top), idx, -1)


def pq_scan_topk_windowed_ref(luts: jax.Array, codes: jax.Array,
                              starts: jax.Array, counts: jax.Array,
                              bases: jax.Array, k: int,
                              mask: jax.Array | None = None
                              ) -> tuple[jax.Array, jax.Array]:
    """Oracle for ``pq_scan_topk_windowed[_masked]``: expands the (Q, A)
    IMI window descriptors to a dense per-(query, row) bias + validity
    mask, then defers to ``pq_scan_topk_ref``."""
    N = codes.shape[0]
    rid = jnp.arange(N, dtype=jnp.int32)[None, None, :]        # (1, 1, N)
    starts = jnp.asarray(starts, jnp.int32)[..., None]         # (Q, A, 1)
    counts = jnp.asarray(counts, jnp.int32)[..., None]
    inw = (rid >= starts) & (rid < starts + counts)            # (Q, A, N)
    bias = jnp.sum(jnp.where(
        inw, jnp.asarray(bases, jnp.float32)[..., None], 0.0), axis=1)
    valid = jnp.any(inw, axis=1)
    if mask is not None:
        valid &= jnp.asarray(mask) != 0
    return pq_scan_topk_ref(luts, codes, k, bias=bias,
                            mask=valid.astype(jnp.uint8))


def kmeans_assign_ref(x: jax.Array, cents: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Full (N, M) distance matrix, then argmin (the memory-heavy baseline
    the fused kernel avoids).  Distances clamped to >= 0 like the kernel."""
    x = x.astype(jnp.float32)
    c = cents.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return (jnp.argmin(d2, axis=-1).astype(jnp.int32),
            jnp.maximum(jnp.min(d2, axis=-1), 0.0))


def kmeans_assign_batched_ref(x: jax.Array, cents: jax.Array
                              ) -> tuple[jax.Array, jax.Array]:
    """(B, N, m) x (B, M, m) -> ((B, N), (B, N)): vmapped single-problem ref."""
    return jax.vmap(kmeans_assign_ref)(x, cents)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = False, softcap: float = 0.0
                        ) -> jax.Array:
    """Dense softmax attention.  q: (B,H,S,d); k,v: (B,H,T,d)."""
    d = q.shape[-1]
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v).astype(q.dtype)
