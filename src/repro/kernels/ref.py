"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_scan_ref(luts: jax.Array, codes: jax.Array) -> jax.Array:
    """luts: (Q, P, M), codes: (N, P) -> (Q, N).

    scores[q, n] = sum_p luts[q, p, codes[n, p]]  (take_along_axis gather)."""
    c = codes.astype(jnp.int32)                    # (N, P)

    def one(lut):                                  # (P, M)
        per = jax.vmap(lambda l, idx: l[idx], in_axes=(0, 1))(lut, c)  # (P, N)
        return jnp.sum(per, axis=0)
    return jax.vmap(one)(luts)


def pq_scan_masked_ref(luts: jax.Array, codes: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """luts: (Q, P, M), codes: (N, P), mask: (Q, N) nonzero=valid -> (Q, N).

    Same contraction as ``pq_scan_ref`` with the planner's filter-pushdown
    sentinel: masked-out rows are exactly ``-inf`` so they cannot survive a
    downstream top-k (all-filtered rows stay -inf, never NaN)."""
    return jnp.where(mask != 0, pq_scan_ref(luts, codes), -jnp.inf)


def kmeans_assign_ref(x: jax.Array, cents: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Full (N, M) distance matrix, then argmin (the memory-heavy baseline
    the fused kernel avoids).  Distances clamped to >= 0 like the kernel."""
    x = x.astype(jnp.float32)
    c = cents.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return (jnp.argmin(d2, axis=-1).astype(jnp.int32),
            jnp.maximum(jnp.min(d2, axis=-1), 0.0))


def kmeans_assign_batched_ref(x: jax.Array, cents: jax.Array
                              ) -> tuple[jax.Array, jax.Array]:
    """(B, N, m) x (B, M, m) -> ((B, N), (B, N)): vmapped single-problem ref."""
    return jax.vmap(kmeans_assign_ref)(x, cents)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = False, softcap: float = 0.0
                        ) -> jax.Array:
    """Dense softmax attention.  q: (B,H,S,d); k,v: (B,H,T,d)."""
    d = q.shape[-1]
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v).astype(q.dtype)
