"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to True (this container is CPU; interpret mode runs
the kernel bodies in Python for correctness).  On real TPU set
``repro.kernels.ops.INTERPRET = False`` (or env REPRO_PALLAS_COMPILE=1) and
the same call sites compile to Mosaic.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kmeans as _km
from repro.kernels import pq_scan as _pq

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def pq_scan(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Single-query ADC: lut (P, M), codes (N, P) -> (N,)."""
    return _pq.pq_scan_batched(lut[None], codes, interpret=INTERPRET)[0]


def pq_scan_batched(luts: jax.Array, codes: jax.Array, *,
                    block_n: int = 1024) -> jax.Array:
    return _pq.pq_scan_batched(luts, codes, block_n=block_n,
                               interpret=INTERPRET)


def kmeans_assign(x: jax.Array, cents: jax.Array):
    return _km.kmeans_assign(x, cents, interpret=INTERPRET)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, softcap: float = 0.0) -> jax.Array:
    """(B, H, S, d) x (B, KV, T, d): repeats KV heads for GQA callers."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _fa.flash_attention(q, k, v, causal=causal, softcap=softcap,
                               interpret=INTERPRET)
