"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to ``None`` = auto: compiled Mosaic when the jax
backend is TPU, interpret mode (kernel bodies run in Python/jax ops for
correctness) on CPU/GPU containers like this one.  Override globally by
setting ``repro.kernels.ops.INTERPRET`` to an explicit bool, or with the
env var ``REPRO_PALLAS_COMPILE=1`` (forces compiled mode everywhere).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kmeans as _km
from repro.kernels import pq_scan as _pq

# None = auto (TPU -> compile, else interpret); see pq_scan.resolve_interpret.
INTERPRET: bool | None = \
    False if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1" else None


def _interpret() -> bool:
    return _pq.resolve_interpret(INTERPRET)


def pq_scan(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Single-query ADC: lut (P, M), codes (N, P) -> (N,)."""
    return _pq.pq_scan_batched(lut[None], codes, interpret=_interpret())[0]


def pq_scan_batched(luts: jax.Array, codes: jax.Array, *,
                    block_n: int = 1024) -> jax.Array:
    """Shared-codes ADC: luts (Q, P, M), codes (N, P) -> (Q, N)."""
    return _pq.pq_scan_batched(luts, codes, block_n=block_n,
                               interpret=_interpret())


def pq_scan_paired(luts: jax.Array, codes: jax.Array, *,
                   block_n: int = 1024) -> jax.Array:
    """Per-query-candidates ADC: luts (Q, P, M), codes (Q, N, P) -> (Q, N)."""
    return _pq.pq_scan_paired(luts, codes, block_n=block_n,
                              interpret=_interpret())


def pq_scan_batched_masked(luts: jax.Array, codes: jax.Array,
                           mask: jax.Array, *,
                           block_n: int = 1024) -> jax.Array:
    """Masked shared-codes ADC: mask (Q, N) nonzero=valid; filtered rows
    return exactly -inf (sentinel applied inside the kernel)."""
    return _pq.pq_scan_batched_masked(luts, codes, mask, block_n=block_n,
                                      interpret=_interpret())


def pq_scan_paired_masked(luts: jax.Array, codes: jax.Array,
                          mask: jax.Array, *,
                          block_n: int = 1024) -> jax.Array:
    """Masked per-query-candidates ADC: mask (Q, N) nonzero=valid; filtered
    rows return exactly -inf (sentinel applied inside the kernel)."""
    return _pq.pq_scan_paired_masked(luts, codes, mask, block_n=block_n,
                                     interpret=_interpret())


def kmeans_assign(x: jax.Array, cents: jax.Array):
    return _km.kmeans_assign(x, cents, interpret=_interpret())


def kmeans_assign_batched(x: jax.Array, cents: jax.Array):
    """B independent assignment problems: (B, N, m) x (B, M, m)."""
    return _km.kmeans_assign_batched(x, cents, interpret=_interpret())


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, softcap: float = 0.0) -> jax.Array:
    """(B, H, S, d) x (B, KV, T, d): repeats KV heads for GQA callers."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _fa.flash_attention(q, k, v, causal=causal, softcap=softcap,
                               interpret=_interpret())
