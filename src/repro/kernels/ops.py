"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to ``None`` = auto: compiled Mosaic when the jax
backend is TPU, interpret mode (kernel bodies run in Python/jax ops for
correctness) on CPU/GPU containers like this one.  Override globally by
setting ``repro.kernels.ops.INTERPRET`` to an explicit bool.

``resolve_use_kernel`` is the companion dispatch for the query path's
``SearchConfig.use_kernel='auto'``: callers get these Pallas kernels
wherever they compile (TPU), and the blocked-jnp formulations elsewhere.
``REPRO_PALLAS_COMPILE=1`` forces the Pallas route even off-TPU — the
kernels then run under the interpreter (forced-compile *parity* mode, the
CI leg that exercises the exact kernel code a TPU would compile).  The
resolution is read at trace time and cached per jitted config, so set the
env var before the process starts, not mid-run.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kmeans as _km
from repro.kernels import pq_scan as _pq

# None = auto (TPU -> compile, else interpret); see pq_scan.resolve_interpret.
INTERPRET: bool | None = None


def _interpret() -> bool:
    return _pq.resolve_interpret(INTERPRET)


def resolve_use_kernel(kind: str) -> str:
    """'auto' -> 'pallas' on a TPU backend or under REPRO_PALLAS_COMPILE=1
    (interpret parity), else 'jnp'.  'jnp' / 'pallas' pass through."""
    if kind == "auto":
        if jax.default_backend() == "tpu" \
                or os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
            return "pallas"
        return "jnp"
    if kind not in ("jnp", "pallas"):
        raise ValueError(f"use_kernel must be auto|jnp|pallas, got {kind!r}")
    return kind


def pq_scan(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Single-query ADC: lut (P, M), codes (N, P) -> (N,)."""
    return _pq.pq_scan_batched(lut[None], codes, interpret=_interpret())[0]


def pq_scan_batched(luts: jax.Array, codes: jax.Array, *,
                    block_n: int = 1024) -> jax.Array:
    """Shared-codes ADC: luts (Q, P, M), codes (N, P) -> (Q, N)."""
    return _pq.pq_scan_batched(luts, codes, block_n=block_n,
                               interpret=_interpret())


def pq_scan_paired(luts: jax.Array, codes: jax.Array, *,
                   block_n: int = 1024) -> jax.Array:
    """Per-query-candidates ADC: luts (Q, P, M), codes (Q, N, P) -> (Q, N)."""
    return _pq.pq_scan_paired(luts, codes, block_n=block_n,
                              interpret=_interpret())


def pq_scan_batched_masked(luts: jax.Array, codes: jax.Array,
                           mask: jax.Array, *,
                           block_n: int = 1024) -> jax.Array:
    """Masked shared-codes ADC: mask (Q, N) nonzero=valid; filtered rows
    return exactly -inf (sentinel applied inside the kernel)."""
    return _pq.pq_scan_batched_masked(luts, codes, mask, block_n=block_n,
                                      interpret=_interpret())


def pq_scan_paired_masked(luts: jax.Array, codes: jax.Array,
                          mask: jax.Array, *,
                          block_n: int = 1024) -> jax.Array:
    """Masked per-query-candidates ADC: mask (Q, N) nonzero=valid; filtered
    rows return exactly -inf (sentinel applied inside the kernel)."""
    return _pq.pq_scan_paired_masked(luts, codes, mask, block_n=block_n,
                                     interpret=_interpret())


def pq_scan_topk_batched(luts: jax.Array, codes: jax.Array, k: int, *,
                         bias: jax.Array | None = None,
                         block_n: int = 1024
                         ) -> tuple[jax.Array, jax.Array]:
    """Fused shared-codes ADC top-k: (Q, P, M) x (N, P) [+ bias (N,)] ->
    (scores (Q, k), rows (Q, k)); the (Q, N) score matrix never exists in
    HBM (DESIGN.md §11).  Dead slots read (-inf, -1)."""
    return _pq.pq_scan_topk_batched(luts, codes, k, bias=bias,
                                    block_n=block_n, interpret=_interpret())


def pq_scan_topk_batched_masked(luts: jax.Array, codes: jax.Array,
                                mask: jax.Array, k: int, *,
                                bias: jax.Array | None = None,
                                block_n: int = 1024
                                ) -> tuple[jax.Array, jax.Array]:
    """Masked fused shared-codes top-k: mask (Q, N) nonzero=selectable;
    filtered rows can never be selected (sentinel inside the pass)."""
    return _pq.pq_scan_topk_batched_masked(luts, codes, mask, k, bias=bias,
                                           block_n=block_n,
                                           interpret=_interpret())


def pq_scan_topk_windowed(luts: jax.Array, codes: jax.Array,
                          starts: jax.Array, counts: jax.Array,
                          bases: jax.Array, k: int, *,
                          block_n: int = 1024
                          ) -> tuple[jax.Array, jax.Array]:
    """Fused IMI-probe top-k over shared codes: (Q, A) window descriptors
    fold the per-cell base term + window validity into the single pass."""
    return _pq.pq_scan_topk_windowed(luts, codes, starts, counts, bases, k,
                                     block_n=block_n, interpret=_interpret())


def pq_scan_topk_windowed_masked(luts: jax.Array, codes: jax.Array,
                                 starts: jax.Array, counts: jax.Array,
                                 bases: jax.Array, mask: jax.Array, k: int,
                                 *, block_n: int = 1024
                                 ) -> tuple[jax.Array, jax.Array]:
    """``pq_scan_topk_windowed`` with the planner's (Q, N) row bitmap also
    riding the pass (filter pushdown, DESIGN.md §10)."""
    return _pq.pq_scan_topk_windowed_masked(luts, codes, starts, counts,
                                            bases, mask, k, block_n=block_n,
                                            interpret=_interpret())


def pq_scan_topk_paired(luts: jax.Array, codes: jax.Array, k: int, *,
                        bias: jax.Array | None = None,
                        block_n: int = 1024
                        ) -> tuple[jax.Array, jax.Array]:
    """Fused per-query candidate top-k: (Q, P, M) x (Q, N, P) [+ bias
    (Q, N)] -> (scores (Q, k), positions (Q, k)) into each query's
    candidate axis; dead slots (-inf, -1)."""
    return _pq.pq_scan_topk_paired(luts, codes, k, bias=bias,
                                   block_n=block_n, interpret=_interpret())


def pq_scan_topk_paired_masked(luts: jax.Array, codes: jax.Array,
                               mask: jax.Array, k: int, *,
                               bias: jax.Array | None = None,
                               block_n: int = 1024
                               ) -> tuple[jax.Array, jax.Array]:
    """Masked fused per-query candidate top-k: mask (Q, N) folds window
    validity AND the planner's gathered row bitmap into the pass."""
    return _pq.pq_scan_topk_paired_masked(luts, codes, mask, k, bias=bias,
                                          block_n=block_n,
                                          interpret=_interpret())


def topk_merge(scores_a: jax.Array, ids_a: jax.Array,
               scores_b: jax.Array, ids_b: jax.Array, k: int,
               payload_a: tuple = (), payload_b: tuple = ()
               ) -> tuple[jax.Array, ...]:
    """Exact cross-shard merge of two fused-scan top-k lists, per query:
    keyed (score desc, id asc) — the ``lax.top_k`` tie rule all
    ``pq_scan_topk_*`` variants implement — so a tree of these merges over
    per-shard lists is bit-identical to one fused scan over the union of
    rows.  Dead slots keep the ``(-inf, -1)`` contract; ``payload_*``
    tuples of side arrays ride the permutation.  Pure jnp (lax.sort) — the
    merge is O(Q·k·S), never the scan bottleneck."""
    return _pq.topk_merge(scores_a, ids_a, scores_b, ids_b, k,
                          payload_a, payload_b)


def kmeans_assign(x: jax.Array, cents: jax.Array):
    return _km.kmeans_assign(x, cents, interpret=_interpret())


def kmeans_assign_batched(x: jax.Array, cents: jax.Array):
    """B independent assignment problems: (B, N, m) x (B, M, m)."""
    return _km.kmeans_assign_batched(x, cents, interpret=_interpret())


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, softcap: float = 0.0) -> jax.Array:
    """(B, H, S, d) x (B, KV, T, d): repeats KV heads for GQA callers."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _fa.flash_attention(q, k, v, causal=causal, softcap=softcap,
                               interpret=_interpret())
