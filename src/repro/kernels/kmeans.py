"""Pallas TPU kernel: fused k-means assignment (distance + argmin).

Index-build hot loop (LOVO one-time extraction economics): for N points and
M centroids, computes argmin_m ||x_n - c_m||^2 *without materializing the
(N, M) distance matrix in HBM* — each (block_n, M) distance tile lives only
in VMEM, is reduced to (block_n,) argmin + min, and discarded.

||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2; the x.c term is an MXU matmul
(block_n x m) @ (m x M).  ||x||^2 is constant per row for the argmin so it
is skipped entirely — beyond-textbook micro-opt, validated vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cents_ref, c2_ref, assign_ref, dist_ref):
    x = x_ref[...].astype(jnp.float32)                 # (bN, m)
    c = cents_ref[...].astype(jnp.float32)             # (M, m)
    c2 = c2_ref[...]                                   # (1, M)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    partial = c2 - 2.0 * dots                          # (bN, M)
    assign = jnp.argmin(partial, axis=-1).astype(jnp.int32)
    dmin = jnp.min(partial, axis=-1)
    x2 = jnp.sum(x * x, axis=-1)
    assign_ref[...] = assign
    dist_ref[...] = dmin + x2                          # true squared dist


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x: jax.Array, cents: jax.Array, *, block_n: int = 1024,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x: (N, m), cents: (M, m) -> (assignments (N,) int32, sqdist (N,))."""
    N, m = x.shape
    M = cents.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    c2 = jnp.sum(jnp.square(cents.astype(jnp.float32)), axis=-1)[None, :]
    grid = ((N + pad) // bn,)
    assign, dist = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((M, m), lambda i: (0, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N + pad,), jnp.int32),
            jax.ShapeDtypeStruct((N + pad,), jnp.float32),
        ],
        interpret=interpret,
    )(x, cents, c2)
    return assign[:N], dist[:N]
