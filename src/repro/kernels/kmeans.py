"""Pallas TPU kernels: fused k-means assignment (distance + argmin).

Index-build hot loop (LOVO one-time extraction economics): for N points and
M centroids, computes argmin_m ||x_n - c_m||^2 *without materializing the
(N, M) distance matrix in HBM* — each (block_n, M) distance tile lives only
in VMEM, is reduced to (block_n,) argmin + min, and discarded.  This is the
assignment step of every Lloyd iteration in ``repro.core.pq`` (coarse
quantizer, per-subspace residual codebooks, and the expanded-codebook
polish), so the whole index build runs in O(N * m) memory.

||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2; the x.c term is an MXU matmul
(block_n x m) @ (m x M).  ||x||^2 is constant per row for the argmin so it
is skipped entirely — beyond-textbook micro-opt, validated vs ref.py.  The
returned distance is clamped to >= 0: the cancellation form can go slightly
negative in f32, which would poison k-means++ sampling probabilities and
``SegmentedIndex.drift_score`` downstream.

Two entry points:

  * ``kmeans_assign``          — (N, m) points vs (M, m) centroids.
  * ``kmeans_assign_batched``  — (B, N, m) vs (B, M, m): B independent
    problems (one per PQ subspace) in ONE launch, grid (B, N/block_n).
    This is the shape ``repro.core.pq`` trains all P subspace codebooks
    simultaneously with — no vmap-over-pallas_call required.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cents_ref, c2_ref, assign_ref, dist_ref):
    x = x_ref[...].astype(jnp.float32)                 # (bN, m)
    c = cents_ref[...].astype(jnp.float32)             # (M, m)
    c2 = c2_ref[...]                                   # (1, M)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    partial = c2 - 2.0 * dots                          # (bN, M)
    assign = jnp.argmin(partial, axis=-1).astype(jnp.int32)
    dmin = jnp.min(partial, axis=-1)
    x2 = jnp.sum(x * x, axis=-1)
    assign_ref[...] = assign
    # true squared distance, clamped: f32 cancellation can dip below zero
    dist_ref[...] = jnp.maximum(dmin + x2, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x: jax.Array, cents: jax.Array, *, block_n: int = 1024,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x: (N, m), cents: (M, m) -> (assignments (N,) int32, sqdist (N,))."""
    N, m = x.shape
    M = cents.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    c2 = jnp.sum(jnp.square(cents.astype(jnp.float32)), axis=-1)[None, :]
    grid = ((N + pad) // bn,)
    assign, dist = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((M, m), lambda i: (0, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N + pad,), jnp.int32),
            jax.ShapeDtypeStruct((N + pad,), jnp.float32),
        ],
        interpret=interpret,
    )(x, cents, c2)
    return assign[:N], dist[:N]


def _batched_kernel(x_ref, cents_ref, c2_ref, assign_ref, dist_ref):
    x = x_ref[0].astype(jnp.float32)                   # (bN, m)
    c = cents_ref[0].astype(jnp.float32)               # (M, m)
    c2 = c2_ref[...]                                   # (1, M)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    partial = c2 - 2.0 * dots                          # (bN, M)
    assign = jnp.argmin(partial, axis=-1).astype(jnp.int32)
    dmin = jnp.min(partial, axis=-1)
    x2 = jnp.sum(x * x, axis=-1)
    assign_ref[...] = assign[None, :]
    dist_ref[...] = jnp.maximum(dmin + x2, 0.0)[None, :]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_batched(x: jax.Array, cents: jax.Array, *,
                          block_n: int = 1024, interpret: bool = True
                          ) -> tuple[jax.Array, jax.Array]:
    """x: (B, N, m), cents: (B, M, m) -> ((B, N) int32, (B, N) f32).

    Grid is (B, N/block_n), batch-major: problem b's centroid block is
    fetched once and stays VMEM-resident across all of its point blocks.
    """
    B, N, m = x.shape
    M = cents.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    c2 = jnp.sum(jnp.square(cents.astype(jnp.float32)), axis=-1)  # (B, M)
    grid = (B, (N + pad) // bn)
    assign, dist = pl.pallas_call(
        _batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, m), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, M, m), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, M), lambda b, i: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, N + pad), jnp.float32),
        ],
        interpret=interpret,
    )(x, cents, c2)
    return assign[:, :N], dist[:, :N]
