"""Pallas TPU kernels: PQ ADC scan + fused scan->select (LOVO's hot loop).

Two kernel families share this module:

**Plain scans** (materialize the full score matrix):

  * ``pq_scan_batched`` — scores[q, n] = sum_p LUT[q, p, codes[n, p]] for Q
    query LUTs against ONE shared code matrix (N, P).
  * ``pq_scan_paired``  — scores[q, n] = sum_p LUT[q, p, codes[q, n, p]]:
    each query scans its OWN candidate rows (Q, N, P).
  * ``pq_scan_batched_masked`` / ``pq_scan_paired_masked`` — the same scans
    with a per-(query, row) validity mask applied INSIDE the kernel: invalid
    rows come back as exactly ``-inf`` (the similarity sentinel), the
    filter-pushdown contract of the complex-query planner (DESIGN.md §10).

**Fused scan->select** (``pq_scan_topk_*``, DESIGN.md §11): the scan keeps a
per-query running top-L — scores AND row indices — in the VMEM-resident
output carry across the sequential N-grid and emits only ``(Q, L)``.  The
``(Q, N)`` score matrix never exists in HBM: the plain pipeline writes
``4*Q*N`` bytes of scores and immediately re-reads them for ``lax.top_k``
(then a third pass applies the IMI base term and window mask); the fused
pipeline folds the per-cell IMI ``base`` term, window validity, and the
planner's row-mask sentinel into the same single pass over the codes, so
total scan traffic drops from ``(P + 8*Q) * N`` bytes to ``P * N`` + the
mask/bias inputs.

  * ``pq_scan_topk_batched[_masked]``  — shared codes, optional per-row bias
    (the exhaustive-ADC coarse term) and (Q, N) validity mask.
  * ``pq_scan_topk_windowed[_masked]`` — shared codes + per-query IMI probe
    windows ``(starts, counts, bases) (Q, A)``: rows outside every window
    score ``-inf``, rows inside get that cell's base term added — the
    batched Algorithm-1 "windows cover the index" branch in one pass.
  * ``pq_scan_topk_paired[_masked]``   — per-query candidate windows
    (Q, N, P) with optional per-position bias/mask.

All fused variants return ``(scores (Q, k) f32, idx (Q, k) int32)`` sorted
descending with ``lax.top_k`` tie semantics (equal scores -> lower index
first).  Dead slots — fewer than k selectable rows, or every row masked —
carry ``idx == -1`` and ``score == -inf``, never a garbage index.

The in-kernel selection is rank-based (no sort primitive): each block's
scores are merged with the carry by counting, for every candidate, how many
candidates beat it under (score desc, index asc); candidates with rank < L
are scattered to output slot ``rank`` by a one-hot select.  Compare /
reduce / where only, so the same body lowers on Mosaic and interprets
elsewhere.  A threshold test (block max vs carried L-th best) skips the
merge for blocks that cannot contribute — after the carry warms up, most
blocks only pay the scan.

``pq_scan_topk_*_jnp`` are the blocked pure-jnp formulations of the same
fusion (lax.scan over code blocks, ``lax.top_k`` merges): the production
path on hosts without a TPU (``SearchConfig.use_kernel='auto'``), where
streaming block-resident scores beats materializing ``(Q, N)`` in RAM just
as VMEM-residency beats HBM round-trips on TPU.

TPU adaptation (DESIGN.md §3): the GPU/CPU formulation is a random gather
from an L1-resident LUT — TPUs hate scattered gathers, so the contraction is
re-expressed as one-hot matmuls on the MXU:

    onehot(codes[:, p]) (bN x M)  @  LUT[:, p, :]^T (M x Q)  -> (bN x Q)

The one-hot inflates nominal FLOPs by M, but MXU throughput at M=256 makes
each block a dense matmul (f32: the LUT carries the two-level quantizer's
per-cell offset term, and bf16 LUT rounding would move candidates across
the overfetch boundary relative to the jnp oracle).  The paired (one query
per grid cell) contraction instead runs over the combined (p, m) index in
chunks — ``lut (1, c*M) @ onehotT (c*M, bN) -> (1, bN)`` — so the output
spans the full lane dimension and each dot is c*M deep, instead of P
one-wide (bN, M) x (M, 1) matvecs that strand the MXU on a single column.

Grid: (N / block_n,) (batched/windowed) or (Q, N / block_n) (paired); block
shapes MXU-aligned (block_n mult of 128, M=2^k, top-L carry padded to 128).

``interpret=None`` (the default) auto-resolves: compiled Mosaic on a TPU
backend, interpret mode (kernel bodies run as jax ops) everywhere else.
``REPRO_PALLAS_COMPILE=1`` routes ``use_kernel='auto'`` callers onto these
kernels even off-TPU (interpret parity mode — CI runs the kernel code that
would compile on TPU, under the interpreter); an explicit bool overrides.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128          # TPU lane width: top-L carries are padded to this
# rank-merge j-chunk: bounds the peak (Q', chunk, L + block_n) compare
# tensor — at the production shape (Q=8, L=512, bn=1024) a 256-chunk keeps
# it ~12 MB even if Mosaic materializes the mask at 4 B/element, inside a
# 16 MB VMEM core alongside the LUT block
_MERGE_CHUNK = 256


def resolve_interpret(interpret: bool | None) -> bool:
    """None -> False (compiled Mosaic) on a TPU backend, True elsewhere.

    ``REPRO_PALLAS_COMPILE=1`` no longer forces ``interpret=False`` off-TPU
    (Mosaic cannot lower there); it instead makes ``resolve_use_kernel``
    route 'auto' callers to these kernels, which then run under the
    interpreter — the forced-compile *parity* leg.
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# plain scans (materializing): pq_scan_batched / pq_scan_paired (+ masked)
# ---------------------------------------------------------------------------

def _block_scores(lut_ref, codes, *, P: int, M: int) -> jax.Array:
    """Shared-codes ADC block: (Q, P, M) LUT ref + (bN, P) codes -> (bN, Q)."""
    bn = codes.shape[0]
    Q = lut_ref.shape[0]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bn, M), 1)

    def body(p, acc):
        # f32 contraction: with two-level codebooks the LUT carries the
        # per-cell offset term, and bf16 LUT rounding (~1e-3 abs) exceeds
        # the approx-score spacing at the overfetch boundary — candidate
        # sets would diverge from the jnp oracle's
        onehot = (codes[:, p][:, None] == iota_m).astype(jnp.float32)
        lut_p = lut_ref[:, p, :]                       # (Q, M) f32
        return acc + jax.lax.dot_general(
            onehot, lut_p, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bN, Q)

    return jax.lax.fori_loop(0, P, body, jnp.zeros((bn, Q), jnp.float32))


def _pm_chunk(P: int) -> int:
    """Largest divisor of P that is <= 8 (paired-contraction chunk)."""
    for c in range(min(P, 8), 0, -1):
        if P % c == 0:
            return c
    return 1


def _paired_block_scores(lut_ref, codes, *, P: int, M: int) -> jax.Array:
    """Per-query ADC block: (1, P, M) LUT ref + (bN, P) codes -> (1, bN).

    The contraction runs over the combined (p, m) index in chunks of c
    subspaces: ``lut (1, c*M) @ onehotT (c*M, bN) -> (1, bN)``.  The output
    row spans the full lane dimension and each dot is c*M deep — real MXU
    tiles, unlike the former per-subspace (bN, M) x (M, 1) matvecs whose
    1-wide result column stranded the systolic array.
    """
    bn = codes.shape[0]
    c = _pm_chunk(P)
    lut_flat = lut_ref[...].reshape(1, P * M)
    codes_t = codes.T                                  # (P, bN)

    def body(j, acc):
        cc = jax.lax.dynamic_slice(codes_t, (j * c, 0), (c, bn))
        iota_m = jax.lax.broadcasted_iota(jnp.int32, (c, M, bn), 1)
        onehot_t = (cc[:, None, :] == iota_m).astype(jnp.float32) \
            .reshape(c * M, bn)
        lut_c = jax.lax.dynamic_slice(lut_flat, (0, j * c * M), (1, c * M))
        return acc + jax.lax.dot_general(
            lut_c, onehot_t, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (1, bN)

    return jax.lax.fori_loop(0, P // c, body,
                             jnp.zeros((1, bn), jnp.float32))


def _kernel(lut_ref, codes_ref, out_ref, *, P: int, M: int):
    codes = codes_ref[...].astype(jnp.int32)          # (bN, P)
    out_ref[...] = _block_scores(lut_ref, codes, P=P, M=M)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_batched(luts: jax.Array, codes: jax.Array, *,
                    block_n: int = 1024,
                    interpret: bool | None = None) -> jax.Array:
    """luts: (Q, P, M) f32; codes: (N, P) integer -> scores (Q, N) f32."""
    Q, P, M = luts.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    grid = ((N + pad) // bn,)
    out = pl.pallas_call(
        functools.partial(_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, P, M), lambda i: (0, 0, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, Q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((N + pad), Q), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes)
    return out[:N].T                                   # (Q, N)


def _masked_kernel(lut_ref, codes_ref, mask_ref, out_ref, *, P: int, M: int):
    """Shared-codes scan with the validity sentinel fused into the pass:
    out[n, q] = mask[q, n] ? sum_p LUT[q, p, codes[n, p]] : -inf."""
    codes = codes_ref[...].astype(jnp.int32)          # (bN, P)
    acc = _block_scores(lut_ref, codes, P=P, M=M)
    valid = mask_ref[...].astype(jnp.int32).T != 0     # (bN, Q)
    out_ref[...] = jnp.where(valid, acc, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_batched_masked(luts: jax.Array, codes: jax.Array,
                           mask: jax.Array, *, block_n: int = 1024,
                           interpret: bool | None = None) -> jax.Array:
    """Masked shared-codes ADC: luts (Q, P, M) f32, codes (N, P) integer,
    mask (Q, N) — nonzero = valid — -> scores (Q, N) f32 with exactly
    ``-inf`` wherever mask is zero (rows a metadata predicate filtered out;
    see module docstring)."""
    Q, P, M = luts.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    grid = ((N + pad) // bn,)
    out = pl.pallas_call(
        functools.partial(_masked_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, P, M), lambda i: (0, 0, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
            pl.BlockSpec((Q, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bn, Q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((N + pad), Q), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes, mask.astype(jnp.uint8))
    return out[:N].T                                   # (Q, N)


def _paired_kernel(lut_ref, codes_ref, out_ref, *, P: int, M: int):
    codes = codes_ref[0].astype(jnp.int32)            # (bN, P)
    out_ref[...] = _paired_block_scores(lut_ref, codes, P=P, M=M)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_paired(luts: jax.Array, codes: jax.Array, *,
                   block_n: int = 1024,
                   interpret: bool | None = None) -> jax.Array:
    """Per-query candidate scan: luts (Q, P, M) f32, codes (Q, N, P) integer
    -> scores (Q, N) f32 with scores[q] = ADC(luts[q], codes[q]).

    Grid is (Q, N/block_n), q-major: each query's LUT block is fetched once
    and reused across all of that query's code blocks.
    """
    Q, P, M = luts.shape
    N = codes.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
    grid = (Q, (N + pad) // bn)
    out = pl.pallas_call(
        functools.partial(_paired_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, P, M), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, bn, P), lambda q, i: (q, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((Q, N + pad), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes)
    return out[:, :N]                                  # (Q, N)


def _paired_masked_kernel(lut_ref, codes_ref, mask_ref, out_ref, *,
                          P: int, M: int):
    """Per-query candidate scan with the validity sentinel fused in:
    out[q, n] = mask[q, n] ? sum_p LUT[q, p, codes[q, n, p]] : -inf."""
    codes = codes_ref[0].astype(jnp.int32)            # (bN, P)
    acc = _paired_block_scores(lut_ref, codes, P=P, M=M)
    valid = mask_ref[...].astype(jnp.int32) != 0       # (1, bN)
    out_ref[...] = jnp.where(valid, acc, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_paired_masked(luts: jax.Array, codes: jax.Array,
                          mask: jax.Array, *, block_n: int = 1024,
                          interpret: bool | None = None) -> jax.Array:
    """Masked per-query candidate scan: luts (Q, P, M) f32, codes (Q, N, P)
    integer, mask (Q, N) — nonzero = valid — -> scores (Q, N) f32 with
    exactly ``-inf`` wherever mask is zero.  Same grid/residency contract
    as ``pq_scan_paired``; the sentinel is applied inside the kernel so
    filtered rows never reach the top-k (DESIGN.md §10)."""
    Q, P, M = luts.shape
    N = codes.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    grid = (Q, (N + pad) // bn)
    out = pl.pallas_call(
        functools.partial(_paired_masked_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, P, M), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, bn, P), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((Q, N + pad), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes, mask.astype(jnp.uint8))
    return out[:, :N]                                  # (Q, N)


# ---------------------------------------------------------------------------
# fused scan->select: in-kernel running top-L (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _topk_pad(k: int) -> int:
    """Carry width: k rounded up to the lane width (>= 128)."""
    return max(_LANES, -(-k // _LANES) * _LANES)


def _rank_merge(cs: jax.Array, ci: jax.Array, L: int
                ) -> tuple[jax.Array, jax.Array]:
    """Exact top-L of (cs (Q, T) f32, ci (Q, T) int32), sorted descending.

    Total order: score desc, then index asc (``lax.top_k`` ties), then
    concat position asc (distinguishes identical (-inf, -1) dead slots —
    without it, equal pairs would share a rank and collide in the scatter).
    rank[i] = #candidates that beat i, counted in j-chunks so the compare
    matrix never exceeds (Q, chunk, T); candidates with rank < L scatter to
    output slot ``rank`` via a one-hot select.  Compare/reduce/where only —
    no sort primitive — so the body lowers on Mosaic and interprets anywhere.
    """
    Q, T = cs.shape
    c = min(_MERGE_CHUNK, T)
    t_pad = -(-T // c) * c - T
    csp, cip = cs, ci
    if t_pad:
        # padded candidates (score -inf, idx INT32_MAX) never beat anything
        csp = jnp.concatenate(
            [cs, jnp.full((Q, t_pad), -jnp.inf, cs.dtype)], axis=1)
        cip = jnp.concatenate(
            [ci, jnp.full((Q, t_pad), jnp.iinfo(jnp.int32).max, ci.dtype)],
            axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (Q, T), 1)

    def chunk(j, rank):
        s_j = jax.lax.dynamic_slice(csp, (0, j * c), (Q, c))[:, :, None]
        i_j = jax.lax.dynamic_slice(cip, (0, j * c), (Q, c))[:, :, None]
        p_j = (j * c
               + jax.lax.broadcasted_iota(jnp.int32, (Q, c), 1))[:, :, None]
        beats = (s_j > cs[:, None, :]) | (
            (s_j == cs[:, None, :]) & (
                (i_j < ci[:, None, :]) | (
                    (i_j == ci[:, None, :]) & (p_j < pos[:, None, :]))))
        return rank + jnp.sum(beats.astype(jnp.int32), axis=1)

    rank = jax.lax.fori_loop(0, (T + t_pad) // c, chunk,
                             jnp.zeros((Q, T), jnp.int32))
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, 1, L), 2)
    onehot = rank[:, :, None] == slot                  # (Q, T, L)
    new_s = jnp.sum(jnp.where(onehot, cs[:, :, None], 0.0), axis=1)
    new_i = jnp.sum(jnp.where(onehot, ci[:, :, None], 0), axis=1)
    return new_s, new_i


def _topk_carry_update(i, n_blocks, s, rid, s_out, i_out, *, L: int) -> None:
    """Fold one block (s, rid) (Q', bN) into the (Q', L) output carry.

    The output blocks themselves are the carry: their index map is constant
    across the sequential N-grid, so they stay VMEM-resident and are flushed
    to HBM once.  A threshold test (block max vs carried L-th best) skips
    the merge when the block cannot contribute — ties at the threshold lose
    to the carried element's lower row index, so skipping is exact.
    """
    @pl.when(i == 0)
    def _init():
        s_out[...] = jnp.full(s_out.shape, -jnp.inf, jnp.float32)
        i_out[...] = jnp.full(i_out.shape, -1, jnp.int32)

    threshold = s_out[:, L - 1:L]                      # (Q', 1)

    @pl.when(jnp.any(jnp.max(s, axis=1, keepdims=True) > threshold))
    def _merge():
        cs = jnp.concatenate([s_out[...], s], axis=1)
        ci = jnp.concatenate([i_out[...], rid], axis=1)
        new_s, new_i = _rank_merge(cs, ci, L)
        s_out[...] = new_s
        i_out[...] = new_i

    @pl.when(i == n_blocks - 1)
    def _finalize():
        # dead slots (nothing selectable behind them) read as idx -1
        i_out[...] = jnp.where(jnp.isfinite(s_out[...]), i_out[...], -1)


def _window_terms(starts, counts, bases, rid, *, A: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-row IMI window terms from (Q, A) descriptors.

    rid (Q, bN) global row ids -> (base_add (Q, bN) f32, in_window (Q, bN)).
    Windows are disjoint slices of the cell-sorted base, so summing the
    per-window selects is exact.
    """
    Q, bn = rid.shape

    def body(a, carry):
        badd, valid = carry
        st = jax.lax.dynamic_slice(starts, (0, a), (Q, 1))
        ct = jax.lax.dynamic_slice(counts, (0, a), (Q, 1))
        bs = jax.lax.dynamic_slice(bases, (0, a), (Q, 1))
        inw = (rid >= st) & (rid < st + ct)
        return badd + jnp.where(inw, bs, 0.0), valid | inw

    return jax.lax.fori_loop(
        0, A, body,
        (jnp.zeros((Q, bn), jnp.float32), jnp.zeros((Q, bn), jnp.bool_)))


def _topk_batched_kernel(lut_ref, codes_ref, *rest, P: int, M: int, L: int,
                         N: int, has_bias: bool, has_mask: bool):
    """Fused shared-codes scan->select; optional per-row bias + (Q, N) mask."""
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    mask_ref = refs.pop(0) if has_mask else None
    s_out, i_out = refs
    i = pl.program_id(0)
    codes = codes_ref[...].astype(jnp.int32)          # (bN, P)
    bn = codes.shape[0]
    acc = _block_scores(lut_ref, codes, P=P, M=M)      # (bN, Q)
    if has_bias:
        acc = acc + bias_ref[...]                      # (bN, 1) broadcast
    rid = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, acc.shape[1]), 0)
    valid = rid < N
    if has_mask:
        valid &= mask_ref[...].astype(jnp.int32).T != 0
    s = jnp.where(valid, acc, -jnp.inf).T              # (Q, bN)
    _topk_carry_update(i, pl.num_programs(0), s, rid.T, s_out, i_out, L=L)


def _topk_windowed_kernel(lut_ref, codes_ref, starts_ref, counts_ref,
                          bases_ref, *rest, P: int, M: int, L: int, N: int,
                          A: int, has_mask: bool):
    """Fused shared-codes scan->select with the IMI base term + window
    validity folded in from (Q, A) probe descriptors."""
    refs = list(rest)
    mask_ref = refs.pop(0) if has_mask else None
    s_out, i_out = refs
    i = pl.program_id(0)
    codes = codes_ref[...].astype(jnp.int32)          # (bN, P)
    bn = codes.shape[0]
    Q = lut_ref.shape[0]
    acc = _block_scores(lut_ref, codes, P=P, M=M).T    # (Q, bN)
    rid = i * bn + jax.lax.broadcasted_iota(jnp.int32, (Q, bn), 1)
    base_add, valid = _window_terms(starts_ref[...], counts_ref[...],
                                    bases_ref[...].astype(jnp.float32),
                                    rid, A=A)
    valid &= rid < N
    if has_mask:
        valid &= mask_ref[...].astype(jnp.int32) != 0
    s = jnp.where(valid, acc + base_add, -jnp.inf)
    _topk_carry_update(i, pl.num_programs(0), s, rid, s_out, i_out, L=L)


def _topk_paired_kernel(lut_ref, codes_ref, *rest, P: int, M: int, L: int,
                        N: int, has_bias: bool, has_mask: bool):
    """Fused per-query candidate scan->select (grid (Q, N/bN), q-major)."""
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    mask_ref = refs.pop(0) if has_mask else None
    s_out, i_out = refs
    i = pl.program_id(1)
    codes = codes_ref[0].astype(jnp.int32)            # (bN, P)
    bn = codes.shape[0]
    s = _paired_block_scores(lut_ref, codes, P=P, M=M)  # (1, bN)
    if has_bias:
        s = s + bias_ref[...]                          # (1, bN)
    pid = i * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    valid = pid < N
    if has_mask:
        valid &= mask_ref[...].astype(jnp.int32) != 0
    s = jnp.where(valid, s, -jnp.inf)
    _topk_carry_update(i, pl.num_programs(1), s, pid, s_out, i_out, L=L)


def _topk_out(Q: int, L: int, index_map):
    return (
        [pl.BlockSpec((Q, L), index_map), pl.BlockSpec((Q, L), index_map)],
        [jax.ShapeDtypeStruct((Q, L), jnp.float32),
         jax.ShapeDtypeStruct((Q, L), jnp.int32)],
    )


def _pq_scan_topk_batched(luts, codes, k, bias, mask, *, block_n, interpret,
                          windows=None):
    """Shared implementation behind the batched/windowed fused entry points."""
    Q, P, M = luts.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    L = _topk_pad(k)
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        if bias is not None:
            bias = jnp.pad(bias, (0, pad))
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    grid = ((N + pad) // bn,)
    in_specs = [
        pl.BlockSpec((Q, P, M), lambda i: (0, 0, 0)),
        pl.BlockSpec((bn, P), lambda i: (i, 0)),
    ]
    args = [luts.astype(jnp.float32), codes]
    if windows is not None:
        starts, counts, bases = windows
        A = starts.shape[1]
        for w in (starts.astype(jnp.int32), counts.astype(jnp.int32),
                  bases.astype(jnp.float32)):
            in_specs.append(pl.BlockSpec((Q, A), lambda i: (0, 0)))
            args.append(w)
        kern = functools.partial(_topk_windowed_kernel, P=P, M=M, L=L, N=N,
                                 A=A, has_mask=mask is not None)
    else:
        if bias is not None:
            in_specs.append(pl.BlockSpec((bn, 1), lambda i: (i, 0)))
            args.append(bias.astype(jnp.float32)[:, None])
        kern = functools.partial(_topk_batched_kernel, P=P, M=M, L=L, N=N,
                                 has_bias=bias is not None,
                                 has_mask=mask is not None)
    if mask is not None:
        in_specs.append(pl.BlockSpec((Q, bn), lambda i: (0, i)))
        args.append(mask.astype(jnp.uint8))
    out_specs, out_shape = _topk_out(Q, L, lambda i: (0, 0))
    scores, idx = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=resolve_interpret(interpret),
    )(*args)
    return scores[:, :k], idx[:, :k]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_scan_topk_batched(luts: jax.Array, codes: jax.Array, k: int, *,
                         bias: jax.Array | None = None, block_n: int = 1024,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Fused shared-codes ADC top-k: luts (Q, P, M) f32, codes (N, P)
    integer, optional per-row ``bias`` (N,) f32 (the exhaustive-ADC coarse
    term) -> (scores (Q, k) f32, rows (Q, k) int32) sorted descending,
    ``lax.top_k`` tie order, dead slots (score -inf) as row -1.  The (Q, N)
    score matrix never exists in HBM (module docstring / DESIGN.md §11)."""
    return _pq_scan_topk_batched(luts, codes, k, bias, None,
                                 block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_scan_topk_batched_masked(luts: jax.Array, codes: jax.Array,
                                mask: jax.Array, k: int, *,
                                bias: jax.Array | None = None,
                                block_n: int = 1024,
                                interpret: bool | None = None
                                ) -> tuple[jax.Array, jax.Array]:
    """``pq_scan_topk_batched`` with the planner's (Q, N) validity bitmap
    (nonzero = selectable) folded into the same pass: filtered rows can
    never be selected; if fewer than k rows survive, the tail slots read
    (-inf, -1)."""
    return _pq_scan_topk_batched(luts, codes, k, bias, mask,
                                 block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_scan_topk_windowed(luts: jax.Array, codes: jax.Array,
                          starts: jax.Array, counts: jax.Array,
                          bases: jax.Array, k: int, *, block_n: int = 1024,
                          interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Fused IMI-probe scan->select over shared codes: rows inside window a
    of query q (``starts[q, a] <= row < starts[q, a] + counts[q, a]``) score
    ``ADC + bases[q, a]``; rows outside every window score -inf.  One pass:
    scan, base add, window mask, and selection never leave VMEM."""
    return _pq_scan_topk_batched(luts, codes, k, None, None,
                                 block_n=block_n, interpret=interpret,
                                 windows=(starts, counts, bases))


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_scan_topk_windowed_masked(luts: jax.Array, codes: jax.Array,
                                 starts: jax.Array, counts: jax.Array,
                                 bases: jax.Array, mask: jax.Array, k: int,
                                 *, block_n: int = 1024,
                                 interpret: bool | None = None
                                 ) -> tuple[jax.Array, jax.Array]:
    """``pq_scan_topk_windowed`` with the planner's (Q, N) row bitmap also
    folded into the pass (tombstones / metadata pushdown, DESIGN.md §10)."""
    return _pq_scan_topk_batched(luts, codes, k, None, mask,
                                 block_n=block_n, interpret=interpret,
                                 windows=(starts, counts, bases))


def _pq_scan_topk_paired(luts, codes, k, bias, mask, *, block_n, interpret):
    Q, P, M = luts.shape
    N = codes.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    L = _topk_pad(k)
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
        if bias is not None:
            bias = jnp.pad(bias, ((0, 0), (0, pad)))
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    grid = (Q, (N + pad) // bn)
    in_specs = [
        pl.BlockSpec((1, P, M), lambda q, i: (q, 0, 0)),
        pl.BlockSpec((1, bn, P), lambda q, i: (q, i, 0)),
    ]
    args = [luts.astype(jnp.float32), codes]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda q, i: (q, i)))
        args.append(bias.astype(jnp.float32))
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda q, i: (q, i)))
        args.append(mask.astype(jnp.uint8))
    out_specs, out_shape = _topk_out(1, L, lambda q, i: (q, 0))
    out_shape = [jax.ShapeDtypeStruct((Q, L), s.dtype) for s in out_shape]
    kern = functools.partial(_topk_paired_kernel, P=P, M=M, L=L, N=N,
                             has_bias=bias is not None,
                             has_mask=mask is not None)
    scores, idx = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=resolve_interpret(interpret),
    )(*args)
    return scores[:, :k], idx[:, :k]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_scan_topk_paired(luts: jax.Array, codes: jax.Array, k: int, *,
                        bias: jax.Array | None = None, block_n: int = 1024,
                        interpret: bool | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Fused per-query candidate scan->select: luts (Q, P, M) f32, codes
    (Q, N, P) integer, optional per-position ``bias`` (Q, N) f32 (the IMI
    base term broadcast over each probe window) -> (scores (Q, k), pos
    (Q, k) int32) — ``pos`` indexes each query's candidate axis; dead slots
    are (-inf, -1).  Same grid/LUT-residency contract as ``pq_scan_paired``
    but only (Q, k) ever reaches HBM."""
    return _pq_scan_topk_paired(luts, codes, k, bias, None,
                                block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_scan_topk_paired_masked(luts: jax.Array, codes: jax.Array,
                               mask: jax.Array, k: int, *,
                               bias: jax.Array | None = None,
                               block_n: int = 1024,
                               interpret: bool | None = None
                               ) -> tuple[jax.Array, jax.Array]:
    """``pq_scan_topk_paired`` with a (Q, N) per-position validity mask
    (window validity AND the planner's gathered row bitmap) folded into the
    same pass."""
    return _pq_scan_topk_paired(luts, codes, k, bias, mask,
                                block_n=block_n, interpret=interpret)


# ---------------------------------------------------------------------------
# blocked-jnp fused formulations (the 'auto' path off-TPU)
# ---------------------------------------------------------------------------

def _adc_block_jnp(luts: jax.Array, codes: jax.Array) -> jax.Array:
    """(Q, P, M) luts x (bN, P) codes -> (Q, bN) via LUT gather (CPU-fast)."""
    c = codes.astype(jnp.int32)

    def one(lut):
        per = jax.vmap(lambda l, idx: l[idx], in_axes=(0, 1))(lut, c)
        return jnp.sum(per, axis=0)

    return jax.vmap(one)(luts)


def _merge_topk_jnp(run_s, run_i, blk_s, blk_i, L):
    """Carry merge via lax.top_k.  The carry precedes the block and block
    ids ascend across blocks, so top_k's lower-position-first tie rule
    reproduces the global lower-index-first order inductively."""
    cs = jnp.concatenate([run_s, blk_s], axis=1)
    ci = jnp.concatenate([run_i, blk_i], axis=1)
    new_s, sel = jax.lax.top_k(cs, L)
    return new_s, jnp.take_along_axis(ci, sel, axis=1)


def _finalize_topk_jnp(scores, idx):
    return scores, jnp.where(jnp.isfinite(scores), idx, -1)


def _topk_scan_blocks_jnp(Q, N, bn, k, step_scores):
    """Shared lax.scan skeleton: step_scores(i0, blk_ix) -> (Q, bn) scores
    (already biased/masked, padded rows -inf)."""
    n_blocks = -(-N // bn)

    def step(carry, blk_ix):
        run_s, run_i = carry
        i0 = blk_ix * bn
        s = step_scores(i0, blk_ix)
        rid = i0 + jnp.arange(bn, dtype=jnp.int32)[None, :]
        run = _merge_topk_jnp(run_s, run_i, s,
                              jnp.broadcast_to(rid, (Q, bn)), k)
        return run, None

    init = (jnp.full((Q, k), -jnp.inf, jnp.float32),
            jnp.full((Q, k), -1, jnp.int32))
    (scores, idx), _ = jax.lax.scan(step, init,
                                    jnp.arange(n_blocks, dtype=jnp.int32))
    return _finalize_topk_jnp(scores, idx)


@functools.partial(jax.jit, static_argnames=("k", "block_n"))
def pq_scan_topk_jnp(luts: jax.Array, codes: jax.Array, k: int,
                     bias: jax.Array | None = None,
                     mask: jax.Array | None = None, *,
                     block_n: int = 4096) -> tuple[jax.Array, jax.Array]:
    """Blocked jnp fused scan->select over shared codes (contract of
    ``pq_scan_topk_batched[_masked]``): streams (Q, block_n) score blocks
    through a running top-k instead of materializing (Q, N)."""
    Q = luts.shape[0]
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    codes_p = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    blocks = codes_p.reshape(-1, bn, codes.shape[1])
    mask_p = None
    if mask is not None:
        mask_p = jnp.pad(mask.astype(jnp.uint8), ((0, 0), (0, pad))) \
            if pad else mask.astype(jnp.uint8)
    bias_p = None
    if bias is not None:
        bias_p = jnp.pad(bias.astype(jnp.float32), (0, pad)) \
            if pad else bias.astype(jnp.float32)

    def step_scores(i0, blk_ix):
        s = _adc_block_jnp(luts, blocks[blk_ix])
        if bias_p is not None:
            s = s + jax.lax.dynamic_slice(bias_p, (i0,), (bn,))[None, :]
        rid = i0 + jnp.arange(bn, dtype=jnp.int32)[None, :]
        valid = rid < N
        if mask_p is not None:
            valid &= jax.lax.dynamic_slice(
                mask_p, (0, i0), (Q, bn)) != 0
        return jnp.where(valid, s, -jnp.inf)

    return _topk_scan_blocks_jnp(Q, N, bn, k, step_scores)


@functools.partial(jax.jit, static_argnames=("k", "block_n"))
def pq_scan_topk_windowed_jnp(luts: jax.Array, codes: jax.Array,
                              starts: jax.Array, counts: jax.Array,
                              bases: jax.Array, k: int,
                              mask: jax.Array | None = None, *,
                              block_n: int = 4096
                              ) -> tuple[jax.Array, jax.Array]:
    """Blocked jnp fused IMI-probe scan->select (contract of
    ``pq_scan_topk_windowed[_masked]``)."""
    Q = luts.shape[0]
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    codes_p = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    blocks = codes_p.reshape(-1, bn, codes.shape[1])
    mask_p = None
    if mask is not None:
        mask_p = jnp.pad(mask.astype(jnp.uint8), ((0, 0), (0, pad))) \
            if pad else mask.astype(jnp.uint8)
    starts = starts.astype(jnp.int32)
    counts = counts.astype(jnp.int32)
    bases = bases.astype(jnp.float32)

    def step_scores(i0, blk_ix):
        s = _adc_block_jnp(luts, blocks[blk_ix])
        rid = i0 + jnp.arange(bn, dtype=jnp.int32)[None, :]    # (1, bN)
        inw = (rid[:, None, :] >= starts[..., None]) & \
            (rid[:, None, :] < (starts + counts)[..., None])   # (Q, A, bN)
        base_add = jnp.sum(jnp.where(inw, bases[..., None], 0.0), axis=1)
        valid = jnp.any(inw, axis=1) & (rid < N)
        if mask_p is not None:
            valid &= jax.lax.dynamic_slice(mask_p, (0, i0), (Q, bn)) != 0
        return jnp.where(valid, s + base_add, -jnp.inf)

    return _topk_scan_blocks_jnp(Q, N, bn, k, step_scores)


@functools.partial(jax.jit, static_argnames=("k", "block_n"))
def pq_scan_topk_paired_jnp(luts: jax.Array, codes: jax.Array, k: int,
                            bias: jax.Array | None = None,
                            mask: jax.Array | None = None, *,
                            block_n: int = 4096
                            ) -> tuple[jax.Array, jax.Array]:
    """Blocked jnp fused per-query candidate scan->select (contract of
    ``pq_scan_topk_paired[_masked]``)."""
    Q, N, P = codes.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    codes_p = jnp.pad(codes, ((0, 0), (0, pad), (0, 0))) if pad else codes
    mask_p = None
    if mask is not None:
        mask_p = jnp.pad(mask.astype(jnp.uint8), ((0, 0), (0, pad))) \
            if pad else mask.astype(jnp.uint8)
    bias_p = None
    if bias is not None:
        bias_p = jnp.pad(bias.astype(jnp.float32), ((0, 0), (0, pad))) \
            if pad else bias.astype(jnp.float32)

    def step_scores(i0, blk_ix):
        cb = jax.lax.dynamic_slice(codes_p, (0, i0, 0), (Q, bn, P))
        s = jax.vmap(lambda lut, c: _adc_block_jnp(lut[None], c)[0]
                     )(luts, cb)
        if bias_p is not None:
            s = s + jax.lax.dynamic_slice(bias_p, (0, i0), (Q, bn))
        pid = i0 + jnp.arange(bn, dtype=jnp.int32)[None, :]
        valid = pid < N
        if mask_p is not None:
            valid &= jax.lax.dynamic_slice(mask_p, (0, i0), (Q, bn)) != 0
        return jnp.where(valid, s, -jnp.inf)

    return _topk_scan_blocks_jnp(Q, N, bn, k, step_scores)


# ---------------------------------------------------------------------------
# Cross-shard top-k merge (the distributed scan farm's reduction primitive)
# ---------------------------------------------------------------------------
def topk_merge(scores_a: jax.Array, ids_a: jax.Array,
               scores_b: jax.Array, ids_b: jax.Array, k: int,
               payload_a: tuple = (), payload_b: tuple = ()
               ) -> tuple[jax.Array, ...]:
    """Exact merge of two fused-scan top-k lists into one, per query row.

    Inputs are two ``(Q, La)`` / ``(Q, Lb)`` (scores, ids) pairs in the
    fused-scan output contract (descending scores, dead slots exactly
    ``(-inf, -1)``).  The merge is a multi-operand ``lax.sort`` keyed
    lexicographically on ``(score desc, id asc)`` — the global tie rule
    every ``pq_scan_topk_*`` variant implements (``lax.top_k``: equal
    scores break toward the lower index) — so folding per-shard lists
    through this merge reproduces BIT-IDENTICALLY the list a single fused
    scan over the union of rows would have produced, as long as the id
    key is globally unique (global row ids across shards).  Dead slots
    sort last and keep the ``(-inf, -1)`` contract.

    ``payload_a`` / ``payload_b`` are optional tuples of equal-shaped
    side arrays (e.g. exact rerank scores, patch ids) carried through the
    permutation without participating in the key.  Returns
    ``(scores (Q, k), ids (Q, k), *payloads)``.
    """
    if len(payload_a) != len(payload_b):
        raise ValueError("payload_a and payload_b must pair up")
    cs = jnp.concatenate([scores_a.astype(jnp.float32),
                          scores_b.astype(jnp.float32)], axis=1)
    ci = jnp.concatenate([ids_a.astype(jnp.int32),
                          ids_b.astype(jnp.int32)], axis=1)
    # dead slots: -score = +inf sorts last; force the id key to int32 max so
    # a dead slot can never order before a live one under any key mix
    dead = ~jnp.isfinite(cs)
    ckey = jnp.where(dead, jnp.iinfo(jnp.int32).max, ci)
    operands = (-cs, ckey, ci) + tuple(
        jnp.concatenate([a, b], axis=1) for a, b in zip(payload_a, payload_b))
    out = jax.lax.sort(operands, dimension=1, num_keys=2, is_stable=True)
    k = min(k, cs.shape[1])
    s = -out[0][:, :k]
    ids = jnp.where(jnp.isfinite(s), out[2][:, :k], -1)
    return (s, ids) + tuple(p[:, :k] for p in out[3:])
