"""Pallas TPU kernel: PQ ADC scan (the LOVO fast-search hot loop).

Four entry points, each one ``pallas_call``:

  * ``pq_scan_batched`` — scores[q, n] = sum_p LUT[q, p, codes[n, p]] for Q
    query LUTs against ONE shared code matrix (N, P).  Used when every query
    scans the same rows (exhaustive ADC, benchmarks).
  * ``pq_scan_paired``  — scores[q, n] = sum_p LUT[q, p, codes[q, n, p]]:
    each query scans its OWN candidate rows (Q, N, P).  This is the batched
    Algorithm-1 shape: after the IMI probe every query has gathered its own
    (top_a * max_cell_size) candidate window, and the whole batch is scanned
    in a single kernel launch instead of Q separate scans — the LUT block
    stays VMEM-resident across that query's code blocks.
  * ``pq_scan_batched_masked`` / ``pq_scan_paired_masked`` — the same scans
    with a per-(query, row) validity mask applied INSIDE the kernel: invalid
    rows come back as exactly ``-inf`` (the similarity sentinel), so they
    can never survive a downstream top-k.  This is the filter-pushdown
    contract of the complex-query planner (DESIGN.md §10): metadata
    predicates (time range, video-id set, tombstones) become a row bitmap
    that rides the scan, instead of a post-hoc filter that silently shrinks
    the result set below k.  The sentinel write is fused into the scan's
    single pass — no second (Q, N) traversal of the score matrix in HBM.

TPU adaptation (DESIGN.md §3): the GPU/CPU formulation is a random gather
from an L1-resident LUT — TPUs hate scattered gathers, so the contraction is
re-expressed as P one-hot matmuls on the MXU:

    onehot(codes[:, p]) (bN x M)  @  LUT[:, p, :]^T (M x Q)  -> (bN x Q)

The one-hot inflates nominal FLOPs by M, but MXU throughput at M=256 makes
each block a dense matmul (f32: the LUT carries the two-level quantizer's
per-cell offset term, and bf16 LUT rounding would move candidates across
the overfetch boundary relative to the jnp oracle); LUTs (Q*P*M*4 B) and
the code block live in VMEM, codes stream HBM->VMEM once — the scan is
HBM-bandwidth-bound exactly like the CPU version is memory-bound, but at
819 GB/s.

Grid: (N / block_n,) (batched) or (Q, N / block_n) (paired); block shapes
MXU-aligned (block_n mult of 128, M=2^k).

``interpret=None`` (the default) auto-resolves: compiled Mosaic on a TPU
backend, interpret mode (kernel bodies run as jax ops) everywhere else.
Override with the env var ``REPRO_PALLAS_COMPILE=1`` or an explicit bool.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def resolve_interpret(interpret: bool | None) -> bool:
    """None -> False (compile) on TPU / REPRO_PALLAS_COMPILE=1, else True."""
    if interpret is not None:
        return interpret
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


def _kernel(lut_ref, codes_ref, out_ref, *, P: int, M: int):
    codes = codes_ref[...].astype(jnp.int32)          # (bN, P)
    bn = codes.shape[0]
    Q = lut_ref.shape[0]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bn, M), 1)

    def body(p, acc):
        # f32 contraction: with two-level codebooks the LUT carries the
        # per-cell offset term, and bf16 LUT rounding (~1e-3 abs) exceeds
        # the approx-score spacing at the overfetch boundary — candidate
        # sets would diverge from the jnp oracle's
        onehot = (codes[:, p][:, None] == iota_m).astype(jnp.float32)
        lut_p = lut_ref[:, p, :]                       # (Q, M) f32
        return acc + jax.lax.dot_general(
            onehot, lut_p, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bN, Q)

    acc = jax.lax.fori_loop(0, P, body,
                            jnp.zeros((bn, Q), jnp.float32))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_batched(luts: jax.Array, codes: jax.Array, *,
                    block_n: int = 1024,
                    interpret: bool | None = None) -> jax.Array:
    """luts: (Q, P, M) f32; codes: (N, P) integer -> scores (Q, N) f32."""
    Q, P, M = luts.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    grid = ((N + pad) // bn,)
    out = pl.pallas_call(
        functools.partial(_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, P, M), lambda i: (0, 0, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, Q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((N + pad), Q), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes)
    return out[:N].T                                   # (Q, N)


def _masked_kernel(lut_ref, codes_ref, mask_ref, out_ref, *, P: int, M: int):
    """Shared-codes scan with the validity sentinel fused into the pass:
    out[n, q] = mask[q, n] ? sum_p LUT[q, p, codes[n, p]] : -inf."""
    codes = codes_ref[...].astype(jnp.int32)          # (bN, P)
    bn = codes.shape[0]
    Q = lut_ref.shape[0]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bn, M), 1)

    def body(p, acc):
        onehot = (codes[:, p][:, None] == iota_m).astype(jnp.float32)
        lut_p = lut_ref[:, p, :]                       # (Q, M) f32
        return acc + jax.lax.dot_general(
            onehot, lut_p, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bN, Q)

    acc = jax.lax.fori_loop(0, P, body,
                            jnp.zeros((bn, Q), jnp.float32))
    valid = mask_ref[...].astype(jnp.int32).T != 0     # (bN, Q)
    out_ref[...] = jnp.where(valid, acc, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_batched_masked(luts: jax.Array, codes: jax.Array,
                           mask: jax.Array, *, block_n: int = 1024,
                           interpret: bool | None = None) -> jax.Array:
    """Masked shared-codes ADC: luts (Q, P, M) f32, codes (N, P) integer,
    mask (Q, N) — nonzero = valid — -> scores (Q, N) f32 with exactly
    ``-inf`` wherever mask is zero (rows a metadata predicate filtered out;
    see module docstring)."""
    Q, P, M = luts.shape
    N = codes.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    grid = ((N + pad) // bn,)
    out = pl.pallas_call(
        functools.partial(_masked_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, P, M), lambda i: (0, 0, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
            pl.BlockSpec((Q, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bn, Q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((N + pad), Q), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes, mask.astype(jnp.uint8))
    return out[:N].T                                   # (Q, N)


def _paired_kernel(lut_ref, codes_ref, out_ref, *, P: int, M: int):
    codes = codes_ref[0].astype(jnp.int32)            # (bN, P)
    bn = codes.shape[0]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bn, M), 1)

    def body(p, acc):
        onehot = (codes[:, p][:, None] == iota_m).astype(jnp.float32)
        lut_p = lut_ref[0, p, :]                       # (M,) f32
        return acc + jax.lax.dot_general(
            onehot, lut_p[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bN, 1)

    acc = jax.lax.fori_loop(0, P, body,
                            jnp.zeros((bn, 1), jnp.float32))
    out_ref[...] = acc[:, 0][None, :]                  # (1, bN)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_paired(luts: jax.Array, codes: jax.Array, *,
                   block_n: int = 1024,
                   interpret: bool | None = None) -> jax.Array:
    """Per-query candidate scan: luts (Q, P, M) f32, codes (Q, N, P) integer
    -> scores (Q, N) f32 with scores[q] = ADC(luts[q], codes[q]).

    Grid is (Q, N/block_n), q-major: each query's LUT block is fetched once
    and reused across all of that query's code blocks.
    """
    Q, P, M = luts.shape
    N = codes.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
    grid = (Q, (N + pad) // bn)
    out = pl.pallas_call(
        functools.partial(_paired_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, P, M), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, bn, P), lambda q, i: (q, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((Q, N + pad), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes)
    return out[:, :N]                                  # (Q, N)


def _paired_masked_kernel(lut_ref, codes_ref, mask_ref, out_ref, *,
                          P: int, M: int):
    """Per-query candidate scan with the validity sentinel fused in:
    out[q, n] = mask[q, n] ? sum_p LUT[q, p, codes[q, n, p]] : -inf."""
    codes = codes_ref[0].astype(jnp.int32)            # (bN, P)
    bn = codes.shape[0]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bn, M), 1)

    def body(p, acc):
        onehot = (codes[:, p][:, None] == iota_m).astype(jnp.float32)
        lut_p = lut_ref[0, p, :]                       # (M,) f32
        return acc + jax.lax.dot_general(
            onehot, lut_p[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bN, 1)

    acc = jax.lax.fori_loop(0, P, body,
                            jnp.zeros((bn, 1), jnp.float32))
    valid = mask_ref[...].astype(jnp.int32) != 0       # (1, bN)
    out_ref[...] = jnp.where(valid, acc[:, 0][None, :], -jnp.inf)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan_paired_masked(luts: jax.Array, codes: jax.Array,
                          mask: jax.Array, *, block_n: int = 1024,
                          interpret: bool | None = None) -> jax.Array:
    """Masked per-query candidate scan: luts (Q, P, M) f32, codes (Q, N, P)
    integer, mask (Q, N) — nonzero = valid — -> scores (Q, N) f32 with
    exactly ``-inf`` wherever mask is zero.  Same grid/residency contract
    as ``pq_scan_paired``; the sentinel is applied inside the kernel so
    filtered rows never reach the top-k (DESIGN.md §10)."""
    Q, P, M = luts.shape
    N = codes.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    grid = (Q, (N + pad) // bn)
    out = pl.pallas_call(
        functools.partial(_paired_masked_kernel, P=P, M=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, P, M), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, bn, P), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((Q, N + pad), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(luts.astype(jnp.float32), codes, mask.astype(jnp.uint8))
    return out[:, :N]                                  # (Q, N)
