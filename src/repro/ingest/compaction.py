"""Background compaction scheduling for live ingest (DESIGN.md §12.4).

The ingest loop appends delta segments; somebody has to fold them back
into the cell-sorted base (search over deltas is a brute scan) and watch
for codebook drift.  This module keeps that work OFF the hot path:

  * :class:`CompactionPolicy` decides *whether* maintenance is due from
    delta-segment pressure (count, rows) and ``drift_score()``;
  * :class:`CompactionScheduler` runs the decision either cooperatively
    (``maybe_run`` from the ingest loop's checkpoint slot) or in a
    background thread (``start``/``stop``), serialized against the
    ingest writer through a shared lock.

The reader-visible pause is bounded by the base pointer swap, not the
merge: ``SegmentedIndex.compact`` builds the new base on the side and
swaps under its lock (``last_swap_pause_s`` records the lock hold time,
collected here into ``pauses`` so the bench can assert the bound).

When drift exceeds ``refresh_drift``, the scheduler escalates from a
code-reusing compact to a full codebook refresh
(``VectorStore.refresh_codebooks``): retrain on the current vectors,
re-encode, atomically swap base + codebooks.  That is the expensive
remedy for a shifted stream distribution — off by default.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from repro import chaos


@dataclasses.dataclass
class CompactionPolicy:
    """When is maintenance due?  Any satisfied trigger compacts; the
    drift escalation (``refresh_drift``) retrains instead."""

    max_segments: int = 3        # pending delta segments
    max_delta_rows: int = 50_000  # total rows across deltas
    max_drift: float = 1.5       # drift_score() beyond this -> compact
    refresh_drift: Optional[float] = None  # beyond this -> codebook refresh

    def decide(self, seg) -> Optional[str]:
        """-> "refresh" | "compact" | None for a ``SegmentedIndex``."""
        has_pending = bool(seg.segments) or bool(seg.tombstones)
        if not has_pending:
            return None
        drift = seg.drift_score()
        if self.refresh_drift is not None and drift > self.refresh_drift:
            return "refresh"
        n_delta = sum(len(s.ids) for s in seg.segments)
        if len(seg.segments) > self.max_segments \
                or n_delta > self.max_delta_rows \
                or (seg.segments and drift > self.max_drift):
            return "compact"
        return None


class CompactionScheduler:
    """Runs :class:`CompactionPolicy` decisions against a store.

    ``store`` is a :class:`repro.store.VectorStore` (or anything with
    ``to_segmented_index()``/``compact()``; ``refresh_codebooks()`` is
    optional — without it, "refresh" degrades to "compact").  ``lock``
    serializes maintenance against the writer; :class:`IngestService`
    installs its own write lock here when given a scheduler.
    """

    def __init__(self, store, policy: Optional[CompactionPolicy] = None, *,
                 interval_s: float = 0.05,
                 lock: Optional[threading.Lock] = None):
        self.store = store
        self.seg = store.to_segmented_index()
        self.policy = policy or CompactionPolicy()
        self.interval_s = float(interval_s)
        self.lock = lock or threading.Lock()
        self.compactions = 0
        self.refreshes = 0
        self.pauses: list[float] = []   # reader-visible swap pauses (s)
        self.last_error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def maybe_run(self) -> Optional[str]:
        """One cooperative maintenance slot: decide and (maybe) act.
        Returns the action taken ("compact" / "refresh") or None."""
        action = self.policy.decide(self.seg)
        if action is None:
            return None
        chaos.failpoint("ingest.compaction.run")
        with self.lock:
            if action == "refresh" \
                    and hasattr(self.store, "refresh_codebooks"):
                self.store.refresh_codebooks()
                self.refreshes += 1
            else:
                self.store.compact()
                self.compactions += 1
                action = "compact"
        pause = getattr(self.seg, "last_swap_pause_s", None)
        if pause is not None:
            self.pauses.append(pause)
        return action

    # -- background thread ----------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval_s):
                try:
                    self.maybe_run()
                except BaseException as e:  # keep the thread alive
                    self.last_error = e

        self._thread = threading.Thread(target=loop, name="lovo-compaction",
                                        daemon=True)
        self._thread.start()

    def stop(self, timeout_s: float = 5.0) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout_s)
        self._thread = None
