"""Live multi-camera ingest service (DESIGN.md §12.1).

Wires the existing seams into a continuously running loop:

    cameras --frames--> adaptive key-frame sampling (CameraBandit budget)
            --encode--> WAL-backed VectorStore (SegmentedIndex deltas)
            --rows_since--> StandingQueryRegistry (delta-only evaluation)
            --alerts--> RetryingSink (at-least-once delivery)

Single writer: :meth:`IngestService.step` is the only index mutator; the
compaction scheduler shares the service's write lock so background
``compact()`` never interleaves with an insert.

Crash consistency (DESIGN.md §12.3): frame attribution metadata (which
camera/source-frame produced each key-frame row) is written to a
frame-meta log and fsync'd BEFORE the vector rows enter the store WAL —
so a row that survives a crash can always be re-attributed on reopen.  A
meta record whose rows never reached the WAL (crash in between) is a
*dangling tail*: reopen trims it and rewinds the camera to re-consume
those frames.  Watermarks, seen-sets, the bandit posterior, camera
positions, and the undelivered-alert queue checkpoint atomically to
``ingest-state.json``; replayed-but-unevaluated rows are evaluated once
at reopen — the exactly-once alert path exercised by the crash tests.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from collections import deque
from typing import Callable, Optional, Protocol, Sequence

import numpy as np

from repro import chaos
from repro.core import imi as imimod
from repro.data import video as videomod
from repro.ingest.alerts import Alert, MemorySink, RetryingSink
from repro.ingest.registry import DeltaChunk, StandingQueryRegistry
from repro.ingest.sampler import CameraBandit

META_LOG = "ingest-frames.log"
STATE_FILE = "ingest-state.json"

# frames (F, H, W, 3) -> patch embeddings (F, patches_per_frame, D')
EncodeFramesFn = Callable[[np.ndarray], np.ndarray]


# ---------------------------------------------------------------------------
# Frame sources
# ---------------------------------------------------------------------------
class FrameSource(Protocol):
    """A camera: hands out consecutive frame chunks; seekable so the
    service can rewind to a checkpointed position after a crash."""

    pos: int

    def read(self, max_frames: int) -> Optional[np.ndarray]: ...

    def seek(self, pos: int) -> None: ...


class ReplayCamera:
    """Replays a prerecorded (T, H, W, 3) array in chunks — the test and
    benchmark camera, and the recovery model for any source that can
    rewind (a file, a segment store, a broker with offsets)."""

    def __init__(self, frames: np.ndarray):
        self.frames = frames
        self.pos = 0

    def read(self, max_frames: int) -> Optional[np.ndarray]:
        if self.pos >= len(self.frames):
            return None
        chunk = self.frames[self.pos: self.pos + max_frames]
        self.pos += len(chunk)
        return chunk

    def seek(self, pos: int) -> None:
        self.pos = min(int(pos), len(self.frames))


def synthetic_camera(seed: int, *, n_frames: int = 96, res: int = 64,
                     max_objects: int = 3
                     ) -> tuple[ReplayCamera, list[str]]:
    """One synthetic camera stream (``data/synthetic.py`` world) plus the
    ground-truth object captions that appear in it — callers register
    standing queries for captions they expect to fire."""
    from repro.data import synthetic

    rng = np.random.default_rng(seed)
    vid = synthetic.make_video(rng, n_frames=n_frames, res=res,
                               max_objects=max_objects)
    captions = sorted({o.caption() for objs in vid.objects for o in objs})
    return ReplayCamera(vid.frames), captions


# ---------------------------------------------------------------------------
# Service
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class IngestStats:
    steps: int = 0
    frames_in: int = 0        # raw frames consumed from cameras
    keyframes: int = 0        # frames that passed sampling and were encoded
    rows: int = 0             # index rows appended
    evaluations: int = 0
    rows_scanned: int = 0     # delta rows scanned by standing queries
    alerts: int = 0


class IngestService:
    """Continuous ingest over a :class:`repro.store.VectorStore`.

    ``encode_frames``: (F, H, W, 3) -> (F, patches_per_frame, D') patch
    embeddings (the serving path binds the ViT; tests bind cheap
    deterministic projections).  ``registry`` supplies the standing
    queries; ``sink`` receives alerts (wrapped in a
    :class:`RetryingSink` unless it already is one).

    Construction recovers any previous ingest session found next to the
    store (frame-meta log + state file) and evaluates replayed rows the
    registry has not seen — alerts for those are enqueued exactly once.
    """

    def __init__(self, store, cameras: Sequence[FrameSource],
                 encode_frames: EncodeFramesFn,
                 registry: StandingQueryRegistry, *,
                 sink=None, bandit: Optional[CameraBandit] = None,
                 frames_per_step: int = 16, keyframe_stride: int = 4,
                 peak_sigma: float = 1.0,
                 keyframe_budget: Optional[int] = None,
                 checkpoint_every_steps: int = 8,
                 scheduler=None, auto_recover: bool = True):
        import threading

        self.store = store
        self.seg = store.to_segmented_index()
        self.cameras = list(cameras)
        self.encode_frames = encode_frames
        self.registry = registry
        self.sink = sink if isinstance(sink, RetryingSink) \
            else RetryingSink(sink if sink is not None else MemorySink())
        self.bandit = bandit or CameraBandit(len(self.cameras))
        self.frames_per_step = int(frames_per_step)
        self.keyframe_stride = int(keyframe_stride)
        self.peak_sigma = float(peak_sigma)
        self.keyframe_budget = int(
            keyframe_budget if keyframe_budget is not None
            else len(self.cameras) * max(1, frames_per_step // keyframe_stride))
        self.checkpoint_every_steps = int(checkpoint_every_steps)
        self.scheduler = scheduler
        self.write_lock = threading.Lock()
        if scheduler is not None:
            scheduler.lock = self.write_lock

        root = pathlib.Path(store.root)
        self.meta_log_path = root / META_LOG
        self.state_path = root / STATE_FILE

        self.stats = IngestStats()
        self.latencies: deque[float] = deque(maxlen=4096)  # append->emit s
        self.exhausted = False
        # frame tables: (frame_seq - _frame_base) -> camera / source frame.
        # _frame_base keys the ingest id space ABOVE every id already in
        # the store (the built base uses its own patch ids), so ingested
        # ids never collide and "ingested rows" is exactly
        # ids >= _frame_base * patches_per_frame
        self._frame_camera: list[int] = []
        self._frame_time: list[int] = []
        self._frame_base = self._present_max_id() \
            // self.registry.patches_per_frame + 1
        self._next_seq = self._frame_base
        self._prev_frame: list[Optional[np.ndarray]] = \
            [None] * len(self.cameras)
        self._append_t: dict[int, float] = {}
        self._kp: Optional[int] = None       # patches/frame, checked on use

        if auto_recover:
            self.recover()

    def data_version(self) -> tuple:
        """The store's current cache token (``VectorStore.cache_token``).

        Every ingest append lands through ``store.insert`` (and deletes /
        compactions through their store calls), each of which advances the
        underlying ``SegmentedIndex.data_version`` — so plan-result caches
        keyed on this token (``repro.core.optimizer.ResultCache``) are
        invalidated by ingest automatically, with no TTLs and no explicit
        cache wiring in the ingest loop.
        """
        return self.store.cache_token()

    def _present_max_id(self) -> int:
        """Highest row id currently in the index (base + deltas)."""
        ids = np.asarray(self.seg.base.ids)
        out = int(ids.max()) if ids.size else -1
        for s in self.seg.segments:
            if len(s.ids):
                out = max(out, int(s.ids.max()))
        return out

    # -- the hot loop ---------------------------------------------------------
    def step(self) -> list[Alert]:
        """One ingest round: sample + encode + append each camera's next
        chunk, evaluate standing queries on the new rows, deliver alerts.
        Returns the alerts that fired this step."""
        budgets = self.bandit.allocate(self.keyframe_budget)
        sampled = np.zeros(len(self.cameras), np.int64)
        got_frames = False
        for ci, cam in enumerate(self.cameras):
            pos0 = cam.pos
            frames = cam.read(self.frames_per_step)
            if frames is None or len(frames) == 0:
                continue
            got_frames = True
            self.stats.frames_in += len(frames)
            kf = videomod.extract_keyframes(
                frames, stride=self.keyframe_stride,
                peak_sigma=self.peak_sigma,
                max_keyframes=max(int(budgets[ci]), 1),
                prev_frame=self._prev_frame[ci], offset=pos0,
                always_first=(pos0 == 0))
            self._prev_frame[ci] = frames[-1]
            self._ingest_chunk(ci, frames, kf, pos0, cam.pos)
            sampled[ci] = len(kf)
        self.exhausted = not got_frames

        alerts = self._evaluate()

        match_per_cam = np.zeros(len(self.cameras), np.int64)
        for a in alerts:
            if 0 <= a.camera < len(self.cameras):
                match_per_cam[a.camera] += 1
        for ci in range(len(self.cameras)):
            if sampled[ci]:
                self.bandit.update(ci, samples=int(sampled[ci]),
                                   matches=int(match_per_cam[ci]))

        # persist watermarks/seen/pending BEFORE delivering: a crash after
        # this point re-delivers (at-least-once), never re-evaluates
        self._save_state()
        if self.sink.try_deliver() and alerts:
            self._save_state()  # shrink the duplicate window: queue drained

        self.stats.steps += 1
        if self.checkpoint_every_steps \
                and self.stats.steps % self.checkpoint_every_steps == 0:
            self.checkpoint()
        elif self.scheduler is not None:
            self.scheduler.maybe_run()
        return alerts

    def run(self, max_steps: Optional[int] = None) -> list[Alert]:
        """Step until every camera is exhausted (or ``max_steps``)."""
        out: list[Alert] = []
        steps = 0
        while max_steps is None or steps < max_steps:
            out.extend(self.step())
            steps += 1
            if self.exhausted:
                break
        return out

    def _ingest_chunk(self, camera: int, frames: np.ndarray,
                      kf: np.ndarray, pos0: int, pos1: int) -> None:
        """Meta-first append: the frame-attribution record is durable
        before the rows enter the store WAL (see module docstring)."""
        seq0 = self._next_seq
        times = (pos0 + kf).tolist()
        self._append_meta({"cam": camera, "seq0": seq0, "times": times,
                           "pos0": pos0, "pos1": pos1})
        if not len(kf):
            return
        embeds = np.asarray(self.encode_frames(frames[kf]), np.float32)
        f, kp, d = embeds.shape
        if self._kp is None:
            self._kp = kp
            if kp != self.registry.patches_per_frame:
                raise ValueError(
                    f"encoder yields {kp} patches/frame but the registry "
                    f"was built for {self.registry.patches_per_frame}")
        ids = (seq0 + np.arange(f, dtype=np.int64))[:, None] * kp \
            + np.arange(kp, dtype=np.int64)[None, :]
        with self.write_lock:
            self.store.insert(embeds.reshape(f * kp, d),
                              ids.reshape(-1).astype(imimod.ID_DTYPE))
        now = time.monotonic()
        for j in range(f):
            self._append_t[seq0 + j] = now
        if len(self._append_t) > 65_536:  # bound the latency book-keeping
            for k in list(self._append_t)[: len(self._append_t) - 65_536]:
                del self._append_t[k]
        self._frame_camera.extend([camera] * f)
        self._frame_time.extend(times)
        self._next_seq += f
        self.stats.keyframes += f
        self.stats.rows += f * kp

    def _evaluate(self) -> list[Alert]:
        wm = self.registry.min_watermark()
        if wm is None:
            return []
        # standing queries see INGESTED rows only: rows predating the
        # service (the built base) have no camera attribution
        floor = self._frame_base * self.registry.patches_per_frame - 1
        rows = self.seg.rows_since(max(wm, floor))
        if rows["ids"].size == 0:
            return []
        chunk = self._make_chunk(rows)
        alerts, st = self.registry.evaluate(self.seg.base, chunk)
        self.stats.evaluations += 1
        self.stats.rows_scanned += st.rows_scanned
        self.stats.alerts += len(alerts)
        now = time.monotonic()
        for a in alerts:
            t0 = self._append_t.get(a.frame_seq)
            if t0 is not None:
                self.latencies.append(now - t0)
        self.sink.enqueue(alerts)
        return alerts

    def _make_chunk(self, rows: dict) -> DeltaChunk:
        kp = self.registry.patches_per_frame
        cam_of = np.asarray(self._frame_camera, np.int32)
        time_of = np.asarray(self._frame_time, np.int32)
        fseq = (rows["ids"] // kp).astype(np.int64)
        frames = np.unique(fseq)                       # sorted, global
        rel = fseq - self._frame_base                  # frame-table rows
        rel_f = frames - self._frame_base
        return DeltaChunk(
            codes=rows["codes"], vectors=rows["vectors"],
            cells=rows["cells"], ids=rows["ids"],
            row_camera=cam_of[rel], row_time=time_of[rel],
            frame_seq=frames, frame_camera=cam_of[rel_f],
            frame_time=time_of[rel_f])

    # -- durability -----------------------------------------------------------
    @staticmethod
    def _fsync_dir(path: pathlib.Path) -> None:
        """Durable-rename tail: an ``os.replace`` only survives a crash
        once the directory entry itself is fsync'd (same helper as
        ``store.manifest``; lint rule DS204)."""
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _append_meta(self, rec: dict) -> None:
        line = json.dumps(rec, sort_keys=True) + "\n"
        with open(self.meta_log_path, "a", encoding="utf-8") as f:
            if chaos.failpoint("ingest.meta_log.append") == "torn":
                # crash mid-append: half a JSON line reaches the log; the
                # recovery scan treats the unparsable tail as dead
                f.write(line[: max(1, len(line) // 2)])
                f.flush()
                chaos.crash_now()
            f.write(line)
            f.flush()
            os.fsync(f.fileno())

    def _save_state(self) -> None:
        state = {
            "bandit": self.bandit.state_dict(),
            "registry": self.registry.state_dict(),
            "sink_pending": [a.to_json() for a in self.sink.pending_alerts],
            "camera_pos": [cam.pos for cam in self.cameras],
            "steps": self.stats.steps,
        }
        tmp = self.state_path.with_suffix(".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(state, f)
            f.flush()
            os.fsync(f.fileno())
        chaos.failpoint("ingest.state.replace")
        os.replace(tmp, self.state_path)
        self._fsync_dir(self.state_path.parent)

    def checkpoint(self) -> None:
        """Fold the store WAL into segments (manifest swap), persist the
        ingest state, and give the compaction scheduler a slot."""
        with self.write_lock:
            self.store.flush()
        self._save_state()
        if self.scheduler is not None:
            self.scheduler.maybe_run()

    def close(self, drain_timeout_s: float = 5.0) -> None:
        """Graceful shutdown: deliver what is queued, fold the WAL, save
        state, close the store."""
        self.sink.drain(drain_timeout_s)
        with self.write_lock:
            self.store.flush()
        self._save_state()
        self.store.close()

    # -- recovery -------------------------------------------------------------
    def recover(self) -> list[Alert]:
        """Resume a previous ingest session (no-op on a fresh store).

        Rebuilds the frame table from the frame-meta log, trims any
        dangling tail (meta records whose rows never reached the WAL)
        and rewinds those cameras, restores bandit/registry/sink state,
        then evaluates replayed rows the registry has not seen — firing
        their alerts exactly once."""
        had_session = self.meta_log_path.exists() or self.state_path.exists()
        records = self._read_meta_log()

        # present_max: the highest row id that actually survived (base +
        # replayed deltas); meta records beyond it are the dangling tail
        present_max = self._present_max_id()
        kp = self.registry.patches_per_frame
        good = []
        for rec in records:
            if rec["times"]:
                last_id = (rec["seq0"] + len(rec["times"])) * kp - 1
                if last_id > present_max:
                    break
            good.append(rec)
        if len(good) < len(records):
            self._rewrite_meta_log(good)

        cam_pos = {}
        if records:
            # the previous session fixed the ingest id space; adopt it
            self._frame_base = int(records[0]["seq0"])
            self._next_seq = self._frame_base
        for rec in good:
            self._frame_camera.extend([rec["cam"]] * len(rec["times"]))
            self._frame_time.extend(int(t) for t in rec["times"])
            self._next_seq = rec["seq0"] + len(rec["times"])
            cam_pos[rec["cam"]] = rec["pos1"]
        for ci, cam in enumerate(self.cameras):
            cam.seek(cam_pos.get(ci, 0))
            # prev_frame is not persisted (frames are large); the first
            # post-recovery chunk falls back to batch-mode boundary energy

        if self.state_path.exists():
            with open(self.state_path, encoding="utf-8") as f:
                state = json.load(f)
            self.bandit.load_state_dict(state["bandit"])
            if state["registry"]:
                self.registry.load_state_dict(state["registry"])
            self.sink.load_pending([Alert.from_json(a)
                                    for a in state["sink_pending"]])
            for ci, pos in enumerate(state.get("camera_pos", [])):
                if ci < len(self.cameras) and ci not in cam_pos:
                    self.cameras[ci].seek(int(pos))
            self.stats.steps = int(state.get("steps", 0))

        if not had_session or not self.registry.subs:
            return []
        # replayed-but-unevaluated rows: evaluate once, persist, deliver
        alerts = self._evaluate()
        self._save_state()
        if self.sink.try_deliver() and alerts:
            self._save_state()
        return alerts

    def _read_meta_log(self) -> list[dict]:
        records = []
        try:
            with open(self.meta_log_path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        records.append(json.loads(line))
                    except json.JSONDecodeError:
                        break  # torn trailing write: everything after is dead
        except FileNotFoundError:
            pass
        return records

    def _rewrite_meta_log(self, records: list[dict]) -> None:
        tmp = self.meta_log_path.with_suffix(".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            for rec in records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.meta_log_path)
        self._fsync_dir(self.meta_log_path.parent)
