"""Standing-query registry: plan trees evaluated at ingest time.

LOVO's index is query-agnostic, so flipping "scan at ask" into "query at
ingest" costs nothing at the index layer — a standing query is just a
``repro.core.plan`` tree whose Text leaves were encoded ONCE at
registration.  Each ingested chunk is then evaluated against every
subscription with a single batched masked PQ scan over ONLY the new
delta rows (DESIGN.md §12.2):

  * the delta cursor is an id watermark per subscription — ingested ids
    are assigned monotonically, so "rows newer than the subscription's
    generation" is exactly ``ids > watermark``, which rides the fused
    scan->select kernels (PR 5) as one more row-mask term next to the
    plan's own predicate pushdown;
  * plans execute in CHUNK-LOCAL coordinates: the chunk's rows/frames
    form their own little ``PlanMeta``, so the boolean/temporal merge
    machinery from ``plan.execute`` is reused verbatim.  ``Not`` inside
    a standing plan therefore means "not matched within this chunk" —
    the only semantics with bounded state on an unbounded stream;
  * matches dedup against a per-subscription seen-set keyed by
    (camera, source frame) — re-sightings of the same frame across
    chunk re-evaluations (e.g. crash replay) never re-alert.

Per-evaluation scanned-row counts are recorded (``EvalStats``) so tests
and benchmarks can verify delta-only evaluation: rows scanned per chunk
stays O(chunk), not O(index).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from collections import OrderedDict
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anns, plan as planmod, pq as pqmod
from repro.core.imi import IMIIndex

EncodeTextsFn = Callable[[Sequence[str]], np.ndarray]  # texts -> (Q, D')


def plan_fingerprint(node: planmod.Node) -> str:
    """Deterministic identity of a plan tree: sha1 of its canonical JSON.
    Two subscriptions with the same tree share a fingerprint, so alert
    consumers can dedup across re-registrations."""
    blob = json.dumps(planmod.to_json(node), sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass
class DeltaChunk:
    """One ingested chunk in evaluation form: the delta rows plus the
    chunk-local frame table.  ``frame_seq`` holds GLOBAL key-frame rows
    (sorted ascending); row/frame arrays are aligned local views."""

    codes: np.ndarray         # (n, P) uint8
    vectors: np.ndarray       # (n, D') f32 (normalized)
    cells: np.ndarray         # (n,) int32
    ids: np.ndarray           # (n,) global patch ids, ascending
    row_camera: np.ndarray    # (n,) int32 camera id per row
    row_time: np.ndarray      # (n,) int32 source-frame index per row
    frame_seq: np.ndarray     # (F,) global key-frame rows, ascending
    frame_camera: np.ndarray  # (F,) int32
    frame_time: np.ndarray    # (F,) int32

    @property
    def n(self) -> int:
        return len(self.ids)


@dataclasses.dataclass
class EvalStats:
    """Per-evaluation instrumentation (delta-only verification)."""

    rows_scanned: int      # delta rows this evaluation touched
    index_rows: int        # total live rows in the index at the time
    n_leaves: int          # text leaves batched into the one scan
    n_alerts: int
    wall_s: float


@dataclasses.dataclass
class Subscription:
    name: str
    node: planmod.Node
    threshold: float
    top_k: int
    fingerprint: str
    leaves: list              # collect_leaves(node) output
    leaf_embeds: np.ndarray   # (L, D') normalized text embeddings
    watermark: int = -1       # evaluate only rows with id > watermark
    seen: "OrderedDict[tuple, None]" = dataclasses.field(
        default_factory=OrderedDict)
    matched: int = 0


class StandingQueryRegistry:
    """Holds subscriptions; evaluates them against ingested chunks.

    ``encode_texts`` maps leaf query strings to (Q, D') embeddings — the
    serving path binds the engine's text encoder, tests bind fakes.  Leaf
    embeddings are computed once at registration (standing queries are
    fixed), so per-chunk evaluation never touches the text encoder.
    """

    def __init__(self, encode_texts: EncodeTextsFn, *,
                 patches_per_frame: int, use_kernel: str = "auto",
                 rerank_overfetch: int = 4, seen_cap: int = 65_536,
                 pad_rows: int = 256):
        self.encode_texts = encode_texts
        self.patches_per_frame = int(patches_per_frame)
        self.use_kernel = use_kernel
        self.rerank_overfetch = int(rerank_overfetch)
        self.seen_cap = int(seen_cap)
        # chunk rows are padded to a multiple of this so varying chunk
        # sizes reuse a handful of kernel executables instead of
        # recompiling per size
        self.pad_rows = int(pad_rows)
        self.subs: dict[str, Subscription] = {}
        # cumulative instrumentation
        self.evaluations = 0
        self.total_rows_scanned = 0
        self.total_alerts = 0

    # -- subscription management ---------------------------------------------
    def register(self, name: str, spec, *, threshold: float = 0.0,
                 top_k: int = 16, start_after: int = -1) -> Subscription:
        """Register a standing plan under ``name``.

        ``spec``: a plan Node, dict, or JSON string (the serve wire
        syntax).  ``threshold`` gates the fused frame score; ``top_k``
        caps alerts per chunk per subscription.  ``start_after``: only
        rows with id strictly greater ever match — pass the index's
        current max id to alert on new data only (the default -1 also
        evaluates rows that predate registration)."""
        if name in self.subs:
            raise ValueError(f"subscription {name!r} already registered")
        node = spec if isinstance(spec, planmod.Node) \
            else planmod.from_json(spec)
        leaves = planmod.collect_leaves(node)
        if not leaves:
            raise ValueError("a standing query needs at least one Text leaf")
        embeds = np.asarray(self.encode_texts([leaf.query
                                               for leaf, _ in leaves]),
                            np.float32)
        embeds = np.asarray(pqmod.normalize(jnp.asarray(embeds)))
        sub = Subscription(name=name, node=node, threshold=float(threshold),
                           top_k=int(top_k),
                           fingerprint=plan_fingerprint(node),
                           leaves=leaves, leaf_embeds=embeds,
                           watermark=int(start_after))
        self.subs[name] = sub
        return sub

    def unregister(self, name: str) -> None:
        del self.subs[name]

    def min_watermark(self) -> Optional[int]:
        """Lowest watermark across subscriptions (the ``rows_since``
        cursor); None when nothing is registered."""
        if not self.subs:
            return None
        return min(s.watermark for s in self.subs.values())

    # -- evaluation -----------------------------------------------------------
    def evaluate(self, base: IMIIndex, chunk: DeltaChunk):
        """Evaluate every subscription against ``chunk`` -> (alerts,
        EvalStats).

        One batched masked scan answers ALL text leaves of ALL
        subscriptions: per-leaf row masks stack predicate pushdown with
        the per-subscription watermark, the per-row IMI coarse term rides
        the paired kernel as its bias (per-query, like ``search_batch``'s
        windowed path), survivors are exact-rescored against the chunk's
        f32 vectors, then each plan merges on the host in chunk-local
        coordinates."""
        from repro.ingest.alerts import Alert

        t0 = time.perf_counter()
        subs = list(self.subs.values())
        n = chunk.n
        index_rows = base.n + n  # chunk rows are the pending deltas

        def stats(n_alerts: int, scanned: int = 0, leaves: int = 0):
            return EvalStats(rows_scanned=scanned, index_rows=index_rows,
                             n_leaves=leaves, n_alerts=n_alerts,
                             wall_s=time.perf_counter() - t0)

        if not subs or n == 0:
            return [], stats(0)

        kp = self.patches_per_frame
        # chunk-local coordinates: frame_seq is sorted, so searchsorted
        # maps each row's global frame to its local frame index
        local_frame = np.searchsorted(chunk.frame_seq, chunk.ids // kp)
        local_ids = (local_frame * kp + chunk.ids % kp).astype(np.int64)
        meta = planmod.PlanMeta(
            row_video=np.asarray(chunk.row_camera, np.int64),
            row_time=np.asarray(chunk.row_time, np.int64),
            frame_video=np.asarray(chunk.frame_camera, np.int64),
            frame_time=np.asarray(chunk.frame_time, np.int64),
            patches_per_frame=kp)

        # stack every leaf of every subscription into one device batch
        flat: list[tuple[Subscription, planmod.Text, tuple]] = []
        for sub in subs:
            for leaf, preds in sub.leaves:
                flat.append((sub, leaf, preds))
        L = len(flat)
        qs = np.concatenate([s.leaf_embeds for s in subs], axis=0)
        masks = np.ones((L, n), bool)
        for i, (sub, _, preds) in enumerate(flat):
            for p in preds:
                masks[i] &= planmod.predicate_row_mask(p, meta)
            # the rows-newer-than-generation term: this is what makes the
            # scan delta-only per subscription
            masks[i] &= chunk.ids > sub.watermark
        if not masks.any():
            self._advance(subs, chunk)
            return [], stats(0, scanned=0, leaves=L)

        # pad the row axis to a multiple of pad_rows (bounded recompiles)
        n_pad = -(-n // self.pad_rows) * self.pad_rows
        pad = n_pad - n
        codes = np.concatenate(
            [chunk.codes, np.zeros((pad, chunk.codes.shape[1]), np.uint8)]) \
            if pad else chunk.codes
        cells = np.concatenate([chunk.cells, np.zeros(pad, np.int32)]) \
            if pad else chunk.cells
        masks_p = np.concatenate(
            [masks, np.zeros((L, pad), bool)], axis=1) if pad else masks

        # device batch: per-leaf LUTs + per-row IMI coarse bias, one fused
        # masked scan->select (PR 5 paired kernel: per-query bias)
        qs_dev = jnp.asarray(qs)
        luts = jax.vmap(lambda q: pqmod.similarity_lut(base.pq, q))(qs_dev)
        h = qs.shape[-1] // 2
        s1 = qs_dev[:, :h] @ base.coarse1.T                       # (L, K)
        s2 = qs_dev[:, h:] @ base.coarse2.T
        cells_dev = jnp.asarray(cells)
        K = base.K
        bias = (jnp.take(s1, cells_dev // K, axis=1)
                + jnp.take(s2, cells_dev % K, axis=1))            # (L, n_pad)
        codes_b = jnp.broadcast_to(jnp.asarray(codes)[None],
                                   (L, n_pad, codes.shape[1]))
        fetch_k = min(max(s.top_k for s in subs) * self.rerank_overfetch,
                      n_pad)
        _, pos = anns._topk_paired(luts, codes_b, bias,
                                   jnp.asarray(masks_p, jnp.uint8),
                                   fetch_k, self.use_kernel)

        # exact refine on the chunk's f32 vectors (host: the chunk is small)
        pos = np.asarray(pos)                                     # (L, fetch_k)
        dead = pos < 0
        safe = np.clip(pos, 0, n - 1)
        exact = np.einsum("lkd,ld->lk",
                          chunk.vectors[safe].astype(np.float32), qs)
        exact[dead] = -np.inf
        out_ids = local_ids[safe]
        out_ids[dead] = -1

        # per-subscription host merge + threshold + dedup
        alerts: list[Alert] = []
        cursor = 0
        for sub in subs:
            ls = len(sub.leaves)
            sl = slice(cursor, cursor + ls)
            cursor += ls

            def search_texts(texts, _masks, _sl=sl):
                return out_ids[_sl], exact[_sl]

            res = planmod.execute(sub.node, meta, search_texts)
            fired = 0
            for f, sc in zip(res.frames, res.scores):
                if fired >= sub.top_k or sc < sub.threshold:
                    break  # scores are sorted descending
                cam = int(meta.frame_video[f])
                t = int(meta.frame_time[f])
                if (cam, t) in sub.seen:
                    continue
                sub.seen[(cam, t)] = None
                while len(sub.seen) > self.seen_cap:
                    sub.seen.popitem(last=False)
                alerts.append(Alert(
                    subscription=sub.name, fingerprint=sub.fingerprint,
                    camera=cam, frame=t, score=float(sc),
                    frame_seq=int(chunk.frame_seq[f])))
                fired += 1
            sub.matched += fired
        self._advance(subs, chunk)
        self.evaluations += 1
        self.total_rows_scanned += n
        self.total_alerts += len(alerts)
        return alerts, stats(len(alerts), scanned=n, leaves=L)

    @staticmethod
    def _advance(subs: Sequence[Subscription], chunk: DeltaChunk) -> None:
        top = int(chunk.ids.max())
        for sub in subs:
            sub.watermark = max(sub.watermark, top)

    # -- checkpoint round-trip ------------------------------------------------
    def state_dict(self) -> dict:
        return {name: {
            "plan": planmod.to_json(sub.node),
            "threshold": sub.threshold,
            "top_k": sub.top_k,
            "watermark": sub.watermark,
            "seen": [list(k) for k in sub.seen],
            "matched": sub.matched,
        } for name, sub in self.subs.items()}

    def load_state_dict(self, state: dict) -> None:
        """Rebuild subscriptions from a checkpoint: plans re-parse, leaf
        embeddings re-encode (the encoder is deterministic), watermarks
        and seen-sets restore — the exactly-once dedup state round-trips."""
        self.subs.clear()
        for name, s in state.items():
            sub = self.register(name, s["plan"],
                                threshold=float(s["threshold"]),
                                top_k=int(s["top_k"]),
                                start_after=int(s["watermark"]))
            sub.seen = OrderedDict(((int(c), int(t)), None)
                                   for c, t in s["seen"])
            sub.matched = int(s.get("matched", 0))
