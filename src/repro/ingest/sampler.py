"""ExSample-style per-camera sampling bandit (PAPERS.md: ExSample).

Live ingest cannot afford to key-frame every camera at full rate; the
budget has to chase the cameras that are currently producing matches.
ExSample frames this as a Thompson-sampling bandit: each camera keeps a
Beta posterior over "a sampled frame from this camera fires an alert",
and every allocation round draws from the posteriors and splits the
key-frame budget proportionally to the draws.

Differences from the paper's setting, on purpose:

  * the reward is "standing-query alert fired" (our match signal), not
    "new distinct object found" — the registry's dedup already removes
    re-sightings, so alert count approximates distinct-result count;
  * counts decay geometrically toward the prior so the posterior tracks
    a non-stationary stream (an idle camera that becomes busy recovers
    its share in O(1/(1-decay)) updates);
  * every camera keeps a ``min_per_camera`` floor — exploration never
    starves a camera to zero, so a match there can still be observed.
"""
from __future__ import annotations

import numpy as np


class CameraBandit:
    """Beta-Bernoulli Thompson sampler allocating key-frame budget.

    Single-threaded by design: the ingest service is the only caller
    (``allocate`` at the top of each step, ``update`` at the bottom).
    """

    def __init__(self, n_cameras: int, *, min_per_camera: int = 1,
                 decay: float = 0.98, prior: tuple[float, float] = (1.0, 1.0),
                 seed: int = 0):
        if n_cameras <= 0:
            raise ValueError("need at least one camera")
        self.n_cameras = n_cameras
        self.min_per_camera = int(min_per_camera)
        self.decay = float(decay)
        self.prior = (float(prior[0]), float(prior[1]))
        self.alpha = np.full(n_cameras, self.prior[0], np.float64)
        self.beta = np.full(n_cameras, self.prior[1], np.float64)
        self._rng = np.random.default_rng(seed)

    def allocate(self, budget: int) -> np.ndarray:
        """Split ``budget`` key-frame slots across cameras -> (C,) ints.

        Thompson draw per camera, proportional split of what remains
        after the ``min_per_camera`` floor, largest-remainder rounding
        (so the result sums exactly to ``budget`` whenever the floor
        fits)."""
        c = self.n_cameras
        budget = int(budget)
        floor = min(self.min_per_camera, budget // c)
        out = np.full(c, floor, np.int64)
        extra = budget - floor * c
        if extra <= 0:
            return out
        draws = self._rng.beta(self.alpha, self.beta)
        w = draws / max(float(draws.sum()), 1e-12)
        give = np.floor(w * extra).astype(np.int64)
        frac = w * extra - give
        short = extra - int(give.sum())
        if short > 0:
            give[np.argsort(-frac)[:short]] += 1
        return out + give

    def update(self, camera: int, *, samples: int, matches: int) -> None:
        """Record one step's outcome for ``camera``: ``samples`` key
        frames taken, ``matches`` of them fired an alert."""
        samples = max(int(samples), 0)
        matches = min(max(int(matches), 0), samples)
        if samples == 0:
            return
        # geometric forgetting toward the prior keeps the posterior
        # responsive to regime changes in the stream
        a0, b0 = self.prior
        self.alpha[camera] = a0 + (self.alpha[camera] - a0) * self.decay
        self.beta[camera] = b0 + (self.beta[camera] - b0) * self.decay
        self.alpha[camera] += matches
        self.beta[camera] += samples - matches

    def match_rate(self) -> np.ndarray:
        """Posterior mean match probability per camera -> (C,)."""
        return self.alpha / (self.alpha + self.beta)

    # -- checkpoint round-trip (ingest-state.json) ---------------------------
    def state_dict(self) -> dict:
        return {"alpha": self.alpha.tolist(), "beta": self.beta.tolist()}

    def load_state_dict(self, state: dict) -> None:
        alpha = np.asarray(state["alpha"], np.float64)
        if len(alpha) != self.n_cameras:
            raise ValueError(
                f"bandit state covers {len(alpha)} cameras, "
                f"this service has {self.n_cameras}")
        self.alpha = alpha
        self.beta = np.asarray(state["beta"], np.float64)
