"""repro.ingest — live multi-camera ingest with standing queries.

Turns the batch "scan at ask" pipeline into "query at ingest"
(DESIGN.md §12): cameras feed adaptive key-frame sampling, sampled
frames encode into the WAL-backed store's delta segments, every ingested
chunk is evaluated against registered plan trees with one batched masked
scan over only the new rows, and matches become at-least-once alerts.
"""
from repro.ingest.alerts import (Alert, AlertSink, JsonlSink, MemorySink,
                                 RetryingSink, dedup_by_key)
from repro.ingest.compaction import CompactionPolicy, CompactionScheduler
from repro.ingest.pipeline import (FrameSource, IngestService, IngestStats,
                                   ReplayCamera, synthetic_camera)
from repro.ingest.registry import (DeltaChunk, EvalStats,
                                   StandingQueryRegistry, Subscription,
                                   plan_fingerprint)
from repro.ingest.sampler import CameraBandit

__all__ = [
    "Alert", "AlertSink", "JsonlSink", "MemorySink", "RetryingSink",
    "dedup_by_key", "CompactionPolicy", "CompactionScheduler",
    "FrameSource", "IngestService", "IngestStats", "ReplayCamera",
    "synthetic_camera", "DeltaChunk", "EvalStats", "StandingQueryRegistry",
    "Subscription", "plan_fingerprint", "CameraBandit",
]
