"""Alert records and delivery sinks for the standing-query registry.

Delivery contract (DESIGN.md §12.3): **at-least-once**.  Alerts are
enqueued into a bounded retry queue; the queue is part of the ingest
service's checkpointed state, so alerts that were evaluated but not yet
delivered when the process died are re-delivered after reopen.  The one
unavoidable duplicate window is "delivered, then crashed before the next
checkpoint" — consumers that need exactly-once de-duplicate on
:attr:`Alert.key`, which is deterministic for a given (plan, camera,
frame).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Callable, Optional, Protocol, Sequence

from repro import chaos
from repro.core.resilience import RetryPolicy


@dataclasses.dataclass(frozen=True)
class Alert:
    """One standing-query match.

    ``frame`` is the source-frame index within the camera's stream (the
    stable coordinate a consumer can seek to); ``frame_seq`` is the
    global key-frame row the match was found at (index provenance).
    """

    subscription: str   # registry name of the subscription
    fingerprint: str    # canonical plan fingerprint (sha1 prefix)
    camera: int
    frame: int
    score: float
    frame_seq: int = -1

    @property
    def key(self) -> tuple[str, int, int]:
        """Deterministic identity for consumer-side dedup."""
        return (self.fingerprint, self.camera, self.frame)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: dict) -> "Alert":
        return cls(subscription=str(obj["subscription"]),
                   fingerprint=str(obj["fingerprint"]),
                   camera=int(obj["camera"]), frame=int(obj["frame"]),
                   score=float(obj["score"]),
                   frame_seq=int(obj.get("frame_seq", -1)))


class AlertSink(Protocol):
    """Anything that accepts a batch of alerts; raising = delivery failed
    (the retry queue keeps the batch and backs off)."""

    def emit(self, alerts: Sequence[Alert]) -> None: ...


class MemorySink:
    """In-process sink (tests, benchmarks, the serve demo)."""

    def __init__(self) -> None:
        self.alerts: list[Alert] = []

    def emit(self, alerts: Sequence[Alert]) -> None:
        self.alerts.extend(alerts)


class JsonlSink:
    """Durable append-only sink: one JSON object per line, fsync'd per
    batch — the file survives the process, so a reopened consumer can
    dedup by :attr:`Alert.key` over the whole history."""

    def __init__(self, path, *, fsync: bool = True):
        self.path = os.fspath(path)
        self.fsync = fsync

    def emit(self, alerts: Sequence[Alert]) -> None:
        if not alerts:
            return
        with open(self.path, "a", encoding="utf-8") as f:
            for a in alerts:
                f.write(json.dumps(a.to_json(), sort_keys=True) + "\n")
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())

    @staticmethod
    def read(path) -> list[Alert]:
        out = []
        try:
            with open(os.fspath(path), encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if line:
                        out.append(Alert.from_json(json.loads(line)))
        except FileNotFoundError:
            pass
        return out


class RetryingSink:
    """Bounded retry/backoff queue in front of any :class:`AlertSink`.

    ``enqueue`` never blocks and never raises: when the queue is full the
    OLDEST alerts are dropped (and counted in ``dropped``) — live alerts
    about the present beat a backlog about the past.  ``try_deliver``
    attempts one delivery of the whole queue, respecting exponential
    backoff after failures; ``drain`` blocks until empty or timeout (the
    graceful-shutdown path).

    The pending queue is exposed for checkpointing (``pending_alerts`` /
    ``load_pending``): the ingest service persists it BEFORE delivering,
    which is what makes the delivery contract at-least-once across
    crashes instead of at-most-once.
    """

    def __init__(self, sink: AlertSink, *, max_queue: int = 4096,
                 base_backoff_s: float = 0.05, max_backoff_s: float = 2.0,
                 policy: Optional[RetryPolicy] = None,
                 give_up_after_s: Optional[float] = None,
                 seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.sink = sink
        self.max_queue = int(max_queue)
        self.base_backoff_s = float(base_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        # The policy owns the backoff curve: exponential with deterministic
        # per-attempt jitter, capped at max_backoff_s.  max_attempts is not
        # used here — the queue retries forever unless give_up_after_s caps
        # the total time a failing batch may hold the head of the queue.
        self.policy = policy or RetryPolicy(
            base_backoff_s=float(base_backoff_s),
            max_backoff_s=float(max_backoff_s), seed=int(seed))
        self.give_up_after_s = (None if give_up_after_s is None
                                else float(give_up_after_s))
        self._clock = clock
        self._sleep = sleep
        self._queue: deque[Alert] = deque()
        self._failures = 0
        self._next_attempt = 0.0
        self._first_failure_at: Optional[float] = None
        self.delivered = 0
        self.dropped = 0
        self.expired = 0

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def pending_alerts(self) -> list[Alert]:
        return list(self._queue)

    def load_pending(self, alerts: Sequence[Alert]) -> None:
        """Restore a checkpointed queue (reopen path); de-duplicates
        against whatever is already queued by alert key."""
        have = {a.key for a in self._queue}
        for a in alerts:
            if a.key not in have:
                self._queue.append(a)
                have.add(a.key)
        self._trim()

    def enqueue(self, alerts: Sequence[Alert]) -> None:
        self._queue.extend(alerts)
        self._trim()

    def emit(self, alerts: Sequence[Alert]) -> None:
        """AlertSink-compatible convenience: enqueue + one attempt."""
        self.enqueue(alerts)
        self.try_deliver()

    def try_deliver(self) -> bool:
        """One delivery attempt of the whole queue (all-or-nothing per
        attempt).  Honors the backoff window; returns True if the queue
        is empty afterwards."""
        if not self._queue:
            return True
        now = self._clock()
        if now < self._next_attempt:
            return False
        batch = list(self._queue)
        try:
            chaos.failpoint("ingest.sink.deliver")
            self.sink.emit(batch)
        except Exception:
            self._failures += 1
            if self._first_failure_at is None:
                self._first_failure_at = now
            if self.give_up_after_s is not None \
                    and now - self._first_failure_at >= self.give_up_after_s:
                # Total-deadline cap: this batch has been failing for the
                # whole budget — drop it so fresh alerts aren't starved
                # behind a dead sink, and count the loss loudly.
                for _ in batch:
                    self._queue.popleft()
                self.expired += len(batch)
                self._failures = 0
                self._next_attempt = 0.0
                self._first_failure_at = None
                return not self._queue
            self._next_attempt = now + self.policy.backoff_s(self._failures)
            return False
        for _ in batch:
            self._queue.popleft()
        self.delivered += len(batch)
        self._failures = 0
        self._next_attempt = 0.0
        self._first_failure_at = None
        return not self._queue

    def drain(self, timeout_s: float = 5.0) -> bool:
        """Blocking flush (shutdown path): retry until the queue is empty
        or ``timeout_s`` passes.  Returns True when fully drained."""
        deadline = self._clock() + timeout_s
        while self._queue:
            if self.try_deliver():
                return True
            now = self._clock()
            if now >= deadline:
                return False
            self._sleep(min(max(self._next_attempt - now, 1e-3),
                            deadline - now))
        return True

    def _trim(self) -> None:
        while len(self._queue) > self.max_queue:
            self._queue.popleft()
            self.dropped += 1


def dedup_by_key(alerts: Sequence[Alert]) -> list[Alert]:
    """Consumer-side helper: first occurrence per :attr:`Alert.key` (the
    exactly-once view over an at-least-once stream)."""
    seen: set = set()
    out = []
    for a in alerts:
        if a.key not in seen:
            seen.add(a.key)
            out.append(a)
    return out
