"""mind [arXiv:1904.08030] — multi-interest capsule retrieval: dim 64,
4 interests, 3 routing iterations.  Item vocabulary 1M (retrieval corpus)."""
from repro.configs.base import RecArch, register
from repro.configs.rec_shapes import rec_shapes


@register("mind")
def config() -> RecArch:
    return RecArch(
        name="mind", family="mind", embed_dim=64,
        n_sparse=1, vocab_sizes=(1_000_000,),
        n_interests=4, capsule_iters=3, seq_len=50,
        interaction="multi-interest",
        shapes=rec_shapes(),
        citation="arXiv:1904.08030 (MIND)",
    )
