"""dlrm-rm2 [arXiv:1906.00091] — 13 dense + 26 sparse features, dim 64,
bot 13-512-256-64, top 512-512-256-1, dot interaction.

Table sizes follow the Criteo-scale RM2 mix (4x10M + 6x1M + 16x100k rows =
47.6M rows x 64 = 12.2 GB f32), row-sharded over 'model'.
"""
from repro.configs.base import RecArch, register
from repro.configs.rec_shapes import rec_shapes

VOCABS = tuple([10_000_000] * 4 + [1_000_000] * 6 + [100_000] * 16)


@register("dlrm-rm2")
def config() -> RecArch:
    return RecArch(
        name="dlrm-rm2", family="dlrm", embed_dim=64,
        n_dense=13, n_sparse=26, vocab_sizes=VOCABS,
        bot_mlp=(13, 512, 256, 64), top_mlp=(512, 512, 256, 1),
        interaction="dot",
        shapes=rec_shapes(),
        citation="arXiv:1906.00091 (DLRM)",
    )
