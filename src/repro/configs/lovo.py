"""The paper's own system as an arch: LOVO index + two-stage query.

Shapes cover the paper's three cost regimes (Fig. 9): offline encode+index
build, online fast search (scaling N per Fig. 10/11), and cross-modality
rerank.  ``query_256m`` is the pod-scale cell: 256M indexed patches ~ 450k
key frames x 576 patches ~ 3.7k hours of video at the paper's key-frame
rates — the "large-scale video dataset" regime the paper targets.
"""
from repro.configs.base import LovoArch, register, shape


@register("lovo")
def config() -> LovoArch:
    return LovoArch(
        name="lovo",
        pq_subspaces=64, pq_centroids=256, imi_k=128,
        top_a_cells=64, max_cell_size=4096,
        shapes=(
            shape("build_encode", "lovo_build", frames=4096,
                  notes="offline: ViT encode 4096 key frames + PQ encode"),
            shape("query_16m", "lovo_query", n_rows=16_777_216, queries=64,
                  notes="online fast search, 16M indexed patches"),
            shape("query_256m", "lovo_query", n_rows=268_435_456, queries=64,
                  notes="pod-scale fast search, 256M patches"),
            shape("rerank_64", "lovo_rerank", candidates=64,
                  notes="stage-2 cross-modality rerank of 64 frames"),
        ),
    )
