"""xdeepfm [arXiv:1803.05170] — 39 sparse fields, dim 10, CIN 200-200-200,
deep MLP 400-400, linear term.  Criteo-style vocabulary mix."""
from repro.configs.base import RecArch, register
from repro.configs.rec_shapes import rec_shapes

VOCABS = tuple([1_000_000] * 8 + [100_000] * 15 + [10_000] * 16)


@register("xdeepfm")
def config() -> RecArch:
    return RecArch(
        name="xdeepfm", family="xdeepfm", embed_dim=10,
        n_sparse=39, vocab_sizes=VOCABS,
        cin_layers=(200, 200, 200), mlp_layers=(400, 400),
        interaction="cin",
        shapes=rec_shapes(),
        citation="arXiv:1803.05170 (xDeepFM)",
    )
