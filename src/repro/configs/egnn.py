"""egnn [arXiv:2102.09844] — E(n)-equivariant GNN, 4 layers, d_hidden 64.

LOVO's technique (vector index / ANN) is inapplicable to message passing —
implemented without it (DESIGN.md §5).  Four shape regimes per the
assignment; ``minibatch_lg`` uses the real layer-wise neighbor sampler in
``repro.data.graph``.
"""
from repro.configs.base import GNNArch, register, shape
from repro.data.graph import SamplerSpec

SAMPLER = SamplerSpec(batch_nodes=1024, fanouts=(15, 10))


@register("egnn")
def config() -> GNNArch:
    return GNNArch(
        name="egnn", family="egnn", n_layers=4, d_hidden=64,
        equivariance="E(n)",
        shapes=(
            shape("full_graph_sm", "gnn_train", n_nodes=2708, n_edges=10556,
                  d_feat=1433, n_classes=7,
                  rules={"nodes": None, "edges": None}),
            shape("minibatch_lg", "gnn_sampled",
                  n_nodes=232_965, n_edges=114_615_892,
                  batch_nodes=1024, d_feat=602, n_classes=41,
                  pad_nodes=SAMPLER.max_nodes, pad_edges=SAMPLER.max_edges,
                  # sampled subgraphs are independent -> shard the *batch of
                  # subgraphs* over data; one subgraph per device group
                  graphs_per_step=16,
                  rules={"batch": ("data",)}),
            shape("ogb_products", "gnn_train", n_nodes=2_449_029,
                  n_edges=61_859_140, d_feat=100, n_classes=47,
                  rules={"edges": ("data", "model"), "nodes": None}),
            shape("molecule", "gnn_molecule", n_nodes=30, n_edges=64,
                  batch=128, d_feat=16,
                  rules={"nodes": ("data",), "edges": ("data",)}),
        ),
        citation="arXiv:2102.09844 (EGNN)",
    )
