"""Shared LM-family shape set: every LM arch gets the same four cells with
per-arch grad-accum / rule overrides supplied by the config file."""
from __future__ import annotations

from repro.configs.base import ShapeSpec, shape


def lm_shapes(*, train_accum: int = 8,
              train_rules: dict | None = None,
              decode_rules: dict | None = None,
              long_rules: dict | None = None) -> tuple[ShapeSpec, ...]:
    decode_rules = decode_rules or {"seq": ("model",)}
    long_rules = long_rules or {"seq": ("data", "model"), "batch": None}
    return (
        shape("train_4k", "train", seq_len=4096, global_batch=256,
              grad_accum=train_accum, rules=train_rules or {}),
        shape("prefill_32k", "prefill", seq_len=32768, global_batch=32),
        shape("decode_32k", "decode", seq_len=32768, global_batch=128,
              rules=decode_rules),
        shape("long_500k", "decode", seq_len=524288, global_batch=1,
              rules=long_rules,
              notes="long-context decode: O(L) per step vs the 500k KV cache;"
                    " quadratic-prefill caveat recorded in DESIGN.md"),
    )
