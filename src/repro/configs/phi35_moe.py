"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct] — 32L MoE,
16 experts top-2, GQA kv=8."""
from repro.configs.base import LMArch, MoESpec, register
from repro.configs.lm_shapes import lm_shapes


@register("phi3.5-moe-42b-a6.6b")
def config() -> LMArch:
    return LMArch(
        name="phi3.5-moe-42b-a6.6b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab=32_064,
        act="silu", tie_embeddings=False, rope_theta=10_000.0,
        moe=MoESpec(n_experts=16, top_k=2, expert_ff=6400),
        rules=(("embed", ("data",)),),
        shapes=lm_shapes(train_accum=8),
        citation="hf:microsoft/Phi-3.5-MoE-instruct",
    )
