"""bert4rec [arXiv:1904.06690] — bidirectional item-sequence transformer:
dim 64, 2 blocks, 2 heads, seq 200.  Item vocabulary 1M."""
from repro.configs.base import RecArch, register
from repro.configs.rec_shapes import rec_shapes


@register("bert4rec")
def config() -> RecArch:
    return RecArch(
        name="bert4rec", family="bert4rec", embed_dim=64,
        n_sparse=1, vocab_sizes=(1_000_000,),
        n_blocks=2, n_heads=2, seq_len=200,
        interaction="bidir-seq",
        shapes=rec_shapes(),
        citation="arXiv:1904.06690 (BERT4Rec)",
    )
