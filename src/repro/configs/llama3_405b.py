"""llama3-405b [arXiv:2407.21783] — dense, GQA kv=8, 128k vocab, untied head.

Memory notes (v5e 16 GB): f32 Adam states need 4.86 TB -> 19 GB/chip at 256
chips; we use bf16 moment states (12.7 GB/chip) + sequence-sharded
activations + grad-accum 16 so train_4k fits a single pod.  Decode shards the
KV cache (batch x 'data', seq x 'model') and 2D-shards weights.
"""
from repro.configs.base import LMArch, register
from repro.configs.lm_shapes import lm_shapes


@register("llama3-405b")
def config() -> LMArch:
    return LMArch(
        name="llama3-405b",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab=128_256,
        act="silu", tie_embeddings=False, rope_theta=500_000.0,
        opt_state_dtype="bfloat16",
        rules=(("embed", ("data",)),),  # FSDP + TP 2D weight sharding
        shapes=lm_shapes(
            train_accum=16,
            train_rules={"seq_act": ("model",)},  # Megatron-SP activations
        ),
        citation="arXiv:2407.21783 (Llama 3 herd)",
    )
