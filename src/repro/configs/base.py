"""Config system: architecture/shape dataclasses, registry, sharding rules.

Every assigned architecture is a frozen dataclass instance registered under its
public id (``--arch <id>``).  A config carries (a) exact model hyperparameters
from the public literature, (b) its own shape set, and (c) per-shape sharding
rules (logical axis -> mesh axes) which are the main perf-iteration lever.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence

# ---------------------------------------------------------------------------
# Logical axis names used throughout the model zoo.  Sharding rules map these
# to mesh axis names ('pod', 'data', 'model').  None -> replicated.
# ---------------------------------------------------------------------------
Rules = Mapping[str, Optional[tuple[str, ...]]]


def _freeze_rules(rules: Mapping[str, Any]) -> tuple[tuple[str, Any], ...]:
    return tuple(sorted((k, tuple(v) if v else None) for k, v in rules.items()))


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture."""

    name: str
    kind: str  # 'train' | 'prefill' | 'decode' | graph/* | rec/* | lovo/*
    dims: tuple[tuple[str, int], ...]  # frozen dict of shape dims
    # sharding-rule overrides for this shape (merged over arch defaults)
    rules: tuple[tuple[str, Any], ...] = ()
    # number of gradient-accumulation microsteps for train kinds
    grad_accum: int = 1
    notes: str = ""

    def dim(self, key: str) -> int:
        for k, v in self.dims:
            if k == key:
                return v
        raise KeyError(f"shape {self.name} has no dim {key}")

    def get(self, key: str, default: int | None = None) -> int | None:
        for k, v in self.dims:
            if k == key:
                return v
        return default


def shape(name: str, kind: str, *, rules: Mapping[str, Any] | None = None,
          grad_accum: int = 1, notes: str = "", **dims: int) -> ShapeSpec:
    return ShapeSpec(name=name, kind=kind, dims=tuple(dims.items()),
                     rules=_freeze_rules(rules or {}), grad_accum=grad_accum,
                     notes=notes)


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0  # leading layers that stay dense
    router_dtype: str = "float32"


@dataclass(frozen=True)
class LMArch:
    """Decoder-only transformer family (dense + MoE)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"  # silu -> SwiGLU; gelu -> GeGLU
    attn_softcap: float = 0.0  # gemma2: 50.0
    final_softcap: float = 0.0  # gemma2: 30.0
    sliding_window: int = 0  # gemma2: 4096
    local_global_pattern: bool = False  # gemma2: alternate local/global layers
    post_norms: bool = False  # gemma2: post-attn/post-ffn norms
    tie_embeddings: bool = True
    moe: Optional[MoESpec] = None
    # default sharding rules for this arch (overridable per shape)
    rules: tuple[tuple[str, Any], ...] = ()
    shapes: tuple[ShapeSpec, ...] = ()
    citation: str = ""
    # training defaults
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16/int8 for the very large archs
    remat_policy: str = "full"  # 'none' | 'full' | 'dots'
    scan_layers: bool = True
    # attention implementation: chunked (flash-memory-class, XLA-lowerable
    # twin of the Pallas kernel) kicks in when seq > attn_chunk; 0 = full
    attn_chunk: int = 1024
    attn_unroll: bool = False  # dry-run cost probes: unroll the chunk scan
    # re-constrain layer weights to their 2D (fsdp x tp) sharding inside the
    # scan body: pins FSDP gathers to per-layer lifetime (§Perf llama iter)
    constrain_layer_weights: bool = False
    # int8 KV cache (KIVI/KVQuant-class): per-(token, head) absmax scales;
    # halves decode cache HBM footprint+traffic vs bf16 (§Perf decode iter)
    kv_quant: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        if self.moe is not None:
            moe_layers = self.n_layers - self.moe.first_k_dense
            dense_layers = self.moe.first_k_dense
            expert = 3 * d * self.moe.expert_ff
            mlp_total = moe_layers * (self.moe.n_experts + self.moe.n_shared_experts) * expert \
                + moe_layers * d * self.moe.n_experts \
                + dense_layers * 3 * d * self.d_ff
        else:
            mlp_total = self.n_layers * 3 * d * self.d_ff
        norms = self.n_layers * d * (4 if self.post_norms else 2) + d
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * attn + mlp_total + norms + embed

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        moe_layers = self.n_layers - self.moe.first_k_dense
        active_mlp = moe_layers * (self.moe.top_k + self.moe.n_shared_experts) \
            * 3 * d * self.moe.expert_ff \
            + self.moe.first_k_dense * 3 * d * self.d_ff
        embed = self.vocab * d
        return self.n_layers * attn + active_mlp + embed


@dataclass(frozen=True)
class GNNArch:
    name: str
    family: str  # 'egnn'
    n_layers: int
    d_hidden: int
    equivariance: str = "E(n)"
    agg_dtype: str = "float32"  # bf16 halves the full-graph psum (§Perf)
    rules: tuple[tuple[str, Any], ...] = ()
    shapes: tuple[ShapeSpec, ...] = ()
    citation: str = ""
    param_dtype: str = "float32"


@dataclass(frozen=True)
class RecArch:
    name: str
    family: str  # 'xdeepfm' | 'mind' | 'dlrm' | 'bert4rec'
    embed_dim: int
    n_dense: int = 0
    n_sparse: int = 0
    vocab_sizes: tuple[int, ...] = ()  # per sparse feature
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    cin_layers: tuple[int, ...] = ()
    mlp_layers: tuple[int, ...] = ()
    n_interests: int = 0
    capsule_iters: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    seq_len: int = 0
    interaction: str = ""
    rules: tuple[tuple[str, Any], ...] = ()
    shapes: tuple[ShapeSpec, ...] = ()
    citation: str = ""
    param_dtype: str = "float32"


@dataclass(frozen=True)
class LovoArch:
    """The paper's own system: index + two-stage query pipeline."""

    name: str
    # visual / text encoders (ViT-B/32-class by default)
    vit_layers: int = 12
    vit_d_model: int = 768
    vit_heads: int = 12
    vit_patch: int = 32
    img_res: int = 768  # -> 24x24 = 576 patches per key frame
    txt_layers: int = 12
    txt_d_model: int = 512
    txt_heads: int = 8
    txt_vocab: int = 32_000
    txt_seq: int = 64
    embed_dim: int = 512  # D' class-embedding dim (shared with text space)
    # PQ / IMI
    pq_subspaces: int = 64  # P
    pq_centroids: int = 256  # M
    imi_k: int = 128  # coarse centroids per half -> K^2 cells
    top_a_cells: int = 64
    max_cell_size: int = 4096
    # rerank transformer
    rerank_layers: int = 6
    rerank_d_model: int = 256
    rerank_heads: int = 8
    rules: tuple[tuple[str, Any], ...] = ()
    shapes: tuple[ShapeSpec, ...] = ()
    citation: str = "LOVO (CS.IR 2025); Owl-ViT arXiv:2205.06230; IMI Babenko&Lempitsky 2012; PQ Jegou TPAMI'11"
    param_dtype: str = "float32"


Arch = Any  # LMArch | GNNArch | RecArch | LovoArch

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], Arch]] = {}


def register(name: str):
    def deco(fn: Callable[[], Arch]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str) -> Arch:
    if name not in _REGISTRY:
        # import config modules lazily so `import repro` stays cheap
        import importlib
        for mod in ("gemma2_9b", "llama3_405b", "qwen2_0_5b", "phi35_moe",
                    "kimi_k2", "egnn", "xdeepfm", "mind", "dlrm_rm2",
                    "bert4rec", "lovo"):
            importlib.import_module(f"repro.configs.{mod}")
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    get_arch.__wrapped__ = None  # noqa: trigger lazy imports below
    try:
        get_arch("__none__")
    except KeyError:
        pass
    return sorted(_REGISTRY)


def merged_rules(arch: Arch, spec: ShapeSpec) -> dict[str, Optional[tuple[str, ...]]]:
    """Arch default rules overlaid with per-shape overrides."""
    out: dict[str, Optional[tuple[str, ...]]] = dict(DEFAULT_RULES)
    out.update({k: (tuple(v) if v else None) for k, v in arch.rules})
    out.update({k: (tuple(v) if v else None) for k, v in spec.rules})
    return out


# Default logical->mesh mapping (single-pod).  The multi-pod dryrun prepends
# 'pod' to the batch axis mapping automatically (see launch/sharding.py).
DEFAULT_RULES: dict[str, Optional[tuple[str, ...]]] = {
    # activations
    "batch": ("data",),
    "seq": None,
    "seq_act": None,
    "act_embed": None,
    "act_heads": ("model",),
    "act_kv_heads": None,
    "act_ff": ("model",),
    "vocab_out": ("model",),
    # params
    "embed": None,
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": None,
    "qkv": None,
    "ff": ("model",),
    "experts": ("model",),
    "expert_ff": None,
    "layers": None,
    # fsdp-style weight sharding axis (applied to the *other* dim of big mats)
    "fsdp": ("data",),
    # recsys / lovo
    "table_rows": ("model",),
    "index_rows": ("data", "model"),
    "candidates": ("data", "model"),
    # gnn
    "nodes": ("data",),
    "edges": ("data", "model"),
}
