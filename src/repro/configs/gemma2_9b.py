"""gemma2-9b [arXiv:2408.00118; hf] — dense, GQA kv=8, local+global
alternating sliding-window attention, attn/final logit softcaps, pre+post
sandwich norms, GeGLU, 256k vocab."""
from repro.configs.base import LMArch, register
from repro.configs.lm_shapes import lm_shapes


@register("gemma2-9b")
def config() -> LMArch:
    return LMArch(
        name="gemma2-9b",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=14336, vocab=256_000,
        act="gelu", attn_softcap=50.0, final_softcap=30.0,
        sliding_window=4096, local_global_pattern=True, post_norms=True,
        tie_embeddings=True, rope_theta=10_000.0,
        rules=(("embed", ("data",)),),  # FSDP big matrices over 'data'
        shapes=lm_shapes(train_accum=8),
        citation="arXiv:2408.00118 (Gemma 2); hf:google/gemma-2-9b",
    )
