"""Shared recsys shape set (4 cells per arch)."""
from repro.configs.base import ShapeSpec, shape


def rec_shapes(*, train_accum: int = 1) -> tuple[ShapeSpec, ...]:
    return (
        shape("train_batch", "rec_train", batch=65_536,
              grad_accum=train_accum),
        shape("serve_p99", "rec_serve", batch=512,
              notes="online inference: latency-critical, small batch"),
        shape("serve_bulk", "rec_serve", batch=262_144,
              notes="offline scoring: throughput regime"),
        shape("retrieval_cand", "rec_retrieval", batch=1,
              n_candidates=1_000_000,
              rules={"candidates": ("data", "model")},
              notes="1 query vs 1e6 candidates = LOVO fast-search regime"),
    )
