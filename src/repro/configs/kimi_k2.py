"""kimi-k2-1t-a32b [arXiv:2501.kimi2 paper-table] — 61L, MoE 384 experts
top-8 + 1 shared expert, GQA kv=8, 163k vocab.

Memory notes: ~1.03e12 params.  Full-f32 Adam (12 B/param) = 12.4 TB — does
not fit 256 or 512 v5e chips; int8 moment states (Dettmers 8-bit Adam) bring
train state to ~6 B/param = 6.2 TB -> 12.1 GB/chip at 512 chips (multi-pod
fits; single-pod 256 is flagged over-budget in EXPERIMENTS.md with the
mitigation recorded).
"""
from repro.configs.base import LMArch, MoESpec, register
from repro.configs.lm_shapes import lm_shapes


@register("kimi-k2-1t-a32b")
def config() -> LMArch:
    return LMArch(
        name="kimi-k2-1t-a32b",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=2048, vocab=163_840,
        act="silu", tie_embeddings=False, rope_theta=50_000.0,
        moe=MoESpec(n_experts=384, top_k=8, expert_ff=2048,
                    n_shared_experts=1, first_k_dense=0),
        opt_state_dtype="int8",
        rules=(("embed", ("data",)),),
        shapes=lm_shapes(
            train_accum=16,
            train_rules={"seq_act": ("model",)},
        ),
        citation="arXiv:2501.kimi2 (Kimi K2 paper table; unverified tier)",
    )
