"""qwen2-0.5b [arXiv:2407.10671; hf] — dense, GQA kv=2, QKV bias, tied head."""
from repro.configs.base import LMArch, register
from repro.configs.lm_shapes import lm_shapes


@register("qwen2-0.5b")
def config() -> LMArch:
    return LMArch(
        name="qwen2-0.5b",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab=151_936,
        act="silu", qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
        shapes=lm_shapes(train_accum=4),
        citation="arXiv:2407.10671 (Qwen2); hf:Qwen/Qwen2-0.5B",
    )
