"""Unified retry / deadline / circuit-breaker / degradation primitives
(DESIGN.md §16.2–§16.3).

One policy vocabulary for every seam that can fail, replacing the
scattered ad-hoc versions (the router's consecutive-failure counters, the
alert sink's fixed doubling, bare sleeps):

  * :class:`RetryPolicy` — exponential backoff with deterministic seeded
    jitter and a per-call deadline budget; ``backoff_s(attempt)`` is the
    pure schedule, ``call(fn, ...)`` the retry loop.
  * :class:`Deadline` — an absolute time budget propagated
    MicroBatcher → router → shard calls; ``expired``/``remaining`` are
    the only questions anyone asks of it.
  * :class:`CircuitBreaker` — per-replica closed → open → half-open
    state machine: open after ``failure_threshold`` consecutive
    failures, refuse while open, allow ``half_open_probes`` trial calls
    after ``recovery_s``, close again on probe success.
  * :class:`Completeness` / :class:`DegradedResult` — the graceful-
    degradation contract: a degraded read says exactly which shards
    answered and how many rows they cover, and anything carrying an
    incomplete :class:`Completeness` must never enter a result cache
    (``ResultCache.put`` enforces the exclusion).

Pure stdlib + dataclasses: importable from the router, the batcher, the
ingest sink, and tests without dragging jax in.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, Callable, Optional, Sequence, Tuple


class DeadlineExceeded(TimeoutError):
    """The request's time budget ran out (before or between attempts)."""


@dataclasses.dataclass(frozen=True)
class Deadline:
    """An absolute expiry on the monotonic clock.

    Built once at the request edge (``Deadline.after(budget_s)``) and
    passed down the call tree by value — every layer subtracts nothing,
    computes nothing, just asks ``remaining()``/``expired()`` against the
    same clock.
    """

    expires_at: float
    clock: Callable[[], float] = time.monotonic

    @classmethod
    def after(cls, seconds: float,
              clock: Callable[[], float] = time.monotonic) -> "Deadline":
        return cls(expires_at=clock() + float(seconds), clock=clock)

    def remaining(self) -> float:
        return self.expires_at - self.clock()

    def expired(self) -> bool:
        return self.remaining() <= 0.0

    def check(self, what: str = "request") -> None:
        if self.expired():
            raise DeadlineExceeded(f"{what} deadline exceeded "
                                   f"({-self.remaining():.3f}s over)")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic seeded jitter.

    ``backoff_s(attempt)`` for attempt = 1, 2, ... is
    ``min(base * multiplier**(attempt-1), max)`` scaled by a jitter
    factor drawn from ``random.Random((seed, attempt))`` — the same
    (policy, attempt) always sleeps the same time, so retry storms are
    decorrelated across seeds yet every run is replayable.
    """

    max_attempts: int = 4
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.5        # backoff is scaled by 1 +/- jitter*u
    seed: int = 0

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry number ``attempt`` (1-based failure count)."""
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        raw = min(self.base_backoff_s * self.multiplier ** (attempt - 1),
                  self.max_backoff_s)
        if self.jitter:
            u = random.Random((self.seed, attempt)).random()   # [0, 1)
            raw *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return min(raw, self.max_backoff_s)

    def call(self, fn: Callable[..., Any], *args: Any,
             deadline: Optional[Deadline] = None,
             retry_on: Tuple[type, ...] = (Exception,),
             sleep: Callable[[float], None] = time.sleep,
             **kwargs: Any) -> Any:
        """Run ``fn`` with retries.  ``deadline`` caps the WHOLE loop: an
        expired budget raises :class:`DeadlineExceeded` instead of
        sleeping into a window nobody is waiting for, and a backoff is
        clipped to the remaining budget."""
        last: Optional[BaseException] = None
        for attempt in range(1, self.max_attempts + 1):
            if deadline is not None:
                deadline.check("retry")
            try:
                return fn(*args, **kwargs)
            except DeadlineExceeded:
                raise
            except retry_on as e:
                last = e
                if attempt == self.max_attempts:
                    raise
                pause = self.backoff_s(attempt)
                if deadline is not None:
                    left = deadline.remaining()
                    if left <= 0:
                        raise DeadlineExceeded(
                            "retry deadline exceeded after "
                            f"{attempt} attempt(s)") from e
                    pause = min(pause, left)
                if pause > 0:
                    sleep(pause)
        raise last  # type: ignore[misc]  # unreachable


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------
STATE_CLOSED = "closed"
STATE_OPEN = "open"
STATE_HALF_OPEN = "half-open"


class CircuitBreaker:
    """Per-target failure gate with half-open probing.

    CLOSED: all calls pass; ``failure_threshold`` consecutive failures
    trip it OPEN.  OPEN: calls refused until ``recovery_s`` has elapsed,
    then the next :meth:`try_acquire` moves to HALF-OPEN and admits up to
    ``half_open_probes`` concurrent probe calls.  A probe success closes
    the breaker (counter reset); a probe failure re-opens it (the
    recovery window restarts).  ``recovery_s=0`` means an open breaker
    is immediately probeable — the legacy ``recovery_probe_s=0.0``
    router behavior.

    Thread-safe; every decision point is under one lock.
    """

    def __init__(self, *, failure_threshold: int = 3,
                 recovery_s: float = 5.0, half_open_probes: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = int(failure_threshold)
        self.recovery_s = float(recovery_s)
        self.half_open_probes = max(1, int(half_open_probes))
        self._clock = clock
        self._lock = threading.Lock()
        self._state = STATE_CLOSED
        self._failures = 0            # consecutive, resets on success
        self._opened_at = 0.0
        self._probes_inflight = 0
        self.opens = 0                # lifetime trips (observability)

    # -- views --------------------------------------------------------------
    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    @property
    def failures(self) -> int:
        with self._lock:
            return self._failures

    @property
    def closed(self) -> bool:
        return self.state == STATE_CLOSED

    def can_attempt(self) -> bool:
        """Would a call be admitted right now?  Non-consuming: does not
        take a probe slot (use :meth:`try_acquire` to actually claim)."""
        with self._lock:
            if self._state == STATE_CLOSED:
                return True
            if self._state == STATE_OPEN:
                return self._clock() - self._opened_at >= self.recovery_s
            return self._probes_inflight < self.half_open_probes

    # -- transitions ---------------------------------------------------------
    def try_acquire(self) -> bool:
        """Claim permission for one call.  In OPEN-past-recovery this
        transitions to HALF-OPEN and takes a probe slot; callers MUST
        follow up with :meth:`record_success` or :meth:`record_failure`."""
        with self._lock:
            if self._state == STATE_CLOSED:
                return True
            if self._state == STATE_OPEN:
                if self._clock() - self._opened_at < self.recovery_s:
                    return False
                self._state = STATE_HALF_OPEN
                self._probes_inflight = 0
            if self._probes_inflight >= self.half_open_probes:
                return False
            self._probes_inflight += 1
            return True

    def record_success(self) -> None:
        with self._lock:
            if self._state == STATE_HALF_OPEN:
                self._probes_inflight = max(0, self._probes_inflight - 1)
            self._state = STATE_CLOSED
            self._failures = 0

    def record_failure(self) -> None:
        with self._lock:
            if self._state == STATE_HALF_OPEN:
                self._probes_inflight = max(0, self._probes_inflight - 1)
                self._trip()
                return
            self._failures += 1
            if self._state == STATE_CLOSED \
                    and self._failures >= self.failure_threshold:
                self._trip()

    def force_close(self) -> None:
        """Operator override (the router's ``mark_recovered``)."""
        with self._lock:
            self._state = STATE_CLOSED
            self._failures = 0
            self._probes_inflight = 0

    def force_open(self) -> None:
        with self._lock:
            self._trip()

    def _trip(self) -> None:
        # caller holds the lock
        self._state = STATE_OPEN
        self._opened_at = self._clock()
        self._failures = max(self._failures, self.failure_threshold)
        self._probes_inflight = 0
        self.opens += 1


# ---------------------------------------------------------------------------
# Graceful degradation contract
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Completeness:
    """How much of the index a (possibly degraded) answer covers.

    Attached to every opted-in degraded read (``QueryRouter.call_sharded``
    with ``degraded_ok=True``): the caller can decide whether "3 of 4
    shards, 75% of rows, generation 7" is good enough to show — the
    system never decides that silently.  ``complete`` is the cache
    admission test: ``ResultCache.put`` refuses anything incomplete.
    """

    shards_total: int
    shards_answered: int
    missing: tuple[str, ...] = ()       # replica names that did not answer
    rows_total: Optional[int] = None    # from RoutingTable row ranges
    rows_covered: Optional[int] = None
    generation: Optional[int] = None    # routing generation answered under

    @property
    def complete(self) -> bool:
        return self.shards_answered == self.shards_total \
            and not self.missing

    @property
    def coverage(self) -> float:
        """Fraction of rows covered (falls back to shard fraction when
        row ranges are unknown)."""
        if self.rows_total:
            return (self.rows_covered or 0) / self.rows_total
        if self.shards_total:
            return self.shards_answered / self.shards_total
        return 0.0


@dataclasses.dataclass(frozen=True)
class DegradedResult:
    """A merged answer plus its :class:`Completeness`.  ``value`` is
    whatever the caller's merge fn produced over the shards that DID
    answer; consumers must check ``completeness.complete`` before
    treating it as authoritative."""

    value: Any
    completeness: Completeness


def completeness_from_routing(answered: Sequence[str],
                              missing: Sequence[str],
                              routing: Any = None) -> Completeness:
    """Build a :class:`Completeness` from answered/missing replica names,
    pulling row ranges and the generation off a
    ``core.distributed.RoutingTable`` when one is installed."""
    answered = list(answered)
    missing = tuple(missing)
    rows_total = rows_covered = generation = None
    if routing is not None:
        generation = getattr(routing, "generation", None)
        assignments = getattr(routing, "assignments", None)
        if assignments:
            spans = {a.replica: a.row_range[1] - a.row_range[0]
                     for a in assignments}
            total = sum(spans.values())
            if total > 0:
                rows_total = total
                rows_covered = sum(spans.get(n, 0) for n in answered)
    return Completeness(
        shards_total=len(answered) + len(missing),
        shards_answered=len(answered), missing=missing,
        rows_total=rows_total, rows_covered=rows_covered,
        generation=generation)
