"""Product Quantization (Jegou et al., TPAMI'11) — LOVO §V-B.

The class-embedding space R^{D'} is split into P subspaces of dim m = D'/P;
each subspace is quantized by a two-level **coarse + residual** codebook
(DESIGN.md §9): a small coarse stage of G cells per subspace, and M residual
centroids around each cell, expanded into a single (P, G*M, m) table

    centroids[p, g*M + c] = coarse[p, g] + resid[p, c]

so a vector is still stored as P uint8 codes and the per-cell offset term
(q_p . coarse[p, g]) is folded into the similarity LUT by construction —
every ADC consumer (``adc_scores``, the ``pq_scan`` Pallas kernels, the
recsys transfer path) stays score-correct with zero plumbing.  The expanded
table is then polished by fused Lloyd iterations, which revives unused
product combinations via empty-cluster re-seeding.  At the same 8-bit/
subspace storage this roughly halves reconstruction MSE vs the seed's flat
M-entry Lloyd (the root cause of the seed recall failure).

An optional OPQ-style learned rotation (``train_opq``: alternating
Procrustes + Lloyd, Ge et al. CVPR'13) is carried inside the ``PQ`` pytree;
``pq_encode`` / ``pq_decode`` / ``similarity_lut`` apply it internally, so
rotated codebooks are drop-in everywhere a plain ``PQ`` is.

All functions are jit-friendly; Lloyd's assignment step runs through the
fused Pallas kernel (`repro.kernels.kmeans`) and never materializes the
(N, M) distance matrix in HBM; the ADC scan has a Pallas TPU kernel
(`repro.kernels.pq_scan`) with this module's ``adc_scores`` as the oracle's
semantics (see kernels/ref.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# k-means (fused-assignment Lloyd) with k-means++ seeding
# ---------------------------------------------------------------------------
def _pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """(N, m) x (M, m) -> (N, M) squared euclidean, clamped to >= 0.

    The expanded form ``|x|^2 - 2 x.c + |c|^2`` cancels catastrophically for
    near-duplicate points: tiny negative outputs would poison k-means++
    sampling probabilities and ``drift_score`` downstream.
    """
    x2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    c2 = jnp.sum(jnp.square(c), axis=-1)
    return jnp.maximum(x2 - 2.0 * (x @ c.T) + c2[None, :], 0.0)


def kmeans_pp_init(rng: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding (Arthur & Vassilvitskii '07).  O(N) memory: keeps a
    running min-distance vector, never an (N, k) matrix."""
    n = x.shape[0]
    r0, rng = jax.random.split(rng)
    first = x[jax.random.randint(r0, (), 0, n)]
    cents = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)

    def body(i, carry):
        cents, rng, d2 = carry
        # distance to the newest centroid; keep running min
        newest = jax.lax.dynamic_index_in_dim(cents, i - 1, keepdims=False)
        d_new = jnp.sum(jnp.square(x - newest), axis=-1)
        d2 = jnp.minimum(d2, d_new)
        rng, sub = jax.random.split(rng)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-12)
        idx = jax.random.categorical(sub, jnp.log(probs + 1e-30))
        return cents.at[i].set(x[idx]), rng, d2

    init_d2 = jnp.full((n,), jnp.inf, x.dtype)
    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, rng, init_d2))
    return cents


def _lloyd_update(x: jax.Array, cents: jax.Array, assign: jax.Array,
                  dist: jax.Array) -> jax.Array:
    """One centroid update given fused-kernel assignments.

    Empty clusters are re-seeded to the points farthest from their assigned
    centroid (rather than staying frozen at a stale position forever — the
    seed bug): the e-th empty cluster takes the e-th farthest point, so
    simultaneous empties land on distinct points.
    """
    k, n = cents.shape[0], x.shape[0]
    sums = jax.ops.segment_sum(x, assign, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign,
                                 num_segments=k)
    new = jnp.where(counts[:, None] > 0,
                    sums / jnp.maximum(counts, 1.0)[:, None], cents)
    empty = counts == 0
    far = jnp.argsort(-dist)
    rank = jnp.clip(jnp.cumsum(empty) - 1, 0, n - 1)
    return jnp.where(empty[:, None], x[far[rank]], new)


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(rng: jax.Array, x: jax.Array, k: int, iters: int = 20
           ) -> tuple[jax.Array, jax.Array]:
    """Lloyd's iteration.  Returns (centroids (k, m), assignments (N,)).

    The assignment step runs through the fused Pallas kernel
    (``kernels.kmeans.kmeans_assign``): each (block_n, k) distance tile
    lives only in VMEM — O(N * m) memory end to end.
    """
    from repro.kernels import ops as kops

    x = x.astype(jnp.float32)
    cents = kmeans_pp_init(rng, x, k)

    def step(_, cents):
        assign, dist = kops.kmeans_assign(x, cents)
        return _lloyd_update(x, cents, assign, dist)

    cents = jax.lax.fori_loop(0, iters, step, cents)
    assign, _ = kops.kmeans_assign(x, cents)
    return cents, assign


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_batched(rng: jax.Array, xs: jax.Array, k: int, iters: int = 20
                   ) -> tuple[jax.Array, jax.Array]:
    """B independent Lloyd problems (one per PQ subspace) in lockstep.

    xs: (B, N, m) -> (centroids (B, k, m), assignments (B, N)).  Assignment
    is ONE ``kmeans_assign_batched`` launch per iteration (grid (B, N/bn));
    the update/re-seed step is vmapped (segment-sum scatter, no (N, k)).
    """
    from repro.kernels import ops as kops

    xs = xs.astype(jnp.float32)
    keys = jax.random.split(rng, xs.shape[0])
    cents = jax.vmap(lambda r, x: kmeans_pp_init(r, x, k))(keys, xs)

    def step(_, cents):
        assign, dist = kops.kmeans_assign_batched(xs, cents)
        return jax.vmap(_lloyd_update)(xs, cents, assign, dist)

    cents = jax.lax.fori_loop(0, iters, step, cents)
    assign, _ = kops.kmeans_assign_batched(xs, cents)
    return cents, assign


# ---------------------------------------------------------------------------
# PQ codebooks
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PQ:
    """Expanded per-subspace codebooks + optional OPQ rotation.

    ``centroids``: (P, M_total, m) where M_total = G * M for a two-level
    (coarse + residual) codebook, or M for a flat one.  ``rotation``: an
    orthogonal (D', D') matrix or None; encode/decode/LUT apply it
    internally (encode-space y = x @ R.T, decode x_hat = y_hat @ R).
    """

    centroids: jax.Array  # (P, M_total, m)
    rotation: Optional[jax.Array] = None  # (D', D') orthogonal, or None

    @property
    def P(self) -> int:
        return self.centroids.shape[0]

    @property
    def M(self) -> int:
        """Total entries per subspace (G * M for two-level codebooks)."""
        return self.centroids.shape[1]

    @property
    def m(self) -> int:
        return self.centroids.shape[2]

    def tree_flatten(self):
        return (self.centroids, self.rotation), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node_class(PQ)


def split_subspaces(x: jax.Array, P: int) -> jax.Array:
    """(N, D') -> (P, N, m)."""
    n, d = x.shape
    assert d % P == 0, (d, P)
    return x.reshape(n, P, d // P).transpose(1, 0, 2)


def _rotate(x: jax.Array, rotation: Optional[jax.Array]) -> jax.Array:
    return x if rotation is None else x @ rotation.T


def _auto_coarse_cells(M: int) -> int:
    """Default coarse stage: 2 cells per subspace when the expanded table
    still fits uint8 codes.  G=4 shaves MSE further but doubles the ADC
    LUT/scan work again; G=2 is the balanced default (callers pass
    ``coarse_cells`` explicitly for accuracy-critical builds)."""
    return 2 if 2 * M <= 256 else 1


@functools.partial(jax.jit, static_argnames=("P", "M", "iters", "G"))
def _train_subspace_codebooks(rng: jax.Array, x: jax.Array, P: int, M: int,
                              iters: int, G: int) -> jax.Array:
    """Two-level product training in encode space -> (P, G*M, m).

    coarse (G cells/subspace) -> residual Lloyd (M centroids) -> expand to
    the G*M product table -> joint Lloyd polish (the product is an init;
    polishing revives unused combinations via empty-cluster re-seeding).
    """
    from repro.kernels import ops as kops

    subs = split_subspaces(x.astype(jnp.float32), P)        # (P, N, m)
    k1, k2 = jax.random.split(rng)
    if G > 1:
        coarse, a = kmeans_batched(k1, subs, G, iters)      # (P, G, m), (P, N)
        resid = subs - jnp.take_along_axis(
            coarse, a[..., None].astype(jnp.int32), axis=1)
    else:
        coarse = jnp.zeros((P, 1, subs.shape[-1]), jnp.float32)
        resid = subs
    rc, _ = kmeans_batched(k2, resid, M, iters)             # (P, M, m)
    expanded = (coarse[:, :, None, :] + rc[:, None, :, :]
                ).reshape(P, G * M, subs.shape[-1])

    def polish(_, cents):
        assign, dist = kops.kmeans_assign_batched(subs, cents)
        return jax.vmap(_lloyd_update)(subs, cents, assign, dist)

    return jax.lax.fori_loop(0, iters, polish, expanded)


def train_pq(rng: jax.Array, x: jax.Array, P: int, M: int, iters: int = 20,
             *, coarse_cells: Optional[int] = None,
             rotation: Optional[jax.Array] = None) -> PQ:
    """Train the two-level residual product quantizer.

    ``M`` is the residual codebook size per subspace; the stored table has
    G * M entries (G = ``coarse_cells``, default `_auto_coarse_cells`).
    ``rotation``: optional orthogonal (D', D') carried into the PQ (see
    ``train_opq``)."""
    G = _auto_coarse_cells(M) if coarse_cells is None else coarse_cells
    if G * M > 256:
        raise ValueError(f"expanded codebook {G}*{M} overflows uint8 codes")
    cents = _train_subspace_codebooks(
        rng, _rotate(x.astype(jnp.float32), rotation), P, M, iters, G)
    return PQ(centroids=cents, rotation=rotation)


def _procrustes(x: jax.Array, yhat: jax.Array) -> jax.Array:
    """Orthogonal R minimizing ||x @ R.T - yhat||_F (Ge et al. OPQ-NP)."""
    u, _, vt = jnp.linalg.svd(x.T @ yhat, full_matrices=False)
    return (u @ vt).T


def train_opq(rng: jax.Array, x: jax.Array, P: int, M: int, iters: int = 20,
              *, opq_iters: int = 3,
              coarse_cells: Optional[int] = None) -> PQ:
    """OPQ: alternate codebook training (Lloyd) with a Procrustes rotation
    update, then train the final codebooks at full iteration count in the
    learned rotation.  Returns a ``PQ`` with ``rotation`` set — drop-in for
    every consumer (encode/decode/LUT rotate internally)."""
    x = x.astype(jnp.float32)
    G = _auto_coarse_cells(M) if coarse_cells is None else coarse_cells
    if G * M > 256:
        raise ValueError(f"expanded codebook {G}*{M} overflows uint8 codes")
    rot = jnp.eye(x.shape[-1], dtype=jnp.float32)
    alt_iters = max(2, iters // 2)
    for i in range(opq_iters):
        sub = jax.random.fold_in(rng, i)
        y = x @ rot.T
        cents = _train_subspace_codebooks(sub, y, P, M, alt_iters, G)
        pq_i = PQ(centroids=cents)
        yhat = pq_decode(pq_i, pq_encode(pq_i, y))
        rot = _procrustes(x, yhat)
    cents = _train_subspace_codebooks(
        jax.random.fold_in(rng, opq_iters), x @ rot.T, P, M, iters, G)
    return PQ(centroids=cents, rotation=rot)


@jax.jit
def pq_encode(pq: PQ, x: jax.Array) -> jax.Array:
    """(N, D') -> uint8 codes (N, P).  Assignment runs through the fused
    Pallas kernel — no (N, M_total) distance matrix in HBM."""
    from repro.kernels import ops as kops

    subs = split_subspaces(
        _rotate(x.astype(jnp.float32), pq.rotation), pq.P)  # (P, N, m)
    assign, _ = kops.kmeans_assign_batched(subs, pq.centroids)
    return assign.T.astype(jnp.uint8)                       # (N, P)


@jax.jit
def pq_decode(pq: PQ, codes: jax.Array) -> jax.Array:
    """(N, P) -> reconstructed (N, D') (back-rotated to the original space)."""
    gathered = jax.vmap(lambda c, idx: c[idx], in_axes=(0, 1))(
        pq.centroids, codes.astype(jnp.int32))          # (P, N, m)
    out = gathered.transpose(1, 0, 2).reshape(codes.shape[0], -1)
    return out if pq.rotation is None else out @ pq.rotation


@jax.jit
def similarity_lut(pq: PQ, q: jax.Array) -> jax.Array:
    """Dot-product LUT: (D',) -> (P, M_total).

    LUT[p, e] = (R q)_p . centroids[p, e].  With the two-level expanded
    table, entry e = g*M + c is coarse[p, g] + resid[p, c], so the per-cell
    offset term (q_p . coarse cell) is folded into the LUT by construction
    and ``adc_scores``/the Pallas scan kernels need no extra term.
    """
    q = _rotate(q.astype(jnp.float32), pq.rotation)
    qs = q.reshape(pq.P, 1, pq.m)
    return jnp.sum(qs * pq.centroids, axis=-1)          # (P, M_total)


def adc_scores(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """ADC scan: (P, M) LUT + (N, P) codes -> (N,) scores.

    Reference formulation (take_along_axis); the Pallas kernel computes the
    same contraction as a one-hot matmul on the MXU.
    """
    per = jax.vmap(lambda l, c: l[c], in_axes=(0, 1))(lut, codes.astype(jnp.int32))
    return jnp.sum(per, axis=0)                          # (N,)


def normalize(x: jax.Array, eps: float = 1e-9) -> jax.Array:
    """Unit-L2 normalization — LOVO §V-A aligns dot product with cosine."""
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)
