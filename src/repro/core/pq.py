"""Product Quantization (Jegou et al., TPAMI'11) — LOVO §V-B.

The class-embedding space R^{D'} is split into P subspaces of dim m = D'/P;
each subspace is quantized to M centroids by Lloyd's iteration (k-means++
seeding).  A vector is stored as P uint8 codes; query similarity uses a
per-query lookup table (LUT[p, c] = q_p . centroid_{p,c}) and the ADC scan
``score(n) = sum_p LUT[p, code[n, p]]``.

All functions are jit-friendly; the ADC scan has a Pallas TPU kernel
(`repro.kernels.pq_scan`) with this module's ``adc_scores`` as the oracle's
semantics (see kernels/ref.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# k-means (Lloyd) with k-means++ seeding
# ---------------------------------------------------------------------------
def _pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """(N, m) x (M, m) -> (N, M) squared euclidean."""
    x2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    c2 = jnp.sum(jnp.square(c), axis=-1)
    return x2 - 2.0 * (x @ c.T) + c2[None, :]


def kmeans_pp_init(rng: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding (Arthur & Vassilvitskii '07)."""
    n = x.shape[0]
    r0, rng = jax.random.split(rng)
    first = x[jax.random.randint(r0, (), 0, n)]
    cents = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)

    def body(i, carry):
        cents, rng, d2 = carry
        # distance to the newest centroid; keep running min
        newest = jax.lax.dynamic_index_in_dim(cents, i - 1, keepdims=False)
        d_new = jnp.sum(jnp.square(x - newest), axis=-1)
        d2 = jnp.minimum(d2, d_new)
        rng, sub = jax.random.split(rng)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-12)
        idx = jax.random.categorical(sub, jnp.log(probs + 1e-30))
        return cents.at[i].set(x[idx]), rng, d2

    init_d2 = jnp.full((n,), jnp.inf, x.dtype)
    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, rng, init_d2))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(rng: jax.Array, x: jax.Array, k: int, iters: int = 20
           ) -> tuple[jax.Array, jax.Array]:
    """Lloyd's iteration.  Returns (centroids (k, m), assignments (N,))."""
    x = x.astype(jnp.float32)
    cents = kmeans_pp_init(rng, x, k)

    def step(cents, _):
        d2 = _pairwise_sqdist(x, cents)
        assign = jnp.argmin(d2, axis=-1)
        one = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        counts = one.sum(axis=0)
        sums = one.T @ x
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None],
                        cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    assign = jnp.argmin(_pairwise_sqdist(x, cents), axis=-1)
    return cents, assign


# ---------------------------------------------------------------------------
# PQ codebooks
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PQ:
    centroids: jax.Array  # (P, M, m)

    @property
    def P(self) -> int:
        return self.centroids.shape[0]

    @property
    def M(self) -> int:
        return self.centroids.shape[1]

    @property
    def m(self) -> int:
        return self.centroids.shape[2]

    def tree_flatten(self):
        return (self.centroids,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node_class(PQ)


def split_subspaces(x: jax.Array, P: int) -> jax.Array:
    """(N, D') -> (P, N, m)."""
    n, d = x.shape
    assert d % P == 0, (d, P)
    return x.reshape(n, P, d // P).transpose(1, 0, 2)


def train_pq(rng: jax.Array, x: jax.Array, P: int, M: int,
             iters: int = 20) -> PQ:
    subs = split_subspaces(x, P)  # (P, N, m)
    keys = jax.random.split(rng, P)
    cents, _ = jax.vmap(lambda k, s: kmeans(k, s, M, iters))(keys, subs)
    return PQ(centroids=cents)


@jax.jit
def pq_encode(pq: PQ, x: jax.Array) -> jax.Array:
    """(N, D') -> uint8 codes (N, P)."""
    subs = split_subspaces(x.astype(jnp.float32), pq.P)  # (P, N, m)
    d2 = jax.vmap(_pairwise_sqdist)(subs, pq.centroids)  # (P, N, M)
    return jnp.argmin(d2, axis=-1).T.astype(jnp.uint8)   # (N, P)


@jax.jit
def pq_decode(pq: PQ, codes: jax.Array) -> jax.Array:
    """(N, P) -> reconstructed (N, D')."""
    gathered = jax.vmap(lambda c, idx: c[idx], in_axes=(0, 1))(
        pq.centroids, codes.astype(jnp.int32))          # (P, N, m)
    return gathered.transpose(1, 0, 2).reshape(codes.shape[0], -1)


@jax.jit
def similarity_lut(pq: PQ, q: jax.Array) -> jax.Array:
    """Dot-product LUT: (D',) -> (P, M); LUT[p, c] = q_p . centroid_{p,c}."""
    qs = q.reshape(pq.P, 1, pq.m).astype(jnp.float32)
    return jnp.sum(qs * pq.centroids, axis=-1)          # (P, M)


def adc_scores(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """ADC scan: (P, M) LUT + (N, P) codes -> (N,) scores.

    Reference formulation (take_along_axis); the Pallas kernel computes the
    same contraction as a one-hot matmul on the MXU.
    """
    per = jax.vmap(lambda l, c: l[c], in_axes=(0, 1))(lut, codes.astype(jnp.int32))
    return jnp.sum(per, axis=0)                          # (N,)


def normalize(x: jax.Array, eps: float = 1e-9) -> jax.Array:
    """Unit-L2 normalization — LOVO §V-A aligns dot product with cosine."""
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)
