"""Incremental index maintenance — the paper's §IX future work, implemented.

LOVO's conclusion: "refine the vector database design by leveraging
segmented parallel processing to reduce the overhead of full rebuilds during
video updates and enhancing the incremental indexing strategy for new
insertions".  This module provides exactly that:

  * ``SegmentedIndex`` — a base (cell-sorted) IMIIndex plus up to
    ``max_segments`` small delta segments.  Inserts quantize against the
    FROZEN codebooks (no retrain) and append to the newest segment; queries
    search base + deltas and merge — search stays O(probe) on the base and
    O(delta) on the (bounded) deltas.
  * ``compact()`` — merges all segments into a new cell-sorted base in one
    pass (the "segmented rebuild": only the merge is periodic work, and it
    reuses stored codes — no re-encoding of video, preserving the paper's
    one-time-extraction economics).
  * deletes via a tombstone id-set applied at merge time.

Codebook drift: inserts reuse the trained coarse/PQ codebooks; quantization
error grows if the data distribution shifts.  ``drift_score()`` monitors
mean residual energy of recent inserts vs the training value so an operator
can schedule a retrain (full rebuild) when it degrades.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anns, imi as imimod, pq as pqmod
from repro.core.imi import IMIIndex


@dataclasses.dataclass
class DeltaSegment:
    codes: np.ndarray     # (n, P) uint8
    vectors: np.ndarray   # (n, D') bf16-able f32
    ids: np.ndarray       # (n,)
    cell_of: np.ndarray   # (n,)
    resid_energy: float


class SegmentedIndex:
    def __init__(self, base: IMIIndex, *, max_segments: int = 4,
                 segment_capacity: int = 65_536):
        self.base = base
        self.segments: list[DeltaSegment] = []
        self.max_segments = max_segments
        self.segment_capacity = segment_capacity
        self.tombstones: set[int] = set()
        # training-time residual energy baseline for drift monitoring
        rec = pqmod.pq_decode(base.pq, base.codes)
        self._train_resid = float(jnp.mean(jnp.sum(jnp.square(
            rec - self._base_residuals()), axis=-1)))

    def _base_residuals(self) -> jax.Array:
        K = self.base.K
        c1 = self.base.coarse1[self.base.cell_of // K]
        c2 = self.base.coarse2[self.base.cell_of % K]
        coarse = jnp.concatenate([c1, c2], axis=-1)
        return self.base.vectors.astype(jnp.float32) - coarse

    @property
    def n(self) -> int:
        return self.base.n + sum(len(s.ids) for s in self.segments) \
            - len(self.tombstones)

    # -- writes ---------------------------------------------------------------
    def insert(self, x: jax.Array, ids: np.ndarray) -> None:
        """Quantize new vectors against the frozen codebooks; append."""
        x = pqmod.normalize(jnp.asarray(x, jnp.float32))
        cell, a1, a2 = imimod.assign_cells(self.base.coarse1,
                                           self.base.coarse2, x)
        resid = x - imimod.coarse_reconstruct(self.base.coarse1,
                                              self.base.coarse2, a1, a2)
        codes = pqmod.pq_encode(self.base.pq, resid)
        rec = pqmod.pq_decode(self.base.pq, codes)
        energy = float(jnp.mean(jnp.sum(jnp.square(rec - resid), axis=-1)))
        seg = DeltaSegment(codes=np.asarray(codes),
                           vectors=np.asarray(x),
                           ids=np.asarray(ids, np.int64),
                           cell_of=np.asarray(cell, np.int32),
                           resid_energy=energy)
        if self.segments and (len(self.segments[-1].ids) + len(seg.ids)
                              <= self.segment_capacity):
            last = self.segments[-1]
            self.segments[-1] = DeltaSegment(
                codes=np.concatenate([last.codes, seg.codes]),
                vectors=np.concatenate([last.vectors, seg.vectors]),
                ids=np.concatenate([last.ids, seg.ids]),
                cell_of=np.concatenate([last.cell_of, seg.cell_of]),
                resid_energy=(last.resid_energy + energy) / 2)
        else:
            self.segments.append(seg)
        if len(self.segments) > self.max_segments:
            self.compact()

    def delete(self, ids) -> None:
        self.tombstones.update(int(i) for i in np.asarray(ids).ravel())

    def drift_score(self) -> float:
        """>1 means recent inserts quantize worse than training data."""
        if not self.segments:
            return 1.0
        recent = np.mean([s.resid_energy for s in self.segments])
        return float(recent / max(self._train_resid, 1e-12))

    # -- reads ----------------------------------------------------------------
    def search(self, q: jax.Array, cfg: anns.SearchConfig) -> dict:
        """Base probe search + brute scan of the (small) deltas; merged."""
        res = anns.search(self.base, q, cfg)
        ids = np.asarray(res["ids"])
        scores = np.asarray(res["scores"])
        qn = np.asarray(pqmod.normalize(jnp.asarray(q, jnp.float32)))
        for seg in self.segments:
            if not len(seg.ids):
                continue
            s = seg.vectors @ qn
            ids = np.concatenate([ids, seg.ids])
            scores = np.concatenate([scores, s])
        if self.tombstones:
            keep = ~np.isin(ids, np.fromiter(self.tombstones, np.int64))
            ids, scores = ids[keep], scores[keep]
        order = np.argsort(-scores)[: cfg.top_k]
        return {"ids": ids[order], "scores": scores[order]}

    # -- maintenance ----------------------------------------------------------
    def compact(self) -> None:
        """Segmented rebuild: merge deltas into a new cell-sorted base.
        Reuses stored codes/cells — no re-encoding, one sort + concat."""
        if not self.segments and not self.tombstones:
            return
        base = self.base
        codes = np.concatenate([np.asarray(base.codes)]
                               + [s.codes for s in self.segments])
        vectors = np.concatenate(
            [np.asarray(base.vectors, np.float32).astype(np.float32)]
            + [s.vectors for s in self.segments])
        ids = np.concatenate([np.asarray(base.ids, np.int64)]
                             + [s.ids for s in self.segments])
        cells = np.concatenate([np.asarray(base.cell_of)]
                               + [s.cell_of for s in self.segments])
        if self.tombstones:
            keep = ~np.isin(ids, np.fromiter(self.tombstones, np.int64))
            codes, vectors, ids, cells = (codes[keep], vectors[keep],
                                          ids[keep], cells[keep])
            self.tombstones.clear()
        order = np.argsort(cells, kind="stable")
        K2 = base.K * base.K
        counts = np.bincount(cells, minlength=K2)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
        self.base = IMIIndex(
            coarse1=base.coarse1, coarse2=base.coarse2, pq=base.pq,
            codes=jnp.asarray(codes[order]),
            vectors=jnp.asarray(vectors[order], jnp.bfloat16),
            ids=jnp.asarray(ids[order], jnp.int32),
            cell_of=jnp.asarray(cells[order], jnp.int32),
            cell_offsets=jnp.asarray(offsets),
        )
        self.segments = []
