"""Incremental index maintenance — the paper's §IX future work, implemented.

LOVO's conclusion: "refine the vector database design by leveraging
segmented parallel processing to reduce the overhead of full rebuilds during
video updates and enhancing the incremental indexing strategy for new
insertions".  This module provides exactly that:

  * ``SegmentedIndex`` — a base (cell-sorted) IMIIndex plus up to
    ``max_segments`` small delta segments.  Inserts quantize against the
    FROZEN codebooks (no retrain) and append to the newest segment; queries
    search base + deltas and merge — search stays O(probe) on the base and
    O(delta) on the (bounded) deltas.
  * ``compact()`` — merges all segments into a new cell-sorted base in one
    pass (the "segmented rebuild": only the merge is periodic work, and it
    reuses stored codes — no re-encoding of video, preserving the paper's
    one-time-extraction economics).
  * deletes via a tombstone id-set: pushed into every base scan as a row
    validity bitmap (filter pushdown, DESIGN.md §10.2) and physically
    dropped at the next ``compact()``.

Codebook drift: inserts reuse the trained coarse/PQ codebooks; quantization
error grows if the data distribution shifts.  ``drift_score()`` monitors
mean residual energy of recent inserts vs the training value so an operator
can schedule a retrain (full rebuild) when it degrades.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anns, imi as imimod, pq as pqmod
from repro.core.imi import IMIIndex


@dataclasses.dataclass
class DeltaSegment:
    codes: np.ndarray     # (n, P) uint8
    vectors: np.ndarray   # (n, D') f32 (normalized)
    ids: np.ndarray       # (n,) imimod.ID_DTYPE
    cell_of: np.ndarray   # (n,) int32
    resid_energy: float   # mean per-row residual energy over the n rows


class SegmentedIndex:
    """Base IMI + bounded delta segments; see module docstring.

    ``persistence`` is an optional durability hook (duck-typed; in practice
    :class:`repro.store.VectorStore`) with three methods:

      * ``log_insert(vectors_f32, ids)`` — called BEFORE the insert is
        applied (write-ahead order) with the raw, pre-normalization inputs
        so a replay through :meth:`insert` reproduces bit-identical state;
      * ``log_delete(ids)`` — same contract for deletes;
      * ``on_compact(seg)`` — called after :meth:`compact` swaps the base.
    """

    def __init__(self, base: IMIIndex, *, max_segments: int = 4,
                 segment_capacity: int = 65_536,
                 persistence: Optional[Any] = None):
        self.base = base
        self.segments: list[DeltaSegment] = []
        self.max_segments = max_segments
        self.segment_capacity = segment_capacity
        self.persistence = persistence
        self.tombstones: set[int] = set()
        # (n_tombstones, host bool (N,), device copy) — rebuilt only when
        # deletes/compaction change it, so masked search costs no per-query
        # O(N) host pass or host->device upload
        self._alive_cache: Optional[tuple] = None
        # training-time residual energy baseline for drift monitoring,
        # estimated on a strided row sample: decoding the WHOLE base would
        # materialize an (N, D') f32 copy — unacceptable for streaming-built
        # indexes sized near host memory
        n = base.n
        rows = jnp.arange(0, n, max(1, n // self._RESID_SAMPLE))
        rec = pqmod.pq_decode(base.pq, base.codes[rows])
        self._train_resid = float(jnp.mean(jnp.sum(jnp.square(
            rec - self._base_residuals(rows)), axis=-1)))

    _RESID_SAMPLE = 4096  # rows used for the drift baseline estimate

    def _base_residuals(self, rows: jax.Array) -> jax.Array:
        K = self.base.K
        cell = self.base.cell_of[rows]
        c1 = self.base.coarse1[cell // K]
        c2 = self.base.coarse2[cell % K]
        coarse = jnp.concatenate([c1, c2], axis=-1)
        return self.base.vectors[rows].astype(jnp.float32) - coarse

    @property
    def n(self) -> int:
        return self.base.n + sum(len(s.ids) for s in self.segments) \
            - len(self.tombstones)

    # -- writes ---------------------------------------------------------------
    def insert(self, x: jax.Array, ids: np.ndarray) -> None:
        """Quantize new vectors against the frozen codebooks; append."""
        x_raw = np.ascontiguousarray(np.asarray(x), np.float32)
        ids = np.ascontiguousarray(ids, imimod.ID_DTYPE).reshape(-1)
        if self.persistence is not None:
            self.persistence.log_insert(x_raw, ids)
        x = pqmod.normalize(jnp.asarray(x_raw))
        cell, a1, a2 = imimod.assign_cells(self.base.coarse1,
                                           self.base.coarse2, x)
        resid = x - imimod.coarse_reconstruct(self.base.coarse1,
                                              self.base.coarse2, a1, a2)
        codes = pqmod.pq_encode(self.base.pq, resid)
        rec = pqmod.pq_decode(self.base.pq, codes)
        energy = float(jnp.mean(jnp.sum(jnp.square(rec - resid), axis=-1)))
        seg = DeltaSegment(codes=np.asarray(codes),
                           vectors=np.asarray(x),
                           ids=ids,
                           cell_of=np.asarray(cell, np.int32),
                           resid_energy=energy)
        if self.segments and (len(self.segments[-1].ids) + len(seg.ids)
                              <= self.segment_capacity):
            last = self.segments[-1]
            n_last, n_new = len(last.ids), len(seg.ids)
            self.segments[-1] = DeltaSegment(
                codes=np.concatenate([last.codes, seg.codes]),
                vectors=np.concatenate([last.vectors, seg.vectors]),
                ids=np.concatenate([last.ids, seg.ids]),
                cell_of=np.concatenate([last.cell_of, seg.cell_of]),
                # row-weighted mean: a tiny append must not halve/shift the
                # segment's residual-energy estimate (drift_score input)
                resid_energy=(last.resid_energy * n_last + energy * n_new)
                / (n_last + n_new))
        else:
            self.segments.append(seg)
        if len(self.segments) > self.max_segments:
            self.compact()

    def delete(self, ids) -> None:
        """Tombstone the given patch ids: immediately invisible to
        ``search`` (mask pushdown), physically removed at ``compact``."""
        ids = np.ascontiguousarray(ids, imimod.ID_DTYPE).reshape(-1)
        if self.persistence is not None:
            self.persistence.log_delete(ids)
        # build first, then one C-level (atomic under the GIL) update so
        # concurrent readers never observe a mid-iteration resize
        self.tombstones.update({int(i) for i in ids})
        self._alive_cache = None

    def _alive_base_mask(self, tombstones: set
                         ) -> tuple[np.ndarray, jax.Array]:
        """(host, device) validity bitmap over base rows for the given
        tombstone snapshot; cached until deletes/compaction invalidate it."""
        cache = self._alive_cache
        if cache is None or cache[0] != len(tombstones):
            host = ~np.isin(np.asarray(self.base.ids),
                            np.fromiter(tombstones, imimod.ID_DTYPE))
            cache = (len(tombstones), host, jnp.asarray(host))
            self._alive_cache = cache
        return cache[1], cache[2]

    def drift_score(self) -> float:
        """>1 means recent inserts quantize worse than training data."""
        if not self.segments:
            return 1.0
        rows = np.asarray([len(s.ids) for s in self.segments], np.float64)
        energies = np.asarray([s.resid_energy for s in self.segments])
        recent = float((energies * rows).sum() / max(rows.sum(), 1.0))
        return float(recent / max(self._train_resid, 1e-12))

    # -- reads ----------------------------------------------------------------
    def search(self, q: jax.Array, cfg: anns.SearchConfig,
               row_mask: Optional[np.ndarray] = None) -> dict:
        """Base probe search + brute scan of the (small) deltas; merged.

        Tombstones are pushed INTO the base scan as a row validity bitmap
        (``anns.search row_mask``): deleted rows score -inf inside the
        kernel, so the base still yields a full ``top_k`` valid candidates
        — no dynamic over-fetch, no per-tombstone-count jit recompiles
        (the former workaround for the shrink-below-k bug class,
        DESIGN.md §10.2).  With the fused scan->select path (DESIGN.md
        §11) the bitmap rides the same single pass that performs the
        selection: the base never materializes a score matrix, returns
        its (top_k,) survivors directly, and the (small) delta segments
        are brute-scored and merged against that fused output below —
        dead padding slots (id -1 / -inf) are dropped before the merge so
        they can never displace a live delta row.  ``row_mask`` lets callers (the query planner)
        stack their own BASE-row filters on top; it is positional over
        base rows, so it cannot describe rows still sitting in delta
        segments — passing one while deltas are pending raises instead of
        silently leaking unfiltered delta rows (``compact()`` first).

        Safe to call from reader threads concurrent with the single writer:
        segments/tombstones are snapshotted with C-level copies (atomic
        under the GIL), so a racing insert/delete is either fully visible
        or not yet — never a torn view.
        """
        segments = list(self.segments)
        tombstones = set(self.tombstones)
        mask = None if row_mask is None \
            else np.ascontiguousarray(row_mask, bool)
        if mask is not None and any(len(s.ids) for s in segments):
            raise ValueError(
                "row_mask is positional over base rows and cannot filter "
                "pending delta segments — compact() before masked search")
        tomb = None
        dev_mask = None if mask is None else jnp.asarray(mask)
        if tombstones:
            tomb = np.fromiter(tombstones, imimod.ID_DTYPE)
            alive_host, alive_dev = self._alive_base_mask(tombstones)
            dev_mask = alive_dev if mask is None \
                else jnp.asarray(mask & alive_host)
        res = anns.search(self.base, q, cfg, dev_mask)
        ids = np.asarray(res["ids"])
        scores = np.asarray(res["scores"])
        # drop exactly-k padding slots (id -1 / -inf score) before merging
        live = np.isfinite(scores)
        ids, scores = ids[live], scores[live]
        qn = np.asarray(pqmod.normalize(jnp.asarray(q, jnp.float32)))
        for seg in segments:
            if not len(seg.ids):
                continue
            keep = np.ones(len(seg.ids), bool)
            if tomb is not None:
                keep &= ~np.isin(seg.ids, tomb)
            ids = np.concatenate([ids, seg.ids[keep]])
            scores = np.concatenate([scores, (seg.vectors @ qn)[keep]])
        order = np.argsort(-scores)[: cfg.top_k]
        return {"ids": ids[order], "scores": scores[order]}

    # -- maintenance ----------------------------------------------------------
    def compact(self) -> None:
        """Segmented rebuild: merge deltas into a new cell-sorted base.
        Reuses stored codes/cells — no re-encoding, one sort + concat."""
        if not self.segments and not self.tombstones:
            return
        base = self.base
        codes = np.concatenate([np.asarray(base.codes)]
                               + [s.codes for s in self.segments])
        vectors = np.concatenate(
            [np.asarray(base.vectors, np.float32).astype(np.float32)]
            + [s.vectors for s in self.segments])
        ids = np.concatenate([np.asarray(base.ids, imimod.ID_DTYPE)]
                             + [s.ids for s in self.segments])
        cells = np.concatenate([np.asarray(base.cell_of)]
                               + [s.cell_of for s in self.segments])
        if self.tombstones:
            keep = ~np.isin(ids, np.fromiter(self.tombstones, imimod.ID_DTYPE))
            codes, vectors, ids, cells = (codes[keep], vectors[keep],
                                          ids[keep], cells[keep])
            self.tombstones.clear()
        order = np.argsort(cells, kind="stable")
        K2 = base.K * base.K
        counts = np.bincount(cells, minlength=K2)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
        self.base = IMIIndex(
            coarse1=base.coarse1, coarse2=base.coarse2, pq=base.pq,
            codes=jnp.asarray(codes[order]),
            vectors=jnp.asarray(vectors[order], jnp.bfloat16),
            ids=jnp.asarray(ids[order], imimod.ID_DTYPE),
            cell_of=jnp.asarray(cells[order], jnp.int32),
            cell_offsets=jnp.asarray(offsets),
        )
        self.segments = []
        self._alive_cache = None   # base rows changed; tombstones folded
        if self.persistence is not None:
            self.persistence.on_compact(self)
