"""Incremental index maintenance — the paper's §IX future work, implemented.

LOVO's conclusion: "refine the vector database design by leveraging
segmented parallel processing to reduce the overhead of full rebuilds during
video updates and enhancing the incremental indexing strategy for new
insertions".  This module provides exactly that:

  * ``SegmentedIndex`` — a base (cell-sorted) IMIIndex plus up to
    ``max_segments`` small delta segments.  Inserts quantize against the
    FROZEN codebooks (no retrain) and append to the newest segment; queries
    search base + deltas and merge — search stays O(probe) on the base and
    O(delta) on the (bounded) deltas.
  * ``compact()`` — merges all segments into a new cell-sorted base in one
    pass (the "segmented rebuild": only the merge is periodic work, and it
    reuses stored codes — no re-encoding of video, preserving the paper's
    one-time-extraction economics).
  * deletes via a tombstone id-set: pushed into every base scan as a row
    validity bitmap (filter pushdown, DESIGN.md §10.2) and physically
    dropped at the next ``compact()``.

Codebook drift: inserts reuse the trained coarse/PQ codebooks; quantization
error grows if the data distribution shifts.  ``drift_score()`` monitors
mean residual energy of recent inserts vs the training value so an operator
can schedule a retrain (full rebuild) when it degrades.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anns, imi as imimod, pq as pqmod
from repro.core.imi import IMIIndex


@dataclasses.dataclass
class DeltaSegment:
    codes: np.ndarray     # (n, P) uint8
    vectors: np.ndarray   # (n, D') f32 (normalized)
    ids: np.ndarray       # (n,) imimod.ID_DTYPE
    cell_of: np.ndarray   # (n,) int32
    resid_energy: float   # mean per-row residual energy over the n rows


class SegmentedIndex:
    """Base IMI + bounded delta segments; see module docstring.

    ``persistence`` is an optional durability hook (duck-typed; in practice
    :class:`repro.store.VectorStore`) with three methods:

      * ``log_insert(vectors_f32, ids)`` — called BEFORE the insert is
        applied (write-ahead order) with the raw, pre-normalization inputs
        so a replay through :meth:`insert` reproduces bit-identical state;
      * ``log_delete(ids)`` — same contract for deletes;
      * ``on_compact(seg)`` — called after :meth:`compact` swaps the base.
    """

    def __init__(self, base: IMIIndex, *, max_segments: int = 4,
                 segment_capacity: int = 65_536,
                 persistence: Optional[Any] = None):
        self.base = base
        self.segments: list[DeltaSegment] = []
        self.max_segments = max_segments
        self.segment_capacity = segment_capacity
        self.persistence = persistence
        self.tombstones: set[int] = set()
        # compaction generation: bumped on every base swap.  Readers that
        # cache base-aligned state (alive bitmaps, positional masks, the
        # ingest registry's delta cursors) key it to know their view is
        # stale.  The swap itself happens under ``_swap_lock`` so a reader
        # never observes the new base paired with the old delta list (which
        # would double-count rows) or the old base with the emptied list
        # (which would drop them).
        self.generation = 0
        self._swap_lock = threading.Lock()
        # reader-visible pause of the most recent base swap (seconds of
        # _swap_lock hold time) — the compaction scheduler's pause-bound
        # instrumentation (DESIGN.md §12.4)
        self.last_swap_pause_s = 0.0
        # (n_tombstones, host bool (N,), device copy) — rebuilt only when
        # deletes/compaction change it, so masked search costs no per-query
        # O(N) host pass or host->device upload
        self._alive_cache: Optional[tuple] = None
        # training-time residual energy baseline for drift monitoring,
        # estimated on a strided row sample: decoding the WHOLE base would
        # materialize an (N, D') f32 copy — unacceptable for streaming-built
        # indexes sized near host memory
        self._train_resid = self._resid_baseline(base)

    _RESID_SAMPLE = 4096  # rows used for the drift baseline estimate

    @classmethod
    def _resid_baseline(cls, base: IMIIndex) -> float:
        n = base.n
        rows = jnp.arange(0, n, max(1, n // cls._RESID_SAMPLE))
        rec = pqmod.pq_decode(base.pq, base.codes[rows])
        return float(jnp.mean(jnp.sum(jnp.square(
            rec - cls._base_residuals(base, rows)), axis=-1)))

    @staticmethod
    def _base_residuals(base: IMIIndex, rows: jax.Array) -> jax.Array:
        K = base.K
        cell = base.cell_of[rows]
        c1 = base.coarse1[cell // K]
        c2 = base.coarse2[cell % K]
        coarse = jnp.concatenate([c1, c2], axis=-1)
        return base.vectors[rows].astype(jnp.float32) - coarse

    @property
    def n(self) -> int:
        return self.base.n + sum(len(s.ids) for s in self.segments) \
            - len(self.tombstones)

    def data_version(self) -> tuple:
        """Monotone data-version token for result-cache invalidation.

        Changes on every result-visible mutation: inserts grow the raw row
        count, deletes grow the tombstone count, compaction/codebook swaps
        bump ``generation`` (which also resets the other two components —
        the tuple as a whole still changes).  Snapshotted under
        ``_swap_lock`` so a concurrent compaction can't produce a token
        describing a half-swapped state.
        """
        with self._swap_lock:
            raw = self.base.n + sum(len(s.ids) for s in self.segments)
            return (self.generation, raw, len(self.tombstones))

    # -- writes ---------------------------------------------------------------
    def insert(self, x: jax.Array, ids: np.ndarray) -> None:
        """Quantize new vectors against the frozen codebooks; append."""
        x_raw = np.ascontiguousarray(np.asarray(x), np.float32)
        ids = np.ascontiguousarray(ids, imimod.ID_DTYPE).reshape(-1)
        if self.persistence is not None:
            self.persistence.log_insert(x_raw, ids)
        x = pqmod.normalize(jnp.asarray(x_raw))
        cell, a1, a2 = imimod.assign_cells(self.base.coarse1,
                                           self.base.coarse2, x)
        resid = x - imimod.coarse_reconstruct(self.base.coarse1,
                                              self.base.coarse2, a1, a2)
        codes = pqmod.pq_encode(self.base.pq, resid)
        rec = pqmod.pq_decode(self.base.pq, codes)
        energy = float(jnp.mean(jnp.sum(jnp.square(rec - resid), axis=-1)))
        seg = DeltaSegment(codes=np.asarray(codes),
                           vectors=np.asarray(x),
                           ids=ids,
                           cell_of=np.asarray(cell, np.int32),
                           resid_energy=energy)
        if self.segments and (len(self.segments[-1].ids) + len(seg.ids)
                              <= self.segment_capacity):
            last = self.segments[-1]
            n_last, n_new = len(last.ids), len(seg.ids)
            self.segments[-1] = DeltaSegment(
                codes=np.concatenate([last.codes, seg.codes]),
                vectors=np.concatenate([last.vectors, seg.vectors]),
                ids=np.concatenate([last.ids, seg.ids]),
                cell_of=np.concatenate([last.cell_of, seg.cell_of]),
                # row-weighted mean: a tiny append must not halve/shift the
                # segment's residual-energy estimate (drift_score input)
                resid_energy=(last.resid_energy * n_last + energy * n_new)
                / (n_last + n_new))
        else:
            self.segments.append(seg)
        if len(self.segments) > self.max_segments:
            self.compact()

    def delete(self, ids) -> None:
        """Tombstone the given patch ids: immediately invisible to
        ``search`` (mask pushdown), physically removed at ``compact``."""
        ids = np.ascontiguousarray(ids, imimod.ID_DTYPE).reshape(-1)
        if self.persistence is not None:
            self.persistence.log_delete(ids)
        # build first, then one C-level (atomic under the GIL) update so
        # concurrent readers never observe a mid-iteration resize
        self.tombstones.update({int(i) for i in ids})
        self._alive_cache = None

    def _alive_base_mask(self, tombstones: set, base: Optional[IMIIndex] = None
                         ) -> tuple[np.ndarray, jax.Array]:
        """(host, device) validity bitmap over base rows for the given
        tombstone snapshot; cached until deletes/compaction invalidate it."""
        cache = self._alive_cache
        if cache is None or cache[0] != len(tombstones):
            host = ~np.isin(np.asarray((base or self.base).ids),
                            np.fromiter(tombstones, imimod.ID_DTYPE))
            cache = (len(tombstones), host, jnp.asarray(host))
            self._alive_cache = cache
        return cache[1], cache[2]

    def drift_score(self) -> float:
        """>1 means recent inserts quantize worse than training data."""
        if not self.segments:
            return 1.0
        rows = np.asarray([len(s.ids) for s in self.segments], np.float64)
        energies = np.asarray([s.resid_energy for s in self.segments])
        recent = float((energies * rows).sum() / max(rows.sum(), 1.0))
        return float(recent / max(self._train_resid, 1e-12))

    # -- reads ----------------------------------------------------------------
    def search(self, q: jax.Array, cfg: anns.SearchConfig,
               row_mask: Optional[np.ndarray] = None) -> dict:
        """Base probe search + brute scan of the (small) deltas; merged.

        Tombstones are pushed INTO the base scan as a row validity bitmap
        (``anns.search row_mask``): deleted rows score -inf inside the
        kernel, so the base still yields a full ``top_k`` valid candidates
        — no dynamic over-fetch, no per-tombstone-count jit recompiles
        (the former workaround for the shrink-below-k bug class,
        DESIGN.md §10.2).  With the fused scan->select path (DESIGN.md
        §11) the bitmap rides the same single pass that performs the
        selection: the base never materializes a score matrix, returns
        its (top_k,) survivors directly, and the (small) delta segments
        are brute-scored and merged against that fused output below —
        dead padding slots (id -1 / -inf) are dropped before the merge so
        they can never displace a live delta row.

        ``row_mask`` lets callers (the query planner, the ingest standing-
        query registry) stack their own filters on top.  It is positional:
        either length ``base.n`` (base rows only — accepted only while no
        delta rows are pending, since such a mask cannot describe them) or
        length ``base.n + sum(delta rows)`` (base rows first, then delta
        rows in segment append order — the live-index layout the ingest
        path filters while segments are pending).  Any other length, or a
        base-only mask with pending deltas, raises instead of silently
        leaking unfiltered delta rows.

        Safe to call from reader threads concurrent with the single writer:
        base/segments/tombstones are snapshotted under ``_swap_lock`` (so
        a racing ``compact()`` swap is either fully visible or not at all),
        and the C-level copies mean a racing insert/delete is never torn.
        """
        with self._swap_lock:
            base = self.base
            segments = list(self.segments)
            tombstones = set(self.tombstones)
        n_base = base.n
        n_delta = sum(len(s.ids) for s in segments)
        mask = None if row_mask is None \
            else np.ascontiguousarray(row_mask, bool).reshape(-1)
        delta_mask = None
        if mask is not None:
            if len(mask) == n_base + n_delta and n_delta:
                mask, delta_mask = mask[:n_base], mask[n_base:]
            elif len(mask) != n_base:
                raise ValueError(
                    f"row_mask length {len(mask)} matches neither base rows "
                    f"({n_base}) nor base+delta rows ({n_base + n_delta})")
            elif n_delta:
                raise ValueError(
                    "row_mask is positional over base rows and cannot filter "
                    "pending delta segments — pass a base+delta mask of "
                    f"length {n_base + n_delta} (base rows first, then delta "
                    "rows in append order) or compact() first")
        tomb = None
        dev_mask = None if mask is None else jnp.asarray(mask)
        if tombstones:
            tomb = np.fromiter(tombstones, imimod.ID_DTYPE)
            alive_host, alive_dev = self._alive_base_mask(tombstones, base)
            dev_mask = alive_dev if mask is None \
                else jnp.asarray(mask & alive_host)
        res = anns.search(base, q, cfg, dev_mask)
        ids = np.asarray(res["ids"])
        scores = np.asarray(res["scores"])
        # drop exactly-k padding slots (id -1 / -inf score) before merging
        live = np.isfinite(scores)
        ids, scores = ids[live], scores[live]
        qn = np.asarray(pqmod.normalize(jnp.asarray(q, jnp.float32)))
        cursor = 0
        for seg in segments:
            n_seg = len(seg.ids)
            if not n_seg:
                continue
            keep = np.ones(n_seg, bool)
            if delta_mask is not None:
                keep &= delta_mask[cursor: cursor + n_seg]
            cursor += n_seg
            if tomb is not None:
                keep &= ~np.isin(seg.ids, tomb)
            ids = np.concatenate([ids, seg.ids[keep]])
            scores = np.concatenate([scores, (seg.vectors @ qn)[keep]])
        order = np.argsort(-scores)[: cfg.top_k]
        return {"ids": ids[order], "scores": scores[order]}

    # -- ingest bridge --------------------------------------------------------
    def rows_since(self, watermark: int) -> dict[str, np.ndarray]:
        """Gather every live row whose id is ``> watermark``, sorted by id.

        This is the standing-query registry's delta cursor (DESIGN.md §12):
        ingested ids are assigned monotonically, so "rows newer than the
        subscription's generation" is exactly ``ids > watermark``.  The
        common case finds them all in the (small) pending delta segments;
        only when a compaction folded un-evaluated rows into the base does
        the gather fall back to an O(N) id scan of the base — the registry
        evaluates before the scheduler compacts, so that path is rare.

        Returns host arrays ``codes`` (n, P), ``vectors`` (n, D') f32,
        ``cells`` (n,), ``ids`` (n,) — id-sorted, which restores frame-major
        append order for ids laid out as ``frame_seq * patches + patch``.
        """
        with self._swap_lock:
            base = self.base
            segments = list(self.segments)
            tombstones = set(self.tombstones)
        parts = []
        for seg in segments:
            sel = seg.ids > watermark
            if sel.any():
                parts.append((seg.codes[sel],
                              np.asarray(seg.vectors, np.float32)[sel],
                              seg.cell_of[sel], seg.ids[sel]))
        base_ids = np.asarray(base.ids)
        sel = base_ids > watermark
        if sel.any():
            parts.append((np.asarray(base.codes)[sel],
                          np.asarray(base.vectors)[sel].astype(np.float32),
                          np.asarray(base.cell_of)[sel], base_ids[sel]))
        if not parts:
            e = np.empty
            return {"codes": e((0, base.codes.shape[1]), np.uint8),
                    "vectors": e((0, base.vectors.shape[1]), np.float32),
                    "cells": e((0,), np.int32),
                    "ids": e((0,), imimod.ID_DTYPE)}
        codes = np.concatenate([p[0] for p in parts])
        vectors = np.concatenate([p[1] for p in parts])
        cells = np.concatenate([p[2] for p in parts])
        ids = np.concatenate([p[3] for p in parts])
        if tombstones:
            keep = ~np.isin(ids, np.fromiter(tombstones, imimod.ID_DTYPE))
            codes, vectors, cells, ids = (codes[keep], vectors[keep],
                                          cells[keep], ids[keep])
        order = np.argsort(ids, kind="stable")
        return {"codes": codes[order], "vectors": vectors[order],
                "cells": cells[order].astype(np.int32), "ids": ids[order]}

    # -- maintenance ----------------------------------------------------------
    def compact(self) -> None:
        """Segmented rebuild: merge deltas into a new cell-sorted base.
        Reuses stored codes/cells — no re-encoding, one sort + concat.

        The rebuild runs entirely on the side; searches keep serving the
        pre-compaction generation until the O(1) pointer swap at the end
        (under ``_swap_lock``), so the reader-visible pause is bounded by
        the swap, not the merge (DESIGN.md §12.4)."""
        if not self.segments and not self.tombstones:
            return
        base = self.base
        tombstones = set(self.tombstones)
        codes = np.concatenate([np.asarray(base.codes)]
                               + [s.codes for s in self.segments])
        vectors = np.concatenate(
            [np.asarray(base.vectors, np.float32).astype(np.float32)]
            + [s.vectors for s in self.segments])
        ids = np.concatenate([np.asarray(base.ids, imimod.ID_DTYPE)]
                             + [s.ids for s in self.segments])
        cells = np.concatenate([np.asarray(base.cell_of)]
                               + [s.cell_of for s in self.segments])
        if tombstones:
            keep = ~np.isin(ids, np.fromiter(tombstones, imimod.ID_DTYPE))
            codes, vectors, ids, cells = (codes[keep], vectors[keep],
                                          ids[keep], cells[keep])
        order = np.argsort(cells, kind="stable")
        K2 = base.K * base.K
        counts = np.bincount(cells, minlength=K2)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
        new_base = IMIIndex(
            coarse1=base.coarse1, coarse2=base.coarse2, pq=base.pq,
            codes=jnp.asarray(codes[order]),
            vectors=jnp.asarray(vectors[order], jnp.bfloat16),
            ids=jnp.asarray(ids[order], imimod.ID_DTYPE),
            cell_of=jnp.asarray(cells[order], jnp.int32),
            cell_offsets=jnp.asarray(offsets),
        )
        import time as _time
        t_swap = _time.perf_counter()
        with self._swap_lock:   # the bounded pause: pointer swaps only
            self.base = new_base
            self.segments = []
            self.tombstones.clear()
            self._alive_cache = None   # base rows changed; tombstones folded
            self.generation += 1
        self.last_swap_pause_s = _time.perf_counter() - t_swap
        if self.persistence is not None:
            self.persistence.on_compact(self)

    def swap_base(self, new_base: IMIIndex) -> None:
        """Install a rebuilt base — the codebook-refresh commit point.

        Requires no pending deltas (``compact()`` first: the new base
        must already contain every row).  Resets the drift baseline to
        the NEW codebooks (the refresh changes what "training-time
        residual energy" means) and bumps the generation, all under the
        same bounded-pause swap discipline as :meth:`compact`."""
        if self.segments:
            raise ValueError(
                "swap_base with pending delta segments would drop their "
                "rows — compact() first")
        baseline = self._resid_baseline(new_base)
        import time as _time
        t_swap = _time.perf_counter()
        with self._swap_lock:
            self.base = new_base
            self.tombstones.clear()
            self._alive_cache = None
            self._train_resid = baseline
            self.generation += 1
        self.last_swap_pause_s = _time.perf_counter() - t_swap
