"""Approximate Nearest Neighbor Search — LOVO Algorithm 1, jit-friendly.

Pipeline per query:
  1. normalize + split q into halves; score coarse centroids per half
  2. exact top-A cells via the multi-sequence frontier (imi.multi_sequence_top_a)
  3. gather each cell's [start, start+max_cell_size) window (static shapes)
  4. ADC over residual-PQ codes:  s ~= s_cell_base + q . residual
     (LUT precomputed once per query — the paper's distance lookup-table)
  5. top-k by approximate score
  6. exact re-scoring of the top-k against stored bf16 vectors
     (s_exact = sum_p q_p . x_p — Algorithm 1 line 14)
  7. patch-id majority vote across subspace components (line 16; in the
     row-wise dense layout each candidate is one row so the vote is exact —
     the subspace-mixed variant is exposed as ``patch_vote`` for parity)

The ADC scan (step 4) is the latency hot spot; ``use_kernel='pallas'``
switches to the Pallas MXU kernel (interpret mode on CPU).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import imi as imimod
from repro.core import pq as pqmod
from repro.core.imi import IMIIndex


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    top_a: int = 32            # cells probed
    max_cell_size: int = 2048  # per-cell candidate window
    top_k: int = 100           # candidates returned by fast search
    exact_rerank: bool = True
    rerank_overfetch: int = 4  # exact-rescore top_k * this approx candidates
    use_kernel: str = "jnp"    # 'jnp' | 'pallas'


def _adc(lut: jax.Array, codes: jax.Array, use_kernel: str) -> jax.Array:
    if use_kernel == "pallas":
        from repro.kernels import ops as kops
        return kops.pq_scan(lut, codes)
    return pqmod.adc_scores(lut, codes)


@functools.partial(jax.jit, static_argnames=("cfg",))
def search(index: IMIIndex, q: jax.Array, cfg: SearchConfig
           ) -> dict[str, jax.Array]:
    """Single-query Algorithm 1.  q: (D',) raw query embedding.

    Returns dict with ids (k,), scores (k,), approx_scores (k,), rows (k,).
    """
    q = pqmod.normalize(q.astype(jnp.float32))
    h = q.shape[-1] // 2
    s1 = index.coarse1 @ q[:h]
    s2 = index.coarse2 @ q[h:]
    # probe selection must agree with the L2 cell assignment (imi.probe_adjust)
    cells = imimod.multi_sequence_top_a(s1 + imimod.probe_adjust(index.coarse1),
                                        s2 + imimod.probe_adjust(index.coarse2),
                                        cfg.top_a)               # (A,)
    K = index.K
    base = s1[cells // K] + s2[cells % K]                        # (A,)

    starts = index.cell_offsets[cells]
    counts = index.cell_offsets[cells + 1] - starts
    counts = jnp.minimum(counts, cfg.max_cell_size)
    window = starts[:, None] + jnp.arange(cfg.max_cell_size)[None, :]
    valid = jnp.arange(cfg.max_cell_size)[None, :] < counts[:, None]
    rows = jnp.clip(window, 0, index.n - 1)                      # (A, W)

    cand_codes = index.codes[rows.reshape(-1)]                   # (A*W, P)
    lut = pqmod.similarity_lut(index.pq, q)                      # (P, M)
    resid = _adc(lut, cand_codes, cfg.use_kernel)                # (A*W,)
    approx = resid.reshape(cells.shape[0], -1) + base[:, None]   # (A, W)
    approx = jnp.where(valid, approx, -jnp.inf).reshape(-1)

    # refine factor: ADC order is approximate, so the true top-k by exact
    # score may sit below rank k in approx order — fetch a multiple, exact-
    # rescore, THEN cut to top_k (IVF-PQ "refine" stage; Algorithm 1 line 14)
    fetch_k = min(cfg.top_k * max(cfg.rerank_overfetch, 1), approx.shape[0]) \
        if cfg.exact_rerank else cfg.top_k
    top_approx, flat_idx = jax.lax.top_k(approx, fetch_k)
    top_rows = rows.reshape(-1)[flat_idx]                        # (fetch_k,)

    if cfg.exact_rerank:
        vecs = index.vectors[top_rows].astype(jnp.float32)       # (fetch_k, D')
        exact = vecs @ q
        # padding slots (-inf approx: window overrun / clipped rows) must
        # not re-enter via their real dot product
        exact = jnp.where(jnp.isfinite(top_approx), exact, -jnp.inf)
        order = jnp.argsort(-exact)[: cfg.top_k]
        top_rows = top_rows[order]
        scores = exact[order]
        top_approx = top_approx[order]
    else:
        scores = top_approx
    return {"ids": index.ids[top_rows], "scores": scores,
            "approx_scores": top_approx, "rows": top_rows}


def search_batch(index: IMIIndex, qs: jax.Array, cfg: SearchConfig
                 ) -> dict[str, jax.Array]:
    return jax.vmap(lambda q: search(index, q, cfg))(qs)


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force(index: IMIIndex, q: jax.Array, k: int = 100
                ) -> dict[str, jax.Array]:
    """Exact search over the stored vectors (paper's LOVO(BF) variant)."""
    q = pqmod.normalize(q.astype(jnp.float32))
    scores = index.vectors.astype(jnp.float32) @ q
    vals, rows = jax.lax.top_k(scores, k)
    return {"ids": index.ids[rows], "scores": vals, "rows": rows}


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def exhaustive_adc(index: IMIIndex, q: jax.Array, k: int = 100,
                   use_kernel: str = "jnp") -> dict[str, jax.Array]:
    """'w/o ANNS' ablation: full ADC scan, no cell pruning (Table IV)."""
    q = pqmod.normalize(q.astype(jnp.float32))
    # score = q . (coarse(cell_of) + residual)
    K = index.K
    h = q.shape[-1] // 2
    s1 = index.coarse1 @ q[:h]
    s2 = index.coarse2 @ q[h:]
    base = s1[index.cell_of // K] + s2[index.cell_of % K]
    lut = pqmod.similarity_lut(index.pq, q)
    scores = base + _adc(lut, index.codes, use_kernel)
    vals, rows = jax.lax.top_k(scores, k)
    vecs = index.vectors[rows].astype(jnp.float32)
    exact = vecs @ q
    order = jnp.argsort(-exact)
    return {"ids": index.ids[rows[order]], "scores": exact[order],
            "rows": rows[order]}


def patch_vote(component_ids: jax.Array) -> jax.Array:
    """LOVO Algorithm 1 line 16: majority patch id across P subspace
    components of a candidate (used by the subspace-mixed retrieval variant).

    component_ids: (..., P) int32 -> (...,) the most frequent id.
    """
    def vote(row):
        eq = row[:, None] == row[None, :]
        freq = jnp.sum(eq, axis=-1)
        return row[jnp.argmax(freq)]
    flat = component_ids.reshape(-1, component_ids.shape[-1])
    return jax.vmap(vote)(flat).reshape(component_ids.shape[:-1])
