"""Approximate Nearest Neighbor Search — LOVO Algorithm 1, jit-friendly.

Pipeline per query:
  1. normalize + split q into halves; score coarse centroids per half
  2. exact top-A cells via the multi-sequence frontier (imi.multi_sequence_top_a)
  3. gather each cell's [start, start+max_cell_size) window (static shapes)
  4. ADC over residual-PQ codes:  s ~= s_cell_base + q . residual
     (LUT precomputed once per query — the paper's distance lookup-table.
     The LUT internalizes the quantizer's two-level per-cell offset and
     the optional OPQ rotation, DESIGN.md §9 — s_cell_base here is the
     IMI coarse-cell term, which stays outside because it varies per
     probed cell, not per code entry)
  5. top-k by approximate score
  6. exact re-scoring of the top-k against stored bf16 vectors
     (s_exact = sum_p q_p . x_p — Algorithm 1 line 14)
  7. patch-id majority vote across subspace components (line 16; in the
     row-wise dense layout each candidate is one row so the vote is exact —
     the subspace-mixed variant is exposed as ``patch_vote`` for parity)

The ADC scan (steps 4–5) is the latency hot spot.  By default it runs
FUSED (``SearchConfig.fused_topk``): the scan keeps a per-query running
top-``fetch_k`` inside the kernel and only the ``(Q, fetch_k)`` survivors
ever leave it — the ``(Q, N)`` score matrix is never materialized, and the
IMI base term, window validity, and the planner's row-mask sentinel ride
the same single pass (DESIGN.md §11).  ``use_kernel`` picks the backend:
``'auto'`` (default) resolves to the Pallas MXU kernels wherever they
compile (TPU, or the ``REPRO_PALLAS_COMPILE=1`` interpret-parity leg) and
to the blocked-jnp formulations elsewhere — fresh engines get the kernel
path with no config plumbing; ``'jnp'``/``'pallas'`` force a backend.

``search_batch`` is the batched formulation of the same algorithm: the
probe, window gather, fused ADC scan->select (one launch sharing LUT/code
VMEM residency), and refine all carry a static leading Q dimension instead
of issuing Q separate searches.  Per-row results match ``search`` (same
ids, scores equal up to f32 reduction-order noise); DESIGN.md §8 records
the static-shape/padding contract.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import imi as imimod
from repro.core import pq as pqmod
from repro.core.imi import IMIIndex


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    top_a: int = 32            # cells probed
    max_cell_size: int = 2048  # per-cell candidate window
    top_k: int = 100           # candidates returned by fast search
    exact_rerank: bool = True
    rerank_overfetch: int = 4  # exact-rescore top_k * this approx candidates
    use_kernel: str = "auto"   # 'auto' | 'jnp' | 'pallas'
    fused_topk: bool = True    # in-kernel scan->select (False: legacy
    #                            materialize-(Q,N)-then-lax.top_k path)
    candidate_overfetch: int = 4  # stage-2 rerank pool: top_n * this
    #                               candidate frames enter the cross-modal
    #                               rerank (QueryEngine._candidate_frames;
    #                               the optimizer's adaptive-depth dial)


def tighten_probe(cfg: SearchConfig, *, n: int, n_cells: int,
                  max_cell_rows: int) -> SearchConfig:
    """Clamp probe-width knobs to statistics-known exact bounds — a
    result-IDENTICAL shrink, never a recall trade.

    Each clamp is applied only under its identity condition:

      * ``max_cell_size -> max_cell_rows``: per-cell counts are already
        ``<= max_cell_rows``, so a wider window only gathers invalid slots;
      * ``top_a -> n_cells``: probing more cells than exist re-probes the
        same CSR ranges;

    both gated on ``fetch_k`` (``min(top_k * rerank_overfetch, top_a * W)``)
    being unchanged by the shrink — if the A*W term was the binding clamp,
    shrinking it would change which approximate candidates survive to the
    exact refine.  Callers with no statistics pass the current values and
    get ``cfg`` back unchanged.
    """
    new_a = min(cfg.top_a, max(n_cells, 1))
    new_w = min(cfg.max_cell_size, max(max_cell_rows, 1))
    if (new_a, new_w) == (cfg.top_a, cfg.max_cell_size):
        return cfg
    fetch = cfg.top_k * max(cfg.rerank_overfetch, 1)
    old_pool = cfg.top_a * cfg.max_cell_size
    new_pool = new_a * new_w
    if min(fetch, old_pool) != min(fetch, new_pool):
        return cfg
    # shrinking below n would also flip the shared-coverage branch for
    # covering configs — keep the branch (and thus the tie-break rule) fixed
    if old_pool >= n > new_pool:
        return cfg
    return dataclasses.replace(cfg, top_a=new_a, max_cell_size=new_w)


def _resolve_kernel(use_kernel: str) -> str:
    """'auto' -> 'pallas' where the kernels compile (TPU / parity leg),
    'jnp' elsewhere; resolved at trace time (see kernels.ops)."""
    from repro.kernels import ops as kops
    return kops.resolve_use_kernel(use_kernel)


def _adc(lut: jax.Array, codes: jax.Array, use_kernel: str) -> jax.Array:
    if _resolve_kernel(use_kernel) == "pallas":
        from repro.kernels import ops as kops
        return kops.pq_scan(lut, codes)
    return pqmod.adc_scores(lut, codes)


def _adc_paired(luts: jax.Array, codes: jax.Array, use_kernel: str,
                mask: Optional[jax.Array] = None) -> jax.Array:
    """luts (Q, P, M), codes (Q, N, P) -> (Q, N): query q scans codes[q].

    ``mask`` (Q, N) nonzero=valid: filtered rows come back exactly -inf —
    the sentinel is fused into the Pallas scan (filter pushdown)."""
    if _resolve_kernel(use_kernel) == "pallas":
        from repro.kernels import ops as kops
        if mask is not None:
            return kops.pq_scan_paired_masked(luts, codes, mask)
        return kops.pq_scan_paired(luts, codes)
    out = jax.vmap(pqmod.adc_scores)(luts, codes)
    return out if mask is None else jnp.where(mask != 0, out, -jnp.inf)


def _adc_shared(luts: jax.Array, codes: jax.Array, use_kernel: str,
                mask: Optional[jax.Array] = None) -> jax.Array:
    """luts (Q, P, M), codes (N, P) -> (Q, N): every query scans all rows.

    ``mask`` (Q, N) nonzero=valid, same sentinel contract as above."""
    if _resolve_kernel(use_kernel) == "pallas":
        from repro.kernels import ops as kops
        if mask is not None:
            return kops.pq_scan_batched_masked(luts, codes, mask)
        return kops.pq_scan_batched(luts, codes)
    out = jax.vmap(lambda l: pqmod.adc_scores(l, codes))(luts)
    return out if mask is None else jnp.where(mask != 0, out, -jnp.inf)


def _gather_windows(starts: jax.Array, counts: jax.Array, W: int, n: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Materialize the probe windows: (Q, A) descriptors -> (valid
    (Q, A, W) slot-within-count, rows (Q, A*W) clipped global rows).

    Shared by the fused-paired and legacy branches of ``search_batch`` so
    the clipping/validity rule cannot drift between the fused path and its
    ``fused_topk=False`` parity reference."""
    Q = starts.shape[0]
    window = starts[..., None] + jnp.arange(W)[None, None, :]    # (Q, A, W)
    valid = jnp.arange(W)[None, None, :] < counts[..., None]
    rows = jnp.clip(window, 0, n - 1).reshape(Q, -1)             # (Q, A*W)
    return valid, rows


def _topk_windowed(luts: jax.Array, codes: jax.Array, starts: jax.Array,
                   counts: jax.Array, bases: jax.Array, fetch_k: int,
                   use_kernel: str, mask: Optional[jax.Array]
                   ) -> tuple[jax.Array, jax.Array]:
    """Fused scan->select over shared codes with IMI window descriptors:
    -> (approx scores (Q, fetch_k), global rows (Q, fetch_k), dead = -1)."""
    if _resolve_kernel(use_kernel) == "pallas":
        from repro.kernels import ops as kops
        if mask is not None:
            return kops.pq_scan_topk_windowed_masked(
                luts, codes, starts, counts, bases, mask, fetch_k)
        return kops.pq_scan_topk_windowed(
            luts, codes, starts, counts, bases, fetch_k)
    from repro.kernels import pq_scan as _pq
    return _pq.pq_scan_topk_windowed_jnp(
        luts, codes, starts, counts, bases, fetch_k, mask)


def _topk_paired(luts: jax.Array, codes: jax.Array, bias: jax.Array,
                 mask: jax.Array, fetch_k: int, use_kernel: str
                 ) -> tuple[jax.Array, jax.Array]:
    """Fused scan->select over per-query candidate windows: -> (approx
    scores, positions into the candidate axis (Q, fetch_k), dead = -1)."""
    if _resolve_kernel(use_kernel) == "pallas":
        from repro.kernels import ops as kops
        return kops.pq_scan_topk_paired_masked(luts, codes, mask, fetch_k,
                                               bias=bias)
    from repro.kernels import pq_scan as _pq
    return _pq.pq_scan_topk_paired_jnp(luts, codes, fetch_k, bias, mask)


def probe_descriptors(coarse1: jax.Array, coarse2: jax.Array, pq: Any,
                      cell_offsets: jax.Array, qs: jax.Array, *,
                      top_a: int, max_cell_size: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array]:
    """The IMI probe math of Algorithm 1 lines 3–9, batched: NORMALIZED
    queries ``qs (Q, D')`` -> ``(cells (Q, A), bases (Q, A), starts (Q, A),
    counts (Q, A), luts (Q, P, M))``.

    Extracted from ``search_batch`` so every consumer of window descriptors
    — the single-host fused scan AND the shard_map scan farm
    (``repro.core.distributed``) — computes them from the SAME code path.
    The distributed bit-parity contract depends on this: descriptors are
    computed once against the GLOBAL CSR (``cell_offsets``) with counts
    capped globally at ``max_cell_size``, then shifted per shard; a
    per-shard recomputation (local CSR, local cap) would select a
    different candidate set than the single-host prefix cap and break
    parity (DESIGN.md §13).
    """
    h = qs.shape[-1] // 2
    s1 = qs[:, :h] @ coarse1.T                                   # (Q, K)
    s2 = qs[:, h:] @ coarse2.T
    # probe selection must agree with the L2 cell assignment (imi.probe_adjust)
    adj1 = imimod.probe_adjust(coarse1)
    adj2 = imimod.probe_adjust(coarse2)
    cells = jax.vmap(
        lambda a, b: imimod.multi_sequence_top_a(a, b, top_a)
    )(s1 + adj1[None, :], s2 + adj2[None, :])                    # (Q, A)
    K = coarse1.shape[0]
    bases = jnp.take_along_axis(s1, cells // K, axis=1) \
        + jnp.take_along_axis(s2, cells % K, axis=1)             # (Q, A)
    starts = cell_offsets[cells]                                 # (Q, A)
    counts = cell_offsets[cells + 1] - starts
    counts = jnp.minimum(counts, max_cell_size)
    luts = jax.vmap(lambda q: pqmod.similarity_lut(pq, q))(qs)
    return cells, bases, starts, counts, luts


def search(index: IMIIndex, q: jax.Array, cfg: SearchConfig,
           row_mask: Optional[jax.Array] = None) -> dict[str, jax.Array]:
    """Single-query Algorithm 1.  q: (D',) raw query embedding.

    A batch of one: delegates to ``search_batch`` so the single and batched
    views cannot drift (parity is structural, not just test-enforced).
    ``row_mask``: optional (N,) validity bitmap over index rows (nonzero =
    searchable) — metadata filter pushdown, see ``search_batch``.
    Returns dict with ids (k,), scores (k,), approx_scores (k,), rows (k,).
    """
    if row_mask is not None and row_mask.ndim == 1:
        row_mask = row_mask[None]
    return {k: v[0]
            for k, v in search_batch(index, q[None], cfg, row_mask).items()}


@functools.partial(jax.jit, static_argnames=("cfg",))
def search_batch(index: IMIIndex, qs: jax.Array, cfg: SearchConfig,
                 row_mask: Optional[jax.Array] = None
                 ) -> dict[str, jax.Array]:
    """Batched Algorithm 1.  qs: (Q, D') raw query embeddings.

    One probe, one gather, one ADC launch, one refine — every stage carries
    the static Q dimension (jit caches one executable per Q; callers pad to
    a fixed batch size, see ``QueryEngine.fast_search_batch``).  Returns the
    same dict as ``search`` with every array gaining a leading Q axis.

    ``row_mask``: optional (N,) or (Q, N) validity bitmap over index rows
    (nonzero = searchable).  Metadata predicates — time windows, video-id
    sets, tombstones — are pushed INTO the ADC scan as this bitmap: filtered
    rows score exactly -inf inside the kernel, so the returned top-k is the
    best k rows *among the valid ones* (a post-hoc filter would instead
    silently shrink the result below k; DESIGN.md §10).

    Exactly-k padding contract: result slots with no valid candidate (score
    -inf) carry ``ids == -1`` and ``rows == -1`` — never a garbage id from a
    clipped gather.  An all-False mask therefore returns k ``-1`` slots.
    """
    qs = pqmod.normalize(qs.astype(jnp.float32))                 # (Q, D')
    Q = qs.shape[0]
    if row_mask is not None:
        row_mask = jnp.broadcast_to(
            jnp.asarray(row_mask), (Q, index.n)).astype(jnp.uint8)
    cells, base, starts, counts, luts = probe_descriptors(
        index.coarse1, index.coarse2, index.pq, index.cell_offsets, qs,
        top_a=cfg.top_a, max_cell_size=cfg.max_cell_size)
    W = cfg.max_cell_size
    shared = cfg.top_a * cfg.max_cell_size >= index.n
    # refine factor: ADC order is approximate, so the true top-k by exact
    # score may sit below rank k in approx order — fetch a multiple, exact-
    # rescore, THEN cut to top_k (IVF-PQ "refine" stage; Algorithm 1 line 14)
    fetch_k = min(cfg.top_k * max(cfg.rerank_overfetch, 1), cfg.top_a * W) \
        if cfg.exact_rerank else cfg.top_k

    if cfg.fused_topk and shared:
        # windows cover the whole index: ONE fused pass over all rows — the
        # IMI base term, window validity, and the planner's bitmap ride the
        # scan, and only the (Q, fetch_k) survivors ever leave the kernel.
        # EXACT approx-score ties at the fetch_k boundary break by global
        # row id here (the oracle's rule) where the legacy path breaks them
        # by probe-window position — identical results whenever boundary
        # scores are distinct, which real-valued data makes generic
        top_approx, top_rows = _topk_windowed(
            luts, index.codes, starts, counts, base, fetch_k,
            cfg.use_kernel, row_mask)
    elif cfg.fused_topk:
        valid, rows = _gather_windows(starts, counts, W, index.n)
        cand_codes = index.codes[rows]                            # (Q,A*W,P)
        # the bitmap travels with the gathered windows: a clipped/overrun
        # row may gather a True slot, but window validity masks it in-kernel
        wmask = valid.reshape(Q, -1)
        if row_mask is not None:
            wmask &= jnp.take_along_axis(row_mask, rows, axis=1) != 0
        bias = jnp.repeat(base, W, axis=1)                        # (Q, A*W)
        top_approx, pos = _topk_paired(luts, cand_codes, bias,
                                       wmask.astype(jnp.uint8),
                                       fetch_k, cfg.use_kernel)
        top_rows = jnp.take_along_axis(rows, jnp.maximum(pos, 0), axis=1)
    else:
        # legacy scan-then-select: materialize the (Q, A*W) score matrix,
        # apply base/validity in a second pass, lax.top_k in a third
        valid, rows = _gather_windows(starts, counts, W, index.n)
        if shared:
            all_scores = _adc_shared(luts, index.codes, cfg.use_kernel,
                                     row_mask)
            resid = jnp.take_along_axis(all_scores, rows, axis=1)
        else:
            wmask = None if row_mask is None \
                else jnp.take_along_axis(row_mask, rows, axis=1)
            resid = _adc_paired(luts, index.codes[rows],
                                cfg.use_kernel, wmask)            # (Q, A*W)
        approx = resid.reshape(Q, cfg.top_a, W) + base[..., None]
        approx = jnp.where(valid, approx, -jnp.inf).reshape(Q, -1)
        top_approx, flat_idx = jax.lax.top_k(approx, fetch_k)
        top_rows = jnp.take_along_axis(rows, flat_idx, axis=1)

    safe_rows = jnp.maximum(top_rows, 0)       # fused dead slots carry -1
    if cfg.exact_rerank:
        vecs = index.vectors[safe_rows].astype(jnp.float32)      # (Q, fk, D')
        exact = jnp.einsum("qkd,qd->qk", vecs, qs)
        # padding slots (-inf approx: window overrun / clipped / filtered
        # rows) must not re-enter via their real dot product
        exact = jnp.where(jnp.isfinite(top_approx), exact, -jnp.inf)
        order = jnp.argsort(-exact, axis=1)[:, : cfg.top_k]
        safe_rows = jnp.take_along_axis(safe_rows, order, axis=1)
        scores = jnp.take_along_axis(exact, order, axis=1)
        top_approx = jnp.take_along_axis(top_approx, order, axis=1)
    else:
        scores = top_approx
    # exactly-k padding: a slot whose score is -inf has no valid candidate
    # behind it (window overrun, or every row filtered by the mask) — its
    # id/row must read as -1, not whatever the clipped gather landed on
    live = jnp.isfinite(scores)
    return {"ids": jnp.where(live, index.ids[safe_rows], -1),
            "scores": scores, "approx_scores": top_approx,
            "rows": jnp.where(live, safe_rows, -1)}


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force(index: IMIIndex, q: jax.Array, k: int = 100
                ) -> dict[str, jax.Array]:
    """Exact search over the stored vectors (paper's LOVO(BF) variant)."""
    q = pqmod.normalize(q.astype(jnp.float32))
    scores = index.vectors.astype(jnp.float32) @ q
    vals, rows = jax.lax.top_k(scores, k)
    return {"ids": index.ids[rows], "scores": vals, "rows": rows}


@functools.partial(jax.jit, static_argnames=("k", "use_kernel",
                                             "rerank_overfetch",
                                             "fused_topk"))
def exhaustive_adc(index: IMIIndex, q: jax.Array, k: int = 100,
                   use_kernel: str = "auto",
                   rerank_overfetch: int = 4,
                   fused_topk: bool = True) -> dict[str, jax.Array]:
    """'w/o ANNS' ablation: full ADC scan, no cell pruning (Table IV).

    Uses the same overfetch + exact-rescore refine protocol as ``search``
    (fetch ``k * rerank_overfetch`` by approximate score, exact-rescore,
    cut to k) so the ablation differs from cell-probe search only in the
    pruning, not in the refine rule.  With ``fused_topk`` (default) the
    per-row coarse term rides the fused scan->select as its bias and only
    the ``fetch_k`` survivors leave the kernel (DESIGN.md §11).
    """
    q = pqmod.normalize(q.astype(jnp.float32))
    # score = q . (coarse(cell_of) + residual)
    K = index.K
    h = q.shape[-1] // 2
    s1 = index.coarse1 @ q[:h]
    s2 = index.coarse2 @ q[h:]
    base = s1[index.cell_of // K] + s2[index.cell_of % K]
    lut = pqmod.similarity_lut(index.pq, q)
    fetch_k = min(k * max(rerank_overfetch, 1), index.n)
    if fused_topk:
        if _resolve_kernel(use_kernel) == "pallas":
            from repro.kernels import ops as kops
            _, rows = kops.pq_scan_topk_batched(lut[None], index.codes,
                                                fetch_k, bias=base)
        else:
            from repro.kernels import pq_scan as _pq
            _, rows = _pq.pq_scan_topk_jnp(lut[None], index.codes,
                                           fetch_k, base)
        rows = rows[0]          # no mask, fetch_k <= n: every slot live
    else:
        scores = base + _adc(lut, index.codes, use_kernel)
        _, rows = jax.lax.top_k(scores, fetch_k)
    vecs = index.vectors[rows].astype(jnp.float32)
    exact = vecs @ q
    order = jnp.argsort(-exact)[:k]
    return {"ids": index.ids[rows[order]], "scores": exact[order],
            "rows": rows[order]}


def patch_vote(component_ids: jax.Array) -> jax.Array:
    """LOVO Algorithm 1 line 16: majority patch id across P subspace
    components of a candidate (used by the subspace-mixed retrieval variant).

    component_ids: (..., P) int32 -> (...,) the most frequent id.
    """
    def vote(row):
        eq = row[:, None] == row[None, :]
        freq = jnp.sum(eq, axis=-1)
        return row[jnp.argmax(freq)]
    flat = component_ids.reshape(-1, component_ids.shape[-1])
    return jax.vmap(vote)(flat).reshape(component_ids.shape[:-1])
