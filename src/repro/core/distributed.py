"""Distributed LOVO index: the shard_map fused scan farm + elastic shards.

The paper scales via Milvus server shards; the TPU-native equivalent shards
index ROWS across the device mesh and lifts the PR-5 fused scan->select
kernels (``repro.kernels.pq_scan``) into a ``shard_map`` farm.  Per shard:

  in-kernel per-query running top-L over LOCAL rows   (one fused pass:
      windowed probe descriptors + row-validity/tombstone bitmap + the
      planner's row mask all ride the scan)
  per-shard exact bf16 rerank of its L survivors      (same einsum shape
      as the single-host path => bitwise-identical per-row scores)
  tree-structured cross-shard top-L merge             (butterfly ppermute
      on a flat power-of-two mesh, all_gather+sort otherwise)

Only ``(Q, L)`` score/id/payload tuples ever cross the interconnect —
never a score matrix — so per-query traffic is O(k·S·log S) bytes on the
butterfly (O(k·S) gathered), independent of index size N: the collective
form of the paper's "latency flat in dataset size" claim (Fig. 11b).

**Bit-parity contract** (DESIGN.md §13, proven by tests/test_sharded_scan):
shards are CONTIGUOUS row ranges of the same cell-sorted global row space,
probe descriptors are computed ONCE against the global CSR
(``anns.probe_descriptors``) and only SHIFTED per shard, and the merge is
keyed ``(approx score desc, global row asc)`` — the ``lax.top_k`` tie rule
the fused kernels implement.  The merged result is therefore bit-identical
to single-host ``anns.search_batch(fused_topk=True)`` on the shared/windowed
branch (``cfg.top_a * cfg.max_cell_size >= n``) for every shard count,
including masked rows, tombstones, and exact score ties at the L boundary.

**Elastic shards**: ``shard_index_from_store`` builds shards straight from a
persistent ``VectorStore`` (segment-aligned: pending delta segments are
folded first, cuts land on cell boundaries, tombstones become the row-valid
bitmap).  ``RoutingTable`` assigns shards to serving replicas with a
generation stamp bumped on every split/migration; ``QueryRouter`` refuses a
``call_sharded`` broadcast against a stale or demoted assignment (a missing
shard must fail loudly, never merge incomplete).  ``repro.store
.migrate_rows`` is the data-plane seam: rows move between shard stores as
WAL-logged delete+insert, so a crash mid-migration loses no rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import chaos
from repro.core import anns
from repro.core import pq as pqmod
from repro.core.imi import IMIIndex
from repro.kernels import pq_scan as _pq


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: the stable spelling (with
    ``check_vma``) when present, else ``jax.experimental.shard_map`` (with
    the older ``check_rep`` knob)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


@dataclasses.dataclass
class ShardedIndex:
    """Contiguous row-range shards of one cell-sorted index + replicated
    codebooks.

    All sharded arrays carry a leading ``S`` (shards) dim, padded to a
    uniform ``n_pad`` rows per shard (``row_valid`` zeroes the padding and
    any tombstoned rows) so shapes are static per device under shard_map.
    ``row_start`` maps local row ``i`` of shard ``s`` to GLOBAL row
    ``row_start[s] + i`` of the cell-sorted space — the fused farm runs on
    global probe descriptors shifted by it, and the cross-shard merge keys
    on the reconstructed global row (DESIGN.md §13).
    """

    codes: jax.Array           # (S, n_pad, P) uint8
    vectors: jax.Array         # (S, n_pad, D') bf16
    ids: jax.Array             # (S, n_pad) int32 global patch ids (-1 pad)
    cell_of: jax.Array         # (S, n_pad) int32 (K*K on padding)
    row_valid: jax.Array       # (S, n_pad) uint8: 0 = padding or tombstone
    row_start: jax.Array       # (S, 1) int32 global row of local row 0
    cell_offsets: jax.Array    # (S, K*K+1) int32 per-shard (local) CSR
    global_offsets: jax.Array  # (K*K+1,) int32 global CSR, replicated
    coarse1: jax.Array         # (K, D'/2) replicated
    coarse2: jax.Array
    pq_centroids: jax.Array    # (P, M, m) replicated
    # None when the quantizer has no OPQ rotation — structurally absent
    # (an empty pytree slot), matching how ``pq.similarity_lut`` skips the
    # rotate, instead of a dense identity matmul on every LUT build
    pq_rotation: Optional[jax.Array] = None

    @property
    def n_shards(self) -> int:
        return self.codes.shape[0]

    def tree_flatten(self):
        return ((self.codes, self.vectors, self.ids, self.cell_of,
                 self.row_valid, self.row_start, self.cell_offsets,
                 self.global_offsets, self.coarse1, self.coarse2,
                 self.pq_centroids, self.pq_rotation), None)

    @classmethod
    def tree_unflatten(cls, aux, kids):
        return cls(*kids)


jax.tree_util.register_pytree_node_class(ShardedIndex)


def shard_index(index: IMIIndex, n_shards: int, *,
                alive: Optional[np.ndarray] = None,
                boundaries: Optional[Sequence[int]] = None,
                cell_aligned: bool = False) -> ShardedIndex:
    """Slice the cell-sorted index into ``n_shards`` CONTIGUOUS row ranges.

    Host-side (numpy) — the ingest/placement step a router performs.
    Contiguity (vs the former round-robin striping) is what makes the
    distributed fused scan exact: global probe windows stay intervals, so
    a shard evaluates ``window ∩ [row_start, row_start + n_local)`` by a
    constant shift of the SAME descriptors the single-host scan uses.

    ``alive``: optional (n,) bool bitmap — tombstoned rows become
    ``row_valid == 0`` and ride the fused pass as the mask (never
    selectable, exactly like the single-host tombstone pushdown).
    ``boundaries``: explicit ``n_shards + 1`` global row cuts (must start
    at 0, end at n, be non-decreasing) — the segment-alignment hook.
    ``cell_aligned``: snap the default equal-split cuts to the nearest
    cell boundary so no probe window straddles shards (cells are the
    finest persisted sort unit of a base segment).
    """
    n = index.n
    offsets = np.asarray(index.cell_offsets, np.int64)
    K2 = offsets.shape[0] - 1
    if boundaries is not None:
        bounds = [int(b) for b in boundaries]
        if len(bounds) != n_shards + 1 or bounds[0] != 0 or bounds[-1] != n \
                or any(b < a for a, b in zip(bounds, bounds[1:])):
            raise ValueError(
                f"boundaries must be {n_shards + 1} non-decreasing cuts "
                f"from 0 to {n}, got {bounds}")
    else:
        bounds = [round(i * n / n_shards) for i in range(n_shards + 1)]
        if cell_aligned:
            bounds = [int(offsets[np.abs(offsets - t).argmin()])
                      for t in bounds]
        bounds[0], bounds[-1] = 0, n
        for i in range(1, len(bounds)):          # snapping can reorder cuts
            bounds[i] = max(bounds[i], bounds[i - 1])
    sizes = [hi - lo for lo, hi in zip(bounds, bounds[1:])]
    n_pad = max(max(sizes), 1)

    codes = np.asarray(index.codes)
    vectors = np.asarray(index.vectors)
    ids = np.asarray(index.ids)
    cell_of = np.asarray(index.cell_of)
    alive_arr = np.ones(n, bool) if alive is None \
        else np.asarray(alive, bool).reshape(n)

    def pad_to(a, fill):
        out = np.full((n_pad,) + a.shape[1:], fill, a.dtype)
        out[: len(a)] = a
        return out

    s_codes, s_vec, s_ids, s_cell, s_valid, s_off = [], [], [], [], [], []
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        s_codes.append(pad_to(codes[lo:hi], 0))
        s_vec.append(pad_to(vectors[lo:hi], 0))
        s_ids.append(pad_to(ids[lo:hi], -1))
        s_cell.append(pad_to(cell_of[lo:hi].astype(np.int32), K2))
        s_valid.append(pad_to(alive_arr[lo:hi].astype(np.uint8), 0))
        # local CSR: the global prefix sums clipped into this shard's range
        s_off.append(np.clip(offsets - lo, 0, hi - lo).astype(np.int32))
    return ShardedIndex(
        codes=jnp.asarray(np.stack(s_codes)),
        vectors=jnp.asarray(np.stack(s_vec)),
        ids=jnp.asarray(np.stack(s_ids), jnp.int32),
        cell_of=jnp.asarray(np.stack(s_cell)),
        row_valid=jnp.asarray(np.stack(s_valid)),
        row_start=jnp.asarray(np.asarray(bounds[:-1], np.int32)[:, None]),
        cell_offsets=jnp.asarray(np.stack(s_off)),
        global_offsets=jnp.asarray(offsets.astype(np.int32)),
        coarse1=index.coarse1, coarse2=index.coarse2,
        pq_centroids=index.pq.centroids,
        pq_rotation=index.pq.rotation,
    )


def shard_index_from_store(store: Any, n_shards: int) -> ShardedIndex:
    """Build shards straight from a persistent ``VectorStore``
    (segment-aligned): pending delta segments are folded into the
    cell-sorted base first (``compact`` — deltas are unsorted appendices,
    so a window-exact shard cannot contain half of one), shard cuts snap
    to cell boundaries (the base segment's internal sort unit), and
    tombstones ride along as the row-valid bitmap WITHOUT forcing a
    physical rewrite.  This is ``add_replica_from_store``'s device-mesh
    counterpart: open the store, call this, ``shard_put`` the result.
    """
    seg = store.seg
    if seg.segments:
        store.compact()
    alive = None
    if seg.tombstones:
        import numpy as _np
        from repro.core import imi as imimod
        alive = ~_np.isin(
            _np.asarray(seg.base.ids),
            _np.fromiter(seg.tombstones, imimod.ID_DTYPE))
    return shard_index(seg.base, n_shards, alive=alive, cell_aligned=True)


def index_shardings(mesh: Mesh, *, has_rotation: bool = True) -> Any:
    """The ``NamedSharding`` pytree matching :class:`ShardedIndex`: row
    shards split their leading S dim over EVERY mesh axis, codebooks
    replicate.  ``has_rotation`` must match the index (the rotation slot is
    structurally absent without OPQ)."""
    axes = tuple(mesh.axis_names)
    row = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    return ShardedIndex(codes=row, vectors=row, ids=row, cell_of=row,
                        row_valid=row, row_start=row, cell_offsets=row,
                        global_offsets=rep, coarse1=rep, coarse2=rep,
                        pq_centroids=rep,
                        pq_rotation=rep if has_rotation else None)


def shard_put(sidx: ShardedIndex, mesh: Mesh) -> ShardedIndex:
    """Place a host-built :class:`ShardedIndex` onto the mesh (one shard
    per device; ``n_shards`` must equal the mesh's device count)."""
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    if sidx.n_shards != n_dev:
        raise ValueError(
            f"index has {sidx.n_shards} shards but mesh has {n_dev} devices")
    sh = index_shardings(mesh, has_rotation=sidx.pq_rotation is not None)
    return jax.tree.map(jax.device_put, sidx, sh)


def tree_merge_topk(parts: Sequence[tuple[jax.Array, jax.Array]], k: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Host-facing tree fold of per-shard fused-scan ``(scores, ids)``
    lists (GLOBAL ids) with the exact lexicographic merge — the same
    reduction the in-farm butterfly performs, usable without a mesh (the
    property tests and the traffic-model benchmark drive it directly)."""
    from repro.kernels import ops as kops
    parts = [(s, i) for s, i in parts]
    if not parts:
        raise ValueError("tree_merge_topk needs at least one shard part")
    while len(parts) > 1:
        nxt = []
        for j in range(0, len(parts) - 1, 2):
            (sa, ia), (sb, ib) = parts[j], parts[j + 1]
            nxt.append(kops.topk_merge(sa, ia, sb, ib, k))
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    s, i = parts[0]
    z = s[:, :0]
    return kops.topk_merge(s, i, z, i[:, :0], k)   # normalize width to k


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def make_sharded_search(mesh: Mesh, *,
                        cfg: Optional[anns.SearchConfig] = None,
                        mode: str = "probe", **overrides):
    """Build the jit-able sharded batched search:
    ``(ShardedIndex, qs (Q, D')[, row_mask]) -> dict(ids, scores,
    approx_scores, rows)`` — the distributed formulation of
    ``anns.search_batch``.

    ``cfg`` is a ``SearchConfig`` (defaults match the single-host path,
    including ``use_kernel='auto'`` resolving through
    ``kernels.ops.resolve_use_kernel`` at trace time — Pallas on TPU /
    forced-compile parity, blocked-jnp elsewhere); keyword ``overrides``
    patch individual fields (``top_k=...`` etc.).

    ``mode``:
      * ``'probe'`` (default; alias ``'cell_probe'``) — IMI top-A probe.
        On a shared-coverage config (``top_a * max_cell_size >= n``) the
        result is BIT-IDENTICAL to single-host
        ``search_batch(fused_topk=True)``: same ids, same scores, same
        dead-slot ``(-inf, -1)`` padding (DESIGN.md §13).
      * ``'exhaustive'`` — descriptors cover all K² cells (the w/o-ANNS
        ablation, distributed): same candidate semantics as
        ``anns.exhaustive_adc``.

    ``row_mask`` (optional (n,) or (Q, n) over GLOBAL rows) is split per
    shard and fused into the same scan pass as the row-valid/tombstone
    bitmap (filter pushdown, DESIGN.md §10).
    """
    base_cfg = cfg or anns.SearchConfig()
    if overrides:
        base_cfg = dataclasses.replace(base_cfg, **overrides)
    if mode == "cell_probe":
        mode = "probe"
    if mode not in ("probe", "exhaustive"):
        raise ValueError(f"mode must be probe|exhaustive, got {mode!r}")
    scfg = base_cfg
    axes = tuple(mesh.axis_names)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))

    def farm(codes, vectors, ids, row_start, smask, qs, starts, counts,
             bases, luts, fetch_k: int):
        # per-shard block shapes: sharded args carry a leading (1, ...) dim
        codes, vectors, ids = codes[0], vectors[0], ids[0]
        smask = smask[0]                       # (1 | Q, n_local)
        r0 = row_start[0, 0]
        Q, n_local = qs.shape[0], codes.shape[0]
        lmask = jnp.broadcast_to(smask != 0, (Q, n_local)).astype(jnp.uint8)
        # the SAME global descriptors, shifted: local row i is global row
        # r0 + i, so membership in [start, start+count) is exactly
        # membership in the shifted window — no per-shard recomputation,
        # no per-shard count cap (which would break parity)
        sc, lrows = anns._topk_windowed(
            luts, codes, starts - r0, counts, bases, fetch_k,
            scfg.use_kernel, lmask)
        safe = jnp.maximum(lrows, 0)
        gid = ids[safe]                                        # (Q, L)
        grow = jnp.where(lrows >= 0, lrows + r0, -1)
        if scfg.exact_rerank:
            # per-shard exact rerank of the L survivors: the einsum shape
            # (Q, L, D') matches the single-host refine exactly, so each
            # row's exact score is bitwise what one host would compute —
            # carrying exact through the merge keeps the refine exact
            # because the global top-L is a subset of the shard top-Ls
            vecs = vectors[safe].astype(jnp.float32)
            exact = jnp.einsum("qkd,qd->qk", vecs, qs)
            exact = jnp.where(jnp.isfinite(sc), exact, -jnp.inf)
        else:
            exact = sc
        # -- tree merge: only (Q, L) tuples cross the interconnect --------
        cur = (sc, grow, exact, gid)
        if n_dev > 1 and len(axes) == 1 and _is_pow2(n_dev):
            # butterfly (recursive doubling): log2(S) ppermute rounds, each
            # shipping L slots/query; after the last round every device
            # holds the identical global top-L (sort-merge is deterministic)
            d = 1
            while d < n_dev:
                perm = [(i, i ^ d) for i in range(n_dev)]
                oth = tuple(jax.lax.ppermute(x, axes[0], perm) for x in cur)
                m = _pq.topk_merge(cur[0], cur[1], oth[0], oth[1], fetch_k,
                                   (cur[2], cur[3]), (oth[2], oth[3]))
                cur = m
                d *= 2
        elif n_dev > 1:
            # non-power-of-two or multi-axis mesh: gather the (Q, L) lists
            # (still O(L·S)/query, never a score matrix) and sort-merge once
            g = tuple(jax.lax.all_gather(x, axes, axis=1, tiled=True)
                      for x in cur)
            cur = _pq.topk_merge(g[0], g[1], g[0][:, :0], g[1][:, :0],
                                 fetch_k, (g[2], g[3]),
                                 (g[2][:, :0], g[3][:, :0]))
        return cur

    def search(sidx: ShardedIndex, qs: jax.Array,
               row_mask: Optional[jax.Array] = None) -> dict[str, jax.Array]:
        # Host-side injection seam: fires per invocation (at trace time
        # under jit — leaves nothing in the jaxpr), modeling the pod-level
        # RPC into the sharded-search collective.
        chaos.failpoint("distributed.shard.rpc")
        qs = pqmod.normalize(qs.astype(jnp.float32))
        Q = qs.shape[0]
        pq = pqmod.PQ(sidx.pq_centroids, rotation=sidx.pq_rotation)
        n_pad = sidx.codes.shape[1]
        if mode == "probe":
            _, bases, starts, counts, luts = anns.probe_descriptors(
                sidx.coarse1, sidx.coarse2, pq, sidx.global_offsets, qs,
                top_a=scfg.top_a, max_cell_size=scfg.max_cell_size)
            cap = scfg.top_a * scfg.max_cell_size
        else:  # exhaustive: every cell is a window, counts uncapped
            K = sidx.coarse1.shape[0]
            h = qs.shape[-1] // 2
            s1 = qs[:, :h] @ sidx.coarse1.T
            s2 = qs[:, h:] @ sidx.coarse2.T
            cells = np.arange(K * K)
            bases = s1[:, cells // K] + s2[:, cells % K]       # (Q, K*K)
            starts = jnp.broadcast_to(sidx.global_offsets[:-1], (Q, K * K))
            counts = jnp.broadcast_to(
                sidx.global_offsets[1:] - sidx.global_offsets[:-1],
                (Q, K * K))
            luts = jax.vmap(lambda q: pqmod.similarity_lut(pq, q))(qs)
            cap = sidx.n_shards * n_pad
        fetch_k = min(scfg.top_k * max(scfg.rerank_overfetch, 1), cap) \
            if scfg.exact_rerank else scfg.top_k
        # fold the planner's GLOBAL row mask into each shard's validity
        # bitmap host-of-mesh side; padding/tombstones are already zero
        if row_mask is not None:
            n_rows = sidx.global_offsets[-1]
            rm = jnp.broadcast_to(
                jnp.asarray(row_mask),
                (Q, row_mask.shape[-1])).astype(jnp.uint8)
            gr = sidx.row_start + jnp.arange(n_pad, dtype=jnp.int32)[None]
            m = rm[:, jnp.clip(gr, 0, n_rows - 1)]             # (Q, S, n_pad)
            smask = jnp.transpose(m, (1, 0, 2)) * sidx.row_valid[:, None, :]
        else:
            smask = sidx.row_valid[:, None, :]                 # (S, 1, n_pad)

        shd = P(axes)
        rep = P()
        f = shard_map_compat(
            lambda *a: farm(*a, fetch_k=fetch_k), mesh=mesh,
            in_specs=(shd, shd, shd, shd, shd, rep, rep, rep, rep, rep),
            out_specs=(rep, rep, rep, rep))
        sc, grow, exact, gid = f(sidx.codes, sidx.vectors, sidx.ids,
                                 sidx.row_start, smask, qs,
                                 starts.astype(jnp.int32),
                                 counts.astype(jnp.int32), bases, luts)
        if scfg.exact_rerank:
            # identical final refine to search_batch: stable argsort over
            # the exact scores of the SAME candidate list in the SAME
            # order => bit-identical top_k cut
            order = jnp.argsort(-exact, axis=1)[:, : scfg.top_k]
            scores = jnp.take_along_axis(exact, order, axis=1)
            approx = jnp.take_along_axis(sc, order, axis=1)
            grow = jnp.take_along_axis(grow, order, axis=1)
            gid = jnp.take_along_axis(gid, order, axis=1)
        else:
            scores = sc[:, : scfg.top_k]
            approx = sc[:, : scfg.top_k]
            grow, gid = grow[:, : scfg.top_k], gid[:, : scfg.top_k]
        live = jnp.isfinite(scores)
        return {"ids": jnp.where(live, gid, -1), "scores": scores,
                "approx_scores": approx,
                "rows": jnp.where(live, grow, -1)}

    return search


# ---------------------------------------------------------------------------
# Elastic shard control plane: generation-stamped routing
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    """One shard's placement: global row range + the replica serving it."""
    shard_id: int
    row_range: tuple[int, int]     # [lo, hi) global rows (informational)
    replica: str


@dataclasses.dataclass(frozen=True)
class RoutingTable:
    """Immutable shard->replica map with a GENERATION stamp.

    Every topology change — migration, split — returns a NEW table with
    ``generation + 1``.  ``QueryRouter.install_routing`` stamps the
    serving replicas with the table's generation; a ``call_sharded``
    broadcast then refuses replicas stamped with an older generation (a
    replica still serving a pre-migration shard layout would merge rows
    twice or not at all).  The stamp protocol is what makes mid-stream
    migration safe: queries race the move, but never observe half of it.
    """
    assignments: tuple[ShardAssignment, ...]
    generation: int = 0

    @classmethod
    def initial(cls, replicas: Sequence[str],
                boundaries: Optional[Sequence[int]] = None) -> "RoutingTable":
        n = len(replicas)
        if boundaries is None:
            boundaries = [0] * (n + 1)       # row ranges unknown/abstract
        if len(boundaries) != n + 1:
            raise ValueError("need len(replicas)+1 boundaries")
        return cls(tuple(
            ShardAssignment(i, (int(boundaries[i]), int(boundaries[i + 1])),
                            r)
            for i, r in enumerate(replicas)))

    def replicas(self) -> tuple[str, ...]:
        return tuple(a.replica for a in self.assignments)

    def migrate(self, shard_id: int, to_replica: str) -> "RoutingTable":
        """Move one shard to a new replica; bumps the generation."""
        if shard_id not in {a.shard_id for a in self.assignments}:
            raise ValueError(f"unknown shard {shard_id}")
        return RoutingTable(tuple(
            dataclasses.replace(a, replica=to_replica)
            if a.shard_id == shard_id else a for a in self.assignments),
            self.generation + 1)

    def split(self, shard_id: int, at_row: int,
              new_replica: str) -> "RoutingTable":
        """Split a hot shard at ``at_row``: the upper half moves to
        ``new_replica`` as a fresh shard id; bumps the generation."""
        out: list[ShardAssignment] = []
        next_id = 1 + max(a.shard_id for a in self.assignments)
        found = False
        for a in self.assignments:
            if a.shard_id == shard_id:
                lo, hi = a.row_range
                if not (lo <= at_row <= hi):
                    raise ValueError(
                        f"split row {at_row} outside shard range {a.row_range}")
                out.append(dataclasses.replace(a, row_range=(lo, at_row)))
                out.append(ShardAssignment(next_id, (at_row, hi),
                                           new_replica))
                found = True
            else:
                out.append(a)
        if not found:
            raise ValueError(f"unknown shard {shard_id}")
        return RoutingTable(tuple(out), self.generation + 1)
