"""Distributed LOVO index: shard_map scan farm over the mesh.

The paper scales via Milvus server shards; the TPU-native equivalent shards
index rows across EVERY mesh axis (the whole pod is one flat scan farm for
serving).  Per device:

  local ADC scan (Pallas kernel on real TPU)  ->  local top-k
  all_gather of (k scores, k global ids)       ->  global top-k

Only O(k x devices) bytes cross the interconnect per query — independent of
index size N, which is the collective-form statement of the paper's
"latency flat in dataset size" claim (Fig. 11b).

Two search modes:
  * ``sharded_exhaustive`` — full ADC over local rows (baseline / w-o-ANNS)
  * ``sharded_cell_probe`` — each shard holds its own CSR layout over the
    SHARED coarse codebooks; top-A cells are probed locally then merged
    (the paper's IMI, distributed).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import pq as pqmod
from repro.core.imi import IMIIndex


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: the stable spelling (with
    ``check_vma``) when present, else ``jax.experimental.shard_map`` (with
    the older ``check_rep`` knob)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


@dataclasses.dataclass
class ShardedIndex:
    """Row-sharded index arrays + replicated codebooks.

    All arrays carry a leading 'shards' dim of size n_devices so shapes are
    static per device under shard_map.
    """

    codes: jax.Array         # (S, n_local, P) uint8
    vectors: jax.Array       # (S, n_local, D') bf16
    ids: jax.Array           # (S, n_local) int32 global patch ids
    cell_of: jax.Array       # (S, n_local) int32
    cell_offsets: jax.Array  # (S, K*K+1) int32 per-shard CSR
    coarse1: jax.Array       # (K, D'/2) replicated
    coarse2: jax.Array
    pq_centroids: jax.Array  # (P, M, m) replicated
    pq_rotation: jax.Array   # (D', D') replicated (identity when no OPQ —
    #                          static shape keeps shard_map specs uniform)

    def tree_flatten(self):
        return ((self.codes, self.vectors, self.ids, self.cell_of,
                 self.cell_offsets, self.coarse1, self.coarse2,
                 self.pq_centroids, self.pq_rotation), None)

    @classmethod
    def tree_unflatten(cls, aux, kids):
        return cls(*kids)


jax.tree_util.register_pytree_node_class(ShardedIndex)


def shard_index(index: IMIIndex, n_shards: int) -> ShardedIndex:
    """Round-robin rows into n_shards, rebuilding per-shard CSR offsets.

    Host-side (numpy) — this is the ingest/placement step a router would do.
    """
    n = index.n
    per = -(-n // n_shards)
    pad = per * n_shards - n
    def pad_rows(a, fill=0):
        a = np.asarray(a)
        if pad:
            a = np.concatenate([a, np.full((pad,) + a.shape[1:], fill,
                                           a.dtype)])
        return a
    # rows are cell-sorted; strided assignment keeps each shard's rows
    # cell-sorted too (order-preserving subsequence)
    codes = pad_rows(index.codes)
    vectors = pad_rows(index.vectors)
    ids = pad_rows(index.ids, fill=-1)
    cell_of = pad_rows(index.cell_of, fill=2 ** 30)
    K2 = index.cell_offsets.shape[0] - 1
    s_codes, s_vec, s_ids, s_cell, s_off = [], [], [], [], []
    for s in range(n_shards):
        sel = np.arange(s, per * n_shards, n_shards)
        c = cell_of[sel]
        s_codes.append(codes[sel])
        s_vec.append(vectors[sel])
        s_ids.append(ids[sel])
        s_cell.append(c)
        counts = np.bincount(np.clip(c, 0, K2 - 1), minlength=K2,
                             weights=(c < K2).astype(np.int64)).astype(np.int64)
        s_off.append(np.concatenate([[0], np.cumsum(counts)]).astype(np.int32))
    return ShardedIndex(
        codes=jnp.asarray(np.stack(s_codes)),
        vectors=jnp.asarray(np.stack(s_vec)),
        ids=jnp.asarray(np.stack(s_ids)),
        cell_of=jnp.asarray(np.stack(s_cell)),
        cell_offsets=jnp.asarray(np.stack(s_off)),
        coarse1=index.coarse1, coarse2=index.coarse2,
        pq_centroids=index.pq.centroids,
        pq_rotation=(index.pq.rotation if index.pq.rotation is not None
                     else jnp.eye(index.vectors.shape[-1], dtype=jnp.float32)),
    )


def index_shardings(mesh: Mesh) -> Any:
    axes = tuple(mesh.axis_names)
    row = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    return ShardedIndex(codes=row, vectors=row, ids=row, cell_of=row,
                        cell_offsets=row, coarse1=rep, coarse2=rep,
                        pq_centroids=rep, pq_rotation=rep)


def make_sharded_search(mesh: Mesh, *, top_k: int = 100,
                        mode: str = "exhaustive", top_a: int = 32,
                        max_cell_size: int = 1024,
                        use_kernel: str = "auto"):
    """Builds a jit-able batched search: (ShardedIndex, qs (Q, D')) ->
    dict(ids (Q, k), scores (Q, k)).

    ``use_kernel`` matches ``SearchConfig.use_kernel`` ('auto' resolves per
    backend); the per-shard scan currently always uses the jnp formulation
    inside shard_map — the parameter is accepted for config symmetry."""
    axes = tuple(mesh.axis_names)

    def local_scan(codes, vectors, ids, cell_of, offsets, c1, c2, cents,
                   rot, qs):
        # shapes inside shard_map: codes (1, n_local, P) etc.; qs replicated
        codes, vectors, ids = codes[0], vectors[0], ids[0]
        cell_of, offsets = cell_of[0], offsets[0]
        pq = pqmod.PQ(cents, rotation=rot)
        K = c1.shape[0]

        def one(q):
            q = pqmod.normalize(q.astype(jnp.float32))
            h = q.shape[-1] // 2
            s1, s2 = c1 @ q[:h], c2 @ q[h:]
            lut = pqmod.similarity_lut(pq, q)
            if mode == "exhaustive":
                base = s1[jnp.clip(cell_of // K, 0, K - 1)] \
                    + s2[jnp.clip(cell_of % K, 0, K - 1)]
                base = jnp.where(cell_of < K * K, base, -jnp.inf)
                scores = base + pqmod.adc_scores(lut, codes)
                rows = None
            else:  # cell_probe
                from repro.core.imi import multi_sequence_top_a, probe_adjust
                cells = multi_sequence_top_a(s1 + probe_adjust(c1),
                                             s2 + probe_adjust(c2), top_a)
                cbase = s1[cells // K] + s2[cells % K]
                starts = offsets[cells]
                counts = jnp.minimum(offsets[cells + 1] - starts,
                                     max_cell_size)
                win = starts[:, None] + jnp.arange(max_cell_size)[None, :]
                valid = jnp.arange(max_cell_size)[None, :] < counts[:, None]
                rows = jnp.clip(win, 0, codes.shape[0] - 1)
                cand = codes[rows.reshape(-1)]
                sc = pqmod.adc_scores(lut, cand).reshape(rows.shape)
                scores_w = jnp.where(valid, sc + cbase[:, None], -jnp.inf)
                scores, rows = scores_w.reshape(-1), rows.reshape(-1)
            # same overfetch + exact-refine protocol as anns.search /
            # exhaustive_adc: ADC order is approximate, so fetch a multiple
            # of top_k, exact-rescore, THEN cut
            fetch_k = min(top_k * 4, scores.shape[0])
            vals, idx = jax.lax.top_k(scores, fetch_k)
            sel = idx if rows is None else rows[idx]
            exact = vectors[sel].astype(jnp.float32) @ q
            exact = jnp.where(jnp.isfinite(vals), exact, -jnp.inf)
            order = jnp.argsort(-exact)[:top_k]
            return exact[order], ids[sel[order]]

        ex, gid = jax.vmap(one)(qs)                       # (Q, k) each
        # global merge: ship only k ids+scores per device
        all_ex = jax.lax.all_gather(ex, axes, axis=1, tiled=True)
        all_id = jax.lax.all_gather(gid, axes, axis=1, tiled=True)
        vals, idx = jax.lax.top_k(all_ex, top_k)
        return vals, jnp.take_along_axis(all_id, idx, axis=1)

    in_specs = (P(axes), P(axes), P(axes), P(axes), P(axes),
                P(), P(), P(), P(), P())
    out_specs = (P(), P())
    f = shard_map_compat(local_scan, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)

    def search(sidx: ShardedIndex, qs: jax.Array):
        vals, ids = f(sidx.codes, sidx.vectors, sidx.ids, sidx.cell_of,
                      sidx.cell_offsets, sidx.coarse1, sidx.coarse2,
                      sidx.pq_centroids, sidx.pq_rotation, qs)
        return {"scores": vals, "ids": ids}

    return search
