"""Inverted multi-index (Babenko & Lempitsky, CVPR'12) — LOVO §V-B.

The coarse quantizer splits R^{D'} into two halves, each with K centroids;
the Cartesian product gives K^2 cells.  Vectors are stored *sorted by cell
id* with a CSR offsets array — the TPU-native replacement for pointer-chasing
inverted lists: a queried cell is a contiguous [start, start+count) range, so
top-A cell probing becomes A fixed-size gathers with static shapes.

Payload per vector: PQ codes of the *residual* (x - coarse centroid), the
original (normalized) vector in bf16 for exact re-scoring, and the patch id
linking to the host-side metadata store (frame id + bbox — the paper's
"relational database").
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod
from repro.core.pq import PQ, kmeans

# Canonical patch-id dtype, end-to-end: build, delta segments, tombstones,
# and the on-disk store all use int32 so persisted segments round-trip
# bit-exactly (int64 would silently downcast on device: x64 is disabled).
# 2^31 ids per shard; beyond that the sharding layer partitions the id space.
ID_DTYPE = np.int32


@dataclasses.dataclass
class IMIIndex:
    """Dense, jit-friendly inverted multi-index."""

    coarse1: jax.Array       # (K, D'/2)
    coarse2: jax.Array       # (K, D'/2)
    pq: PQ                   # residual codebooks (P, M, m)
    codes: jax.Array         # (N, P) uint8, cell-sorted
    vectors: jax.Array       # (N, D') bf16, cell-sorted (exact re-scoring)
    ids: jax.Array           # (N,) int32 patch ids, cell-sorted
    cell_of: jax.Array       # (N,) int32 cell id per (sorted) row
    cell_offsets: jax.Array  # (K*K + 1,) int32 CSR offsets

    @property
    def K(self) -> int:
        return self.coarse1.shape[0]

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    def tree_flatten(self):
        kids = (self.coarse1, self.coarse2, self.pq, self.codes,
                self.vectors, self.ids, self.cell_of, self.cell_offsets)
        return kids, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node_class(IMIIndex)


def assign_cells(coarse1: jax.Array, coarse2: jax.Array, x: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Nearest coarse centroid per half -> (cell_id, a1, a2).

    Runs through the fused Pallas assignment kernel: no (N, K) distance
    matrix in HBM, same memory contract as the codebook training loops.
    """
    from repro.kernels import ops as kops

    K = coarse1.shape[0]
    h = x.shape[-1] // 2
    x1, x2 = x[..., :h], x[..., h:]
    a1, _ = kops.kmeans_assign(x1, coarse1)
    a2, _ = kops.kmeans_assign(x2, coarse2)
    return a1 * K + a2, a1, a2


def coarse_reconstruct(coarse1: jax.Array, coarse2: jax.Array,
                       a1: jax.Array, a2: jax.Array) -> jax.Array:
    return jnp.concatenate([coarse1[a1], coarse2[a2]], axis=-1)


def train_imi_codebooks(rng: jax.Array, x: jax.Array, *,
                        K: int, P: int, M: int, kmeans_iters: int = 15,
                        opq_iters: int = 0, coarse_cells: int | None = None
                        ) -> tuple[jax.Array, jax.Array, PQ, jax.Array,
                                   jax.Array]:
    """The one codebook-training protocol (monolithic AND streaming builds
    call this — parity between them is structural, not hand-synchronized).

    x: (N, D') already normalized.  Returns (coarse1, coarse2, pq, cell,
    residual) for the training rows.
    """
    h = x.shape[-1] // 2
    r1, r2, r3 = jax.random.split(rng, 3)
    coarse1, _ = kmeans(r1, x[:, :h], K, kmeans_iters)
    coarse2, _ = kmeans(r2, x[:, h:], K, kmeans_iters)
    cell, a1, a2 = assign_cells(coarse1, coarse2, x)
    residual = x - coarse_reconstruct(coarse1, coarse2, a1, a2)
    if opq_iters > 0:
        pq = pqmod.train_opq(r3, residual, P, M, kmeans_iters,
                             opq_iters=opq_iters, coarse_cells=coarse_cells)
    else:
        pq = pqmod.train_pq(r3, residual, P, M, kmeans_iters,
                            coarse_cells=coarse_cells)
    return coarse1, coarse2, pq, cell, residual


def build_imi(rng: jax.Array, x: jax.Array, ids: jax.Array, *,
              K: int, P: int, M: int, kmeans_iters: int = 15,
              opq_iters: int = 0, coarse_cells: int | None = None
              ) -> IMIIndex:
    """Train coarse + residual-PQ codebooks and build the sorted layout.

    x: (N, D') raw class embeddings (normalized inside); ids: (N,) patch ids.
    ``opq_iters > 0`` learns an OPQ rotation for the residual quantizer
    (alternating Procrustes + Lloyd); the rotation rides inside the ``PQ``
    pytree so search stays score-correct with no extra plumbing.
    ``coarse_cells`` sizes the per-subspace coarse stage of the two-level
    residual codebook (None = auto).
    """
    x = pqmod.normalize(x.astype(jnp.float32))
    coarse1, coarse2, pq, cell, residual = train_imi_codebooks(
        rng, x, K=K, P=P, M=M, kmeans_iters=kmeans_iters,
        opq_iters=opq_iters, coarse_cells=coarse_cells)
    codes = pqmod.pq_encode(pq, residual)

    order = jnp.argsort(cell, stable=True)
    counts = jnp.bincount(cell, length=K * K)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)]).astype(jnp.int32)
    return IMIIndex(
        coarse1=coarse1, coarse2=coarse2, pq=pq,
        codes=codes[order],
        vectors=x[order].astype(jnp.bfloat16),
        ids=ids[order].astype(jnp.int32),
        cell_of=cell[order].astype(jnp.int32),
        cell_offsets=offsets,
    )


def probe_adjust(coarse: jax.Array) -> jax.Array:
    """Per-centroid additive term making dot-product cell ranking agree
    with the L2 cell ASSIGNMENT: argmin ||q - c||^2 == argmax (q.c - |c|^2/2)
    for fixed q.  Without it, a vector whose centroid has a small norm can
    be assigned to a cell the dot-ranked probe never visits — the row then
    becomes unreachable no matter how large top_k is."""
    return -0.5 * jnp.sum(jnp.square(coarse), axis=-1)


def cell_scores(index: IMIIndex, q: jax.Array) -> jax.Array:
    """Similarity of query to every cell: outer sum of half-similarities.

    s[c1, c2] = q1 . coarse1[c1] + q2 . coarse2[c2]   -> (K, K) flattened.
    """
    h = q.shape[-1] // 2
    s1 = index.coarse1 @ q[:h]     # (K,)
    s2 = index.coarse2 @ q[h:]     # (K,)
    return (s1[:, None] + s2[None, :]).reshape(-1)


def multi_sequence_top_a(s1: jax.Array, s2: jax.Array, a: int) -> jax.Array:
    """Babenko-Lempitsky multi-sequence traversal, vectorized: exact top-A
    cells of the outer sum (s1[i] + s2[j]) without materializing all K^2.

    Exactness: if cell (i, j) is in the true top-A then fewer than A cells
    beat it; every (i', j) with s1[i'] > s1[i] beats it, so rank(i) <= A
    (same for j).  Hence the (A x A) outer sum over the per-half top-A
    frontiers contains the true top-A.
    """
    K = s1.shape[0]
    r = min(K, a)
    v1, i1 = jax.lax.top_k(s1, r)
    v2, i2 = jax.lax.top_k(s2, r)
    outer = v1[:, None] + v2[None, :]
    _, flat = jax.lax.top_k(outer.reshape(-1), a)
    c1 = i1[flat // r]
    c2 = i2[flat % r]
    return c1 * K + c2
