"""Offline index-build pipeline — LOVO Fig. 4 / §IV-D.

videos -> key frames -> ViT patch class-embeddings + boxes -> IMI build.
The vector database holds (codes, vectors, patch ids); the "relational
database" side-table (frame id, bbox per patch id) is a host-side
MetadataStore keyed by patch id — exactly the paper's split, minus the SQL
engine (the layout/linking is the contribution, see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import imi as imimod
from repro.data import video as videomod
from repro.data.synthetic import Video
from repro.models import vit as vitmod


@dataclasses.dataclass
class MetadataStore:
    """patch id -> (video id, frame index, bbox).  Arrays for O(1) lookup."""

    video_of: np.ndarray   # (N,) int32
    frame_of: np.ndarray   # (N,) int32  (index into the *original* video)
    bbox_of: np.ndarray    # (N, 4) float32 cxcywh

    def lookup(self, patch_ids: np.ndarray) -> dict[str, np.ndarray]:
        pid = np.asarray(patch_ids)
        return {"video": self.video_of[pid], "frame": self.frame_of[pid],
                "bbox": self.bbox_of[pid]}


@dataclasses.dataclass
class BuiltIndex:
    index: imimod.IMIIndex
    metadata: MetadataStore
    keyframes: np.ndarray      # (F, H, W, 3) the stored key frames
    keyframe_video: np.ndarray  # (F,) int32
    keyframe_frame: np.ndarray  # (F,) int32
    patches_per_frame: int


def encode_keyframes(vit_params: Any, frames: np.ndarray,
                     cfg: vitmod.ViTConfig, *, batch: int = 8
                     ) -> tuple[np.ndarray, np.ndarray]:
    """(F, H, W, 3) -> (class_embeds (F, K, D'), boxes (F, K, 4))."""
    encode = jax.jit(lambda p, im: vitmod.vit_encode(p, im, cfg)[:2])
    outs_c, outs_b = [], []
    for i in range(0, len(frames), batch):
        chunk = frames[i: i + batch]
        pad = batch - len(chunk)
        if pad:
            chunk = np.concatenate([chunk, np.zeros_like(chunk[:1]).repeat(pad, 0)])
        c, b = encode(vit_params, jnp.asarray(chunk))
        outs_c.append(np.asarray(c)[: len(chunk) - pad if pad else None])
        outs_b.append(np.asarray(b)[: len(chunk) - pad if pad else None])
    return np.concatenate(outs_c), np.concatenate(outs_b)


def build_from_videos(rng: jax.Array, videos: Sequence[Video],
                      vit_params: Any, cfg: vitmod.ViTConfig, *,
                      K: int = 16, P: int = 8, M: int = 64,
                      keyframe_stride: int = 8,
                      use_keyframes: bool = True,
                      kmeans_iters: int = 10) -> BuiltIndex:
    all_frames, kf_video, kf_frame = [], [], []
    for vi, v in enumerate(videos):
        if use_keyframes:
            idx = videomod.extract_keyframes(v.frames, stride=keyframe_stride)
        else:  # 'w/o Key frame' ablation: every frame is indexed
            idx = np.arange(v.frames.shape[0], dtype=np.int32)
        all_frames.append(v.frames[idx])
        kf_video.extend([vi] * len(idx))
        kf_frame.extend(idx.tolist())
    frames = np.concatenate(all_frames)           # (F, H, W, 3)
    kf_video = np.asarray(kf_video, np.int32)
    kf_frame = np.asarray(kf_frame, np.int32)

    cls, boxes = encode_keyframes(vit_params, frames, cfg)
    F, Kp, Dp = cls.shape
    flat = cls.reshape(F * Kp, Dp)
    patch_ids = np.arange(F * Kp, dtype=np.int32)
    index = imimod.build_imi(rng, jnp.asarray(flat), jnp.asarray(patch_ids),
                             K=K, P=P, M=M, kmeans_iters=kmeans_iters)
    meta = MetadataStore(
        video_of=np.repeat(kf_video, Kp),
        frame_of=np.repeat(kf_frame, Kp),
        bbox_of=boxes.reshape(F * Kp, 4).astype(np.float32),
    )
    return BuiltIndex(index=index, metadata=meta, keyframes=frames,
                      keyframe_video=kf_video, keyframe_frame=kf_frame,
                      patches_per_frame=Kp)


def save_built(path, built: BuiltIndex, *, meta: dict | None = None) -> None:
    """Persist a build (index + keyframes + metadata side-table) as a
    ``repro.store.VectorStore`` directory — the one-time-extraction artifact
    that makes restarts and replica joins cheap (DESIGN.md §4)."""
    from repro.store import VectorStore
    VectorStore.create(path, built, meta=meta).close()


def load_built(path, *, verify: bool = True) -> BuiltIndex:
    """Reopen a persisted build without re-encoding video or re-training
    codebooks; outstanding WAL/deltas are folded so the returned index is
    the complete current state."""
    from repro.store import VectorStore
    with VectorStore.open(path, verify=verify) as store:
        return store.to_built_index()
