"""Offline index-build pipeline — LOVO Fig. 4 / §IV-D.

videos -> key frames -> ViT patch class-embeddings + boxes -> IMI build.
The vector database holds (codes, vectors, patch ids); the "relational
database" side-table (frame id, bbox per patch id) is a host-side
MetadataStore keyed by patch id — exactly the paper's split, minus the SQL
engine (the layout/linking is the contribution, see DESIGN.md §3).

Two build paths share the same codebook training:

  * ``build_from_videos`` — monolithic: every embedding in host memory.
  * ``StreamingIndexBuilder`` / ``build_imi_streaming`` — bounded memory
    (DESIGN.md §9): codebooks are trained on a reservoir sample, then the
    corpus is encoded in fixed-size chunks that spill straight into
    ``repro.store`` segment files; the final cell-sorted base is assembled
    by gathering rows from the mmap'd spill segments.  Peak host memory is
    the final index arrays (uint8 codes + bf16 vectors) plus ONE raw f32
    chunk — never the full f32 corpus, and (via the fused Pallas assignment
    kernel) never an (N, M) distance matrix.
"""
from __future__ import annotations

import dataclasses
import pathlib
import shutil
from typing import Any, Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import imi as imimod
from repro.core import pq as pqmod
from repro.data import video as videomod
from repro.data.synthetic import Video
from repro.models import vit as vitmod


@dataclasses.dataclass
class MetadataStore:
    """patch id -> (video id, frame index, bbox).  Arrays for O(1) lookup."""

    video_of: np.ndarray   # (N,) int32
    frame_of: np.ndarray   # (N,) int32  (index into the *original* video)
    bbox_of: np.ndarray    # (N, 4) float32 cxcywh

    def lookup(self, patch_ids: np.ndarray) -> dict[str, np.ndarray]:
        pid = np.asarray(patch_ids)
        return {"video": self.video_of[pid], "frame": self.frame_of[pid],
                "bbox": self.bbox_of[pid]}


@dataclasses.dataclass
class BuiltIndex:
    index: imimod.IMIIndex
    metadata: MetadataStore
    keyframes: np.ndarray      # (F, H, W, 3) the stored key frames
    keyframe_video: np.ndarray  # (F,) int32
    keyframe_frame: np.ndarray  # (F,) int32
    patches_per_frame: int


def encode_keyframes(vit_params: Any, frames: np.ndarray,
                     cfg: vitmod.ViTConfig, *, batch: int = 8
                     ) -> tuple[np.ndarray, np.ndarray]:
    """(F, H, W, 3) -> (class_embeds (F, K, D'), boxes (F, K, 4))."""
    encode = jax.jit(lambda p, im: vitmod.vit_encode(p, im, cfg)[:2])
    outs_c, outs_b = [], []
    for i in range(0, len(frames), batch):
        chunk = frames[i: i + batch]
        pad = batch - len(chunk)
        if pad:
            chunk = np.concatenate([chunk, np.zeros_like(chunk[:1]).repeat(pad, 0)])
        c, b = encode(vit_params, jnp.asarray(chunk))
        outs_c.append(np.asarray(c)[: len(chunk) - pad if pad else None])
        outs_b.append(np.asarray(b)[: len(chunk) - pad if pad else None])
    return np.concatenate(outs_c), np.concatenate(outs_b)


def build_from_videos(rng: jax.Array, videos: Sequence[Video],
                      vit_params: Any, cfg: vitmod.ViTConfig, *,
                      K: int = 16, P: int = 8, M: int = 64,
                      keyframe_stride: int = 8,
                      use_keyframes: bool = True,
                      kmeans_iters: int = 10) -> BuiltIndex:
    all_frames, kf_video, kf_frame = [], [], []
    for vi, v in enumerate(videos):
        if use_keyframes:
            idx = videomod.extract_keyframes(v.frames, stride=keyframe_stride)
        else:  # 'w/o Key frame' ablation: every frame is indexed
            idx = np.arange(v.frames.shape[0], dtype=np.int32)
        all_frames.append(v.frames[idx])
        kf_video.extend([vi] * len(idx))
        kf_frame.extend(idx.tolist())
    frames = np.concatenate(all_frames)           # (F, H, W, 3)
    kf_video = np.asarray(kf_video, np.int32)
    kf_frame = np.asarray(kf_frame, np.int32)

    cls, boxes = encode_keyframes(vit_params, frames, cfg)
    F, Kp, Dp = cls.shape
    flat = cls.reshape(F * Kp, Dp)
    patch_ids = np.arange(F * Kp, dtype=np.int32)
    index = imimod.build_imi(rng, jnp.asarray(flat), jnp.asarray(patch_ids),
                             K=K, P=P, M=M, kmeans_iters=kmeans_iters)
    meta = MetadataStore(
        video_of=np.repeat(kf_video, Kp),
        frame_of=np.repeat(kf_frame, Kp),
        bbox_of=boxes.reshape(F * Kp, 4).astype(np.float32),
    )
    return BuiltIndex(index=index, metadata=meta, keyframes=frames,
                      keyframe_video=kf_video, keyframe_frame=kf_frame,
                      patches_per_frame=Kp)


# ---------------------------------------------------------------------------
# Streaming / sharded build (DESIGN.md §9)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StreamingBuildConfig:
    """Knobs for the bounded-memory build.

    ``sample_size`` bounds the codebook-training working set (reservoir);
    ``chunk_rows`` bounds the encode working set.  With ``sample_size >=
    corpus size`` the reservoir degenerates to the full corpus in original
    order and the streaming build is bit-identical to ``build_imi`` (tested
    in tests/test_quantization.py).
    """

    K: int = 16
    P: int = 8
    M: int = 64
    kmeans_iters: int = 10
    opq_iters: int = 0
    coarse_cells: Optional[int] = None
    sample_size: int = 32_768
    chunk_rows: int = 8_192
    reservoir_seed: int = 0


class StreamingIndexBuilder:
    """Two-phase, bounded-memory IMI build against ``repro.store`` spill
    segments.

    Phase 1: ``observe(x)`` every chunk (reservoir sampling, Vitter's
    algorithm R, vectorized).  ``train()`` then fits coarse halves +
    residual (O)PQ codebooks on the <= ``sample_size`` reservoir — the only
    rows codebook training ever sees.

    Phase 2: ``add(x, ids)`` encodes each chunk against the frozen
    codebooks (fused Pallas assignment; codes are row-independent, so
    chunked encoding is bit-equal to monolithic).  With ``spill_dir`` set,
    each encoded chunk is flushed to an immutable CRC'd store segment and
    the raw chunk is dropped; ``finish()`` assembles the cell-sorted base
    by gathering rows from the mmap'd spill segments into the final arrays.
    """

    def __init__(self, rng: jax.Array, cfg: StreamingBuildConfig, *,
                 spill_dir: Optional[str | pathlib.Path] = None):
        self.rng = rng
        self.cfg = cfg
        self.spill_dir = pathlib.Path(spill_dir) if spill_dir else None
        self._made_spill_dir = False
        if self.spill_dir is not None:
            self._made_spill_dir = not self.spill_dir.exists()
            self.spill_dir.mkdir(parents=True, exist_ok=True)
        self._np_rng = np.random.default_rng(cfg.reservoir_seed)
        self._reservoir: Optional[np.ndarray] = None
        self._filled = 0
        self._seen = 0
        self._chunks: list[Any] = []   # spill segment names or array dicts
        self._n_rows = 0
        self._dim: Optional[int] = None
        self.coarse1 = self.coarse2 = self.pq = None

    def _resliced(self, x: np.ndarray):
        """Enforce the ``chunk_rows`` working-set bound regardless of how
        the caller sized its chunks (the §9.3 memory contract must not
        depend on caller discipline)."""
        for lo in range(0, len(x), self.cfg.chunk_rows):
            yield x[lo: lo + self.cfg.chunk_rows]

    # -- phase 1: reservoir -------------------------------------------------
    def observe(self, x: np.ndarray) -> None:
        """Feed a raw (n, D') chunk into the training reservoir; oversized
        chunks are processed in ``chunk_rows`` slices."""
        if self.pq is not None:
            raise RuntimeError("observe() after train()")
        x = np.asarray(x)
        if len(x) > self.cfg.chunk_rows:
            for part in self._resliced(x):
                self.observe(part)
            return
        x = np.ascontiguousarray(x, np.float32)
        if self._dim is None:
            self._dim = x.shape[1]
            self._reservoir = np.empty((self.cfg.sample_size, self._dim),
                                       np.float32)
        take = min(self.cfg.sample_size - self._filled, len(x))
        if take > 0:
            self._reservoir[self._filled: self._filled + take] = x[:take]
            self._filled += take
            self._seen += take
            x = x[take:]
        if len(x):
            # vectorized algorithm R: row with global 0-based index t keeps a
            # slot with prob S/(t+1); duplicate slot draws resolve in row
            # order (numpy fancy assignment), matching sequential semantics
            t = self._seen + np.arange(len(x))
            slots = self._np_rng.integers(0, t + 1)
            keep = slots < self.cfg.sample_size
            self._reservoir[slots[keep]] = x[keep]
            self._seen += len(x)

    def train(self) -> None:
        """Fit coarse + residual-PQ codebooks on the reservoir sample
        (``imi.train_imi_codebooks`` — the same protocol as ``build_imi``,
        so streaming == monolithic parity is structural)."""
        if self._filled == 0:
            raise RuntimeError("train() before observe()")
        cfg = self.cfg
        sample = pqmod.normalize(jnp.asarray(self._reservoir[: self._filled]))
        self.coarse1, self.coarse2, self.pq, _, _ = \
            imimod.train_imi_codebooks(
                self.rng, sample, K=cfg.K, P=cfg.P, M=cfg.M,
                kmeans_iters=cfg.kmeans_iters, opq_iters=cfg.opq_iters,
                coarse_cells=cfg.coarse_cells)
        self._reservoir = None  # training working set released

    # -- phase 2: chunked encode -------------------------------------------
    def add(self, x: np.ndarray, ids: np.ndarray) -> None:
        """Encode one chunk against the frozen codebooks and flush it.
        Oversized chunks are encoded in ``chunk_rows`` slices (encoding is
        row-independent, so slicing cannot change the codes)."""
        if self.pq is None:
            raise RuntimeError("add() before train()")
        x = np.asarray(x)
        ids = np.ascontiguousarray(ids, imimod.ID_DTYPE).reshape(-1)
        if len(ids) != len(x):
            raise ValueError(f"add(): {len(x)} vectors but {len(ids)} ids")
        if len(x) > self.cfg.chunk_rows:
            for part, idp in zip(self._resliced(x), self._resliced(ids)):
                self.add(part, idp)
            return
        xn = pqmod.normalize(jnp.asarray(x, jnp.float32))
        cell, a1, a2 = imimod.assign_cells(self.coarse1, self.coarse2, xn)
        residual = xn - imimod.coarse_reconstruct(
            self.coarse1, self.coarse2, a1, a2)
        codes = pqmod.pq_encode(self.pq, residual)
        arrays = {
            "codes": np.asarray(codes),
            "vectors": np.asarray(xn.astype(jnp.bfloat16)),
            "ids": ids,
            "cells": np.asarray(cell, np.int32),
        }
        if self.spill_dir is not None:
            from repro.store import segment as segmentmod
            name = f"chunk-{len(self._chunks):06d}"
            segmentmod.write_segment(self.spill_dir / name, arrays,
                                     {"kind": "build-chunk"})
            self._chunks.append(name)
        else:
            self._chunks.append(arrays)
        self._n_rows += len(arrays["ids"])

    def _open_chunk(self, chunk) -> dict[str, np.ndarray]:
        if isinstance(chunk, dict):
            return chunk
        from repro.store import segment as segmentmod
        arrays, _ = segmentmod.open_segment(self.spill_dir / chunk,
                                            verify=False)
        return arrays

    def finish(self, *, cleanup: bool = True) -> imimod.IMIIndex:
        """Assemble the cell-sorted base from the spilled chunks.

        Peak memory: the final index arrays plus the permutation vector —
        chunk rows are gathered straight from mmap'd spill segments into
        their sorted positions; the raw f32 corpus never exists in host
        memory.
        """
        if self.pq is None:
            raise RuntimeError("finish() before train()")
        n, d = self._n_rows, self._dim
        cfg = self.cfg
        cells = np.empty((n,), np.int32)
        pos = 0
        for chunk in self._chunks:
            c = self._open_chunk(chunk)["cells"]
            cells[pos: pos + len(c)] = c
            pos += len(c)
        order = np.argsort(cells, kind="stable")
        inv = np.empty((n,), np.int64)
        inv[order] = np.arange(n)
        counts = np.bincount(cells, minlength=cfg.K * cfg.K)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

        import ml_dtypes
        out_codes = np.empty((n, cfg.P), np.uint8)
        out_vecs = np.empty((n, d), ml_dtypes.bfloat16)
        out_ids = np.empty((n,), imimod.ID_DTYPE)
        pos = 0
        for chunk in self._chunks:
            arrays = self._open_chunk(chunk)
            rows = len(arrays["ids"])
            dest = inv[pos: pos + rows]
            out_codes[dest] = arrays["codes"]
            out_vecs[dest] = arrays["vectors"]
            out_ids[dest] = arrays["ids"]
            pos += rows
        if cleanup and self.spill_dir is not None:
            # delete only what this builder wrote — the caller may have
            # pointed spill_dir at a directory that holds other data
            for chunk in self._chunks:
                if not isinstance(chunk, dict):
                    shutil.rmtree(self.spill_dir / chunk, ignore_errors=True)
            if self._made_spill_dir:
                try:
                    self.spill_dir.rmdir()   # only if now empty
                except OSError:
                    pass
        return imimod.IMIIndex(
            coarse1=self.coarse1, coarse2=self.coarse2, pq=self.pq,
            codes=jnp.asarray(out_codes),
            vectors=jnp.asarray(out_vecs),
            ids=jnp.asarray(out_ids),
            cell_of=jnp.asarray(cells[order]),
            cell_offsets=jnp.asarray(offsets),
        )


def build_imi_streaming(rng: jax.Array,
                        chunks: Callable[[], Iterable[tuple[np.ndarray,
                                                            np.ndarray]]],
                        cfg: StreamingBuildConfig, *,
                        spill_dir: Optional[str | pathlib.Path] = None
                        ) -> imimod.IMIIndex:
    """Two-pass streaming build: ``chunks()`` must yield the same
    (vectors, ids) sequence on both calls (pass 1 trains on a reservoir,
    pass 2 encodes)."""
    builder = StreamingIndexBuilder(rng, cfg, spill_dir=spill_dir)
    for x, _ in chunks():
        builder.observe(x)
    builder.train()
    for x, ids in chunks():
        builder.add(x, ids)
    return builder.finish()


def build_from_videos_streaming(rng: jax.Array, videos: Sequence[Video],
                                vit_params: Any, cfg: vitmod.ViTConfig, *,
                                K: int = 16, P: int = 8, M: int = 64,
                                keyframe_stride: int = 8,
                                kmeans_iters: int = 10,
                                opq_iters: int = 0,
                                chunk_frames: int = 32,
                                sample_size: int = 32_768,
                                spill_dir: Optional[str] = None
                                ) -> BuiltIndex:
    """Streaming twin of ``build_from_videos``: key frames are ViT-encoded
    once, in chunks, with embeddings spilled to store segments; codebook
    training sees only the reservoir sample.  (Key frames themselves are
    still collected for the BuiltIndex sidecar — the paper keeps them for
    rerank — so frame storage, not embeddings, is the memory floor here.)
    """
    import tempfile

    all_frames, kf_video, kf_frame = [], [], []
    for vi, v in enumerate(videos):
        idx = videomod.extract_keyframes(v.frames, stride=keyframe_stride)
        all_frames.append(v.frames[idx])
        kf_video.extend([vi] * len(idx))
        kf_frame.extend(idx.tolist())
    frames = np.concatenate(all_frames)
    kf_video = np.asarray(kf_video, np.int32)
    kf_frame = np.asarray(kf_frame, np.int32)

    own_spill = spill_dir is None
    spill = pathlib.Path(spill_dir or tempfile.mkdtemp(prefix="lovo-build-"))
    emb_dir = spill / "embeddings"
    from repro.store import segment as segmentmod

    try:
        # single ViT pass: encode each frame chunk once, spill embeddings
        emb_names, boxes_all, kp = [], [], None
        emb_dir.mkdir(parents=True, exist_ok=True)
        for ci, lo in enumerate(range(0, len(frames), chunk_frames)):
            cls, boxes = encode_keyframes(
                vit_params, frames[lo: lo + chunk_frames], cfg)
            f, kp, dp = cls.shape
            name = f"emb-{ci:06d}"
            segmentmod.write_segment(emb_dir / name,
                                     {"cls": cls.reshape(f * kp, dp)},
                                     {"kind": "build-emb"})
            emb_names.append(name)
            boxes_all.append(boxes.reshape(f * kp, 4).astype(np.float32))

        def chunks():
            pos = 0
            for name in emb_names:
                arrays, _ = segmentmod.open_segment(emb_dir / name,
                                                    verify=False)
                flat = arrays["cls"]
                ids = np.arange(pos, pos + len(flat), dtype=imimod.ID_DTYPE)
                pos += len(flat)
                yield np.asarray(flat), ids

        bcfg = StreamingBuildConfig(K=K, P=P, M=M, kmeans_iters=kmeans_iters,
                                    opq_iters=opq_iters,
                                    sample_size=sample_size)
        index = build_imi_streaming(rng, chunks, bcfg,
                                    spill_dir=spill / "chunks")
        meta = MetadataStore(
            video_of=np.repeat(kf_video, kp),
            frame_of=np.repeat(kf_frame, kp),
            bbox_of=np.concatenate(boxes_all),
        )
    finally:
        # a failed build must not leak the spilled corpus to disk — that is
        # the very resource the streaming path exists to bound
        if own_spill:
            shutil.rmtree(spill, ignore_errors=True)
        else:
            shutil.rmtree(emb_dir, ignore_errors=True)
    return BuiltIndex(index=index, metadata=meta, keyframes=frames,
                      keyframe_video=kf_video, keyframe_frame=kf_frame,
                      patches_per_frame=kp)


def save_built(path, built: BuiltIndex, *, meta: dict | None = None) -> None:
    """Persist a build (index + keyframes + metadata side-table) as a
    ``repro.store.VectorStore`` directory — the one-time-extraction artifact
    that makes restarts and replica joins cheap (DESIGN.md §4)."""
    from repro.store import VectorStore
    VectorStore.create(path, built, meta=meta).close()


def load_built(path, *, verify: bool = True) -> BuiltIndex:
    """Reopen a persisted build without re-encoding video or re-training
    codebooks; outstanding WAL/deltas are folded so the returned index is
    the complete current state."""
    from repro.store import VectorStore
    with VectorStore.open(path, verify=verify) as store:
        return store.to_built_index()
